"""Per-kernel shape/dtype sweeps, assert_allclose vs the ref.py oracles."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("n_keys,n_probe", [(100, 512), (5000, 2048), (20000, 4096)])
@pytest.mark.parametrize("vis_density", [1.0, 0.5])
def test_hash_probe_sweep(n_keys, n_probe, vis_density):
    rng = np.random.default_rng(n_keys + n_probe)
    keys = rng.choice(1 << 20, n_keys, replace=False).astype(np.int32)
    vis = np.where(
        rng.random(n_keys) < vis_density, 0xFFFFFFFF, 0
    ).astype(np.uint32)
    tk, tv, _ = ops.build_hash_table(keys, vis)
    pk = np.concatenate(
        [keys[: n_probe // 2], (rng.choice(1 << 20, n_probe - n_probe // 2) + (1 << 20)).astype(np.int32)]
    )
    qm = np.uint32(1)
    got = np.asarray(ops.probe(pk, tk, tv, qm))
    want = np.asarray(
        ref.hash_probe_lens_ref(jnp.asarray(pk, jnp.int32), tk, tv, jnp.asarray([qm], jnp.uint32))
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n_keys", [64, 1000, 5000])
def test_hash_build_insert_roundtrip(n_keys):
    """The in-kernel batch insert builds a table the probe kernel resolves:
    every inserted key probes back to its batch index."""
    rng = np.random.default_rng(n_keys)
    keys = rng.choice(1 << 20, n_keys, replace=False).astype(np.int32)
    tk, te, ok = ops.build_insert(keys)
    tk, te = np.asarray(tk), np.asarray(te)
    assert np.asarray(ok)[0] == 1
    vis = jnp.ones(tk.shape[0], jnp.uint32)
    found = np.asarray(ops.probe(keys, jnp.asarray(tk), vis, np.uint32(1)))
    assert (found >= 0).all()
    np.testing.assert_array_equal(te[found], np.arange(n_keys))


def test_hash_build_insert_flags_duplicates():
    """Duplicate keys make the table unservable: ok must clear so the
    backend can fall back to the reference probe."""
    _, _, ok = ops.build_insert(np.array([7, 9, 7], np.int32))
    assert np.asarray(ok)[0] == 0


@pytest.mark.parametrize("n,v,g", [(100, 1, 8), (3000, 8, 64), (10000, 4, 200)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_seg_aggregate_sweep(n, v, g, dtype):
    rng = np.random.default_rng(n + v + g)
    codes = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=(n, v)).astype(dtype)
    got = np.asarray(ops.segmented_sum(codes, vals, g))
    want = np.asarray(ref.seg_aggregate_ref(jnp.asarray(codes), jnp.asarray(vals), g))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bh,s,dh", [(1, 128, 64), (2, 256, 128), (3, 384, 64)])
@pytest.mark.parametrize("window", [None, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(bh, s, dh, window, dtype):
    rng = np.random.default_rng(bh * s + dh)
    q = jnp.asarray(rng.normal(size=(bh, s, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(bh, s, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(bh, s, dh)), dtype)
    got = np.asarray(ops.attention(q, k, v, window=window), np.float32)
    want = np.asarray(ref.flash_attention_ref(q, k, v, window=window), np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("b,s,d", [(1, 256, 128), (2, 512, 256), (3, 1024, 128)])
def test_linrec_sweep(b, s, d):
    rng = np.random.default_rng(b + s + d)
    a = jnp.asarray(rng.uniform(0.7, 0.999, size=(b, s, d)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, d)) * 0.2, jnp.float32)
    got = np.asarray(ops.linear_recurrence(a, bb))
    want = np.asarray(ref.linrec_ref(a, bb))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_linrec_matches_rglru_semantics():
    """The kernel computes the same recurrence the RG-LRU layer uses."""
    import jax

    from repro.models.recurrent import rg_lru

    rng = np.random.default_rng(0)
    p = {
        "w_a": jnp.asarray(rng.normal(size=(128, 128)) * 0.05, jnp.float32),
        "w_x": jnp.asarray(rng.normal(size=(128, 128)) * 0.05, jnp.float32),
        "lam": jnp.asarray(rng.uniform(-4, -2, 128), jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(2, 256, 128)) * 0.3, jnp.float32)
    from repro.models.recurrent import _rg_lru_gates

    a, b = _rg_lru_gates(p, x)
    h_kernel = np.asarray(ops.linear_recurrence(a, b))
    h_layer = np.asarray(rg_lru(p, x), np.float32)
    np.testing.assert_allclose(h_kernel, h_layer, rtol=2e-4, atol=2e-4)
