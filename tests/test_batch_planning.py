"""Graft-aware batch planning (DESIGN.md §15): metamorphic + purity suite.

The planner's contract is behavioral, so it is locked down as properties:

* (a) **coverage dominance** — a cohort's planned represented coverage is
  >= the sum of per-query greedy snapshot coverage on the same engine
  snapshot, per member and in total;
* (b) **permutation invariance** — the plan is a function of the (snapshot,
  member-set) pair, never of the input order;
* (c) **singleton equivalence** — a batch of size 1 takes byte-identical
  admission steps to the greedy path (results, counters, admission log,
  clock);
* (d) **purity** — planning twice on one snapshot yields the same plan and
  mutates nothing the engine's determinism depends on.

Plus the §10 admission-memo regression (AdmissionController used to rescan
every queued arrival's graft potential at every decision step) and the
serving-plane flavor (``ServingConfig(batch_fold=True)``).
"""

import itertools

import numpy as np
import pytest

import graftdb
from graftdb import EngineConfig, ServingConfig
from repro.core.batchplan import CohortPlan, plan_cohort, snapshot_coverage, profile_query
from repro.relational import queries, refexec
from repro.relational.table import days

ADMIT = dict(
    mode="graft",
    morsel_size=4096,
    retention="epoch",
    admission="adaptive",
    admission_max_inflight=2,
    admission_share_threshold=0.4,
)


def _q3(db, date, seg=1.0, arrival=0.0):
    return queries.make_query(
        db, "q3", {"segment": seg, "date": float(days(date))}, arrival
    )


def _canon(res):
    keys = sorted(res)
    order = np.lexsort([np.asarray(res[k]) for k in keys])
    return {k: np.asarray(res[k])[order] for k in keys}


def _burst(db, rng, n, arrival=0.0):
    return [queries.sample_query(db, rng, arrival=arrival) for _ in range(n)]


def _spread(db, rng, n, gap=1e6):
    return [queries.sample_query(db, rng, arrival=i * gap) for i in range(n)]


def _rebuild(db, qs):
    return [
        queries.make_query(db, q.template, q.params, arrival=q.arrival) for q in qs
    ]


def _warm_session(db, **overrides):
    """A session with live shared state: one wide q3 executed and retired
    (epoch retention keeps it attachable), so cohort planning scores against
    a non-trivial snapshot."""
    cfg = dict(mode="graft", morsel_size=4096, retention="epoch")
    cfg.update(overrides)
    session = graftdb.connect(db, EngineConfig(**cfg))
    session.submit(_q3(db, "1995-03-28"))
    session.run()
    return session


# ---------------------------------------------------------------------------
# (a) coverage dominance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_cohort_coverage_dominates_greedy_snapshot(db, seed):
    """Property (a): planned coverage >= per-query greedy snapshot coverage,
    member-wise and in total, on warm and cold snapshots alike."""
    rng = np.random.default_rng(31_000 + seed)
    session = _warm_session(db) if seed % 2 else graftdb.connect(
        db, EngineConfig(mode="graft", morsel_size=4096)
    )
    qs = _burst(db, rng, int(rng.integers(2, 6)))
    plan = plan_cohort(session.engine, qs)
    assert plan.size == len(qs)
    for m in plan.members:
        assert m.planned_rows >= m.snapshot_rows, m
        assert m.planned_rows <= m.demand_rows
    assert plan.planned_rows >= plan.snapshot_rows
    assert plan.gain_rows == plan.planned_rows - plan.snapshot_rows
    # the snapshot column really is the greedy baseline on this snapshot
    for m in plan.members:
        q = next(q for q in qs if q.qid == m.qid)
        assert m.snapshot_rows == snapshot_coverage(
            session.engine, profile_query(session.engine, q)
        )
    session.close()


def test_nested_burst_has_strict_gain(db):
    """A narrow-first same-instant q3 burst is the planner's bread and
    butter: greedy snapshot coverage is 0 on a cold engine, while the
    planned order lets the narrower dates ride the widest member."""
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=4096))
    qs = [_q3(db, d) for d in ("1995-03-05", "1995-03-12", "1995-03-25")]
    plan = plan_cohort(session.engine, qs)
    # widest (latest date) admits first: it provides for both others
    assert plan.order[0] == qs[-1].qid
    assert plan.gain_rows > 0
    assert plan.members[0].provider_weight > max(
        m.provider_weight for m in plan.members[1:]
    )
    session.close()


# ---------------------------------------------------------------------------
# (b) permutation invariance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_plan_invariant_under_input_permutation(db, seed):
    rng = np.random.default_rng(32_000 + seed)
    session = _warm_session(db)
    qs = _burst(db, rng, 4)
    base = plan_cohort(session.engine, qs)
    for perm in itertools.permutations(qs):
        assert plan_cohort(session.engine, list(perm)) == base
    session.close()


def test_same_instant_ties_order_by_qid(db):
    """Equal-arrival, equal-weight members break ties on qid — the one
    intrinsic key left — so a replayed trace plans identically."""
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=4096))
    qs = [_q3(db, "1995-03-10", seg=float(s)) for s in (0.0, 2.0, 3.0)]
    plan = plan_cohort(session.engine, qs)
    # disjoint segments: nobody provides for anybody, FIFO (arrival, qid)
    assert plan.order == tuple(q.qid for q in qs)
    assert all(m.provider_weight == 0 for m in plan.members)
    session.close()


# ---------------------------------------------------------------------------
# (c) singleton equivalence: batch path == greedy path, byte for byte
# ---------------------------------------------------------------------------


def _run_trace(db, qs, **cfg):
    session = graftdb.connect(db, EngineConfig(**cfg))
    futs = session.submit_all(qs)
    session.run()
    return session, futs


@pytest.mark.parametrize("workers,partitions", [(1, 1), (4, 4)])
def test_singleton_cohorts_byte_identical_to_greedy(db, workers, partitions):
    """Property (c): with arrivals spread far beyond any batch window, every
    cohort has size 1 and the batched admission path must replay the greedy
    engine exactly — results, counters, admission log, and clock."""
    rng = np.random.default_rng(77)
    qs = _spread(db, rng, 4)
    cfg = dict(ADMIT, workers=workers, partitions=partitions)
    sg, fg = _run_trace(db, _rebuild(db, qs), **cfg)
    sb, fb = _run_trace(db, _rebuild(db, qs), **dict(cfg, batch_planning=True))
    for a, b in zip(fg, fb):
        ra, rb = a.result(), b.result()
        assert set(ra) == set(rb)
        for k in ra:
            np.testing.assert_array_equal(np.asarray(ra[k]), np.asarray(rb[k]), err_msg=k)
    assert sb.counters == sg.counters  # includes admission_evals + batch_* == 0
    assert sb.counters["batch_cohorts"] == 0
    assert sb.cohort_log() == []
    # qids are globally allocated, so compare the records positionally
    assert [
        sb._runner.admission_log[b.qid] for b in fb
    ] == [sg._runner.admission_log[g_.qid] for g_ in fg]
    assert sb.now == sg.now
    sg.close(), sb.close()


def test_flag_off_is_the_greedy_engine(db):
    """batch_planning=False must not even route through the batched path:
    same results, counters, and clock as an explicit greedy run."""
    rng = np.random.default_rng(78)
    qs = _burst(db, rng, 4)  # same-instant: the case batching would change
    sg, fg = _run_trace(db, _rebuild(db, qs), **dict(ADMIT, workers=1, partitions=1))
    so, fo = _run_trace(
        db, _rebuild(db, qs), **dict(ADMIT, workers=1, partitions=1, batch_planning=False)
    )
    for a, b in zip(fg, fo):
        ra, rb = a.result(), b.result()
        for k in ra:
            np.testing.assert_array_equal(np.asarray(ra[k]), np.asarray(rb[k]), err_msg=k)
    assert so.counters == sg.counters
    assert so.now == sg.now
    sg.close(), so.close()


# ---------------------------------------------------------------------------
# (d) purity
# ---------------------------------------------------------------------------


def test_planner_is_pure_function_of_snapshot(db):
    session = _warm_session(db)
    eng = session.engine
    rng = np.random.default_rng(5)
    qs = _burst(db, rng, 4)
    gen0 = eng.state_gen
    counters0 = dict(eng.counters)
    states0 = {sig: list(lst) for sig, lst in eng.state_index.items()}
    aggs0 = dict(eng.agg_index)
    p1 = plan_cohort(eng, qs)
    p2 = plan_cohort(eng, qs)
    assert p1 == p2
    assert isinstance(p1, CohortPlan)
    assert eng.state_gen == gen0
    assert dict(eng.counters) == counters0
    assert {sig: list(lst) for sig, lst in eng.state_index.items()} == states0
    assert dict(eng.agg_index) == aggs0
    session.close()


def test_explain_cohort_read_only_and_consistent(db):
    session = _warm_session(db)
    qs = [_q3(db, d, arrival=session.now) for d in ("1995-03-05", "1995-03-25")]
    gen0 = session.engine.state_gen
    exp = session.explain_cohort(qs)
    assert session.engine.state_gen == gen0
    assert exp.plan == plan_cohort(session.engine, qs)
    text = exp.render()
    assert "EXPLAIN GRAFT COHORT: 2 queries" in text
    assert "scan group" in text
    assert text.count("EXPLAIN GRAFT q") == 2  # member reports in plan order
    d = exp.to_dict()
    assert set(d) == {"plan", "members"}
    assert d["plan"]["order"] == list(exp.plan.order)
    assert [m["qid"] for m in d["plan"]["members"]] == list(exp.plan.order)
    session.close()


# ---------------------------------------------------------------------------
# cohort formation + accounting through the public surface
# ---------------------------------------------------------------------------


def test_batch_window_groups_cohorts(db):
    """Arrivals at (0, 0, far-later) with a tight window form exactly one
    2-cohort; the straggler admits as a singleton (not logged)."""
    session = graftdb.connect(
        db,
        EngineConfig(
            mode="graft", morsel_size=4096, batch_planning=True, batch_window=0.1
        ),
    )
    qs = [
        _q3(db, "1995-03-05", arrival=0.0),
        _q3(db, "1995-03-25", arrival=0.0),
        _q3(db, "1995-03-15", arrival=1e9),
    ]
    futs = session.submit_all(qs)
    session.run()
    log = session.cohort_log()
    assert len(log) == 1
    assert log[0]["cohort"] == 0
    assert log[0]["plan"].size == 2
    assert set(log[0]["plan"].order) == {qs[0].qid, qs[1].qid}
    assert session.counters["batch_cohorts"] == 1
    assert session.counters["batch_planned_queries"] == 2
    st = session.stats()
    assert st["batch_planning"] is True and st["batch_window"] == 0.1
    for f, q in zip(futs, qs):
        c = _canon(f.result())
        r = _canon(refexec.execute(db, q.plan))
        for k in c:
            np.testing.assert_allclose(c[k], r[k], rtol=1e-12, atol=1e-12)
    session.close()


def test_future_stats_expose_cohort_record(db):
    session = graftdb.connect(
        db, EngineConfig(**dict(ADMIT, admission_max_inflight=8, batch_planning=True))
    )
    qs = [_q3(db, d) for d in ("1995-03-05", "1995-03-12", "1995-03-25")]
    futs = session.submit_all(qs)
    session.run()
    metas = [f.stats()["admission"].get("cohort") for f in futs]
    metas = [m for m in metas if m is not None]
    assert metas, "no admission record carried cohort metadata"
    assert all(set(m) == {"cohort", "size", "slot"} for m in metas)
    assert sorted(m["slot"] for m in metas) == list(range(len(metas)))
    c = futs[0].stats()["counters"]
    assert c["batch_cohorts"] >= 1
    assert c["batch_planned_queries"] == len(metas)
    assert c["batch_coverage_gain_rows"] > 0
    session.close()


# ---------------------------------------------------------------------------
# §10 admission-memo regression (the satellite bugfix)
# ---------------------------------------------------------------------------


def test_admission_potentials_memoized_until_state_changes(db):
    from repro.core.scheduler import AdmissionController

    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=4096))
    eng = session.engine
    ctl = AdmissionController(max_inflight=2)
    q = _q3(db, "1995-03-15")
    ctl.potentials(eng, q)
    ctl.potentials(eng, q)
    assert eng.counters["admission_evals"] == 1  # second call hit the memo
    f = session.submit(_q3(db, "1995-03-20"))  # attach/registration bumps state_gen
    session.run()
    f.result()
    ctl.potentials(eng, q)
    assert eng.counters["admission_evals"] == 2  # invalidated by the state change
    session.close()


def test_admit_verdict_drops_memo_entry(db):
    from repro.core.scheduler import AdmissionController

    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=4096))
    ctl = AdmissionController(max_inflight=2)
    q = _q3(db, "1995-03-15")
    verdict, _ = ctl.decide(session.engine, q)
    assert verdict == "admit"
    assert q.qid not in ctl._pot_memo  # admitted arrivals never pin stale entries
    session.close()


def test_deep_queue_no_longer_rescans_every_step(db):
    """The regression: a deep deferred FIFO queue used to re-evaluate every
    arrival's graft potential at every decision step. Pin: real evaluations
    stay strictly below controller decisions, and each query is only
    re-evaluated when the engine state generation actually moved."""
    from repro.core.scheduler import AdmissionController

    class Counting(AdmissionController):
        def __init__(self, **kw):
            super().__init__(**kw)
            self.decisions = 0

        def decide(self, engine, query, active_count=None):
            self.decisions += 1
            return super().decide(engine, query, active_count=active_count)

    rng = np.random.default_rng(9)
    qs = _burst(db, rng, 6)
    session = graftdb.connect(
        db,
        EngineConfig(
            mode="graft", morsel_size=4096, retention="epoch",
            admission="adaptive", admission_max_inflight=1,
            admission_share_threshold=0.99,
        ),
    )
    ctl = Counting(max_inflight=1, share_threshold=0.99)
    session._runner.admission = ctl
    futs = session.submit_all(qs)
    session.run()
    for f in futs:
        f.result()
    evals = session.counters["admission_evals"]
    assert session.counters["queued_admissions"] > 0  # the queue was deep
    assert ctl.decisions > len(qs)  # deferrals forced re-decisions...
    assert evals < ctl.decisions  # ...but the memo absorbed the rescans
    # each arrival evaluates at most once per state-generation epoch it waits
    # through (+1 for its first look)
    assert evals <= len(qs) * (session.engine.state_gen + 1)
    session.close()


# ---------------------------------------------------------------------------
# serving plane: batch_fold (§15, KV-prefix flavor)
# ---------------------------------------------------------------------------


def _serve_requests():
    from repro.serve.folding import Request

    base = tuple(range(100))
    return [
        Request(rid=1, prompt=base[:40], n_decode=4, arrival=0.0),
        Request(rid=2, prompt=base[:70], n_decode=4, arrival=0.0),
        Request(rid=3, prompt=base, n_decode=4, arrival=0.0),
    ]


def test_serving_batch_fold_longest_first():
    """Three nested same-instant prompts: joint admission folds the shorter
    two onto the longest's fresh state, so total computed prefill tokens
    drop to the longest prompt alone."""
    from repro.serve.folding import FoldingScheduler, SimExecutor

    plain = FoldingScheduler(SimExecutor(), fold=True)
    r_plain = plain.run(_serve_requests())
    batched = FoldingScheduler(SimExecutor(), fold=True, batch_fold=True)
    r_batch = batched.run(_serve_requests())
    assert r_batch["completed"] == r_plain["completed"] == 3
    assert batched.metrics["batch_groups"] == 1
    assert batched.metrics["batch_folded"] == 2
    assert r_batch["prefill_tokens"]["computed"] == 100  # just the longest
    assert r_batch["prefill_tokens"]["computed"] < r_plain["prefill_tokens"]["computed"]
    assert plain.metrics["batch_groups"] == 0  # flag off: path untouched


def test_serving_session_batch_fold_config():
    import graftdb as g
    from repro.serve.folding import Request

    session = g.connect_serving(config=ServingConfig(fold=True, batch_fold=True))
    session.submit_all(_serve_requests())
    summary = session.run()
    assert session.scheduler.batch_fold is True
    assert summary["prefill_tokens"]["batch_groups"] == 1
    assert summary["prefill_tokens"]["batch_folded"] == 2
    bad = ServingConfig.__init__
    with pytest.raises((TypeError, ValueError)):
        ServingConfig(batch_fold="yes")
