"""Reuse plane (DESIGN.md §12): artifact store tiers and budgets, spill ->
rehydrate -> attach parity against a never-evicted oracle, fingerprint
near-miss negatives, the three-way cost decision, EXPLAIN ``served_from_cache``
accounting, Session.close semantics, and the serving-plane prefix cache.

Parity runs under the default pool geometry, so the CI matrix leg
(GRAFTDB_TEST_WORKERS=4) exercises every scenario partition-parallel."""

import numpy as np
import pytest

import graftdb
from graftdb import EngineConfig, ServingConfig
from repro.core.reuse import (
    ArtifactStore,
    StateArtifact,
    aggregate_fingerprint,
    hash_state_fingerprint,
    prefix_fingerprint,
    rehydrate_wins,
    reuse_scores,
)
from repro.relational import queries, refexec
from repro.relational.table import days
from repro.serve.folding import Request

ALL_MODES = ["isolated", "scan_sharing", "qpipe_osp", "residual", "graft"]

# epoch retention with a zero budget: every retirement immediately evicts,
# so with a cache every retirement immediately spills
EVICT_ALL = dict(retention="epoch", memory_budget=0)
CACHE = dict(EVICT_ALL, reuse_cache_budget=64_000_000)


def _q3(db, date, seg=1.0, arrival=0.0):
    return queries.make_query(db, "q3", {"segment": seg, "date": float(days(date))}, arrival)


def _art(fp, nbytes, kind="hash_build", sig=None, meta=None):
    return StateArtifact(
        fp, kind, sig, nbytes, meta or {}, {"x": np.zeros(max(1, nbytes // 8))}
    )


def _run_sequence(db, mode, config_extra, arrivals):
    """Run (template, params) repeats serially-by-arrival on one session;
    returns (results in submit order, session)."""
    session = graftdb.connect(db, EngineConfig(mode=mode, **config_extra))
    futs = []
    for i, (t, p) in enumerate(arrivals):
        futs.append(session.submit(queries.make_query(db, t, p, arrival=float(i))))
    session.run()
    return [f.result() for f in futs], session


def _assert_same_results(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert set(g) == set(w)
        for k in g:
            np.testing.assert_allclose(
                np.asarray(g[k], dtype=np.float64),
                np.asarray(w[k], dtype=np.float64),
                rtol=1e-9,
            )


# ---------------------------------------------------------------------------
# ArtifactStore: tiers, budgets, eviction order
# ---------------------------------------------------------------------------


def test_store_budget_evicts_oldest_first():
    c = {}
    store = ArtifactStore(budget=1000, counters=c)
    for i in range(3):
        assert store.put(_art(("hash_build", ("k", i), ()), 400))
    # 3x400 > 1000: the oldest spill is gone, the newest two remain
    assert len(store) == 2
    assert store.get(("hash_build", ("k", 0), ())) is None
    assert store.get(("hash_build", ("k", 2), ())) is not None
    assert c["cache_evictions"] == 1 and c["cache_spills"] == 3
    assert store.mem_bytes == 800 <= store.budget
    assert c["cache_high_water_bytes"] <= store.budget


def test_store_rejects_oversized_artifact():
    c = {}
    store = ArtifactStore(budget=100, counters=c)
    assert not store.put(_art(("hash_build", ("big",), ()), 4096))
    assert len(store) == 0 and store.mem_bytes == 0


def test_store_disk_tier_demotes_and_reloads():
    c = {}
    store = ArtifactStore(budget=500, disk_budget=10_000, counters=c)
    a0 = _art(("hash_build", ("d", 0), ()), 400)
    payload = a0.arrays["x"].copy()
    store.put(a0)
    store.put(_art(("hash_build", ("d", 1), ()), 400))  # evicts a0 -> disk
    assert store.disk_bytes == 400 and store.mem_bytes == 400
    back = store.get(("hash_build", ("d", 0), ()))
    assert back is not None and back.arrays is not None
    np.testing.assert_array_equal(back.arrays["x"], payload)
    # oversized-for-memory artifacts land straight on disk
    assert store.put(_art(("hash_build", ("d", 2), ()), 900))
    assert store.get(("hash_build", ("d", 2), ())) is not None
    assert c["cache_disk_high_water_bytes"] <= 10_000


def test_store_take_consumes_and_flush_resets():
    store = ArtifactStore(budget=1000, disk_budget=1000)
    fp = ("hash_build", ("t",), ())
    store.put(_art(fp, 100))
    assert store.take(fp) is not None
    assert store.get(fp) is None and len(store) == 0
    store.put(_art(fp, 100))
    store.flush()
    assert len(store) == 0 and store.mem_bytes == 0 and store.disk_bytes == 0
    store.close()
    assert not store.put(_art(fp, 100))  # closed: refuses spills


def test_by_sig_groups_fingerprints_and_orders_by_spill():
    store = ArtifactStore(budget=10_000)
    sig_key = ("q3-build",)
    store.put(_art(("hash_build", sig_key, ("e1",)), 100))
    store.put(_art(("hash_build", sig_key, ("e2",)), 100))
    store.put(_art(("hash_build", ("other",), ()), 100))
    arts = store.by_sig("hash_build", sig_key)
    assert [a.fingerprint[2] for a in arts] == [("e1",), ("e2",)]


# ---------------------------------------------------------------------------
# Fingerprints + cost scoring
# ---------------------------------------------------------------------------


def _plan_fingerprints(q):
    from repro.core.descriptors import hash_build_signature
    from repro.core.grafting import all_boundaries
    from repro.core.plans import collect_subtree_pred
    from repro.core.predicates import Conjunction

    out = []
    for j in all_boundaries(q.plan):
        sig = hash_build_signature(j)
        conj = Conjunction.from_pred(collect_subtree_pred(j.build))
        out.append(hash_state_fingerprint(sig, [(conj, True)]))
    return out


def test_fingerprint_distinguishes_predicate_intervals(db):
    """Near-miss negatives: same structural signature, different delivered
    intervals -> distinct fingerprints (reuse then goes through coverage,
    never identity). Identical intervals -> identical fingerprint
    (semantic, not pointer-based)."""
    fa = _plan_fingerprints(_q3(db, "1995-03-15"))
    fb = _plan_fingerprints(_q3(db, "1995-06-15"))
    fc = _plan_fingerprints(_q3(db, "1995-03-15"))
    assert fa != fb  # the date-bearing build's interval differs
    assert fa == fc  # fresh plan objects, same semantics
    # the structural prefix (kind, sig.key) agrees even where intervals
    # differ — near misses share the by_sig bucket and are then culled by
    # coverage, never served as identities
    assert [f[:2] for f in fa] == [f[:2] for f in fb]


def test_reuse_scores_three_way():
    cm = {"scan": 1e-9, "filter": 1e-9, "insert": 1e-9, "rehydrate": 60e-9}
    s = reuse_scores(cm, demand_rows=1000, covered_rows=800, artifact_entries=10)
    assert s["recompute_s"] == pytest.approx(3e-6)
    assert s["saved_s"] == pytest.approx(2.4e-6)
    assert s["rehydrate_s"] == pytest.approx(600e-9)
    assert rehydrate_wins(cm, 1000, 800, 10)
    # zero coverage or rehydration dearer than the savings: recompute
    assert not rehydrate_wins(cm, 1000, 0, 10)
    assert not rehydrate_wins(cm, 1000, 10, 100_000)


# ---------------------------------------------------------------------------
# Spill -> rehydrate -> attach parity (oracle: never evicted)
# ---------------------------------------------------------------------------

REPEAT_SEQ = [
    ("q3", {"segment": 1.0, "date": 750.0}),
    ("q6", {"date": 400.0, "discount": 0.05, "quantity": 25.0}),
    ("q3", {"segment": 1.0, "date": 750.0}),  # exact repeat: fingerprint hit
    ("q3", {"segment": 1.0, "date": 800.0}),  # near miss: same keys, new date
    ("q3", {"segment": 1.0, "date": 750.0}),
]


@pytest.mark.parametrize("mode", ALL_MODES)
def test_spill_rehydrate_attach_parity(db, mode):
    """A run whose every retirement spills to cache and whose repeats
    rehydrate returns bit-equal results to the never-evicted oracle, in
    every sharing mode (the cache is inert where the mode forbids
    represented extents)."""
    oracle, s0 = _run_sequence(db, mode, dict(retention="epoch"), REPEAT_SEQ)
    cached, s1 = _run_sequence(db, mode, CACHE, REPEAT_SEQ)
    _assert_same_results(cached, oracle)
    if mode == "graft":
        assert s1.counters["cache_spills"] > 0
        assert s1.counters["cache_hits"] > 0
        assert s1.counters["rehydrate_bytes"] > 0
    s0.close()
    s1.close()


def test_rehydrated_state_matches_reference_executor(db):
    """End-to-end: a cache-served repeat equals the reference executor."""
    _, session = _run_sequence(db, "graft", CACHE, REPEAT_SEQ[:3])
    assert session.counters["cache_hits"] > 0
    fut = session.submit(
        queries.make_query(db, "q3", {"segment": 1.0, "date": 750.0}, arrival=99.0)
    )
    got = fut.result()
    want = refexec.execute(db, fut.query.plan)
    _assert_same_results([got], [want])
    session.close()


def test_near_miss_is_not_served_as_identity(db):
    """A q3 with a different date must NOT be answered by the cached
    aggregate identity of the original (fingerprints differ); its results
    must match the oracle."""
    seq = [
        ("q3", {"segment": 1.0, "date": 750.0}),
        ("q3", {"segment": 1.0, "date": 800.0}),
    ]
    oracle, s0 = _run_sequence(db, "graft", dict(retention="epoch"), seq)
    cached, s1 = _run_sequence(db, "graft", CACHE, seq)
    _assert_same_results(cached, oracle)
    s0.close()
    s1.close()


def test_agg_identity_cache_hit_skips_recompute(db):
    """An exact repeat whose aggregate identity is cached is served whole
    from the artifact (cache_hits on ITS handle) and still bit-matches."""
    session = graftdb.connect(db, EngineConfig(mode="graft", **CACHE))
    f0 = session.submit(_q3(db, "1995-03-15", arrival=0.0))
    session.run()
    f1 = session.submit(_q3(db, "1995-03-15", arrival=1.0))
    session.run()
    _assert_same_results([f1.result()], [f0.result()])
    st = f1.stats()
    assert st["served_from_cache"] and st["cache_hits"] >= 1
    assert not f0.stats()["served_from_cache"]
    session.close()


def test_disk_tier_round_trip_through_engine(db):
    """A tiny memory tier + disk tier: artifacts demote to .npz and still
    rehydrate correctly."""
    cfg = dict(EVICT_ALL, reuse_cache_budget=20_000, reuse_disk_budget=64_000_000)
    oracle, s0 = _run_sequence(db, "graft", dict(retention="epoch"), REPEAT_SEQ)
    cached, s1 = _run_sequence(db, "graft", cfg, REPEAT_SEQ)
    _assert_same_results(cached, oracle)
    assert s1.counters["cache_high_water_bytes"] <= 20_000
    s0.close()
    s1.close()


# ---------------------------------------------------------------------------
# EXPLAIN GRAFT: served_from_cache + exact accounting
# ---------------------------------------------------------------------------


def _accounting_exact(ex):
    for b in ex._all():
        assert b.represented_rows + b.residual_rows + b.unattached_rows == b.demand_rows
        if b.part_demand_rows:
            assert sum(b.part_demand_rows) == b.demand_rows
            assert sum(b.part_represented_rows) == b.represented_rows
            assert sum(b.part_residual_rows) == b.residual_rows
            assert sum(b.part_unattached_rows) == b.unattached_rows


@pytest.mark.parametrize("partitions", [1, 4])
def test_explain_served_from_cache_accounting(db, partitions):
    session = graftdb.connect(
        db, EngineConfig(mode="graft", partitions=partitions, **CACHE)
    )
    session.submit(_q3(db, "1995-03-15"))
    session.run()
    ex = session.explain_graft(_q3(db, "1995-03-15"))
    cached = [b for b in ex._all() if b.served_from_cache]
    assert cached, "repeat against a spilled state must surface served_from_cache"
    _accounting_exact(ex)
    assert any(b["served_from_cache"] for b in ex.to_dict()["boundaries"])
    assert "(cache)" in ex.render()
    # near miss: different date -> the date-bearing boundary may partially
    # cover, but accounting stays exact
    _accounting_exact(session.explain_graft(_q3(db, "1995-06-15")))
    # EXPLAIN is read-only: the artifact was not consumed
    assert session.stats()["cached_artifacts"] > 0
    session.close()


def test_explain_without_cache_unchanged(db):
    session = graftdb.connect(db, EngineConfig(mode="graft", retention="epoch"))
    session.submit(_q3(db, "1995-03-15"))
    session.run()
    ex = session.explain_graft(_q3(db, "1995-03-15"))
    assert not any(b.served_from_cache for b in ex._all())
    _accounting_exact(ex)
    session.close()


# ---------------------------------------------------------------------------
# Admission: the three-way decision
# ---------------------------------------------------------------------------


def test_admission_reports_cache_reason(db):
    """Past the inflight limit, an arrival whose only overlap is a cached
    artifact is admitted on reuse potential (reason 'cache')."""
    from repro.core.scheduler import AdmissionController
    from repro.core.reuse import reuse_potential

    session = graftdb.connect(db, EngineConfig(mode="graft", **CACHE))
    session.submit(_q3(db, "1995-03-15"))
    session.run()
    q = _q3(db, "1995-03-15", arrival=5.0)
    assert reuse_potential(session.engine, q) > 0.0
    ac = AdmissionController(max_inflight=1, share_threshold=0.4)
    verdict, reason = ac.decide(session.engine, q)
    assert verdict == "admit" and reason == "cache"
    # a no-overlap arrival is labeled fresh
    fresh = queries.make_query(db, "q6", {"date": 100.0, "discount": 0.02, "quantity": 24.0})
    assert ac.decide(session.engine, fresh) == ("admit", "fresh")
    session.close()


def test_score_arrival_three_way(db):
    from repro.core.costmodel import score_arrival

    session = graftdb.connect(db, EngineConfig(mode="graft", **CACHE))
    session.submit(_q3(db, "1995-03-15"))
    session.run()
    s = score_arrival(session.engine, _q3(db, "1995-03-15"))
    assert set(s) >= {"recompute_s", "graft_s", "cache_s", "choice"}
    assert s["choice"] == "cache"
    session.close()


# ---------------------------------------------------------------------------
# Config validation + Session lifecycle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kw",
    [
        {"reuse_cache_budget": -1},
        {"reuse_cache_budget": 1 << 20},  # requires retention='epoch'
        {"retention": "epoch", "reuse_disk_budget": 1 << 20},  # needs cache
    ],
)
def test_config_rejects_bad_reuse_values(kw):
    with pytest.raises((ValueError, TypeError)):
        EngineConfig(**kw)


def test_serving_config_rejects_cache_without_retention():
    with pytest.raises(ValueError):
        ServingConfig(reuse_cache_tokens=1024)


def test_session_close_releases_everything(db):
    session = graftdb.connect(db, EngineConfig(mode="graft", **CACHE))
    session.submit(_q3(db, "1995-03-15"))
    session.run()
    assert session.stats()["cached_artifacts"] > 0
    session.close()
    assert session.stats()["cached_artifacts"] == 0
    assert session.stats()["retained_bytes"] == 0
    session.close()  # idempotent
    with pytest.raises(RuntimeError):
        session.submit(_q3(db, "1995-06-15"))


def test_session_context_manager(db):
    with graftdb.connect(db, EngineConfig(mode="graft", **CACHE)) as session:
        session.submit(_q3(db, "1995-03-15"))
        session.run()
    with pytest.raises(RuntimeError):
        session.explain_graft(_q3(db, "1995-03-15"))


def test_stats_surface_cache_counters(db):
    session = graftdb.connect(db, EngineConfig(mode="graft", **CACHE))
    fut = session.submit(_q3(db, "1995-03-15"))
    session.run()
    st = session.stats()
    assert st["reuse_cache_budget"] == CACHE["reuse_cache_budget"]
    for k in ("cache_hits", "cache_spills", "cache_evictions", "rehydrate_bytes"):
        assert k in fut.stats()["counters"]
    assert st["cache_high_water_bytes"] <= CACHE["reuse_cache_budget"]
    session.close()


# ---------------------------------------------------------------------------
# Serving plane: KV-prefix artifacts
# ---------------------------------------------------------------------------


def test_serving_prefix_spill_and_rehydrate():
    """With a zero token budget every retired prefix spills; a repeat
    prompt rehydrates it and folds as if the state never left."""
    prompt = tuple(range(100))
    session = graftdb.connect_serving(
        fold=True,
        retain_prefixes=True,
        memory_budget_tokens=0,
        reuse_cache_tokens=4096,
    )
    session.submit(Request(0, prompt, 4, arrival=0.0))
    session.run()
    ex = session.explain_fold(Request(1, prompt, 4, arrival=1.0))
    assert ex["served_from_cache"]
    session.submit(Request(1, prompt, 4, arrival=1.0))
    session.run()
    lm = session.stats()["lifecycle"]
    assert lm["cache_spills"] >= 1 and lm["cache_hits"] == 1
    assert lm["rehydrate_tokens"] == len(prompt)
    # the fold itself: the repeat's prompt was represented by the
    # rehydrated prefix
    assert session._explains[1]["represented_tokens"] == len(prompt)


def test_serving_prefix_cache_respects_token_budget():
    session = graftdb.connect_serving(
        fold=True,
        retain_prefixes=True,
        memory_budget_tokens=0,
        reuse_cache_tokens=64,  # one ~50-token prefix fits, two do not
    )
    for i in range(3):
        session.submit(Request(i, tuple(range(i * 1000, i * 1000 + 50)), 2, arrival=float(i)))
    session.run()
    lm = session.stats()["lifecycle"]
    assert lm["cache_evictions"] >= 1
    store = session.scheduler.reuse
    assert store.mem_bytes <= 8 * 64
