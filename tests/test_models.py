"""Per-arch smoke tests (reduced same-family configs): forward/train step on
CPU asserting output shapes + finite values; decode-vs-forward parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, smoke_config
from repro.models import model as M
from repro.train.optim import init_opt_state
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    specs = M.input_specs(cfg, {"kind": "train", "seq_len": S, "global_batch": B}, dtype=jnp.float32)
    batch = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(KEY, v.shape, 0, cfg.vocab)
        else:
            batch[k] = jax.random.normal(KEY, v.shape, v.dtype) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    hidden = M.forward_train(cfg, params, batch)
    S_total = 32 if cfg.frontend != "vision_stub" else 32
    assert hidden.shape[0] == 2 and hidden.shape[-1] == cfg.d_model
    assert bool(jnp.all(jnp.isfinite(hidden)))
    opt = init_opt_state(params, cfg.optimizer)
    step = make_train_step(cfg, lr=1e-3)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = sum(
        float(jnp.abs(a - b).sum()) for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params))
    )
    assert delta > 0


@pytest.mark.parametrize(
    "arch",
    ["h2o-danube-3-4b", "rwkv6-7b", "recurrentgemma-9b", "chatglm3-6b", "stablelm-3b", "seamless-m4t-large-v2"],
)
def test_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    if cfg.moe is not None:
        cfg.moe.capacity_factor = 8.0  # no token drops -> exact parity
    params = M.init_params(cfg, KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.n_encoder_layers:
        batch["src_embeds"] = jax.random.normal(KEY, (B, 4, cfg.d_model)) * 0.1
    hidden = M.forward_train(cfg, params, batch)
    ref = jnp.einsum("bsd,dv->bsv", hidden, M.lm_head_weight(cfg, params))
    cache = M.init_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.n_encoder_layers:
        # precompute cross-attention memory KV for the stub encoder output
        mem = batch["src_embeds"]
        from repro.models.model import _run_groups, layer_groups
        from repro.models.layers import rms_norm

        m = _run_groups(
            cfg, params["enc_groups"], [(("attn",), cfg.n_encoder_layers)], mem,
            causal=False, memory=None, act_spec=None, remat=False,
        )
        memory = rms_norm(params["enc_final_norm"], m)
        # fill ck/cv per decoder layer
        new_cache = []
        for (pattern, n_rep), gp, gc in zip(layer_groups(cfg), params["groups"], cache):
            gcd = dict(gc)
            name = "attn0"
            ck = jnp.einsum("bsd,ndgk->nbsgk", memory, gp[name]["cwk"])
            cv = jnp.einsum("bsd,ndgk->nbsgk", memory, gp[name]["cwv"])
            ent = dict(gcd[name])
            ent["ck"], ent["cv"] = ck, cv
            gcd[name] = ent
            new_cache.append(gcd)
        cache = new_cache
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(cfg, params, cache, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 1e-3, f"{arch}: decode/forward rel err {rel}"


def test_ring_buffer_window_decode():
    """SWA ring-buffer decode beyond the window: positions wrap, masking by
    stored position stays correct vs full forward."""
    cfg = smoke_config("h2o-danube-3-4b")
    assert cfg.attn_window == 16
    params = M.init_params(cfg, KEY)
    B, S = 1, 40  # > 2x window
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    hidden = M.forward_train(cfg, params, {"tokens": tokens})
    ref = jnp.einsum("bsd,dv->bsv", hidden, M.lm_head_weight(cfg, params))
    cache = M.init_cache(cfg, B, S, dtype=jnp.float32)  # capacity = window
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(cfg, params, cache, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 1e-3, rel


def test_cells_cover_assignment():
    """40 assigned cells: long_500k only for sub-quadratic archs."""
    cs = cells()
    assert len(cs) == 33  # 10 archs x 4 shapes - 7 skipped long_500k
    subq = {a for a, s in cs if s == "long_500k"}
    assert subq == {"recurrentgemma-9b", "h2o-danube-3-4b", "rwkv6-7b"}


def test_param_counts_sane():
    for arch in ARCH_IDS:
        c = get_config(arch).param_counts()
        assert c["total"] >= c["active"] > 0
    big = get_config("llama4-maverick-400b-a17b").param_counts()
    assert 3.0e11 < big["total"] < 5.5e11, big  # ~400B
    assert 1.0e10 < big["active"] < 3.5e10, big  # ~17B + attn/embed
