"""Prover unit tests + hypothesis soundness properties (§4.2)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.predicates import (
    And,
    Cmp,
    ColCmp,
    Conjunction,
    Coverage,
    InSet,
    TRUE,
    evaluate,
    evaluate_conj,
    pred_and,
    prove_implies,
)

# -- direct cases -----------------------------------------------------------


def test_range_containment():
    p = Cmp("d", "<", 10)
    q = Cmp("d", "<", 20)
    assert prove_implies(p, q)
    assert not prove_implies(q, p)


def test_conjunction_containment():
    p = And((Cmp("seg", "==", 1), Cmp("d", "<", 10)))
    q = And((Cmp("seg", "==", 1), Cmp("d", "<", 20)))
    assert prove_implies(p, q)
    assert not prove_implies(q, p)
    # differing equality -> no containment
    r = And((Cmp("seg", "==", 2), Cmp("d", "<", 20)))
    assert not prove_implies(p, r)


def test_missing_constraint_is_weaker():
    p = Cmp("d", "<", 10)
    q = And((Cmp("d", "<", 20), Cmp("seg", "==", 1)))
    assert not prove_implies(p, q)  # p says nothing about seg
    assert prove_implies(And((Cmp("d", "<", 5), Cmp("seg", "==", 1))), q)


def test_inset_containment():
    p = InSet("n", frozenset((1.0, 2.0)))
    q = InSet("n", frozenset((1.0, 2.0, 3.0)))
    assert prove_implies(p, q)
    assert not prove_implies(q, p)
    assert prove_implies(Cmp("n", "==", 2.0), q)


def test_outside_fragment_unproven():
    p = ColCmp("a", "<", "b")  # cross-column: outside the fragment
    assert not prove_implies(p, Cmp("a", "<", 5))
    assert Conjunction.from_pred(p) is None


def test_coverage_interval_merge():
    cov = Coverage()
    cov.add(Conjunction.from_pred(And((Cmp("seg", "==", 1), Cmp("d", "<", 10)))))
    band = Conjunction.from_pred(
        And((Cmp("seg", "==", 1), Cmp("d", ">=", 10), Cmp("d", "<", 20)))
    )
    cov.add(band)
    # merged coverage must cover the union extent
    assert cov.covers(Conjunction.from_pred(And((Cmp("seg", "==", 1), Cmp("d", "<", 20)))))
    # but not a different segment
    assert not cov.covers(Conjunction.from_pred(And((Cmp("seg", "==", 2), Cmp("d", "<", 5)))))


# -- hypothesis: soundness of the prover over random conjunctions ------------

attr = st.sampled_from(["a", "b", "c"])
bound = st.integers(min_value=-20, max_value=20)
op = st.sampled_from(["<", "<=", ">", ">=", "=="])


@st.composite
def conj(draw):
    n = draw(st.integers(1, 4))
    return And(tuple(Cmp(draw(attr), draw(op), float(draw(bound))) for _ in range(n)))


@given(conj(), conj(), st.integers(0, 2**31 - 1))
@settings(max_examples=200, deadline=None)
def test_prove_implies_sound(p, q, seed):
    """If the prover says P => Q, then every row satisfying P satisfies Q."""
    rng = np.random.default_rng(seed)
    cols = {k: rng.integers(-25, 25, 300).astype(np.float64) for k in ("a", "b", "c")}
    if prove_implies(p, q):
        mp, mq = evaluate(p, cols), evaluate(q, cols)
        assert not (mp & ~mq).any()


@given(conj(), st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_canonical_eval_equivalence(p, seed):
    """Canonicalization preserves semantics."""
    c = Conjunction.from_pred(p)
    rng = np.random.default_rng(seed)
    cols = {k: rng.integers(-25, 25, 200).astype(np.float64) for k in ("a", "b", "c")}
    np.testing.assert_array_equal(evaluate(p, cols), evaluate_conj(c, cols))


@given(st.lists(conj(), min_size=1, max_size=4), conj(), st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_coverage_covers_sound(extents, probe, seed):
    """covers(B) -> every row of B lies in the union of the extents."""
    cov = Coverage()
    cs = []
    for e in extents:
        c = Conjunction.from_pred(e)
        cs.append(c)
        cov.add(c)
    b = Conjunction.from_pred(probe)
    if cov.covers(b):
        rng = np.random.default_rng(seed)
        cols = {k: rng.integers(-25, 25, 400).astype(np.float64) for k in ("a", "b", "c")}
        mb = evaluate_conj(b, cols)
        mu = np.zeros_like(mb)
        for c in cs:
            mu |= evaluate_conj(c, cols)
        assert not (mb & ~mu).any()
