"""Sharding-rule structure tests (pure, single-device mesh) and the static
HLO analyzer (trip-count-aware FLOPs/collectives)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, smoke_config, get_config
from repro.launch import sharding as SH
from repro.launch.hlo_analysis import analyze, parse_module, shape_bytes
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M
from repro.train.optim import abstract_opt_state


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_tree(arch):
    cfg = get_config(arch)
    ap = M.abstract_params(cfg, jnp.bfloat16)
    mesh = make_smoke_mesh()
    specs = SH.param_specs(cfg, mesh, ap)
    flat_a = jax.tree.leaves(ap)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_a) == len(flat_s)
    for a, s in zip(flat_a, flat_s):
        assert len(s) <= a.ndim


@pytest.mark.parametrize("arch", ["stablelm-3b", "dbrx-132b", "rwkv6-7b"])
def test_opt_specs_mirror_params(arch):
    cfg = get_config(arch)
    ap = M.abstract_params(cfg, jnp.bfloat16)
    mesh = make_smoke_mesh()
    ps = SH.param_specs(cfg, mesh, ap)
    ao = abstract_opt_state(ap, cfg.optimizer)
    os_ = SH.opt_specs(cfg, mesh, ao, ps)
    # every moment leaf has a spec; the 'step' scalar is replicated
    flat = jax.tree.leaves(os_, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)


def test_hlo_analyzer_counts_loop_trips():
    """A scan of 7 matmuls must report ~7x one matmul's FLOPs."""

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)
    txt = jax.jit(f).lower(w, x).compile().as_text()
    st = analyze(txt)
    expected = 7 * 2 * 8 * 64 * 64
    assert 0.9 * expected <= st.flops <= 1.3 * expected, (st.flops, expected)


def test_hlo_analyzer_shape_bytes():
    assert shape_bytes("bf16[4,8]") == 64
    assert shape_bytes("f32[2,2] , s32[3]") == 28
    assert shape_bytes("(f32[2], pred[8])") == 16


def test_hlo_analyzer_parses_entry():
    txt = jax.jit(lambda x: x * 2.0).lower(jnp.ones((4,))).compile().as_text()
    comps = parse_module(txt)
    assert "__entry__" in comps
