"""Launcher-level integration: train driver end-to-end (+restore), the
real-model folded serving driver, and roofline bookkeeping."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as T
from repro.launch.roofline import analyze_record, model_flops_per_device


def test_train_driver_runs_and_restores(tmp_path):
    ckpt = str(tmp_path / "ck")
    T.main(
        ["--arch", "chatglm3-6b", "--smoke", "--steps", "6", "--batch", "4",
         "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "3"]
    )
    # resume and continue
    T.main(
        ["--arch", "chatglm3-6b", "--smoke", "--steps", "9", "--batch", "4",
         "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "3"]
    )


def test_serve_driver_folding_exactness(capsys):
    from repro.launch import serve as S

    S.main(["--requests", "3", "--prefix-len", "24", "--suffix-len", "4", "--decode", "3"])
    out = capsys.readouterr().out
    assert "outputs identical: True" in out


def test_roofline_record_analysis():
    rec = {
        "arch": "stablelm-3b",
        "shape": "train_4k",
        "mesh": "16x16",
        "hlo_stats": {
            "flops_per_device": 1.0e14,
            "mem_bytes_per_device": 8.19e12,
            "coll_bytes_per_device": {"all-gather": 5e11},
        },
    }
    r = analyze_record(rec)
    assert r["dominant"] == "memory"
    assert 0 < r["useful_ratio"] < 1.5
    assert abs(r["memory_s"] - 10.0) < 0.1
    # decode flops are per-token
    d = model_flops_per_device("rwkv6-7b", "decode_32k", 256)
    t = model_flops_per_device("rwkv6-7b", "train_4k", 256)
    assert t / d > 1e4
