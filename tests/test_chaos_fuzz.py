"""Fault-tolerant folded execution (DESIGN.md §16): seeded fault injection,
per-query lifecycle (cancel / deadline), producer handoff, quarantine +
unfold degradation, and the chaos differential-fuzz leg.

The chaos fuzzer replays seeded random TPC-H workloads under seeded fault
schedules across every sharing mode and worker count, with cancellation and
deadline mixes folded in. Every query that survives must be bit-identical
to the fault-free reference executor; every query that does not must carry
a terminal §16 status and raise ``QueryCancelled`` — no silent wrong
answers, no stranded beneficiaries, no leaked lens leases. Replaying the
same (workload seed, fault seed) pair must reproduce statuses, results,
and fault counters exactly: injection is a pure function of the virtual
clock's schedule, never of wall time.

Also covers the §16 satellites: checksum-verified disk artifacts (corrupt
or truncated ``.npz`` = cache miss, never an arrival-path error), stale
reuse temp-dir sweeping, and ``Session.close`` with queued + in-flight
arrivals.

Uses ``tests/_hypothesis_compat.py`` so tier-1 passes without hypothesis.
"""

import os
import shutil
import tempfile

import numpy as np
import pytest

import graftdb
from graftdb import EngineConfig, FaultPlan, QueryCancelled
from repro.core.faults import SITES, FaultPlane
from repro.core.reuse import ArtifactStore, StateArtifact
from repro.relational import queries, refexec

ALL_MODES = ["isolated", "scan_sharing", "qpipe_osp", "residual", "graft"]

#: chaos workload seeds (base 31_000); each seed runs a mode x fault-mix
#: sub-matrix, so the sweep covers every mode and every fault site
CHAOS_SEEDS = range(6)

#: same-plan pair under batch planning: the only admission shape where a
#: query pends on a FOREIGN producer (§15 cohorts), i.e. where cancelling
#: the producer exercises producer handoff rather than sealing
BATCHED = dict(mode="graft", morsel_size=2048, batch_planning=True, batch_window=0.001)


def _canon(res):
    keys = sorted(res)
    order = np.lexsort([np.asarray(res[k]) for k in keys])
    return {k: np.asarray(res[k])[order] for k in keys}


def _assert_parity(engine_res, ref_res, ctx):
    ca, cb = _canon(engine_res), _canon(ref_res)
    assert set(ca) == set(cb), ctx
    for k in ca:
        assert ca[k].shape == cb[k].shape, (ctx, k)
        np.testing.assert_allclose(
            ca[k], cb[k], rtol=1e-12, atol=1e-12, err_msg=f"{ctx}/{k}"
        )


def _workload(db, rng, n_lo=3, n_hi=6):
    n = int(rng.integers(n_lo, n_hi))
    qs, t = [], 0.0
    for _ in range(n):
        t += float(rng.choice([0.0, 0.002, 0.02]))
        qs.append(queries.sample_query(db, rng, arrival=t))
    return qs


def _rebuild(db, qs):
    return [
        queries.make_query(db, q.template, q.params, arrival=q.arrival) for q in qs
    ]


# ---------------------------------------------------------------------------
# FaultPlane: seeded deterministic injection
# ---------------------------------------------------------------------------


class _TickClock:
    def __init__(self):
        self.now = 0.0

    def tick(self, dt):
        self.now += dt


def test_fault_plane_is_deterministic_per_site():
    plan = FaultPlan(seed=7, schedule={s: 0.3 for s in SITES})
    seqs = []
    for _ in range(2):
        fp = FaultPlane(plan, counters={})
        seqs.append([(s, fp.fire(s)) for _ in range(50) for s in SITES])
    assert seqs[0] == seqs[1], "same (seed, site, index) must draw identically"
    other = FaultPlane(FaultPlan(seed=8, schedule={s: 0.3 for s in SITES}), counters={})
    assert seqs[0] != [(s, other.fire(s)) for _ in range(50) for s in SITES]


def test_fault_plane_schedule_forms_and_caps():
    c = {}
    fp = FaultPlane(FaultPlan(seed=1, schedule={"morsel": {0, 2}}), counters=c)
    assert [fp.fire("morsel") for _ in range(4)] == [True, False, True, False]
    assert all(not fp.fire("exchange") for _ in range(10))  # unscheduled site
    assert c["faults_injected"] == 2
    capped = FaultPlane(
        FaultPlan(seed=1, schedule={"morsel": 1.0}, max_injections=3), counters={}
    )
    assert sum(capped.fire("morsel") for _ in range(10)) == 3
    assert not FaultPlane(FaultPlan(seed=1, schedule={"morsel": 0.0}), {}).fire("morsel")


def test_fault_plan_validates():
    with pytest.raises(ValueError):
        FaultPlan(schedule={"warp_drive": 0.5})
    with pytest.raises(ValueError):
        FaultPlan(schedule={"morsel": 1.5})
    with pytest.raises(ValueError):
        FaultPlan(schedule={"morsel": 0.1}, retry_limit=-1)
    with pytest.raises(ValueError):
        EngineConfig(faults="chaos")  # must be a FaultPlan


def test_attempt_retries_charge_virtual_clock():
    clock = _TickClock()
    c = {}
    fp = FaultPlane(
        FaultPlan(seed=3, schedule={"morsel": 1.0}, retry_limit=2, backoff_s=1e-4),
        counters=c,
    )
    assert not fp.attempt("morsel", clock)  # rate 1.0: every retry faults too
    assert c["faults_injected"] == 3  # initial + 2 retries
    assert c["fault_retries"] == 2
    assert clock.now == pytest.approx(1e-4 * (1 + 2))  # 2**0 + 2**1 backoff
    ok_clock = _TickClock()
    ok = FaultPlane(FaultPlan(seed=3, schedule={"morsel": 0.0}, retry_limit=2), {})
    assert ok.attempt("morsel", ok_clock) and ok_clock.now == 0.0


# ---------------------------------------------------------------------------
# Zero-perturbation identity: hooks must cost nothing semantically
# ---------------------------------------------------------------------------


def test_empty_schedule_bit_identical_to_no_faults(db):
    rng = np.random.default_rng(31_000)
    qs = _workload(db, rng)
    outs = []
    for faults in (None, FaultPlan(seed=123, schedule={})):
        session = graftdb.connect(
            db, EngineConfig(mode="graft", morsel_size=4096, faults=faults)
        )
        futs = session.submit_all(_rebuild(db, qs))
        session.run()
        outs.append(
            (
                [{k: np.asarray(v) for k, v in f.result().items()} for f in futs],
                session.now,
                {k: v for k, v in session._engine.counters.items()},
            )
        )
        session.close()
    (res_a, now_a, c_a), (res_b, now_b, c_b) = outs
    assert now_a == now_b, "armed-but-empty FaultPlane perturbed the clock"
    for ra, rb in zip(res_a, res_b):
        assert set(ra) == set(rb)
        for k in ra:
            np.testing.assert_array_equal(ra[k], rb[k])
    assert c_b["faults_injected"] == 0 and c_b["fault_retries"] == 0
    for k in set(c_a) | set(c_b):
        assert c_a.get(k, 0) == c_b.get(k, 0), f"counter {k} diverged"


# ---------------------------------------------------------------------------
# Per-query lifecycle: cancel, deadline, QueryCancelled
# ---------------------------------------------------------------------------


def test_cancel_and_deadline_lifecycle(db):
    rng = np.random.default_rng(31_100)
    q0, q1, q2 = (queries.sample_query(db, rng, arrival=0.0) for _ in range(3))
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=2048))
    f0 = session.submit(q0)
    f1 = session.submit(q1, deadline=1e-7)  # expires before any progress
    f2 = session.submit(q2, deadline=1e9)  # never expires
    assert f0.status in ("queued", "active")
    assert f0.cancel() is True
    assert f0.status == "cancelled" and f0.cancelled
    session.run()
    assert f1.status == "deadline" and f1.cancelled
    assert f2.status == "done" and not f2.cancelled
    _assert_parity(f2.result(), refexec.execute(db, q2.plan), "deadline-met")
    for f, status in ((f0, "cancelled"), (f1, "deadline")):
        with pytest.raises(QueryCancelled) as ei:
            f.result()
        assert ei.value.status == status
        assert f.stats()["status"] == status
        assert f.cancel() is False  # terminal: cancel is a no-op
    assert f2.cancel() is False  # completed: cancel is a no-op
    stats = f2.stats()
    assert stats["faults"]["cancelled"] >= 2
    assert stats["faults"]["deadline_cancellations"] == 1
    session.close()


def test_submit_deadline_validation(db):
    rng = np.random.default_rng(31_101)
    session = graftdb.connect(db, EngineConfig(mode="graft"))
    for bad in (float("nan"), float("inf"), "soon", True):
        with pytest.raises((TypeError, ValueError)):
            session.submit(queries.sample_query(db, rng), deadline=bad)
    session.close()


# ---------------------------------------------------------------------------
# Producer handoff: a dead producer's extents adopt to survivors
# ---------------------------------------------------------------------------


def test_producer_handoff_preserves_survivor_results(db):
    """Batched same-plan pairs where the producing query hits its deadline
    mid-delivery: surviving beneficiaries adopt the residual extents and
    finish bit-identical to the fault-free oracle. The machinery assertion
    (handoffs > 0) keeps the scenario honest — if admission shape changes
    and nothing pends on a foreign producer, this test must fail loudly."""
    handoffs = 0
    deep = {"q3", "q4", "q5", "q7", "q9", "q10"}  # multi-join: several producers
    for trial in range(8):
        rng = np.random.default_rng(31_200 + trial)
        q = queries.sample_query(db, rng)
        while q.template not in deep:
            q = queries.sample_query(db, rng)
        oracle = refexec.execute(db, q.plan)
        for deadline in (2e-5, 1e-4):
            session = graftdb.connect(db, EngineConfig(**BATCHED))
            fa = session.submit(
                queries.make_query(db, q.template, q.params, arrival=0.0),
                deadline=deadline,
            )
            fb = session.submit(queries.make_query(db, q.template, q.params, arrival=0.0))
            session.run()
            eng = session._engine
            handoffs += int(eng.counters["producer_handoffs"])
            assert not eng._lens_leases, "lens leases must drain by idle"
            assert fb.status == "done", (trial, deadline, fb.status)
            _assert_parity(fb.result(), oracle, f"handoff t{trial} dl={deadline}")
            if fa.status == "done":
                _assert_parity(fa.result(), oracle, f"handoff t{trial} fa")
            else:
                assert fa.status == "deadline"
            session.close()
    assert handoffs > 0, "no producer handoff exercised — scenario went stale"


def test_unfold_marks_degraded_and_stays_correct(db):
    """One injected morsel fault with retries exhausted: the impacted
    queries unfold to isolated execution, finish correct, and report
    ``degraded`` through stats() and EXPLAIN GRAFT."""
    rng = np.random.default_rng(31_300)
    qs = [queries.sample_query(db, rng, arrival=0.0) for _ in range(2)]
    refs = [refexec.execute(db, q.plan) for q in qs]
    session = graftdb.connect(
        db,
        EngineConfig(
            mode="graft",
            morsel_size=4096,
            capture_explain=True,
            faults=FaultPlan(seed=5, schedule={"morsel": {0}}, retry_limit=0),
        ),
    )
    futs = session.submit_all(_rebuild(db, qs))
    session.run()
    eng = session._engine
    assert eng.counters["faults_injected"] >= 1
    assert eng.counters["quarantined_states"] >= 1
    assert eng.counters["unfolds"] >= 1
    degraded = 0
    for f, ref in zip(futs, refs):
        assert f.status == "done", f.status
        _assert_parity(f.result(), ref, "unfolded")
        if f.stats()["degraded"]:
            degraded += 1
            assert f.explain().degraded
            assert "DEGRADED" in f.explain().render()
    assert degraded >= 1, "no query degraded — the fault never escalated"
    session.close()


def test_rate_one_fault_storm_terminates(db):
    """Unit fault rate with one retry: bounded degradation guarantees the
    run terminates and every query lands on a terminal status."""
    for trial in range(3):
        rng = np.random.default_rng(31_400 + trial)
        qs = [queries.sample_query(db, rng, arrival=i * 0.001) for i in range(4)]
        session = graftdb.connect(
            db,
            EngineConfig(
                mode="graft",
                morsel_size=4096,
                faults=FaultPlan(seed=trial, schedule={"morsel": 1.0}, retry_limit=1),
            ),
        )
        futs = session.submit_all(_rebuild(db, qs))
        session.run()
        for f in futs:
            assert f.status == "failed", (trial, f.status)
            with pytest.raises(QueryCancelled):
                f.result()
        assert not session._engine._lens_leases
        session.close()


# ---------------------------------------------------------------------------
# Chaos differential fuzz: the §16 acceptance leg
# ---------------------------------------------------------------------------

FAULT_MIXES = (
    ("morsel-light", {"morsel": 0.01}),
    ("morsel-stall", {"morsel": 0.02, "stall": 0.05}),
    ("rehydrate", {"rehydrate": 0.3, "morsel": 0.01}),
)


def _chaos_run(db, qs, mode, workers, sched, fault_seed, cancel_ix, deadline_ix):
    cfg = dict(
        mode=mode,
        morsel_size=4096,
        workers=workers,
        partitions=workers,
        faults=FaultPlan(seed=fault_seed, schedule=sched, retry_limit=2),
    )
    if "rehydrate" in sched:
        cfg.update(retention="epoch", memory_budget=150_000,
                   reuse_cache_budget=400_000)
    session = graftdb.connect(db, EngineConfig(**cfg))
    futs = []
    for i, q in enumerate(_rebuild(db, qs)):
        futs.append(
            session.submit(q, deadline=(2e-4 if i in deadline_ix else None))
        )
    for i in cancel_ix:
        futs[i].cancel()
    session.run()
    statuses = [f.status for f in futs]
    results = [f.result() if s == "done" else None for f, s in zip(futs, statuses)]
    counters = {
        k: session._engine.counters.get(k, 0)
        for k in ("faults_injected", "fault_retries", "producer_handoffs",
                  "quarantined_states", "unfolds", "cancelled",
                  "deadline_cancellations", "cache_corrupt")
    }
    assert not session._engine._lens_leases, "lens leases leaked"
    session.close()
    return statuses, results, counters


def test_chaos_differential_fuzz(db):
    """Seeded fault schedules x all five modes x workers {1, 4} x
    cancellation/deadline mixes. Every surviving query is bit-identical to
    the fault-free reference; every non-survivor is terminal. The sweep
    self-checks that it actually injected faults and exercised retries."""
    terminal = {"cancelled", "deadline", "failed"}
    injected = retried = survived = killed = 0
    for seed in CHAOS_SEEDS:
        rng = np.random.default_rng(31_000 + seed)
        qs = _workload(db, rng)
        refs = [refexec.execute(db, q.plan) for q in qs]
        mode = ALL_MODES[seed % len(ALL_MODES)]
        mix_name, sched = FAULT_MIXES[seed % len(FAULT_MIXES)]
        cancel_ix = {int(rng.integers(len(qs)))} if seed % 2 else set()
        deadline_ix = {int(rng.integers(len(qs)))} if seed % 3 == 0 else set()
        for workers in (1, 4):
            statuses, results, counters = _chaos_run(
                db, qs, mode, workers, sched, 900 + seed, cancel_ix, deadline_ix
            )
            injected += counters["faults_injected"]
            retried += counters["fault_retries"]
            for i, (status, res) in enumerate(zip(statuses, results)):
                ctx = f"seed{seed}/{mode}/{mix_name}/w{workers}/q{i}"
                if status == "done":
                    survived += 1
                    _assert_parity(res, refs[i], ctx)
                else:
                    killed += 1
                    assert status in terminal, ctx
                    if i in cancel_ix:
                        continue  # explicitly cancelled: any terminal reason
    assert injected > 0, "chaos sweep never injected a fault"
    assert retried > 0, "chaos sweep never exercised a retry"
    assert survived >= 20, f"too few survivors ({survived}) to claim parity coverage"
    assert killed > 0, "no query was ever cancelled/failed — mixes too gentle"


def test_chaos_replay_is_deterministic(db):
    """Same (workload seed, fault seed): statuses, results, and fault
    counters replay exactly — injection depends only on the virtual clock
    schedule."""
    rng = np.random.default_rng(31_900)
    qs = _workload(db, rng)
    runs = [
        _chaos_run(db, qs, "graft", 4, {"morsel": 0.03, "stall": 0.05}, 42,
                   cancel_ix=set(), deadline_ix={0})
        for _ in range(2)
    ]
    (st_a, res_a, c_a), (st_b, res_b, c_b) = runs
    assert st_a == st_b
    assert c_a == c_b
    for ra, rb in zip(res_a, res_b):
        assert (ra is None) == (rb is None)
        if ra is not None:
            for k in ra:
                np.testing.assert_array_equal(np.asarray(ra[k]), np.asarray(rb[k]))


# ---------------------------------------------------------------------------
# Session.close with queued + in-flight arrivals (§16 satellite)
# ---------------------------------------------------------------------------


def test_session_close_cancels_queued_and_inflight(db):
    rng = np.random.default_rng(31_500)
    session = graftdb.connect(
        db,
        EngineConfig(
            mode="graft",
            morsel_size=2048,
            admission="adaptive",
            admission_max_inflight=1,
        ),
    )
    futs = [
        session.submit(queries.sample_query(db, rng, arrival=i * 0.001))
        for i in range(4)
    ]
    # a few scheduler steps: first query in flight, the rest queued
    with pytest.raises(RuntimeError):
        session._runner.run((), max_steps=4)
    assert any(f.status == "active" for f in futs)
    assert any(f.status == "queued" for f in futs)
    session.close()
    for f in futs:
        assert f.status in ("cancelled", "done"), f.status
        if f.status == "cancelled":
            with pytest.raises(QueryCancelled):
                f.result()
        assert f.cancel() is False  # post-close: always a no-op
    assert not session._runner._heap and not session._runner.deadlines
    eng = session._engine
    assert not eng.active_handles and not eng._lens_leases
    assert not any(s.pins for h in eng.handles.values() for s in h.attached_states)


def test_close_is_idempotent_and_post_close_submit_fails(db):
    session = graftdb.connect(db, EngineConfig(mode="graft"))
    session.close()
    session.close()  # idempotent
    with pytest.raises(RuntimeError):
        session.submit(queries.sample_query(db, np.random.default_rng(0)))


# ---------------------------------------------------------------------------
# Artifact integrity + temp-dir hygiene (§16 satellites)
# ---------------------------------------------------------------------------


def _disk_art(store, key, nbytes=400):
    fp = ("hash_build", (key,), ())
    art = StateArtifact(fp, "hash_build", None, nbytes, {},
                       {"x": np.arange(max(1, nbytes // 8), dtype=np.float64)})
    assert store.put(art)
    return fp


def test_corrupt_artifact_is_a_cache_miss():
    c = {}
    store = ArtifactStore(budget=100, disk_budget=10_000, counters=c)
    fp = _disk_art(store, "flip")  # budget 100 < 400: lands on disk
    path = store._paths[fp]
    raw = bytearray(open(path, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(path, "wb").write(bytes(raw))
    assert store.get(fp) is None  # miss, not an exception
    assert c["cache_corrupt"] == 1
    assert store.get(fp) is None  # entry fully dropped
    assert c["cache_corrupt"] == 1
    # truncation is also a miss
    fp2 = _disk_art(store, "trunc")
    path2 = store._paths[fp2]
    open(path2, "wb").write(open(path2, "rb").read()[:16])
    assert store.get(fp2) is None
    assert c["cache_corrupt"] == 2
    # deletion out from under the store is an unreadable-artifact miss
    fp3 = _disk_art(store, "gone")
    os.unlink(store._paths[fp3])
    assert store.get(fp3) is None
    assert c["cache_corrupt"] == 3
    # the store remains fully serviceable after every corruption
    fp4 = _disk_art(store, "fresh")
    assert store.get(fp4) is not None
    store.close()


def test_rehydrate_fault_injection_counts_as_corrupt(db):
    """``rehydrate`` site faults surface as artifact corruption: the cache
    entry dies, the query recomputes, results stay correct."""
    rng = np.random.default_rng(31_600)
    qs = [queries.sample_query(db, rng, arrival=float(i)) for i in range(3)]
    # repeat the same query so retirements spill and repeats rehydrate
    qs = [queries.make_query(db, qs[0].template, qs[0].params, arrival=float(i))
          for i in range(3)]
    refs = [refexec.execute(db, q.plan) for q in qs]
    session = graftdb.connect(
        db,
        EngineConfig(
            mode="graft",
            morsel_size=4096,
            retention="epoch",
            memory_budget=0,
            reuse_cache_budget=64_000_000,
            faults=FaultPlan(seed=9, schedule={"rehydrate": 1.0}),
        ),
    )
    futs = session.submit_all(qs)
    session.run()
    c = session._engine.counters
    assert c["cache_corrupt"] >= 1, "no rehydrate fault fired"
    for f, ref in zip(futs, refs):
        assert f.status == "done"
        _assert_parity(f.result(), ref, "rehydrate-fault")
    session.close()


def test_disk_tier_temp_dir_cleanup_and_stale_sweep():
    # close() removes this store's temp dir
    store = ArtifactStore(budget=100, disk_budget=10_000)
    _disk_art(store, "a")
    d = store._dir
    assert d is not None and os.path.isdir(d)
    store.close()
    assert not os.path.exists(d)

    root = tempfile.gettempdir()
    # a dir owned by a dead process is swept on the next store open
    dead = tempfile.mkdtemp(prefix="graftdb-reuse-", dir=root)
    with open(os.path.join(dead, "owner.pid"), "w") as f:
        f.write("999999999")  # beyond pid_max: guaranteed dead
    # a dir owned by THIS process is never touched
    mine = tempfile.mkdtemp(prefix="graftdb-reuse-", dir=root)
    with open(os.path.join(mine, "owner.pid"), "w") as f:
        f.write(str(os.getpid()))
    # a fresh un-marked dir (sibling mid-mkdtemp) is never raced
    fresh = tempfile.mkdtemp(prefix="graftdb-reuse-", dir=root)
    try:
        s2 = ArtifactStore(budget=100, disk_budget=10_000)
        assert not os.path.exists(dead), "dead-owner dir survived the sweep"
        assert os.path.isdir(mine), "live-owner dir was swept"
        assert os.path.isdir(fresh), "unmarked fresh dir was raced"
        s2.close()
    finally:
        shutil.rmtree(mine, ignore_errors=True)
        shutil.rmtree(fresh, ignore_errors=True)
        shutil.rmtree(dead, ignore_errors=True)
