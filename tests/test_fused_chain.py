"""Device-resident fused stage-chain data plane (DESIGN.md §13) tests.

The chain kernel fuses a morsel's whole stage sequence — hash probe →
lens-word translation → compiled grant predicates → interval stage
filters → sink word translation — into one Pallas launch over
entry-indexed device mirrors. Everything it returns must leave results,
row counters and the virtual clock bit-identical to both the NumPy
member-major path and the per-member oracle, so these tests are all
differential: total-order float encoding vs IEEE compares, chain-served
sessions vs reference/oracle sessions across modes and pool geometries,
grant-compiled and >32-slot chains, per-reason fallback attribution,
incremental mirror maintenance, and the spill -> rehydrate -> chain-probe
round trip through the reuse plane (§12)."""

import numpy as np
import pytest

import graftdb
from graftdb import EngineConfig
from repro.relational import queries, refexec
from repro.relational.table import days

jax = pytest.importorskip("jax")

MODES = ["isolated", "scan_sharing", "qpipe_osp", "residual", "graft"]

#: row-counter subset that must match exactly across execution paths
ROW_COUNTERS = [
    "scan_rows", "probe_rows", "agg_rows", "ordinary_build_rows",
    "residual_build_rows", "represented_rows", "eliminated_rows",
    "fused_filter_rows", "rows_inserted", "rows_marked", "morsels_skipped",
]


def _q3(db, date, seg=1.0, arrival=0.0):
    return queries.make_query(
        db, "q3", {"segment": seg, "date": float(days(date))}, arrival
    )


def _fuzz_workload(db, rng):
    n = int(rng.integers(3, 6))
    qs, t = [], 0.0
    for _ in range(n):
        t += float(rng.choice([0.0, 0.002, 0.02, 0.08]))
        qs.append(queries.sample_query(db, rng, arrival=t))
    return qs


def _rebuild(db, qs):
    return [queries.make_query(db, q.template, q.params, arrival=q.arrival) for q in qs]


def _run(db, qs, **cfg):
    session = graftdb.connect(db, EngineConfig(**cfg))
    futs = session.submit_all(qs)
    session.run()
    return session, [f.result() for f in futs]


def _assert_bitequal(got, want, ctx=""):
    assert len(got) == len(want)
    for i, (a, b) in enumerate(zip(got, want)):
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{ctx}/q{i}/{k}")


# ---------------------------------------------------------------------------
# Total-order float64 encoding (the kernel's compare substrate)
# ---------------------------------------------------------------------------


def test_total_order_encoding_matches_ieee_compares():
    """Unsigned-lexicographic compares on the encoding reproduce IEEE
    ``<=`` exactly, including ±inf, denormals, and the two zeros."""
    from repro.kernels.fused_chain import total_order_u32

    vals = np.array(
        [-np.inf, -1e300, -1.5, -5e-324, -0.0, 0.0, 5e-324, 1.0, 1e300, np.inf]
    )
    hi, lo = total_order_u32(vals)
    enc = (hi.astype(np.uint64) << np.uint64(32)) | lo.astype(np.uint64)
    for i, a in enumerate(vals):
        for j, b in enumerate(vals):
            assert (a <= b) == (enc[i] <= enc[j]), (a, b)
    # -0.0 canonicalizes: the zeros encode equal
    assert enc[4] == enc[5]


def test_total_order_encoding_rejects_nan_from_every_interval():
    """NaN encodes strictly outside the [-inf, +inf] band (on its sign's
    side), so a constrained interval compare can never admit it — matching
    NumPy's ``(x >= lo) & (x <= hi)`` on NaN."""
    from repro.kernels.fused_chain import total_order_u32

    def enc(v):
        hi, lo = total_order_u32(np.asarray([v]))
        return (np.uint64(hi[0]) << np.uint64(32)) | np.uint64(lo[0])

    lo_inf, hi_inf = enc(-np.inf), enc(np.inf)
    for nan in (np.nan, -np.nan, np.float64.fromhex("nan")):
        e = enc(nan)
        assert e > hi_inf or e < lo_inf


def test_total_order_bound_scalar_matches_array():
    from repro.kernels.fused_chain import total_order_bound, total_order_u32

    for v in (-np.inf, -3.25, 0.0, 7.5, np.inf):
        hi, lo = total_order_u32(np.asarray([v]))
        assert total_order_bound(v) == (int(hi[0]), int(lo[0]))


# ---------------------------------------------------------------------------
# Chain-served sessions: bit-exact against oracle + reference, all modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_chain_parity_all_modes(db, mode):
    """Fuzzer workloads through the chain-dispatching Pallas backend match
    the per-member oracle AND the NumPy member-major path bit-for-bit:
    results, row counters, and the virtual clock."""
    launched = 0
    for seed in range(2):
        rng = np.random.default_rng(42_000 + seed)
        qs = _fuzz_workload(db, rng)
        cfg = dict(mode=mode, morsel_size=4096)
        s_c, res_c = _run(db, _rebuild(db, qs), backend="pallas",
                          member_major=True, **cfg)
        s_o, res_o = _run(db, _rebuild(db, qs), backend="pallas",
                          member_major=False, **cfg)
        s_n, res_n = _run(db, _rebuild(db, qs), member_major=True, **cfg)
        _assert_bitequal(res_c, res_o, f"{mode}/seed{seed}/oracle")
        _assert_bitequal(res_c, res_n, f"{mode}/seed{seed}/numpy")
        for k in ROW_COUNTERS:
            assert s_c.counters.get(k, 0) == s_o.counters.get(k, 0), (mode, seed, k)
            assert s_c.counters.get(k, 0) == s_n.counters.get(k, 0), (mode, seed, k)
        # the virtual clock is backend-relative (the fused-lens probe models
        # fewer match ops than the reference probe), so exact clock identity
        # holds within a backend: chain-served fused vs per-member oracle
        assert s_c.now == s_o.now, (mode, seed)
        launched += int(s_c.counters["kernel_chain_launches"])
    if mode != "isolated":
        assert launched > 0, "the fused chain never served a morsel"


def test_chain_parity_partition_parallel(db):
    """Chain dispatch composes with the partition pool (workers=4) and the
    eviction/admission lifecycle without perturbing parity."""
    stress = dict(
        mode="graft", morsel_size=4096, retention="epoch", memory_budget=200_000,
        admission="adaptive", admission_max_inflight=3,
        admission_share_threshold=0.4, workers=4, partitions=4,
    )
    rng = np.random.default_rng(77_000)
    qs = _fuzz_workload(db, rng)
    s_c, res_c = _run(db, _rebuild(db, qs), backend="pallas",
                      member_major=True, **stress)
    s_o, res_o = _run(db, _rebuild(db, qs), backend="pallas",
                      member_major=False, **stress)
    s_n, res_n = _run(db, _rebuild(db, qs), member_major=True, **stress)
    _assert_bitequal(res_c, res_o, "partitioned/oracle")
    _assert_bitequal(res_c, res_n, "partitioned/numpy")
    for k in ROW_COUNTERS:
        assert s_c.counters.get(k, 0) == s_o.counters.get(k, 0), k
        assert s_c.counters.get(k, 0) == s_n.counters.get(k, 0), k
    assert s_c.now == s_o.now
    assert s_c.counters["kernel_chain_launches"] > 0


def test_chain_serves_slots_beyond_32(db):
    """Members holding slots >= 32 probe through the chain (the lens
    mirrors are (lo, hi) uint32 pairs): the former uint32 slot<32 kernel
    limit is gone, so ``fallback_probes_slot_limit`` stays zero forever."""
    dates = [f"1995-03-{d:02d}" for d in range(1, 29)]
    qs = [
        _q3(db, d, seg=float(s % 3), arrival=0.0)
        for s, d in enumerate(dates + dates[:12])
    ]  # 40 concurrent members on the shared build states
    s_c, res_c = _run(db, qs, backend="pallas", member_major=True,
                      mode="scan_sharing", morsel_size=8192)
    s_n, res_n = _run(
        db,
        [queries.make_query(db, q.template, q.params, arrival=q.arrival) for q in qs],
        member_major=True, mode="scan_sharing", morsel_size=8192,
    )
    _assert_bitequal(res_c, res_n, "slots>=32")
    assert s_c.counters["kernel_chain_launches"] > 0
    assert s_c.counters["fallback_probes_slot_limit"] == 0
    assert s_c.backend.fallback_reasons["slot_limit"] == 0


def test_grant_compiled_chain_parity(db):
    """Extent-scoped grants whose conjunctions canonicalize to intervals
    compile into the chain launch (grants no longer force the staged
    fallback); near-miss grafted repeats exercise them end-to-end."""
    seq = [
        ("q3", {"segment": 1.0, "date": 750.0}),
        ("q3", {"segment": 1.0, "date": 760.0}),
        ("q3", {"segment": 1.0, "date": 750.0}),
        ("q3", {"segment": 1.0, "date": 800.0}),
    ]
    res = {}
    sessions = {}
    for label, cfg in (
        ("chain", dict(backend="pallas", member_major=True)),
        ("numpy", dict(member_major=True)),
        ("oracle", dict(backend="pallas", member_major=False)),
    ):
        session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=4096, **cfg))
        futs = [
            session.submit(queries.make_query(db, t, p, arrival=float(i) * 0.01))
            for i, (t, p) in enumerate(seq)
        ]
        session.run()
        res[label] = [f.result() for f in futs]
        sessions[label] = session
    _assert_bitequal(res["chain"], res["numpy"], "grants/numpy")
    _assert_bitequal(res["chain"], res["oracle"], "grants/oracle")
    for k in ROW_COUNTERS:
        assert sessions["chain"].counters.get(k, 0) == sessions["numpy"].counters.get(k, 0), k
        assert sessions["chain"].counters.get(k, 0) == sessions["oracle"].counters.get(k, 0), k
    assert sessions["chain"].now == sessions["oracle"].now  # same backend
    assert sessions["chain"].counters["kernel_chain_launches"] > 0


# ---------------------------------------------------------------------------
# Per-reason fallback attribution (satellite: split fallback_probes)
# ---------------------------------------------------------------------------


def test_predicate_decline_counted_and_parity_kept(db):
    """q5's column-equality post-filter cannot canonicalize to intervals:
    its pipeline declines the chain with reason ``predicate`` (counted in
    the session counters AND on the backend) and runs the staged path —
    results still bit-match the NumPy plane."""
    q5 = queries.make_query(db, "q5", {"region": 1.0, "date": 730.0}, 0.0)
    s_c, res_c = _run(db, [q5], backend="pallas", member_major=True,
                      mode="graft", morsel_size=8192)
    s_n, res_n = _run(
        db,
        [queries.make_query(db, "q5", {"region": 1.0, "date": 730.0}, 0.0)],
        member_major=True, mode="graft", morsel_size=8192,
    )
    _assert_bitequal(res_c, res_n, "q5")
    assert s_c.counters["fallback_probes_predicate"] > 0
    assert s_c.backend.fallback_reasons["predicate"] > 0
    stats = s_c.backend.stats()
    assert stats["fallback_predicate"] == s_c.backend.fallback_reasons["predicate"]


def test_fallback_reason_counters_surface_in_stats(db):
    """QueryFuture.stats()["counters"] carries every per-reason decline
    counter; a clean q3 run leaves them all zero."""
    session = graftdb.connect(
        db, EngineConfig(mode="graft", morsel_size=8192, backend="pallas")
    )
    fut = session.submit(_q3(db, "1995-03-15"))
    fut.result()
    counters = fut.stats()["counters"]
    for reason in ("grants", "slot_limit", "keyrange", "capacity", "predicate"):
        assert counters[f"fallback_probes_{reason}"] == 0
    assert counters["kernel_chain_launches"] > 0
    assert session.backend.fallback_probes == 0


# ---------------------------------------------------------------------------
# Entry-indexed mirror maintenance (satellite: no rebuild invalidation)
# ---------------------------------------------------------------------------


def _mini_state(n0=64):
    from repro.core.descriptors import StateSignature
    from repro.core.state import SharedHashBuildState

    sig = StateSignature("hash_build", ("t", ("k",), ("x",)))
    s = SharedHashBuildState(1, sig, ("k",), ("x",))
    keys = np.arange(n0, dtype=np.int64)
    # seed visibility in the HIGH word half (bit 63): exercises the uint32
    # pair split and leaves the low slots free for the test's allocations
    s.insert_or_mark(
        keys, keys, {"k": keys.astype(float), "x": keys.astype(float)},
        np.full(n0, np.uint64(1) << np.uint64(63)), np.zeros(n0, np.uint64),
    )
    return s, keys


def test_mirror_appends_and_marks_patch_incrementally():
    """Growing the state (which rebuilds the probe table) and marking
    existing entries must NOT regather the lens mirror: appends and mark-log
    entries patch in place (``mirror_patched_rows``), and rebuilds leave the
    entry-indexed mirror untouched (``mirror_full_regathers == 0``)."""
    from repro.api.backends import PallasBackend

    s, keys = _mini_state(64)
    slot = s.slots.get(7)
    backend = PallasBackend(interpret=True)
    first = backend.probe_visible(s, keys, 7)
    assert first is not None and len(first[0]) == 0  # nothing marked for q7
    assert backend.mirror_full_regathers == 0

    # append enough to force a probe-table rebuild (64 -> 200 keys doubles
    # the 128-slot table) while staying inside the mirror's entry capacity,
    # and mark a few entries visible to q7's slot — both must patch
    new = np.arange(64, 200, dtype=np.int64)
    s.insert_or_mark(
        new, new, {"k": new.astype(float), "x": new.astype(float)},
        np.full(len(new), np.uint64(1) << np.uint64(63)), np.zeros(len(new), np.uint64),
    )
    marked = np.array([3, 5, 11], dtype=np.int64)
    s.insert_or_mark(
        marked, marked,
        {"k": marked.astype(float), "x": marked.astype(float)},
        np.full(3, np.uint64(1) << np.uint64(slot)), np.zeros(3, np.uint64),
    )
    second = backend.probe_visible(s, np.arange(200, dtype=np.int64), 7)
    assert second is not None
    np.testing.assert_array_equal(np.sort(second[0]), marked)
    assert backend.mirror_full_regathers == 0
    assert backend.mirror_patched_rows > 0


def test_detach_bumps_vis_epoch_and_regathers_once():
    """``detach`` clears a slot's bit across all vis words without touching
    the mark log; the vis-epoch stamp must force exactly one mirror
    regather so stale visibility can never leak out of the kernel."""
    from repro.api.backends import PallasBackend

    s, keys = _mini_state(64)
    slot = s.slots.get(9)
    s.insert_or_mark(
        keys, keys, {"k": keys.astype(float), "x": keys.astype(float)},
        np.full(64, np.uint64(1) << np.uint64(slot)), np.zeros(64, np.uint64),
    )
    backend = PallasBackend(interpret=True)
    first = backend.probe_visible(s, keys, 9)
    assert first is not None and len(first[0]) == 64

    epoch_before = s.vis_epoch
    s.detach(9)
    assert s.vis_epoch == epoch_before + 1
    s.slots.get(9)  # reattach: same qid, fresh (unmarked) slot
    again = backend.probe_visible(s, keys, 9)
    assert again is not None and len(again[0]) == 0  # cleared bits observed
    assert backend.mirror_full_regathers == 1


# ---------------------------------------------------------------------------
# Reuse plane round trip (satellite: spill -> rehydrate -> chain probe)
# ---------------------------------------------------------------------------


def test_spill_rehydrate_then_chain_probe_parity(db):
    """A state that retires to the artifact cache, rehydrates on a repeat
    (§12), and then probes through the fused chain returns bit-equal
    results to a never-evicted NumPy oracle — the rehydrated SoA feeds the
    device mirrors exactly like a fresh build."""
    seq = [
        ("q3", {"segment": 1.0, "date": 750.0}),
        ("q6", {"date": 400.0, "discount": 0.05, "quantity": 25.0}),
        ("q3", {"segment": 1.0, "date": 750.0}),  # fingerprint hit -> rehydrate
        ("q3", {"segment": 1.0, "date": 800.0}),
        ("q3", {"segment": 1.0, "date": 750.0}),
    ]
    cache = dict(retention="epoch", memory_budget=0, reuse_cache_budget=64_000_000)

    def run_seq(extra):
        session = graftdb.connect(db, EngineConfig(mode="graft", **extra))
        futs = [
            session.submit(queries.make_query(db, t, p, arrival=float(i)))
            for i, (t, p) in enumerate(seq)
        ]
        session.run()
        return session, [f.result() for f in futs]

    s_o, oracle = run_seq(dict(retention="epoch", member_major=True))
    s_c, cached = run_seq(dict(cache, backend="pallas", member_major=True))
    _assert_bitequal(cached, oracle, "reuse")
    assert s_c.counters["cache_spills"] > 0
    assert s_c.counters["cache_hits"] > 0
    assert s_c.counters["kernel_chain_launches"] > 0

    # and a rehydrate-served repeat equals the reference executor
    session = graftdb.connect(
        db, EngineConfig(mode="graft", backend="pallas", member_major=True, **cache)
    )
    f0 = session.submit(queries.make_query(db, "q3", {"segment": 1.0, "date": 750.0}, 0.0))
    f0.result()
    f1 = session.submit(queries.make_query(db, "q3", {"segment": 1.0, "date": 750.0}, 1.0))
    got = f1.result()
    want = refexec.execute(db, f1.query.plan)
    assert set(got) == set(want)
    for k in got:
        np.testing.assert_allclose(
            np.asarray(got[k], np.float64), np.asarray(want[k], np.float64), rtol=1e-9
        )
