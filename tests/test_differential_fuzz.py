"""Differential workload fuzzer + eviction-safety properties (DESIGN.md §10).

The fuzzer generates seeded random TPC-H query mixes and replays each one
through the full overload path — graft mode with ``retention='epoch'``, a
deliberately tiny ``memory_budget`` (so the evictor fires mid-run), and
``admission='adaptive'`` (so arrivals queue) — under ``workers ∈ {1, 4}``,
plus an isolated-mode run of the same workload. Every completed query is
checked for exact parity against the reference executor
(``relational/refexec.py``); the suite asserts >= 200 such parity instances
so the acceptance floor is self-checking.

Eviction safety is tested as properties: an evicted state hard-fails any
observation (the runtime guard IS the soundness mechanism — a fuzz run that
completes cleanly never read reclaimed fragments), EXPLAIN GRAFT's
per-partition represented + residual + unattached == demand identity
survives forced evictions, and re-admitting a query whose state range was
evicted recomputes from scratch, correctly.

Uses ``tests/_hypothesis_compat.py`` so tier-1 passes without hypothesis.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import graftdb
from graftdb import EngineConfig
from repro.relational import queries, refexec
from repro.relational.table import days

# The overload path under stress: tiny budget -> constant evictions; small
# max_inflight -> real queueing; small morsels -> many scheduling steps.
EVICT = dict(
    mode="graft",
    morsel_size=4096,
    retention="epoch",
    memory_budget=200_000,
    admission="adaptive",
    admission_max_inflight=3,
    admission_share_threshold=0.4,
)

#: parity-checked (query, engine-run) instances across the fuzz sweep —
#: the acceptance criterion requires >= 200 in the tier-1 budget
FUZZ_SEEDS = range(24)


def _canon(res):
    keys = sorted(res)
    order = np.lexsort([np.asarray(res[k]) for k in keys])
    return {k: np.asarray(res[k])[order] for k in keys}


def _assert_parity(engine_res, ref_res, ctx):
    ca, cb = _canon(engine_res), _canon(ref_res)
    assert set(ca) == set(cb), ctx
    for k in ca:
        assert ca[k].shape == cb[k].shape, (ctx, k)
        np.testing.assert_allclose(
            ca[k], cb[k], rtol=1e-12, atol=1e-12, err_msg=f"{ctx}/{k}"
        )


def _fuzz_workload(db, rng):
    """3-5 queries from the Zipf template mix; arrivals interleave racing
    (same-instant) and spread gaps so completions — and therefore the
    retire/evict/revive cycle — overlap admissions."""
    n = int(rng.integers(3, 6))
    qs, t = [], 0.0
    for _ in range(n):
        t += float(rng.choice([0.0, 0.002, 0.02, 0.08]))
        qs.append(queries.sample_query(db, rng, arrival=t))
    return qs


def _rebuild(db, qs):
    """Fresh Query objects (unique qids) with identical plans/arrivals."""
    return [
        queries.make_query(db, q.template, q.params, arrival=q.arrival) for q in qs
    ]


def _run_all(db, qs, **cfg):
    session = graftdb.connect(db, EngineConfig(**cfg))
    futs = session.submit_all(qs)
    session.run()
    return session, futs


def test_differential_fuzzer_parity(db):
    """>= 200 seeded workload parity instances: graft + eviction + admission
    under workers 1 and 4, and isolated mode, all vs the reference executor."""
    checks = 0
    evictions = queued = spills = hits = 0
    for seed in FUZZ_SEEDS:
        rng = np.random.default_rng(10_000 + seed)
        qs = _fuzz_workload(db, rng)
        refs = [refexec.execute(db, q.plan) for q in qs]
        runs = (
            ("graft-w1", dict(EVICT, workers=1, partitions=1)),
            ("graft-w4", dict(EVICT, workers=4, partitions=4)),
            # the reuse plane under stress (§12): a cache small enough that
            # the artifact tier itself evicts mid-run, so parity covers
            # spill -> age-out -> recompute alongside spill -> rehydrate
            ("graft-w1-cache", dict(EVICT, workers=1, partitions=1,
                                    memory_budget=100_000,
                                    reuse_cache_budget=400_000)),
            ("isolated", dict(mode="isolated", morsel_size=4096, workers=1, partitions=1)),
        )
        for label, cfg in runs:
            session, futs = _run_all(db, _rebuild(db, qs), **cfg)
            for i, (f, ref) in enumerate(zip(futs, refs)):
                _assert_parity(f.result(), ref, ctx=f"seed{seed}/{label}/q{i}")
                checks += 1
            st_ = session.stats()
            evictions += st_["evictions"]
            queued += st_["queued_admissions"]
            spills += st_.get("cache_spills", 0)
            hits += st_.get("cache_hits", 0)
            if "cache_high_water_bytes" in st_:
                assert st_["cache_high_water_bytes"] <= 400_000
            assert st_["queued_pending"] == 0  # run() drained the admit queue
            session.close()
    assert checks >= 200, f"only {checks} parity instances — raise FUZZ_SEEDS"
    # the sweep must actually exercise the overload machinery, not idle it
    assert evictions > 0, "no evictions across the fuzz sweep — budget too loose"
    assert queued > 0, "no queued admissions across the fuzz sweep"
    assert spills > 0, "the cache leg never spilled — budget too loose"
    assert hits > 0, "the cache leg never rehydrated an artifact"


# ---------------------------------------------------------------------------
# Batch-planning differential leg (§15)
# ---------------------------------------------------------------------------

#: seeds for the burst-heavy batch leg (ties dominate so cohorts form)
BATCH_SEEDS = range(10)


def _burst_workload(db, rng):
    """3-6 queries with same-instant ties the rule, not the exception: the
    gap mix is weighted toward 0.0 so most decision steps hold a cohort."""
    n = int(rng.integers(3, 7))
    qs, t = [], 0.0
    for _ in range(n):
        t += float(rng.choice([0.0, 0.0, 0.0, 0.002, 0.02]))
        qs.append(queries.sample_query(db, rng, arrival=t))
    return qs


def _explain_accounting(exp, ctx):
    """EXPLAIN GRAFT exactness: represented + residual + unattached == demand
    in total and per key partition, for every boundary."""
    for root in exp.boundaries:
        for b in root.flat():
            assert (
                b.represented_rows + b.residual_rows + b.unattached_rows
                == b.demand_rows
            ), (ctx, b)
            assert sum(b.part_demand_rows) == b.demand_rows, (ctx, b)
            for p in range(len(b.part_demand_rows)):
                assert (
                    b.part_represented_rows[p]
                    + b.part_residual_rows[p]
                    + b.part_unattached_rows[p]
                    == b.part_demand_rows[p]
                ), (ctx, b, p)
    assert exp.total_demand_rows == (
        exp.represented_rows + exp.residual_rows + exp.unattached_rows
    ), ctx


def test_graft_batch_differential_leg(db):
    """Randomized burst arrivals replayed through greedy grafting, batch
    planning (workers 1 and 4), and isolated execution: every leg matches
    the reference executor bit-for-bit (canonical order), the two batch
    worker counts match each other, and each batch-admitted query's captured
    EXPLAIN satisfies the per-partition accounting identity."""
    checks = cohorts = 0
    for seed in BATCH_SEEDS:
        rng = np.random.default_rng(21_000 + seed)
        qs = _burst_workload(db, rng)
        refs = [refexec.execute(db, q.plan) for q in qs]
        runs = (
            ("greedy-w1", dict(EVICT, workers=1, partitions=1)),
            ("batch-w1", dict(EVICT, workers=1, partitions=1,
                              batch_planning=True, capture_explain=True)),
            ("batch-w4", dict(EVICT, workers=4, partitions=4,
                              batch_planning=True)),
            ("isolated", dict(mode="isolated", morsel_size=4096,
                              workers=1, partitions=1)),
        )
        leg_results = {}
        for label, cfg in runs:
            session, futs = _run_all(db, _rebuild(db, qs), **cfg)
            leg_results[label] = [_canon(f.result()) for f in futs]
            for i, (f, ref) in enumerate(zip(futs, refs)):
                _assert_parity(f.result(), ref, ctx=f"seed{seed}/{label}/q{i}")
                checks += 1
            if label.startswith("batch"):
                cohorts += int(session.counters["batch_cohorts"])
                assert session.stats()["queued_pending"] == 0
                assert (
                    session.counters["batch_planned_queries"]
                    >= 2 * session.counters["batch_cohorts"]
                )
            if label == "batch-w1":
                for i, f in enumerate(futs):
                    _explain_accounting(f.explain(), ctx=f"seed{seed}/q{i}")
            session.close()
        # worker-count independence of the batched engine
        for a, b in zip(leg_results["batch-w1"], leg_results["batch-w4"]):
            for k in a:
                np.testing.assert_allclose(
                    a[k], b[k], rtol=1e-12, atol=1e-12, err_msg=f"seed{seed}/w1-vs-w4/{k}"
                )
    assert checks >= 100, f"only {checks} parity instances"
    assert cohorts > 0, "the burst sweep never formed a cohort — gaps too wide"


# ---------------------------------------------------------------------------
# Eviction safety properties
# ---------------------------------------------------------------------------


def _q3(db, date, seg=1.0, arrival=0.0):
    return queries.make_query(
        db, "q3", {"segment": seg, "date": float(days(date))}, arrival
    )


def test_evicted_state_observation_hard_fails():
    """The lens-soundness guard: every observation path of an evicted state
    raises instead of answering from reclaimed fragments."""
    from repro.core.descriptors import StateSignature
    from repro.core.state import SharedHashBuildState

    sig = StateSignature("hash_build", ("t", ("k",), ("x",)))
    s = SharedHashBuildState(1, sig, ("k",), ("x",))
    s.insert_or_mark(
        np.arange(8),
        np.arange(8),
        {"k": np.arange(8.0), "x": np.arange(8.0)},
        np.ones(8, dtype=np.uint64),
        np.ones(8, dtype=np.uint64),
    )
    s.evicted = True
    for op in (
        lambda: s.probe(np.arange(4)),
        lambda: s.visible_mask(1, np.arange(2)),
        lambda: s.attach(2),
        lambda: s.insert_or_mark(
            np.arange(2), np.arange(2), {"k": np.zeros(2), "x": np.zeros(2)},
            np.ones(2, dtype=np.uint64), np.ones(2, dtype=np.uint64),
        ),
        lambda: s.pin("token"),
    ):
        with pytest.raises(RuntimeError, match="evicted"):
            op()


def test_pinned_state_never_evicted(db):
    """Pins (live lenses or admission pins) keep a state out of the
    evictor's reach; forcing eviction on a pinned state raises."""
    session = graftdb.connect(
        db, EngineConfig(mode="graft", morsel_size=4096, retention="epoch")
    )
    session.submit(_q3(db, "1995-03-15"))
    eng = session.engine
    live = [s for lst in eng.state_index.values() for s in lst]
    assert live and all(not s.evictable for s in live)  # lens refs pin them
    with pytest.raises(RuntimeError, match="pinned"):
        eng._evict(live[0])
    session.run()
    # after completion the refs dropped: states retired, now evictable
    retired = list(eng.lifecycle.retired.values())
    assert retired and all(s.evictable for s in retired)
    # an explicit admission pin blocks retirement-eviction again
    retired[0].pin("admission-tok")
    with pytest.raises(RuntimeError, match="pinned"):
        eng._evict(retired[0])
    retired[0].unpin("admission-tok")
    assert eng.enforce_memory_budget(0) == len(retired)  # force-evict all
    assert all(s.evicted for s in retired)
    assert not any(lst for lst in eng.state_index.values())


@given(seed=st.integers(0, 10_000), partitions=st.integers(1, 6))
@settings(max_examples=6, deadline=None)
def test_explain_sums_to_demand_after_forced_eviction(db, seed, partitions):
    """EXPLAIN GRAFT accounting survives eviction: after force-evicting all
    retained state, per-partition represented + residual + unattached still
    equals demand exactly (everything falls back to ordinary/fresh)."""
    session = graftdb.connect(
        db,
        EngineConfig(
            mode="graft", morsel_size=4096, retention="epoch",
            workers=1, partitions=partitions,
        ),
    )
    rng = np.random.default_rng(seed)
    session.submit_all([queries.sample_query(db, rng, arrival=i * 0.01) for i in range(3)])
    session.run()
    eng = session.engine
    assert eng.lifecycle.retired  # something was retired
    probe = queries.sample_query(db, rng, arrival=session.now)
    before = session.explain_graft(probe)
    evicted = eng.enforce_memory_budget(0)
    assert evicted > 0
    after = session.explain_graft(probe)
    for exp in (before, after):
        for b in [x for root in exp.boundaries for x in root.flat()]:
            assert sum(b.part_demand_rows) == b.demand_rows
            for p in range(len(b.part_demand_rows)):
                assert (
                    b.part_represented_rows[p]
                    + b.part_residual_rows[p]
                    + b.part_unattached_rows[p]
                    == b.part_demand_rows[p]
                ), (b, p)
        assert exp.total_demand_rows == (
            exp.represented_rows + exp.residual_rows + exp.unattached_rows
        )
    # identical plan, identical demand — only the attachment classes moved
    assert after.total_demand_rows == before.total_demand_rows
    # evicted hash states can no longer represent anything
    assert all(
        b.state_id is None
        for root in after.boundaries
        for b in root.flat()
        if b.decision in ("represented", "partial", "residual")
    ) or after.represented_rows + after.residual_rows <= before.represented_rows + before.residual_rows


def test_readmitting_evicted_range_recomputes_correctly(db_mid):
    """Re-admission after eviction: the second identical query rebuilds from
    scratch (no represented observation of reclaimed fragments) and still
    matches the reference executor and the pre-eviction result."""
    session = graftdb.connect(
        db_mid, EngineConfig(mode="graft", morsel_size=4096, retention="epoch")
    )
    qa = _q3(db_mid, "1995-03-15")
    fa = session.submit(qa)
    session.run()
    ra = fa.result()
    eng = session.engine
    rep_before = eng.counters["represented_rows"]
    assert eng.enforce_memory_budget(0) > 0  # evict every retained state
    qb = _q3(db_mid, "1995-03-15", arrival=session.now)
    fb = session.submit(qb)
    session.run()
    rb = fb.result()
    ref = refexec.execute(db_mid, qb.plan)
    _assert_parity(rb, ref, ctx="readmit-vs-ref")
    _assert_parity(rb, ra, ctx="readmit-vs-first-run")
    # no represented-extent observation happened against evicted state
    assert eng.counters["represented_rows"] == rep_before
    assert eng.counters["evictions"] > 0


def test_retained_state_serves_represented_after_release(db_mid):
    """The point of epoch retention: a later narrower arrival grafts fully
    represented extents off a *retired* state (refcount would rebuild)."""
    session = graftdb.connect(
        db_mid,
        EngineConfig(mode="graft", morsel_size=4096, retention="epoch",
                     capture_explain=True),
    )
    fa = session.submit(_q3(db_mid, "1995-03-20"))
    session.run()
    assert session.engine.lifecycle.retired  # qa's states retired, not dropped
    qb = _q3(db_mid, "1995-03-10", arrival=session.now)
    exp = session.explain_graft(qb)
    assert exp.represented_rows > 0
    assert any(
        b.state_retired for root in exp.boundaries for b in root.flat()
    ), "explain did not flag the retired candidate"
    fb = session.submit(qb)
    session.run()
    _assert_parity(fb.result(), refexec.execute(db_mid, qb.plan), ctx="retained-graft")
    assert session.counters["state_revivals"] > 0


@given(budget=st.integers(0, 400_000), seed=st.integers(0, 9_999))
@settings(max_examples=6, deadline=None)
def test_memory_budget_respected_under_any_budget(db, budget, seed):
    """Property: for any budget, the retained high-water never exceeds it
    (the evictor runs at every retire) and results stay correct."""
    rng = np.random.default_rng(seed)
    qs = [queries.sample_query(db, rng, arrival=i * 0.01) for i in range(4)]
    session, futs = _run_all(
        db, qs, **dict(EVICT, memory_budget=budget, workers=1, partitions=1)
    )
    for i, f in enumerate(futs):
        _assert_parity(f.result(), refexec.execute(db, qs[i].plan), ctx=f"budget{budget}/q{i}")
    assert session.stats()["retained_high_water_bytes"] <= budget


def test_queued_arrival_pins_candidates_against_eviction(db_mid):
    """A deferred-but-admissible arrival pins its candidate states: while
    it queues, even zero-budget enforcement cannot reclaim them (pins block
    eviction, not retirement), and admission unpins + grafts represented
    extents off the survivor."""
    from repro.core.scheduler import AdmissionController

    session = graftdb.connect(
        db_mid,
        EngineConfig(mode="graft", morsel_size=4096, retention="epoch"),
    )
    session.submit(_q3(db_mid, "1995-03-20"))
    session.run()
    eng = session.engine
    retired = list(eng.lifecycle.retired.values())
    assert retired  # qa's states retired, retained (no budget)
    runner = session._runner

    class DeferOnce(AdmissionController):
        def __init__(self):
            super().__init__(max_inflight=1)
            self.deferred = 0

        def decide(self, engine, query):
            if self.deferred == 0:
                self.deferred += 1
                return ("defer", "overload")
            return ("admit", "graft")

    runner.admission = DeferOnce()
    qc = _q3(db_mid, "1995-03-10", arrival=session.now)
    fc = session.submit(qc)  # deferred: pins the retired candidates
    pinned = runner._queued_pins.get(qc.qid, [])
    assert pinned, "deferred arrival pinned nothing"
    assert all(not s.evictable for s in pinned)
    # zero-budget enforcement while queued: pinned candidates survive,
    # everything else retired goes
    eng.enforce_memory_budget(0)
    assert all(not s.evicted for s in pinned), "evictor reclaimed pinned state"
    # pins block eviction but NOT retirement: still stamped, still indexed
    assert all(s.retired_epoch is not None for s in pinned)
    done = session.run()
    assert {f.qid for f in done} >= {fc.qid}
    assert not runner._queued_pins, "pins must release at admission"
    assert all(not s.pins for s in pinned)
    _assert_parity(fc.result(), refexec.execute(db_mid, qc.plan), ctx="pinned-graft")
    # the narrower qc grafted off the pinned survivor
    assert eng.counters["represented_rows"] > 0
    assert eng.counters["state_revivals"] > 0
