"""Serving-layer dynamic folding (KV-prefix reuse — the paper's mechanism
transferred to the serving substrate, DESIGN.md §6)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.folding import FoldingScheduler, Request, SimExecutor


def _reqs(n, prefix_len=256, suffix_len=32, arrival_gap=0.01, n_decode=16):
    rng = np.random.default_rng(0)
    shared = tuple(rng.integers(0, 1000, prefix_len).tolist())
    out = []
    for i in range(n):
        suffix = tuple(rng.integers(0, 1000, suffix_len).tolist())
        out.append(Request(i, shared + suffix, n_decode, arrival=i * arrival_gap))
    return out


def test_folding_reduces_prefill_tokens():
    reqs = _reqs(8)
    fold = FoldingScheduler(SimExecutor(), fold=True).run(_reqs(8))
    iso = FoldingScheduler(SimExecutor(), fold=False).run(_reqs(8))
    assert fold["completed"] == iso["completed"] == 8
    f_tok = fold["prefill_tokens"]
    i_tok = iso["prefill_tokens"]
    assert f_tok["represented"] + f_tok["residual"] > 0
    assert i_tok["represented"] == 0
    # shared prefix computed once -> big prefill saving and lower latency
    assert fold["mean_latency"] < iso["mean_latency"]
    assert fold["elapsed"] < iso["elapsed"]


def test_extent_partition_accounting():
    reqs = _reqs(4, prefix_len=128, suffix_len=64)
    sched = FoldingScheduler(SimExecutor(), fold=True)
    sched.run(reqs)
    for r in reqs[1:]:
        # each later request's prompt decomposes exactly
        assert r.represented_tokens + r.residual_tokens + r.ordinary_tokens == len(r.prompt)
        assert r.ordinary_tokens == 64  # unique suffix stays ordinary work
    # first request is all ordinary (it created the state)
    assert reqs[0].ordinary_tokens == len(reqs[0].prompt)


def test_retention_releases_prefix_states():
    sched = FoldingScheduler(SimExecutor(), fold=True)
    sched.run(_reqs(4))
    assert sched.states == []  # all refs released


def test_no_fold_below_min_share():
    rng = np.random.default_rng(1)
    reqs = [
        Request(i, tuple(rng.integers(0, 1000, 64).tolist()), 4, arrival=0.0)
        for i in range(4)
    ]  # disjoint prompts
    sched = FoldingScheduler(SimExecutor(), fold=True)
    res = sched.run(reqs)
    assert res["prefill_tokens"]["represented"] == 0


@given(
    n=st.integers(2, 10),
    prefix=st.integers(16, 200),
    suffix=st.integers(1, 100),
    gap=st.floats(0.0, 0.2),
)
@settings(max_examples=25, deadline=None)
def test_folding_prefill_work_conservation(n, prefix, suffix, gap):
    """Folding never computes MORE prefill tokens than isolated execution
    (decode-batching dynamics may shuffle wall time slightly, but the
    prefill work saved by represented extents is a hard invariant)."""
    def mk():
        rng = np.random.default_rng(42)
        shared = tuple(rng.integers(0, 1000, prefix).tolist())
        return [
            Request(i, shared + tuple(rng.integers(0, 1000, suffix).tolist()), 4, arrival=i * gap)
            for i in range(n)
        ]

    fold = FoldingScheduler(SimExecutor(), fold=True).run(mk())
    iso = FoldingScheduler(SimExecutor(), fold=False).run(mk())
    assert fold["completed"] == iso["completed"] == n
    assert (
        fold["prefill_tokens"].get("computed", 0)
        <= iso["prefill_tokens"].get("computed", 0) + 1e-9
    )
