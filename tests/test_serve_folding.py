"""Serving-layer dynamic folding (KV-prefix reuse — the paper's mechanism
transferred to the serving substrate, DESIGN.md §6)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import graftdb
from repro.serve.folding import Request


def _serve(reqs, fold=True):
    """Run one serving episode through the unified Session surface."""
    session = graftdb.connect_serving(fold=fold)
    session.submit_all(reqs)
    return session.run()


def _reqs(n, prefix_len=256, suffix_len=32, arrival_gap=0.01, n_decode=16):
    rng = np.random.default_rng(0)
    shared = tuple(rng.integers(0, 1000, prefix_len).tolist())
    out = []
    for i in range(n):
        suffix = tuple(rng.integers(0, 1000, suffix_len).tolist())
        out.append(Request(i, shared + suffix, n_decode, arrival=i * arrival_gap))
    return out


def test_folding_reduces_prefill_tokens():
    fold = _serve(_reqs(8), fold=True)
    iso = _serve(_reqs(8), fold=False)
    assert fold["completed"] == iso["completed"] == 8
    f_tok = fold["prefill_tokens"]
    i_tok = iso["prefill_tokens"]
    assert f_tok["represented"] + f_tok["residual"] > 0
    assert i_tok["represented"] == 0
    # shared prefix computed once -> big prefill saving and lower latency
    assert fold["mean_latency"] < iso["mean_latency"]
    assert fold["elapsed"] < iso["elapsed"]


def test_extent_partition_accounting():
    reqs = _reqs(4, prefix_len=128, suffix_len=64)
    session = graftdb.connect_serving(fold=True)
    futures = session.submit_all(reqs)
    session.run()
    for fut in futures[1:]:
        # each later request's prompt decomposes exactly
        r = fut.result()
        prompt_len = len(fut.request.prompt)
        assert (
            r["represented_tokens"] + r["residual_tokens"] + r["ordinary_tokens"]
            == prompt_len
        )
        assert r["ordinary_tokens"] == 64  # unique suffix stays ordinary work
        # the admission-time explain agrees with the executed partition
        exp = fut.explain()
        assert exp["matched_tokens"] == prompt_len - r["ordinary_tokens"]
    # first request is all ordinary (it created the state)
    assert futures[0].result()["ordinary_tokens"] == len(reqs[0].prompt)


def test_retention_releases_prefix_states():
    session = graftdb.connect_serving(fold=True)
    session.submit_all(_reqs(4))
    session.run()
    assert session.live_states == 0  # all refs released


def test_no_fold_below_min_share():
    rng = np.random.default_rng(1)
    reqs = [
        Request(i, tuple(rng.integers(0, 1000, 64).tolist()), 4, arrival=0.0)
        for i in range(4)
    ]  # disjoint prompts
    res = _serve(reqs, fold=True)
    assert res["prefill_tokens"]["represented"] == 0


def test_fresh_state_explain_matches_preflight():
    """A state-creating admission reports matched_tokens=0 (nothing
    pre-existing matched), agreeing with the pre-flight explain_fold."""
    session = graftdb.connect_serving(fold=True)
    req = _reqs(1)[0]
    pre = session.explain_fold(req)
    fut = session.submit(req)
    session.run()
    post = fut.explain()
    assert pre["matched_tokens"] == post["matched_tokens"] == 0
    assert pre["created_state"] and post["created_state"]
    assert post["ordinary_tokens"] == len(req.prompt)


def test_episode_summaries_report_per_episode_tokens():
    """run() summaries carry per-episode token deltas even though the
    scheduler's cumulative metrics persist across episodes."""
    session = graftdb.connect_serving(fold=True)
    session.submit_all(_reqs(2))
    s1 = session.run()
    batch2 = _reqs(2)
    for i, r in enumerate(batch2):
        r.rid = 100 + i  # distinct ids; same prompts as episode 1
    session.submit_all(batch2)
    s2 = session.run()
    assert s1["completed"] == s2["completed"] == 2
    # identical workloads (episode-1 states were released) -> identical
    # per-episode deltas, and the deltas sum to the cumulative metrics
    assert s1["prefill_tokens"]["ordinary"] == s2["prefill_tokens"]["ordinary"]
    total = session.stats()["prefill_tokens"]
    assert (
        s1["prefill_tokens"]["ordinary"] + s2["prefill_tokens"]["ordinary"]
        == total["ordinary"]
    )


def test_prefix_state_ids_isolated_per_session():
    """State ids are scheduler-scoped: constructing a second session must
    restart them (the old class-level counter leaked across instances)."""
    s1 = graftdb.connect_serving(fold=True)
    s1.submit_all(_reqs(3))
    s1.run()
    s2 = graftdb.connect_serving(fold=True)
    futures = s2.submit_all(_reqs(3))
    s2.run()
    assert futures[0].explain()["state_sid"] == 1


@given(
    n=st.integers(2, 10),
    prefix=st.integers(16, 200),
    suffix=st.integers(1, 100),
    gap=st.floats(0.0, 0.2),
)
@settings(max_examples=25, deadline=None)
def test_folding_prefill_work_conservation(n, prefix, suffix, gap):
    """Folding never computes MORE prefill tokens than isolated execution
    (decode-batching dynamics may shuffle wall time slightly, but the
    prefill work saved by represented extents is a hard invariant)."""
    def mk():
        rng = np.random.default_rng(42)
        shared = tuple(rng.integers(0, 1000, prefix).tolist())
        return [
            Request(i, shared + tuple(rng.integers(0, 1000, suffix).tolist()), 4, arrival=i * gap)
            for i in range(n)
        ]

    fold = _serve(mk(), fold=True)
    iso = _serve(mk(), fold=False)
    assert fold["completed"] == iso["completed"] == n
    assert (
        fold["prefill_tokens"].get("computed", 0)
        <= iso["prefill_tokens"].get("computed", 0) + 1e-9
    )


# ---------------------------------------------------------------------------
# Prefix-state lifecycle (§10): retention, revival, token-budget eviction
# ---------------------------------------------------------------------------


def test_retained_prefix_serves_later_wave():
    """retain_prefixes keeps a zero-ref prefix state alive across episodes:
    a later wave with the same shared prefix folds onto it (refcount-only
    would drop the state and re-prefill the prefix)."""
    session = graftdb.connect_serving(
        fold=True, retain_prefixes=True, memory_budget_tokens=2048
    )
    session.submit_all(_reqs(4))
    session.run()
    assert session.live_states >= 1  # retained, not dropped
    wave2 = [
        Request(100 + i, r.prompt, r.n_decode, arrival=10.0 + i * 0.01)
        for i, r in enumerate(_reqs(3))
    ]
    futs = session.submit_all(wave2)
    session.run()
    for f in futs:
        assert f.result()["represented_tokens"] > 0  # folded onto retained KV
    # the drop-at-zero-refs baseline rebuilds instead
    base = graftdb.connect_serving(fold=True)
    base.submit_all(_reqs(4))
    base.run()
    assert base.live_states == 0


def test_prefix_token_budget_evicts_oldest_and_is_respected():
    """Retired prefixes are evicted oldest-epoch-first past the token
    budget; the retained high-water never exceeds it and pinned states are
    never touched."""
    session = graftdb.connect_serving(
        fold=True, retain_prefixes=True, memory_budget_tokens=300
    )
    rng = np.random.default_rng(3)
    # distinct prompts -> distinct prefix states, each ~144 tokens
    waves = [
        [Request(w * 10 + i, tuple(rng.integers(0, 1000, 144).tolist()), 4,
                 arrival=w * 5.0 + i * 0.01) for i in range(2)]
        for w in range(3)
    ]
    for wave in waves:
        session.submit_all(wave)
        session.run()
    lc = session.stats()["lifecycle"]
    assert lc["evicted_states"] > 0
    assert lc["retained_tokens"] <= 300
    assert lc["retained_tokens_high_water"] <= 300
    with pytest.raises(ValueError):
        graftdb.connect_serving(memory_budget_tokens=100)  # needs retain_prefixes
