"""Unified Session API: EngineConfig validation, QueryFuture equivalence,
EXPLAIN GRAFT extent accounting, backend selection, and SlotAllocator
lifecycle (the visibility substrate the Session's sharing relies on)."""

import numpy as np
import pytest

import graftdb
from graftdb import EngineConfig, PallasBackend, ReferenceBackend, ServingConfig
from repro.core.visibility import MAX_SLOTS, SlotAllocator
from repro.relational import queries, refexec
from repro.relational.table import days

ALL_MODES = ["isolated", "scan_sharing", "qpipe_osp", "residual", "graft"]


def _q3(db, date, seg=1.0, arrival=0.0):
    return queries.make_query(db, "q3", {"segment": seg, "date": float(days(date))}, arrival)


# ---------------------------------------------------------------------------
# EngineConfig validation
# ---------------------------------------------------------------------------


def test_engine_config_defaults_valid():
    cfg = EngineConfig()
    assert cfg.mode == "graft" and cfg.backend == "reference"
    assert cfg.make_backend().name == "reference"


@pytest.mark.parametrize(
    "kw",
    [
        {"mode": "turbo"},
        {"morsel_size": 0},
        {"morsel_size": -4},
        {"clock": "lamport"},
        {"clock": object()},
        {"backend": "cuda"},
        {"retention": "lru"},
        {"cost_model": {"warp": 1e-9}},
        {"max_steps": 0},
    ],
)
def test_engine_config_rejects_bad_values(kw):
    with pytest.raises((ValueError, TypeError)):
        EngineConfig(**kw)


def test_serving_config_rejects_bad_values():
    with pytest.raises(ValueError):
        ServingConfig(min_share=-1)
    with pytest.raises(ValueError):
        ServingConfig(prefill_tok_s=0.0)


def test_connect_kwargs_shortcut(db):
    session = graftdb.connect(db, mode="isolated", morsel_size=4096)
    assert session.mode == "isolated"
    with pytest.raises(TypeError):
        graftdb.connect(db, EngineConfig(), mode="graft")


# ---------------------------------------------------------------------------
# QueryFuture.result() equivalence with the isolated baseline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ALL_MODES)
def test_future_results_match_isolated_baseline(db, mode):
    """Same queries through every sharing mode return exactly the isolated
    (reference-executor) results — futures hide none of the semantics."""
    rng = np.random.default_rng(123)
    qs = [queries.sample_query(db, rng, arrival=i * 0.001) for i in range(4)]
    session = graftdb.connect(db, EngineConfig(mode=mode, morsel_size=8192))
    futures = session.submit_all(qs)
    for q, fut in zip(qs, futures):
        res = fut.result()  # drives the session on first call
        ref = refexec.execute(db, q.plan)
        assert set(res) == set(ref)
        for k in ref:
            np.testing.assert_allclose(
                np.sort(np.asarray(res[k], float)),
                np.sort(np.asarray(ref[k], float)),
                rtol=1e-9,
                atol=1e-6,
                err_msg=f"{q.template}/{k}/{mode}",
            )
        assert fut.done and fut.latency() >= 0.0
        assert fut.stats()["done"] is True


def test_future_wait_false_raises_before_run(db):
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=8192))
    fut = session.submit(_q3(db, "1995-03-15"))
    with pytest.raises(RuntimeError):
        fut.result(wait=False)
    assert fut.result() is not None


# ---------------------------------------------------------------------------
# EXPLAIN GRAFT extent accounting
# ---------------------------------------------------------------------------


def test_explain_graft_extents_sum_to_demand(db_mid):
    """TPC-H Q3 overlap scenario (the paper's Fig. 3 instance): the captured
    EXPLAIN GRAFT partitions every boundary's demand exactly into
    represented + residual + unattached."""
    # workers/partitions pinned: the 0.02s offset must land mid-flight in
    # single-stream time (the pool finishes Q_A earlier at higher worker
    # counts; overlap under workers>1 is covered in test_partition_parallel)
    session = graftdb.connect(
        db_mid,
        EngineConfig(
            mode="graft", morsel_size=4096, capture_explain=True, workers=1, partitions=1
        ),
    )
    qa = _q3(db_mid, "1995-03-15")
    qb = _q3(db_mid, "1995-03-20", arrival=0.02)  # broader, arrives mid-flight
    fa, fb = session.submit_all([qa, qb])
    session.run()

    for fut in (fa, fb):
        exp = fut.explain()
        assert exp.total_demand_rows > 0
        for b in [x for root in exp.boundaries for x in root.flat()]:
            assert (
                b.represented_rows + b.residual_rows + b.unattached_rows
                == b.demand_rows
            ), b
        assert (
            exp.represented_rows + exp.residual_rows + exp.unattached_rows
            == exp.total_demand_rows
        )

    # Q_A found an empty engine: all demand is unattached ordinary work.
    ea = fa.explain()
    assert ea.unattached_rows == ea.total_demand_rows
    # Q_B grafted onto Q_A's live state: some demand is represented and the
    # attachment targets Q_A's states.
    eb = fb.explain()
    assert eb.represented_rows > 0
    assert any(b.state_id is not None for root in eb.boundaries for b in root.flat())
    # rendering and dict export stay consistent
    d = eb.to_dict()
    assert d["total_demand_rows"] == eb.total_demand_rows
    assert "EXPLAIN GRAFT" in eb.render()


def test_explain_graft_preflight_is_read_only(db_mid):
    session = graftdb.connect(db_mid, EngineConfig(mode="graft", morsel_size=4096))
    session.submit(_q3(db_mid, "1995-03-15"))  # creates live shared states
    before = session.stats()["live_states"]
    qb = _q3(db_mid, "1995-03-20", arrival=0.0)
    exp = session.explain_graft(qb)
    # analysis attaches nothing: no new states, no refs, no grants
    assert session.stats()["live_states"] == before
    assert exp.total_demand_rows == (
        exp.represented_rows + exp.residual_rows + exp.unattached_rows
    )
    # pre-flight against incomplete coverage: attachment is residual-only
    assert exp.residual_rows > 0
    session.run()


def test_explain_requires_capture_flag(db):
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=8192))
    fut = session.submit(_q3(db, "1995-03-15"))
    session.run()
    with pytest.raises(RuntimeError, match="capture_explain"):
        fut.explain()


# ---------------------------------------------------------------------------
# Execution backends
# ---------------------------------------------------------------------------


def test_pallas_backend_matches_reference(db):
    jax = pytest.importorskip("jax")
    qa = _q3(db, "1995-03-15")
    qb = _q3(db, "1995-03-20", arrival=0.01)
    ref_session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=16384))
    pal_session = graftdb.connect(
        db, EngineConfig(mode="graft", morsel_size=16384, backend="pallas")
    )
    r_futs = ref_session.submit_all([_q3(db, "1995-03-15"), _q3(db, "1995-03-20", arrival=0.01)])
    p_futs = pal_session.submit_all([qa, qb])
    for rf, pf in zip(r_futs, p_futs):
        rres, pres = rf.result(), pf.result()
        assert set(rres) == set(pres)
        for k in rres:
            np.testing.assert_allclose(
                np.sort(np.asarray(pres[k], float)),
                np.sort(np.asarray(rres[k], float)),
                rtol=1e-9,
                atol=1e-6,
            )
    assert pal_session.backend.kernel_probes > 0  # the Pallas path actually ran


def test_pallas_batch_insert_detects_in_batch_duplicates(db):
    """Duplicate keycodes arriving in ONE insert batch must mark the probe
    table unservable (fall back to the reference multi-match probe), not
    silently drop the second entry."""
    pytest.importorskip("jax")
    from repro.core.descriptors import StateSignature
    from repro.core.state import SharedHashBuildState

    sig = StateSignature("hash_build", ("t", ("k",), ("x",)))
    state = SharedHashBuildState(1, sig, ("k",), ("x",))
    kc = np.array([7, 7, 9], dtype=np.int64)
    dids = np.arange(3, dtype=np.int64)
    state.insert_or_mark(
        dids,
        kc,
        {"k": kc.astype(float), "x": kc.astype(float)},
        np.full(3, np.uint64(1)),
        np.zeros(3, np.uint64),
    )
    pal, ref = PallasBackend(), ReferenceBackend()
    probe = np.array([7, 9], dtype=np.int64)
    p_pairs = pal.probe(state, probe)
    r_pairs = ref.probe(state, probe)
    np.testing.assert_array_equal(p_pairs[0], r_pairs[0])
    np.testing.assert_array_equal(p_pairs[1], r_pairs[1])
    assert pal.fallback_probes == 1  # multi-match state: reference path


def test_seg_aggregate_kernel_matches_bincount():
    pytest.importorskip("jax")
    b = PallasBackend(use_agg_kernel=True)
    r = ReferenceBackend()
    rng = np.random.default_rng(0)
    gids = rng.integers(0, 37, 500).astype(np.int64)
    vals = rng.normal(size=500)
    np.testing.assert_allclose(
        b.segment_sum(gids, vals, 37), r.segment_sum(gids, vals, 37), rtol=1e-5
    )
    np.testing.assert_allclose(b.segment_sum(gids, None, 37), r.segment_sum(gids, None, 37))


def test_backend_instance_passthrough(db):
    backend = ReferenceBackend()
    session = graftdb.connect(db, EngineConfig(backend=backend))
    assert session.backend is backend


# ---------------------------------------------------------------------------
# Data-plane perf counters (vectorized state plane, DESIGN.md §8)
# ---------------------------------------------------------------------------


def test_stats_expose_data_plane_counters(db):
    """QueryFuture.stats carries the shared-plane counters; a graft run
    exercises the fused filter and the batched did-index growth path."""
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=8192))
    fut = session.submit(_q3(db, "1995-03-15"))
    fut.result()
    counters = fut.stats()["counters"]
    assert set(counters) == {
        "index_rebuilds",
        "kernel_lens_probes",
        "fused_filter_rows",
        "kernel_multi_lens_probes",
        "fused_vis_rows",
        "fused_stage_filter_rows",
        "fused_sink_rows",
        "kernel_chain_launches",
        "fallback_probes_grants",
        "fallback_probes_slot_limit",
        "fallback_probes_keyrange",
        "fallback_probes_capacity",
        "fallback_probes_predicate",
        "agg_cohort_rows",
        "overflow_members",
        "partition_merges",
        "partition_probe_merges",
        "evictions",
        "evicted_bytes",
        "state_revivals",
        "queued_admissions",
        "forced_admissions",
        "admission_evals",
        "batch_cohorts",
        "batch_planned_queries",
        "batch_coverage_gain_rows",
        "cache_hits",
        "cache_spills",
        "cache_evictions",
        "cache_corrupt",
        "rehydrate_bytes",
    }
    assert counters["fused_filter_rows"] > 0  # source predicates ran fused
    assert counters["fused_sink_rows"] > 0  # member-major build tagging ran (§11)
    assert counters["overflow_members"] == 0  # nothing spilled past 64 slots
    # refcount retention + always-admission (defaults): lifecycle idle
    assert counters["evictions"] == 0 and counters["queued_admissions"] == 0
    assert fut.stats()["admission"] is None  # no controller on this session
    assert fut.stats()["queue_delay_s"] == 0.0
    assert counters["index_rebuilds"] > 0  # did/key indexes doubled under growth
    assert counters["kernel_lens_probes"] == 0  # reference backend: no kernel lens
    # the worker-pool utilization block rides along on every stats dict
    wstats = fut.stats()["workers"]
    assert wstats["n"] >= 1 and len(wstats["busy_s"]) == wstats["n"]
    # engine-level stats mirror the same counters
    stats = session.stats()
    for k, v in counters.items():
        assert stats[k] == v


def test_pallas_lens_probe_resolves_in_kernel(db):
    """Single-member probes route through the fused-lens kernel with the
    state's real visibility words — and still match the reference result."""
    pytest.importorskip("jax")
    q = _q3(db, "1995-03-15")
    ref_session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=16384))
    pal_session = graftdb.connect(
        db, EngineConfig(mode="graft", morsel_size=16384, backend="pallas")
    )
    rres = ref_session.submit(_q3(db, "1995-03-15")).result()
    pfut = pal_session.submit(q)
    pres = pfut.result()
    for k in rres:
        np.testing.assert_allclose(
            np.sort(np.asarray(pres[k], float)),
            np.sort(np.asarray(rres[k], float)),
            rtol=1e-9,
            atol=1e-6,
        )
    counters = pfut.stats()["counters"]
    assert counters["kernel_lens_probes"] > 0
    assert pal_session.backend.kernel_lens_probes == counters["kernel_lens_probes"]
    # unique-key dimension states must not have fallen back to the
    # reference probe (acceptance: no new Pallas fallbacks)
    assert pal_session.backend.fallback_probes == 0


# ---------------------------------------------------------------------------
# SlotAllocator lifecycle (visibility substrate)
# ---------------------------------------------------------------------------


def test_slot_allocator_exhaustion_and_recycling():
    alloc = SlotAllocator()
    slots = [alloc.get(qid) for qid in range(MAX_SLOTS)]
    assert sorted(slots) == list(range(MAX_SLOTS))
    # 65th concurrent query on one state must raise
    with pytest.raises(RuntimeError, match="slots exhausted"):
        alloc.get(MAX_SLOTS)
    # idempotent for an already-attached query
    assert alloc.get(3) == slots[3]
    # release recycles: the freed bit is handed to the next attach
    alloc.release(10)
    assert alloc.peek(10) is None
    assert alloc.get(MAX_SLOTS) == slots[10]
    # releasing an unknown qid is a no-op
    alloc.release(99999)


def test_run_reports_each_completion_once(db):
    """A reused session's run() returns only the round's new completions."""
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=8192))
    session.submit(_q3(db, "1995-03-15"))
    first = session.run()
    assert len(first) == 1
    session.submit(_q3(db, "1995-03-20"))
    second = session.run()
    assert len(second) == 1
    assert first[0].qid != second[0].qid
    assert session.run() == []  # drained: nothing new to report


def test_session_lifecycle(db):
    session = graftdb.connect(db, EngineConfig(mode="isolated", morsel_size=8192))
    with session:
        fut = session.submit(_q3(db, "1995-03-15"))
        assert fut.result() is not None
    with pytest.raises(RuntimeError, match="closed"):
        session.submit(_q3(db, "1995-03-20"))
