"""Hypothesis properties of shared hash-build state (§4.3): derivation
dedup, visibility monotonicity, extent provenance, cost-model calibration."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.descriptors import StateSignature
from repro.core.predicates import And, Cmp, Conjunction
from repro.core.state import ALL_EXTENTS, SharedHashBuildState


def _mk_state():
    sig = StateSignature("hash_build", ("t", ("k",), ("x",)))
    return SharedHashBuildState(1, sig, ("k",), ("x",), did_domain=1 << 20)


@given(
    batches=st.lists(
        st.lists(st.integers(0, 50), min_size=1, max_size=30), min_size=1, max_size=5
    ),
    qbit=st.integers(0, 3),
)
@settings(max_examples=60, deadline=None)
def test_insert_or_mark_dedups_by_derivation(batches, qbit):
    """One physical entry per derivation id, regardless of re-delivery."""
    s = _mk_state()
    mask = s.slots.mask(qbit)  # per-state slot allocation for query `qbit`
    seen = set()
    for batch in batches:
        dids = np.array(batch, np.int64)
        s.insert_or_mark(
            dids,
            dids * 2,
            {"k": dids.astype(np.float64), "x": dids.astype(np.float64)},
            np.full(len(dids), mask, np.uint64),
            np.zeros(len(dids), np.uint64),
        )
        seen |= set(batch)
    assert s.n_entries == len(seen)
    # every delivered derivation is visible to the query
    idx = np.arange(s.n_entries)
    assert s.visible_mask(qbit, idx).all()


@given(
    d1=st.integers(1, 40),
    d2=st.integers(1, 40),
)
@settings(max_examples=40, deadline=None)
def test_extent_grant_visibility_sound(d1, d2):
    """A grant over extent (x < d2) sees exactly the entries satisfying it,
    and only via provenance extents whose predicate implies the grant's
    non-retained part (here retained — direct evaluation)."""
    s = _mk_state()
    conj = Conjunction.from_pred(Cmp("x", "<", d1))
    eid = s.register_extent(conj)
    rows = np.arange(0, d1, dtype=np.int64)
    s.insert_or_mark(
        rows,
        rows,
        {"k": rows.astype(np.float64), "x": rows.astype(np.float64)},
        np.zeros(len(rows), np.uint64),
        np.full(len(rows), np.uint64(1) << np.uint64(eid), np.uint64),
    )
    s.complete_extent(eid)
    q = 7
    grant_pred = Conjunction.from_pred(Cmp("x", "<", d2))
    s.add_grant(q, ALL_EXTENTS, grant_pred)
    vis = s.visible_mask(q, np.arange(s.n_entries))
    expect = s.cols["x"].data < d2
    np.testing.assert_array_equal(vis, expect)


def test_coverage_from_completed_extents_only():
    s = _mk_state()
    c1 = Conjunction.from_pred(Cmp("x", "<", 10))
    e1 = s.register_extent(c1)
    assert not s.coverage().covers(c1)  # producer still pending
    s.complete_extent(e1)
    assert s.coverage().covers(c1)
    assert s.covers_with(c1, np.uint64(1) << np.uint64(e1))


def test_cost_model_calibration_positive():
    from repro.core.costmodel import calibrate, scaled_default

    cm = calibrate(n=1 << 16)
    assert all(v > 0 for v in cm.values())
    sd = scaled_default(100.0)
    assert abs(sd["scan"] - 100e-9) < 1e-12
