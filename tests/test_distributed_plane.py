"""Distributed relational data plane: numerical correctness on the
single-device mesh (the production-mesh lower+compile is exercised by the
dry-run's --db-plane pass, shared with tests via launch.db_plane)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.launch.mesh import make_smoke_mesh
from repro.relational.distributed import (
    FILL,
    BucketOverflowError,
    exchange_by_key,
    make_partitioned_aggregate,
    make_partitioned_join,
    pad_groups,
    pad_partition,
)


def _run_join(mesh, bk, bv, pk, pv, capacity=1024, pad_shards=None):
    """Pad + run the partitioned join; returns (out, hit, out_keys, overflow)."""
    n = pad_shards if pad_shards is not None else mesh.shape["data"]
    jbk, jbv, _ = pad_partition(bk, bv, n)
    jpk, jpv, _ = pad_partition(pk, pv, n)
    join = make_partitioned_join(mesh, bv.shape[1], pv.shape[1], capacity=capacity)
    out, hit, out_keys, overflow = join(jbk, jbv, jpk, jpv)
    return (
        np.asarray(out),
        np.asarray(hit),
        np.asarray(out_keys),
        int(overflow),
    )


def test_partitioned_join_matches_numpy():
    rng = np.random.default_rng(0)
    nb, npr = 200, 500
    bk = rng.choice(10_000, nb, replace=False).astype(np.int64)
    bv = rng.normal(size=(nb, 2)).astype(np.float32)
    pk = np.concatenate([bk[:100], rng.choice(10_000, npr - 100).astype(np.int64) + 10_000])
    pv = rng.normal(size=(npr, 3)).astype(np.float32)

    mesh = make_smoke_mesh()
    out, hit, out_keys, overflow = _run_join(mesh, bk, bv, pk, pv)
    assert overflow == 0

    # oracle
    bmap = {int(k): bv[i] for i, k in enumerate(bk)}
    expect_hits = sum(int(k) in bmap for k in pk)
    assert hit.sum() == expect_hits
    for i in np.flatnonzero(hit):
        k = int(out_keys[i])
        assert k in bmap
        np.testing.assert_allclose(out[i, 3:], bmap[k], rtol=1e-6)


def test_bucket_overflow_is_counted_never_silent():
    """Satellite: deliberately overflow a bucket — the join must REPORT the
    dropped rows through its overflow output instead of silently losing
    them (pre-fix, hit counts just shrank with no signal)."""
    bk = np.arange(64, dtype=np.int64)
    bv = np.ones((64, 1), np.float32)
    pk = np.arange(64, dtype=np.int64)
    pv = np.ones((64, 1), np.float32)
    mesh = make_smoke_mesh()
    # capacity 16 < 64 rows all hashing to the single shard: 48 build +
    # 48 probe rows overflow
    _, hit, _, overflow = _run_join(mesh, bk, bv, pk, pv, capacity=16)
    assert int(hit.sum()) < 64  # rows really did not fit
    assert overflow == 2 * (64 - 16)
    # ample capacity: nothing overflows, nothing is lost
    _, hit_ok, _, overflow_ok = _run_join(mesh, bk, bv, pk, pv, capacity=128)
    assert int(hit_ok.sum()) == 64
    assert overflow_ok == 0


def test_exchange_by_key_grows_instead_of_dropping():
    """Satellite: the host wrapper recovers every overflowed row by
    regrowing capacity, surfaces the count, and can hard-fail instead."""
    mesh = make_smoke_mesh()
    keys = np.arange(1, 101, dtype=np.int64)
    vals = keys.astype(np.float32)[:, None]
    rec = exchange_by_key(mesh, keys, vals, capacity=16)
    assert rec["bucket_overflow_rows"] > 0  # overflow happened...
    assert rec["attempts"] > 1  # ...and was recovered by regrowing
    got = np.sort(np.asarray(rec["keys"])[np.asarray(rec["valid"])])
    np.testing.assert_array_equal(got, keys)  # zero rows lost
    # payload survived with its key
    v = np.asarray(rec["values"])[np.asarray(rec["valid"])]
    np.testing.assert_allclose(np.sort(v[:, 0]), keys.astype(np.float32))
    with pytest.raises(BucketOverflowError):
        exchange_by_key(mesh, keys, vals, capacity=16, on_overflow="raise")


def test_exchange_by_key_routes_by_engine_partition():
    """dest= overrides the device hash with the engine's splitmix64
    key_partition, so exchange placement matches state-shard ownership."""
    from repro.core.hashindex import key_partition

    mesh = make_smoke_mesh()
    P = mesh.shape["data"]
    keys = np.arange(1, 257, dtype=np.int64)
    dest = key_partition(keys, P)
    rec = exchange_by_key(mesh, keys, keys.astype(np.float32)[:, None], dest=dest)
    cap = rec["capacity"]
    got_k = np.asarray(rec["keys"]).reshape(P, P * cap)
    got_ok = np.asarray(rec["valid"]).reshape(P, P * cap)
    for p in range(P):
        np.testing.assert_array_equal(
            np.sort(got_k[p][got_ok[p]]), np.sort(keys[dest == p])
        )


@pytest.mark.parametrize("pad_shards", [1, 2, 3, 5, 8])
def test_pad_partition_round_trip_exact(pad_shards):
    """Satellite: padding rows carry the FILL sentinel and every shard-local
    consumer masks them — join results are identical for ANY padding
    factor (property over n_shards that force padding)."""
    rng = np.random.default_rng(3)
    bk = rng.choice(5_000, 150, replace=False).astype(np.int64)
    bv = rng.normal(size=(150, 2)).astype(np.float32)
    pk = np.concatenate([bk[:70], rng.choice(5_000, 30).astype(np.int64) + 5_000])
    pv = rng.normal(size=(100, 3)).astype(np.float32)
    mesh = make_smoke_mesh()
    out, hit, out_keys, overflow = _run_join(
        mesh, bk, bv, pk, pv, pad_shards=pad_shards
    )
    assert overflow == 0
    bmap = {int(k): bv[i] for i, k in enumerate(bk)}
    assert int(hit.sum()) == 70  # padding contributed zero phantom hits
    for i in np.flatnonzero(hit):
        np.testing.assert_allclose(out[i, 3:], bmap[int(out_keys[i])], rtol=1e-6)


@pytest.mark.parametrize("pad_shards", [1, 3, 7])
def test_pad_groups_round_trip_exact(pad_shards):
    """Satellite: aggregate padding carries the gid=-1 sentinel, masked
    shard-locally — totals identical for any padding factor."""
    rng = np.random.default_rng(4)
    n, g, w = 1000, 16, 4
    gids = rng.integers(0, g, n).astype(np.int64)
    vals = rng.normal(size=(n, w)).astype(np.float32)
    mesh = make_smoke_mesh()
    agg = make_partitioned_aggregate(mesh, g, w)
    gp, vp = pad_groups(gids, vals, pad_shards)
    assert gp.shape[0] % pad_shards == 0
    got = np.asarray(agg(gp, vp))
    want = np.zeros((g, w), np.float32)
    np.add.at(want, gids, vals)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_partitioned_aggregate_matches_segment_sum():
    rng = np.random.default_rng(2)
    n, g, w = 1000, 16, 4
    gids = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=(n, w)).astype(np.float32)
    mesh = make_smoke_mesh()
    agg = make_partitioned_aggregate(mesh, g, w)
    gp, vp = pad_groups(gids, vals, mesh.shape["data"])
    got = np.asarray(agg(gp, vp))
    want = np.zeros((g, w), np.float32)
    np.add.at(want, gids, vals)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
