"""Distributed relational data plane: numerical correctness on the
single-device mesh (the production-mesh lower+compile is exercised by the
dry-run's --db-plane pass)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.launch.mesh import make_smoke_mesh
from repro.relational.distributed import (
    FILL,
    make_partitioned_aggregate,
    make_partitioned_join,
    pad_partition,
)


def test_partitioned_join_matches_numpy():
    rng = np.random.default_rng(0)
    nb, npr = 200, 500
    bk = rng.choice(10_000, nb, replace=False).astype(np.int64)
    bv = rng.normal(size=(nb, 2)).astype(np.float32)
    pk = np.concatenate([bk[:100], rng.choice(10_000, npr - 100).astype(np.int64) + 10_000])
    pv = rng.normal(size=(npr, 3)).astype(np.float32)

    mesh = make_smoke_mesh()
    jbk, jbv = pad_partition(bk, bv, mesh.shape["data"])
    jpk, jpv = pad_partition(pk, pv, mesh.shape["data"])
    join = make_partitioned_join(mesh, 2, 3, capacity=1024)
    out, hit, out_keys = join(jbk, jbv, jpk, jpv)
    out, hit, out_keys = np.asarray(out), np.asarray(hit), np.asarray(out_keys)

    # oracle
    bmap = {int(k): bv[i] for i, k in enumerate(bk)}
    expect_hits = sum(int(k) in bmap for k in pk)
    assert hit.sum() == expect_hits
    for i in np.flatnonzero(hit):
        k = int(out_keys[i])
        assert k in bmap
        np.testing.assert_allclose(out[i, 3:], bmap[k], rtol=1e-6)


def test_partitioned_join_capacity_drop_is_detectable():
    """Overflowing a bucket drops rows (documented static-capacity knob);
    with ample capacity no probe row is lost."""
    rng = np.random.default_rng(1)
    bk = np.arange(64, dtype=np.int64)
    bv = np.ones((64, 1), np.float32)
    pk = np.arange(64, dtype=np.int64)
    pv = np.ones((64, 1), np.float32)
    mesh = make_smoke_mesh()
    jbk, jbv = pad_partition(bk, bv, 1)
    jpk, jpv = pad_partition(pk, pv, 1)
    join = make_partitioned_join(mesh, 1, 1, capacity=128)
    _, hit, _ = join(jbk, jbv, jpk, jpv)
    assert int(np.asarray(hit).sum()) == 64


def test_partitioned_aggregate_matches_segment_sum():
    rng = np.random.default_rng(2)
    n, g, w = 1000, 16, 4
    gids = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=(n, w)).astype(np.float32)
    mesh = make_smoke_mesh()
    agg = make_partitioned_aggregate(mesh, g, w)
    per = -(-n // mesh.shape["data"]) * mesh.shape["data"]
    gp = np.zeros(per, np.int32)
    vp = np.zeros((per, w), np.float32)
    gp[:n] = gids
    vp[:n] = vals
    got = np.asarray(agg(jnp.asarray(gp), jnp.asarray(vp)))
    want = np.zeros((g, w), np.float32)
    np.add.at(want, gids, vals)
    # padding rows land in group 0 with zero values -> no effect
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
