"""Mesh-sharded shared state (DESIGN.md §14): the smoke-mesh session is
bit-identical to the mesh-less 1×1 oracle in every mode, the config layer
pins partitions = workers = data-axis size, the per-device state views and
the real exchange validation hold, and the db-plane dry-run record
validates on the smoke mesh. Multi-device parity (2/4/8 host devices) runs
in benchmarks/mesh_sweep.py — jax pins the device count at first init, so
tier-1 stays on the single real device."""

import numpy as np
import pytest

import graftdb
from graftdb import EngineConfig
from repro.launch.mesh import make_smoke_mesh, mesh_data_size, resolve_mesh
from repro.relational import queries

ALL_MODES = ["isolated", "scan_sharing", "qpipe_osp", "residual", "graft"]


def _workload(db, n=6, seed=123, spacing=0.001):
    rng = np.random.default_rng(seed)
    return [queries.sample_query(db, rng, arrival=i * spacing) for i in range(n)]


def _run(db, qs, **cfg):
    session = graftdb.connect(db, EngineConfig(morsel_size=4096, **cfg))
    futs = session.submit_all(qs)
    session.run()
    return session, [f.result() for f in futs]


def _assert_bit_identical(ra, rb, ctx=""):
    assert set(ra) == set(rb), ctx
    for k in ra:
        np.testing.assert_array_equal(
            np.asarray(ra[k]), np.asarray(rb[k]), err_msg=f"{ctx}/{k}"
        )


# ---------------------------------------------------------------------------
# Parity: smoke-mesh session vs the mesh-less 1×1 oracle, all five modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ALL_MODES)
def test_smoke_mesh_bit_identical_to_oracle(db, mode):
    _, r1 = _run(db, _workload(db), mode=mode, workers=1, partitions=1)
    sm, r2 = _run(db, _workload(db), mode=mode, mesh="smoke")
    for a, b in zip(r1, r2):
        _assert_bit_identical(a, b, ctx=mode)
    assert sm.engine.n_partitions == 1
    assert sm.stats()["mesh_data_shards"] == 1


def test_smoke_mesh_clock_identical_to_oracle(db):
    s1, _ = _run(db, _workload(db), mode="graft", workers=1, partitions=1)
    s2, _ = _run(db, _workload(db), mode="graft", mesh="smoke")
    # virtual completion clocks are part of the §14 determinism contract
    assert s1.now == s2.now


# ---------------------------------------------------------------------------
# Config layer: mesh spec resolution + partition/worker pinning
# ---------------------------------------------------------------------------


def test_mesh_config_pins_partitions_and_workers():
    cfg = EngineConfig(mesh=4)
    assert cfg.partitions == 4 and cfg.workers == 4
    cfg = EngineConfig(mesh="smoke")
    assert cfg.partitions == 1 and cfg.workers == 1
    # explicit matching values are fine
    cfg = EngineConfig(mesh=2, partitions=2, workers=2)
    assert cfg.partitions == 2


def test_mesh_config_rejects_mismatch_and_bad_specs():
    with pytest.raises(ValueError, match="partitions"):
        EngineConfig(mesh=4, partitions=3)
    with pytest.raises(ValueError, match="workers"):
        EngineConfig(mesh=4, workers=3)
    with pytest.raises(ValueError):
        EngineConfig(mesh="nope")
    with pytest.raises(ValueError):
        EngineConfig(mesh=0)
    with pytest.raises(ValueError):
        EngineConfig(mesh=True)
    with pytest.raises(ValueError, match="clock"):
        EngineConfig(mesh=2, clock="wall")


def test_resolve_mesh_layer():
    assert mesh_data_size("smoke") == 1
    assert mesh_data_size(8) == 8
    mesh = resolve_mesh("smoke")
    assert mesh.shape["data"] == 1
    assert mesh_data_size(mesh) == 1
    with pytest.raises(ValueError):
        resolve_mesh(None)


# ---------------------------------------------------------------------------
# Per-device state views + the real exchange on the session mesh
# ---------------------------------------------------------------------------


def test_mesh_stats_and_device_layout(db):
    # retention='epoch' keeps retired states resident so the per-device
    # layout is inspectable after the trace drains
    sm, _ = _run(db, _workload(db), mode="graft", mesh="smoke", retention="epoch")
    st = sm.mesh_stats()
    assert st["data_shards"] == 1
    assert len(st["devices"]) == 1
    assert st["mesh_exchange_rows"] == 0  # single shard: no exchange modeled
    assert st["bucket_overflow_rows"] == 0
    layouts = st["states"]
    assert layouts, "graft run must leave shared build state behind"
    for lay in layouts:
        assert lay["n_shards"] == 1
        assert len(lay["entries_by_device"]) == 1
        assert sum(lay["entries_by_device"]) > 0
        assert len(lay["bytes_by_device"]) == 1
        # replicated control plane: every extent frontier committed fully
        for done, total in lay["extent_frontiers"].values():
            assert done == total


def test_state_shard_views_partition_everything(db):
    sm, _ = _run(db, _workload(db), mode="graft", mesh="smoke", retention="epoch")
    states = [s for sts in sm.engine.state_index.values() for s in sts]
    states += [
        s
        for s in sm.engine.lifecycle.retired.values()
        if hasattr(s, "shard_entry_counts")
    ]
    assert states
    for st_ in states:
        counts = st_.shard_entry_counts(4)
        assert counts.sum() == len(st_.keycode.data)
        fr = st_.device_frontiers()
        assert set(fr) == set(st_.extents)
        for eid, (done, total) in fr.items():
            assert (done, total) == st_.extent_partition_frontier(eid)


def test_validate_mesh_plane_round_trips(db):
    sm, _ = _run(db, _workload(db), mode="graft", mesh="smoke")
    rec = sm.validate_mesh_plane(sample_rows=512)
    assert rec["data_shards"] == 1
    assert rec["rows"] > 0
    assert rec["rows_lost"] == 0
    assert rec["rows_placed"] == rec["rows"]
    assert rec["routing_matches_state_shards"] is True


def test_mesh_explain_accounting_per_shard(db):
    """EXPLAIN GRAFT accounting is preserved exactly per shard:
    represented + residual + unattached == demand on every device."""
    qs = _workload(db, n=4)
    session = graftdb.connect(db, EngineConfig(mode="graft", mesh="smoke"))
    futs = session.submit_all(qs[:3])
    session.run()
    ex = session.explain_graft(qs[3])
    for pt in ex.partition_totals():
        assert (
            pt["represented"] + pt["residual"] + pt["unattached"] == pt["demand"]
        )
    assert (
        ex.represented_rows + ex.residual_rows + ex.unattached_rows
        == ex.total_demand_rows
    )


# ---------------------------------------------------------------------------
# db-plane dry-run record on the smoke mesh (satellite: promoted function)
# ---------------------------------------------------------------------------


def test_db_plane_record_validates_on_smoke_mesh():
    from repro.launch.db_plane import db_plane_record, validate_db_plane_record

    rec = db_plane_record(make_smoke_mesh(), rows=1 << 12, chain_rows=512)
    validate_db_plane_record(rec)  # raises on any structural problem
    assert rec["status"] == "ok"
    assert rec["data_shards"] == 1
    assert rec["chain"]["parity"] is True
    assert rec["chain"]["matched_rows"] > 0
    assert rec["hlo_stats"]["mem_bytes_per_device"] > 0


def test_db_plane_validator_rejects_broken_records():
    from repro.launch.db_plane import db_plane_record, validate_db_plane_record

    rec = db_plane_record(make_smoke_mesh(), rows=1 << 12, chain_rows=512)
    bad = dict(rec)
    bad["status"] = "fail"
    with pytest.raises(ValueError, match="failed"):
        validate_db_plane_record(bad)
    bad = dict(rec)
    del bad["hlo_stats"]
    with pytest.raises(ValueError, match="missing"):
        validate_db_plane_record(bad)
    bad = dict(rec)
    bad["chain"] = {"parity": False}
    with pytest.raises(ValueError, match="bit-identical"):
        validate_db_plane_record(bad)


def test_sharded_chain_launch_parity_on_smoke_mesh():
    """chain_launch(mesh=...) wraps the identical kernel in shard_map;
    on the smoke mesh every output is bit-identical to the plain launch."""
    from repro.launch.db_plane import _chain_parity

    block = _chain_parity(make_smoke_mesh(), rows=1024)
    assert block["parity"] is True
    assert block["matched_rows"] > 0
