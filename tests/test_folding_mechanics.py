"""Mechanism-level tests: represented/residual extents, gates, retention,
aggregate identity, QPipe-OSP window, and Algorithm-2 invariants.

These scenarios pin arrival offsets in virtual time (mid-flight overlap,
OSP windows). They run under the partition-parallel pool — the session is
built on an explicit per-worker ``WorkClock`` factory honoring
``$GRAFTDB_TEST_WORKERS`` — with the offsets scaled by the pool speedup
(``_t``): an N-worker pool finishes the same work in ~1/N virtual time, so
a mid-flight instant at workers=1 stays mid-flight at workers=N."""

import numpy as np

import graftdb
from graftdb import EngineConfig
from repro.core.dag import check_invariants, snapshot
from repro.core.scheduler import WorkClock, extract_ready_fragments
from repro.relational import queries
from repro.relational.table import days

# pool geometry under test: the CI matrix leg sets GRAFTDB_TEST_WORKERS=4
POOL = EngineConfig().workers


def _t(base: float) -> float:
    """Scale a single-worker arrival offset to the pool's virtual time."""
    return base / POOL


def _q3(db, date, seg=1.0, arrival=0.0):
    return queries.make_query(db, "q3", {"segment": seg, "date": float(days(date))}, arrival)


def _run(db, qs, mode, morsel=4096, invariant_checks=False):
    # explicit WorkClock fixture: one fresh virtual clock per worker, so the
    # timing-pinned scenarios replay deterministically at any pool size
    session = graftdb.connect(
        db,
        EngineConfig(
            mode=mode, morsel_size=morsel, clock=WorkClock, workers=POOL, partitions=POOL
        ),
    )
    eng = session.engine  # mechanism tests observe the internal layer
    if invariant_checks:
        orig = eng.check_activations

        def checked():
            orig()
            errs = check_invariants(eng)
            assert not errs, errs

        eng.check_activations = checked
    session.submit_all(qs)
    done = session.run()
    return eng, done


def test_represented_extent_on_midflight_arrival(db_mid):
    """Q_B (broader) arriving while Q_A's order-side state is live must
    observe a represented extent and register residual production (Fig.3)."""
    qa = _q3(db_mid, "1995-03-15")
    qb = _q3(db_mid, "1995-03-20", arrival=_t(0.02))
    eng, _ = _run(db_mid, [qa, qb], "graft")
    c = eng.counters
    assert c["represented_rows"] > 0, "no represented-extent observation"
    assert c["residual_build_rows"] > 0, "no residual production"


def test_narrower_arrival_fully_covered(db_mid):
    """Q_B narrower than live coverage: fully represented, zero residual at
    the order-side boundary (customer state also covered)."""
    qa = _q3(db_mid, "1995-03-20")
    qb = _q3(db_mid, "1995-03-10", arrival=_t(0.04))
    eng, done = _run(db_mid, [qa, qb], "graft")
    assert eng.counters["represented_rows"] > 0


def test_no_sharing_after_release(db_mid):
    """Retention: states released at zero refs — a later non-overlapping
    arrival rebuilds from scratch (paper §6.1)."""
    qa = _q3(db_mid, "1995-03-15")
    qb = _q3(db_mid, "1995-03-20", arrival=10.0)  # long after A completes
    eng, _ = _run(db_mid, [qa, qb], "graft")
    assert eng.counters["represented_rows"] == 0
    assert eng.counters["residual_build_rows"] == 0


def test_aggregate_identity_sharing(db_mid):
    """Exact duplicate instances share one aggregate state (§4.5)."""
    qa = _q3(db_mid, "1995-03-15")
    qb = _q3(db_mid, "1995-03-15", arrival=_t(0.01))  # exact duplicate, overlapping
    eng, done = _run(db_mid, [qa, qb], "graft")
    assert eng.counters.get("agg_attaches", 0) >= 1
    a, b = done[0].result(), done[1].result()
    for k in a:
        np.testing.assert_allclose(np.sort(a[k]), np.sort(b[k]))


def test_qpipe_window_closes(db_mid):
    """QPipe-OSP merges identical profiles only at zero progress."""
    qa = _q3(db_mid, "1995-03-15")
    qb = _q3(db_mid, "1995-03-15", arrival=0.0)
    eng, _ = _run(db_mid, [qa, qb], "qpipe_osp")
    assert eng.counters.get("qpipe_merges", 0) > 0 or eng.counters.get("agg_attaches", 0) > 0
    # delayed identical arrival -> window closed, no merge
    qa = _q3(db_mid, "1995-03-15")
    qb = _q3(db_mid, "1995-03-15", arrival=_t(0.05))
    eng, _ = _run(db_mid, [qa, qb], "qpipe_osp")
    assert eng.counters.get("qpipe_merges", 0) == 0


def test_algorithm2_invariants_throughout(db):
    rng = np.random.default_rng(17)
    qs = [queries.sample_query(db, rng, arrival=_t(i * 0.001)) for i in range(6)]
    _run(db, qs, "graft", invariant_checks=True)


def test_dag_snapshot_shapes(db):
    qa = _q3(db, "1995-03-15")
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=4096))
    session.submit(qa)  # arrival 0 <= now: grafted onto the shared DAG now
    eng = session.engine
    snap = snapshot(eng)
    kinds = {n.kind for n in snap.nodes}
    assert "scan" in kinds and "pipeline" in kinds and "state" in kinds
    assert snap.state_ref_edges, "state-ref edges missing"
    frags = extract_ready_fragments(eng)
    assert frags, "no ready fragments after submit"
    session.run()


def test_scan_sharing_counts_io_once(db_mid):
    """Two concurrent Q1 instances share the lineitem scan: scan_rows must
    be well below 2x the isolated run."""
    rng = np.random.default_rng(3)
    mk = lambda arr: queries.make_query(db_mid, "q1", {"delta": 90}, arrival=arr)
    eng_iso, _ = _run(db_mid, [mk(0.0), mk(0.0)], "isolated")
    eng_share, _ = _run(db_mid, [mk(0.0), mk(0.0)], "scan_sharing")
    assert eng_share.counters["scan_rows"] < 0.6 * eng_iso.counters["scan_rows"]
