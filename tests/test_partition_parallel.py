"""Partition-parallel execution core (DESIGN.md §9): parity of the sharded
scan/state/scheduler stack against the 1×1 oracle across every mode,
determinism of partial-aggregate merges under permuted interleavings,
sharded-state index parity, worker-pool scheduling/utilization, the
per-partition EXPLAIN GRAFT accounting, and the WallClock sleep cap."""

import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import graftdb
from graftdb import EngineConfig
from repro.core.descriptors import StateSignature
from repro.core.plans import AggSpec
from repro.core.runtime import ScanNode
from repro.core.scheduler import WallClock
from repro.core.state import SharedAggregateState, SharedHashBuildState
from repro.relational import queries, refexec
from repro.relational.table import days

ALL_MODES = ["isolated", "scan_sharing", "qpipe_osp", "residual", "graft"]


def _workload(db, n=6, seed=123, spacing=0.001):
    rng = np.random.default_rng(seed)
    return [queries.sample_query(db, rng, arrival=i * spacing) for i in range(n)]


def _run(db, mode, workers, partitions, qs, morsel=4096):
    session = graftdb.connect(
        db,
        EngineConfig(mode=mode, morsel_size=morsel, workers=workers, partitions=partitions),
    )
    futs = session.submit_all(qs)
    session.run()
    return session, futs


def _canon(res):
    """Canonical row order: lexsort over all columns (group order is
    partition-merge order under P > 1, which is not the oracle's)."""
    keys = sorted(res)
    order = np.lexsort([res[k] for k in keys])
    return {k: np.asarray(res[k])[order] for k in keys}


def assert_results_match(ra, rb, ctx=""):
    """Element-wise identity after canonical row ordering. Keys, counts,
    min/max merge exactly; sum/avg accumulate per-partition partials, so
    they are compared at 1-ulp-scale tolerance (reassociation only)."""
    ca, cb = _canon(ra), _canon(rb)
    assert set(ca) == set(cb), ctx
    for k in ca:
        assert ca[k].shape == cb[k].shape, (ctx, k)
        np.testing.assert_allclose(ca[k], cb[k], rtol=1e-12, atol=1e-12, err_msg=f"{ctx}/{k}")


# ---------------------------------------------------------------------------
# Parity: workers>1, partitions>1 vs the 1×1 oracle, all five modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ALL_MODES)
def test_partition_parity_vs_1x1_oracle(db, mode):
    qs1 = _workload(db)
    _, f1 = _run(db, mode, 1, 1, qs1)
    qs2 = _workload(db)  # fresh Query objects (qids are unique per build)
    _, f2 = _run(db, mode, 4, 8, qs2)
    for a, b, q in zip(f1, f2, qs1):
        assert_results_match(a.result(), b.result(), ctx=f"{mode}/q{q.template}")
        # and both agree with the reference executor
        assert_results_match(b.result(), refexec.execute(db, q.plan), ctx=f"{mode}/ref")


@given(workers=st.integers(1, 5), partitions=st.integers(1, 9), seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_partition_parity_property(db, workers, partitions, seed):
    """Any (workers, partitions) grid point reproduces the 1×1 oracle."""
    qs1 = _workload(db, n=4, seed=seed)
    _, f1 = _run(db, "graft", 1, 1, qs1)
    qs2 = _workload(db, n=4, seed=seed)
    _, f2 = _run(db, "graft", workers, partitions, qs2)
    for a, b in zip(f1, f2):
        assert_results_match(a.result(), b.result(), ctx=f"w{workers}p{partitions}s{seed}")


def test_run_is_deterministic(db):
    """The pool is a deterministic simulation: identical configs produce
    bit-identical latencies, timestamps, and counters."""
    runs = []
    for _ in range(2):
        s, futs = _run(db, "graft", 3, 5, _workload(db))
        runs.append(
            (
                [f.latency() for f in futs],
                [f.stats()["t_complete"] for f in futs],
                {k: v for k, v in s.counters.items()},
            )
        )
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# Open-loop stress: queued admissions stay deterministic under permutation
# ---------------------------------------------------------------------------

OVERLOAD_CFG = dict(
    mode="graft",
    morsel_size=4096,
    retention="epoch",
    memory_budget=300_000,
    admission="adaptive",
    admission_max_inflight=2,
    admission_share_threshold=0.4,
)


@pytest.mark.parametrize("workers,partitions", [(1, 1), (4, 4)])
def test_open_loop_permuted_arrival_determinism(db, workers, partitions):
    """§10 extension of the determinism grid to queued admissions: one fixed
    arrival trace submitted in permuted orders (the arrival heap keys on
    (arrival, qid)) produces bit-identical results, latencies, admission
    decisions, and counters — through the full retention + admission path,
    including same-instant arrival ties."""
    rng = np.random.default_rng(77)
    n = 8
    # arrival times with deliberate ties (same-instant bursts)
    offsets = [0.01, 0.01, 0.013, 0.02, 0.02, 0.02, 0.05, 0.08]
    runs = []
    for perm_seed in (None, 1, 2):
        qs = _workload(db, n=n, seed=777, spacing=0.0)
        for q, t in zip(qs, offsets):
            q.arrival = t
        order = list(range(n))
        if perm_seed is not None:
            order = list(np.random.default_rng(perm_seed).permutation(n))
        session = graftdb.connect(
            db,
            EngineConfig(workers=workers, partitions=partitions, **OVERLOAD_CFG),
        )
        futs = [None] * n
        for i in order:
            futs[i] = session.submit(qs[i])
        session.run()
        decisions = [
            (session._runner.admission_log.get(q.qid) or {}).get("decision")
            for q in qs
        ]
        delays = [
            round((session._runner.admission_log.get(q.qid) or {}).get("queue_delay_s", 0.0), 12)
            for q in qs
        ]
        runs.append(
            (
                [round(f.latency(), 12) for f in futs],
                decisions,
                delays,
                {k: v for k, v in session.counters.items()},
                [tuple(np.asarray(v).tolist() for _, v in sorted(f.result().items())) for f in futs],
            )
        )
    for other in runs[1:]:
        assert other[0] == runs[0][0], "latencies differ across submission orders"
        assert other[1] == runs[0][1], "admission decisions differ"
        assert other[2] == runs[0][2], "queue delays differ"
        assert other[3] == runs[0][3], "counters differ"
        assert other[4] == runs[0][4], "results differ"


# ---------------------------------------------------------------------------
# Deterministic partial-aggregate merge under permuted worker interleavings
# ---------------------------------------------------------------------------


def _mk_agg(n_partitions):
    aggs = (
        AggSpec("sum", None, name="s"),
        AggSpec("count", None, name="c"),
        AggSpec("min", None, name="lo"),
        AggSpec("max", None, name="hi"),
        AggSpec("avg", None, name="m"),
        AggSpec("count", None, distinct=True, name="d"),
    )
    return SharedAggregateState(1, None, ("g",), aggs, n_partitions=n_partitions)


def _agg_streams(n_parts, n_batches=6, seed=0):
    """Fixed per-partition update streams (what the scan shards deliver)."""
    rng = np.random.default_rng(seed)
    streams = []
    for p in range(n_parts):
        batches = []
        for _ in range(n_batches):
            n = int(rng.integers(5, 40))
            g = rng.integers(0, 7, n).astype(np.float64)
            v = rng.normal(size=n)
            batches.append((p, [g], n, v))
        streams.append(batches)
    return streams


def _feed(state, order, streams):
    cursors = [0] * len(streams)
    for p in order:
        part, keys, n, v = streams[p][cursors[p]]
        cursors[p] += 1
        vals = [v, v, v, v, v, np.round(v, 1)]
        state.update(keys, vals, n, part=part)


def test_merge_determinism_under_permuted_interleavings():
    """The same per-partition streams, delivered in any cross-partition
    interleaving (= any worker schedule), merge to bit-identical results —
    including count(distinct), whose seen-pairs dedup globally."""
    P, B = 4, 6
    streams = _agg_streams(P, B)
    round_robin = [p for _ in range(B) for p in range(P)]
    reversed_rr = [p for _ in range(B) for p in reversed(range(P))]
    rng = np.random.default_rng(42)
    shuffled = list(round_robin)
    # permute while preserving each partition's internal order
    order = np.argsort(rng.random(len(shuffled)), kind="stable")
    shuffled = [x for _, x in sorted(zip(order, shuffled), key=lambda t: t[0])]
    results = []
    for order_ in (round_robin, reversed_rr, shuffled):
        st_ = _mk_agg(P)
        _feed(st_, order_, streams)
        results.append(st_.result())
    for other in results[1:]:
        assert set(other) == set(results[0])
        for k in results[0]:
            a, b = _canon(results[0]), _canon(other)
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_partitioned_distinct_counts_match_unpartitioned():
    """count(distinct) with global seen-pair dedup: P partials agree with
    the single-stream oracle exactly."""
    P = 3
    streams = _agg_streams(P, 4, seed=9)
    order = [p for _ in range(4) for p in range(P)]
    sp = _mk_agg(P)
    _feed(sp, order, streams)
    s1 = _mk_agg(1)
    # oracle: same rows, single partition, same delivery order
    cursors = [0] * P
    for p in order:
        part, keys, n, v = streams[p][cursors[p]]
        cursors[p] += 1
        vals = [v, v, v, v, v, np.round(v, 1)]
        s1.update(keys, vals, n, part=0)
    a, b = _canon(sp.result()), _canon(s1.result())
    for k in ("g", "c", "d", "lo", "hi"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    np.testing.assert_allclose(a["s"], b["s"], rtol=1e-12)


# ---------------------------------------------------------------------------
# Sharded hash-build state: storage is partition-independent, probes exact
# ---------------------------------------------------------------------------


def _fill_state(state, rng):
    for _ in range(8):
        n = int(rng.integers(10, 200))
        dids = rng.integers(0, 500, n)
        # a derivation always carries one keycode (did -> row -> build key),
        # the invariant key-hash did-sharding relies on; % keeps plenty of
        # duplicate keys across and within batches (multi-match states)
        kc = dids % 97
        state.insert_or_mark(
            dids,
            kc,
            {"k": kc.astype(float), "x": dids.astype(float)},
            rng.integers(1, 4, n).astype(np.uint64),
            rng.integers(0, 2, n).astype(np.uint64),
        )


@pytest.mark.parametrize("n_partitions", [2, 5, 8])
def test_hash_state_shard_parity(n_partitions):
    """P-sharded did/probe indexes leave the SoA bit-identical to P=1 and
    return byte-identical probe match pairs (probe-row-major, entries in
    insertion order), including multi-match keys."""
    sig = StateSignature("hash_build", ("t", ("k",), ("x",)))
    s1 = SharedHashBuildState(1, sig, ("k",), ("x",))
    sp = SharedHashBuildState(2, sig, ("k",), ("x",), n_partitions=n_partitions)
    _fill_state(s1, np.random.default_rng(3))
    _fill_state(sp, np.random.default_rng(3))
    np.testing.assert_array_equal(s1.did.data, sp.did.data)
    np.testing.assert_array_equal(s1.keycode.data, sp.keycode.data)
    np.testing.assert_array_equal(s1.vis.data, sp.vis.data)
    np.testing.assert_array_equal(s1.emask.data, sp.emask.data)
    assert (s1.rows_inserted, s1.rows_marked) == (sp.rows_inserted, sp.rows_marked)
    rng = np.random.default_rng(11)
    for _ in range(4):
        pk = rng.integers(-5, 120, int(rng.integers(1, 300)))
        p1, e1 = s1.probe(pk)
        p2, e2 = sp.probe(pk)
        np.testing.assert_array_equal(p1, p2)
        np.testing.assert_array_equal(e1, e2)
        # interleave growth with probing (lazy index sync under sharding)
        _fill_state(s1, np.random.default_rng(77))
        _fill_state(sp, np.random.default_rng(77))


# ---------------------------------------------------------------------------
# Worker pool: utilization stats, modeled speedup, scan shard geometry
# ---------------------------------------------------------------------------


def test_worker_utilization_stats(db):
    s, futs = _run(db, "graft", 4, 8, _workload(db))
    w = s.worker_stats()
    assert w["n"] == 4 and len(w["busy_s"]) == 4 and len(w["utilization"]) == 4
    assert all(b > 0 for b in w["busy_s"])  # every worker executed units
    assert all(0.0 <= u <= 1.0 + 1e-9 for u in w["utilization"])
    assert w["makespan_s"] == pytest.approx(s.now)
    # futures surface the same block
    assert futs[0].stats()["workers"]["n"] == 4


def test_modeled_speedup_at_4_workers(db):
    """The pool models real parallel speedup: 4×8 must finish the same
    graft workload in well under half the 1×1 virtual makespan."""
    s1, _ = _run(db, "graft", 1, 1, _workload(db, n=8, seed=5))
    s4, _ = _run(db, "graft", 4, 8, _workload(db, n=8, seed=5))
    assert s4.now < 0.6 * s1.now, (s1.now, s4.now)


def test_scan_partitions_cover_cycle(db):
    node = ScanNode(1, db["lineitem"], 1024, n_partitions=5)
    assert node.part_counts.sum() == node.n_morsels
    assert (node.part_counts > 0).all()
    assert node.part_starts[0] == 0
    assert (np.diff(node.part_starts) == node.part_counts[:-1]).all()
    # more partitions than morsels: clamped, never empty shards
    tiny = ScanNode(2, db["nation"], 1 << 20, n_partitions=16)
    assert tiny.n_partitions == tiny.n_morsels == 1


def test_partitions_default_to_workers(db):
    cfg = EngineConfig(workers=3)
    assert cfg.n_partitions == 3
    assert EngineConfig(workers=3, partitions=7).n_partitions == 7
    with pytest.raises(ValueError):
        EngineConfig(workers=0)
    with pytest.raises(ValueError):
        EngineConfig(partitions=-2)
    # the pool needs virtual clocks: name, class, and instance all rejected
    with pytest.raises(ValueError):
        EngineConfig(workers=2, clock="wall")
    with pytest.raises(ValueError):
        EngineConfig(workers=2, clock=WallClock)
    with pytest.raises(ValueError):
        EngineConfig(workers=2, clock=WallClock())


def test_env_default_workers_downgrade_on_wall_clock(monkeypatch):
    """GRAFTDB_TEST_WORKERS is a *default*: wall-clock configs silently
    stay single-worker instead of failing scripts that never asked for a
    pool; explicitly conflicting requests still raise."""
    monkeypatch.setenv("GRAFTDB_TEST_WORKERS", "4")
    cfg = EngineConfig(clock="wall")
    assert cfg.workers == 1
    assert EngineConfig(clock="work").workers == 4
    with pytest.raises(ValueError):
        EngineConfig(workers=2, clock="wall")  # explicit: still an error


def test_gate_partition_frontier_progresses(db_mid):
    """The per-partition visibility frontier (§9): a consumer's gate
    reports producer scan-shard delivery while closed, and the DAG
    snapshot surfaces it on state-ref edges."""
    from repro.core.dag import snapshot

    session = graftdb.connect(
        db_mid, EngineConfig(mode="graft", morsel_size=4096, workers=1, partitions=4)
    )
    q = queries.make_query(
        db_mid, "q3", {"segment": 1.0, "date": float(days("1995-03-15"))}, 0.0
    )
    session.submit(q)
    eng = session.engine
    # drive the engine unit by unit and watch a closed gate's frontier
    # advance toward (total, total)
    from repro.core.scheduler import extract_ready_units

    main_member = next(m for h in eng.handles.values() for m in h.members if m.kind == "main")
    gate = main_member.gates[0]
    assert not gate.open()
    seen = set()
    for _ in range(2000):
        units = extract_ready_units(eng)
        if not units or gate.open():
            break
        node, part = units[0]
        node.advance(eng, part)
        eng.check_activations()
        seen.add(gate.partition_frontier())
    done_totals = sorted(seen)
    assert len(done_totals) > 1, "frontier never progressed"
    assert all(d <= t for d, t in done_totals)
    # snapshot surfaces the frontier tuple on every state-ref edge
    snap = snapshot(eng)
    assert snap.state_ref_edges
    for _, _, _, gate_open, frontier in snap.state_ref_edges:
        d, t = frontier
        assert 0 <= d <= t
    session.run()


# ---------------------------------------------------------------------------
# Per-partition EXPLAIN GRAFT accounting
# ---------------------------------------------------------------------------


def test_explain_partition_splits_sum_to_demand(db_mid):
    """Per-partition represented/residual splits partition each boundary's
    isolated-plan demand exactly (workers=1 keeps the overlap offset valid;
    partitions>1 shards the accounting)."""
    session = graftdb.connect(
        db_mid,
        EngineConfig(
            mode="graft", morsel_size=4096, workers=1, partitions=4, capture_explain=True
        ),
    )
    qa = queries.make_query(
        db_mid, "q3", {"segment": 1.0, "date": float(days("1995-03-15"))}, 0.0
    )
    qb = queries.make_query(
        db_mid, "q3", {"segment": 1.0, "date": float(days("1995-03-20"))}, 0.02
    )
    fa, fb = session.submit_all([qa, qb])
    session.run()
    for fut in (fa, fb):
        exp = fut.explain()
        for b in [x for root in exp.boundaries for x in root.flat()]:
            assert len(b.part_demand_rows) == 4
            assert sum(b.part_demand_rows) == b.demand_rows
            for p in range(4):
                assert (
                    b.part_represented_rows[p]
                    + b.part_residual_rows[p]
                    + b.part_unattached_rows[p]
                    == b.part_demand_rows[p]
                ), (b, p)
        totals = exp.partition_totals()
        assert sum(r["demand_rows"] for r in totals) == exp.total_demand_rows
        assert sum(r["represented_rows"] for r in totals) == exp.represented_rows
        d = exp.to_dict()
        assert d["partition_totals"] == totals
    assert fb.explain().represented_rows > 0  # the overlap did graft


# ---------------------------------------------------------------------------
# WallClock sleep cap (virtual-dominant traces must not block)
# ---------------------------------------------------------------------------


def test_wallclock_caps_long_sleeps():
    clk = WallClock(max_sleep_s=0.02)
    target = clk.now + 5.0
    t0 = time.perf_counter()
    clk.advance_to(target)
    assert time.perf_counter() - t0 < 1.0  # capped: no 5s block
    assert clk.now >= target  # the remainder was skipped virtually
    # short gaps still sleep for real (clock stays near real time)
    t1 = time.perf_counter()
    clk.advance_to(clk.now + 0.01)
    assert 0.005 < time.perf_counter() - t1 < 0.5


def test_wallclock_uncapped_still_sleeps():
    clk = WallClock()
    t0 = time.perf_counter()
    clk.advance_to(clk.now + 0.02)
    assert time.perf_counter() - t0 >= 0.015


def test_wall_sessions_use_configured_cap(db):
    # wall clocks are single-worker by validation (pin against the
    # GRAFTDB_TEST_WORKERS matrix leg)
    session = graftdb.connect(
        db, EngineConfig(mode="graft", clock="wall", max_sleep_s=0.05, workers=1)
    )
    assert session.clock.clocks[0].max_sleep_s == 0.05
    q = queries.make_query(
        db, "q3", {"segment": 1.0, "date": float(days("1995-03-15"))}, arrival=2.0
    )
    t0 = time.perf_counter()
    fut = session.submit(q)
    fut.result()  # arrival 2s in the future: uncapped this would sleep ~2s
    assert time.perf_counter() - t0 < 1.5
    assert fut.latency() >= 0.0
