import numpy as np
import pytest

from repro.relational import tpch


@pytest.fixture(scope="session")
def db():
    """Small TPC-H instance shared across the suite."""
    return tpch.get_database(0.01, seed=7)


@pytest.fixture(scope="session")
def db_mid():
    return tpch.get_database(0.02, seed=7)


@pytest.fixture(autouse=True)
def _clear_shard_hints():
    """Sharding hints are process-global; never leak them between tests."""
    yield
    from repro.models.shardctx import clear_shard_hints

    clear_shard_hints()
