"""Engine-vs-reference correctness: every mode must produce exactly the
reference executor's results for every template, including under concurrent
folding with randomized arrivals (the core semantics guarantee of §5.4)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import graftdb
from graftdb import EngineConfig
from repro.relational import queries, refexec
from repro.relational.table import days

MODES = ["isolated", "scan_sharing", "qpipe_osp", "residual", "graft"]


def _check(db, qs, mode, morsel=8192):
    session = graftdb.connect(db, EngineConfig(mode=mode, morsel_size=morsel))
    futures = session.submit_all(qs)
    done = session.run()
    assert len(done) == len(qs)
    for q, fut in zip(qs, futures):
        ref = refexec.execute(db, q.plan)
        res = fut.result()
        assert set(res) == set(ref), (q.template, set(res) ^ set(ref))
        for k in ref:
            a = np.sort(np.asarray(res[k], dtype=float))
            b = np.sort(np.asarray(ref[k], dtype=float))
            assert a.shape == b.shape, (q.template, k, a.shape, b.shape)
            np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-6, err_msg=f"{q.template}/{k}/{mode}")
    return session.engine


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("template", queries.DEFAULT_TEMPLATES)
def test_template_matches_reference(db, mode, template):
    rng = np.random.default_rng(hash((mode, template)) % (2**31))
    qs = [
        queries.make_query(db, template, queries._sample_params(template, rng), arrival=i * 0.001)
        for i in range(2)
    ]
    _check(db, qs, mode)


@pytest.mark.parametrize("mode", ["qpipe_osp", "residual", "graft"])
def test_concurrent_mixed_workload(db, mode):
    rng = np.random.default_rng(99)
    qs = [queries.sample_query(db, rng, arrival=i * 0.0005) for i in range(12)]
    _check(db, qs, mode)


@given(
    dateA=st.integers(0, 30),
    dateB=st.integers(0, 30),
    segB=st.integers(0, 4),
    offset_frac=st.floats(0.0, 2.0),
)
@settings(max_examples=12, deadline=None)
def test_q3_fold_property(db, dateA, dateB, segB, offset_frac):
    """Folding is semantics-preserving for arbitrary Q3 pairs: any predicate
    relation (broader/narrower/disjoint segments) and any arrival offset."""
    base = float(days("1995-03-01"))
    qa = queries.make_query(db, "q3", {"segment": 1.0, "date": base + dateA}, arrival=0.0)
    # estimate solo duration cheaply with a fixed scale
    qb = queries.make_query(
        db, "q3", {"segment": float(segB), "date": base + dateB}, arrival=offset_frac * 0.05
    )
    ra = refexec.execute(db, qa.plan)
    rb = refexec.execute(db, qb.plan)
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=4096))
    fa, fb = session.submit_all([qa, qb])
    session.run()
    for fut, ref in ((fa, ra), (fb, rb)):
        res = fut.result()
        for k in ref:
            np.testing.assert_allclose(
                np.sort(np.asarray(res[k], float)),
                np.sort(np.asarray(ref[k], float)),
                rtol=1e-9,
                atol=1e-6,
            )


def test_counters_consistent(db):
    rng = np.random.default_rng(5)
    qs = [queries.sample_query(db, rng, arrival=0.0) for i in range(8)]
    eng = _check(db, qs, "graft")
    c = eng.counters
    # every demand row is classified at most once; eliminated+attributed <= demand
    attributed = (
        c["ordinary_build_rows"] + c["residual_build_rows"] + c["represented_rows"] + c["eliminated_rows"]
    )
    assert c["demand_rows"] > 0
    # residual re-delivery can exceed demand slightly (marked rows), but the
    # total must stay within 2x demand in sane workloads
    assert attributed <= 2.0 * c["demand_rows"]


def test_retention_releases_states(db):
    rng = np.random.default_rng(6)
    qs = [queries.sample_query(db, rng, arrival=0.0) for _ in range(4)]
    session = graftdb.connect(db, EngineConfig(mode="graft", morsel_size=8192))
    session.submit_all(qs)
    session.run()
    # after all queries complete, no live states remain in the index
    stats = session.stats()
    assert stats["live_states"] == 0
    assert stats["live_agg_states"] == 0
