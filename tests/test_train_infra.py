"""Training-infrastructure tests: checkpoint roundtrip + elastic restore,
deterministic data pipeline (hypothesis), elastic controller, gradient
compression, optimizer."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import smoke_config
from repro.models import model as M
from repro.train import checkpoint as CKPT
from repro.train import compress
from repro.train.data import batch_at
from repro.train.elastic import Action, ElasticConfig, ElasticController, remesh_plan
from repro.train.optim import adamw_update, init_opt_state

KEY = jax.random.PRNGKey(0)


def test_checkpoint_roundtrip(tmp_path):
    cfg = smoke_config("stablelm-3b")
    params = M.init_params(cfg, KEY)
    opt = init_opt_state(params, cfg.optimizer)
    CKPT.save(tmp_path, 7, params, opt, data_cursor=7, mesh_shape=(1, 1))
    assert CKPT.latest_step(tmp_path) == 7
    p2, o2, manifest = CKPT.restore(tmp_path, target_params=params, target_opt=opt)
    assert manifest["step"] == 7 and manifest["data_cursor"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_latest_fallback(tmp_path):
    cfg = smoke_config("stablelm-3b")
    params = M.init_params(cfg, KEY)
    opt = init_opt_state(params, cfg.optimizer)
    t = CKPT.save(tmp_path, 1, params, opt, async_write=True)
    t.join()
    CKPT.save(tmp_path, 2, params, opt)
    # corrupt LATEST to point past a complete checkpoint
    (tmp_path / "LATEST").write_text("99")
    assert CKPT.latest_step(tmp_path) == 2


@given(
    step=st.integers(0, 1000),
    n_shards=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**20),
)
@settings(max_examples=30, deadline=None)
def test_data_pipeline_shard_consistency(step, n_shards, seed):
    """Sharded reads tile the global batch exactly: content is a pure
    function of (seed, step, global example index)."""
    gb, sl, vocab = 16, 12, 97
    full = batch_at(step, seed=seed, global_batch=gb, seq_len=sl, vocab=vocab)
    parts = [
        batch_at(step, seed=seed, global_batch=gb, seq_len=sl, vocab=vocab, shard=s, n_shards=n_shards)
        for s in range(n_shards)
    ]
    glued = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(full["tokens"], glued)
    # deterministic across calls
    again = batch_at(step, seed=seed, global_batch=gb, seq_len=sl, vocab=vocab)
    np.testing.assert_array_equal(full["targets"], again["targets"])


def test_structured_data_learnable():
    b = batch_at(3, seed=1, global_batch=4, seq_len=64, vocab=101, structured=True)
    pred = (b["tokens"].astype(np.int64) * 31 + 7) % 101
    frac = (pred == b["targets"]).mean()
    assert frac > 0.7  # ~90% follow the bigram rule


def test_elastic_straggler_detection():
    ctl = ElasticController(4, ElasticConfig(straggler_factor=2.0, patience=2))
    decisions = []
    for step in range(3):
        for p in range(4):
            ctl.heartbeat(p, 1.0 if p != 2 else 5.0)  # pod 2 slow
        decisions.append(ctl.evaluate())
    assert decisions[0].action == Action.CONTINUE  # patience not yet reached
    drops = [d for d in decisions if d.action == Action.DROP_PODS]
    assert drops and drops[0].drop == (2,) and drops[0].new_mesh_pods == 3
    assert 2 not in ctl.active  # dropped pod stays dropped
    plan = remesh_plan(4, 3)
    assert plan["new_mesh"] == (3, 16, 16)


def test_elastic_dead_pod_and_abort():
    ctl = ElasticController(2, ElasticConfig(dead_after=2, min_pods=2))
    ctl.heartbeat(0, 1.0)
    ctl.miss(1)
    ctl.miss(1)
    d = ctl.evaluate()
    assert d.action == Action.ABORT_RESTART  # dropping would go below min


def test_gradient_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    res = compress.init_residuals(g)
    acc = jnp.zeros((64, 64))
    exact = jnp.zeros((64, 64))
    for _ in range(20):
        q, s, res = compress.compress(g, res)
        acc = acc + compress.decompress(q, s)["w"]
        exact = exact + g["w"]
    # error feedback: accumulated quantized stream tracks the exact sum
    rel = float(jnp.abs(acc - exact).max() / jnp.abs(exact).max())
    assert rel < 0.01, rel


@pytest.mark.parametrize("kind", ["adamw", "adamw_bf16"])
def test_adamw_decreases_loss(kind):
    w = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)), jnp.float32)}
    opt = init_opt_state(w, kind)
    target = jnp.eye(8)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(w))
    for _ in range(50):
        g = jax.grad(loss)(w)
        w, opt = adamw_update(w, g, opt, kind=kind, lr=3e-2, weight_decay=0.0)
    assert float(loss(w)) < 0.3 * l0
