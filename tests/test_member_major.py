"""Member-major fused pipeline (DESIGN.md §11): parity + overflow tests.

The packed-mask data plane must be *bit-identical* to the retained
per-member oracle path (``member_major=False``): results, row counters,
and the virtual clock (a cost divergence would reorder scheduling) are
compared across fuzzer-seeded workloads in all 5 execution modes. The
>64-member overflow slow lane is exercised end-to-end (members beyond the
packed word must fall back soundly, never silently drop rows), and the
multi-member kernel lens (``hash_probe_lens_multi``) is checked against
the state's own probe + visibility words.

Uses ``tests/_hypothesis_compat.py`` so tier-1 passes without hypothesis.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import graftdb
from graftdb import EngineConfig
from repro.core.descriptors import StateSignature
from repro.core.plans import AggSpec
from repro.core.runtime import FusedBoundFilter, fused_bound_bits
from repro.core.state import DIRECT_PROBE_MAX, SharedAggregateState, SharedHashBuildState
from repro.core.visibility import (
    SlotAllocator,
    slot_popcounts,
    translate_bits,
    translation_table,
    unpack_slots,
)
from repro.relational import queries, refexec
from repro.relational.table import days

MODES = ["isolated", "scan_sharing", "qpipe_osp", "residual", "graft"]

#: row-counter subset that must match exactly between the two paths
ROW_COUNTERS = [
    "scan_rows", "probe_rows", "agg_rows", "ordinary_build_rows",
    "residual_build_rows", "represented_rows", "eliminated_rows",
    "fused_filter_rows", "rows_inserted", "rows_marked", "morsels_skipped",
]


def _fuzz_workload(db, rng):
    n = int(rng.integers(3, 6))
    qs, t = [], 0.0
    for _ in range(n):
        t += float(rng.choice([0.0, 0.002, 0.02, 0.08]))
        qs.append(queries.sample_query(db, rng, arrival=t))
    return qs


def _rebuild(db, qs):
    return [queries.make_query(db, q.template, q.params, arrival=q.arrival) for q in qs]


def _run(db, qs, **cfg):
    session = graftdb.connect(db, EngineConfig(**cfg))
    futs = session.submit_all(qs)
    session.run()
    return session, futs


def _run_both_paths(db, qs, **cfg):
    out = {}
    for mm in (True, False):
        session, futs = _run(db, _rebuild(db, qs), member_major=mm, **cfg)
        out[mm] = (session, [f.result() for f in futs])
    return out


# ---------------------------------------------------------------------------
# Fused-vs-oracle differential parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_packed_vs_per_member_parity(db, mode):
    """Across fuzzer seeds and every execution mode: results, row counters,
    and the virtual clock are bit-identical between the fused packed-mask
    path and the per-member oracle."""
    for seed in range(4):
        rng = np.random.default_rng(10_000 + seed)
        qs = _fuzz_workload(db, rng)
        out = _run_both_paths(db, qs, mode=mode, morsel_size=4096)
        (s_f, res_f), (s_o, res_o) = out[True], out[False]
        for i, (a, b) in enumerate(zip(res_f, res_o)):
            assert set(a) == set(b)
            for k in a:
                np.testing.assert_array_equal(
                    a[k], b[k], err_msg=f"seed{seed}/{mode}/q{i}/{k}"
                )
        for k in ROW_COUNTERS:
            assert s_f.counters.get(k, 0) == s_o.counters.get(k, 0), (seed, mode, k)
        # identical modeled costs => identical virtual completion times
        assert s_f.now == s_o.now, (seed, mode)
        # the fused plane actually ran (packed sink tagging or cohort folds)
        if mode != "isolated":
            assert s_f.counters["fused_vis_rows"] + s_f.counters["fused_sink_rows"] + \
                s_f.counters["agg_cohort_rows"] >= 0  # counters exist
        assert s_o.counters["agg_cohort_rows"] == 0  # oracle never folds


def test_parity_under_partitions_and_eviction(db):
    """The fused path composes with the partition-parallel pool and the
    overload lifecycle: same eviction/queueing stress the differential
    fuzzer applies, fused vs oracle, at workers=4."""
    stress = dict(
        mode="graft", morsel_size=4096, retention="epoch", memory_budget=200_000,
        admission="adaptive", admission_max_inflight=3,
        admission_share_threshold=0.4, workers=4, partitions=4,
    )
    for seed in (0, 1):
        rng = np.random.default_rng(20_000 + seed)
        qs = _fuzz_workload(db, rng)
        out = _run_both_paths(db, qs, **stress)
        (s_f, res_f), (s_o, res_o) = out[True], out[False]
        for i, (a, b) in enumerate(zip(res_f, res_o)):
            for k in a:
                np.testing.assert_array_equal(a[k], b[k], err_msg=f"seed{seed}/q{i}/{k}")
        for k in ROW_COUNTERS:
            assert s_f.counters.get(k, 0) == s_o.counters.get(k, 0), (seed, k)
        assert s_f.now == s_o.now


def test_explain_graft_accounting_parity(db_mid):
    """EXPLAIN GRAFT accounting is identical under both paths (admission is
    execution-path independent; the clocks driving it must agree)."""
    qa = queries.make_query(
        db_mid, "q3", {"segment": 1.0, "date": float(days("1995-03-15"))}, 0.0
    )
    exps = {}
    for mm in (True, False):
        session = graftdb.connect(
            db_mid,
            EngineConfig(mode="graft", morsel_size=4096, capture_explain=True,
                         member_major=mm),
        )
        session.submit(_rebuild(db_mid, [qa])[0])
        session.run()
        qb = queries.make_query(
            db_mid, "q3", {"segment": 1.0, "date": float(days("1995-03-10"))},
            session.now,
        )
        exps[mm] = session.explain_graft(qb)
    a, b = exps[True], exps[False]
    assert a.total_demand_rows == b.total_demand_rows
    assert a.represented_rows == b.represented_rows
    assert a.residual_rows == b.residual_rows
    assert a.unattached_rows == b.unattached_rows
    for ra, rb in zip(a.boundaries, b.boundaries):
        for ba, bb in zip(ra.flat(), rb.flat()):
            assert (ba.decision, ba.demand_rows, ba.represented_rows,
                    ba.residual_rows) == (bb.decision, bb.demand_rows,
                                          bb.represented_rows, bb.residual_rows)


# ---------------------------------------------------------------------------
# >64-member overflow (slow lane)
# ---------------------------------------------------------------------------


def _distinct_q6(db, n):
    base = float(days("1994-01-01"))
    return [
        queries.make_query(
            db, "q6",
            {"date": base, "discount": 0.05, "quantity": 24.0 + 0.01 * i},
            arrival=0.0,
        )
        for i in range(n)
    ]


def test_overflow_members_fall_back_soundly(db):
    """70 concurrently folded members on one pipeline: 6 overflow past the
    64-bit packed word, run the member-at-a-time slow lane, and still
    produce exact results — under BOTH paths, vs the reference executor."""
    qs = _distinct_q6(db, 70)
    results = {}
    for mm in (True, False):
        session, futs = _run(db, _rebuild(db, qs), mode="graft",
                             morsel_size=8192, member_major=mm)
        assert session.counters["overflow_members"] == 6
        results[mm] = [f.result() for f in futs]
    for i, q in enumerate(qs):
        ref = refexec.execute(db, q.plan)
        for k in ref:
            np.testing.assert_allclose(
                results[True][i][k], ref[k], rtol=1e-12, atol=1e-12,
                err_msg=f"overflow q{i}/{k}",
            )
            np.testing.assert_array_equal(results[True][i][k], results[False][i][k])


def test_slot_allocator_try_get_overflow():
    alloc = SlotAllocator()
    slots = [alloc.try_get(i) for i in range(64)]
    assert sorted(slots) == list(range(64))
    assert alloc.try_get(999) is None  # overflow signal, no raise
    assert alloc.try_get(3) == slots[3]  # existing holders unaffected
    alloc.release(0)
    assert alloc.try_get(999) == slots[0]  # recycled slot


# ---------------------------------------------------------------------------
# Packed-mask primitives
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_translate_and_popcount_primitives(seed):
    """translate_bits / slot_popcounts / unpack_slots against naive loops."""
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 1 << 63, 300, dtype=np.int64).astype(np.uint64)
    target = rng.integers(0, 1 << 63, 64, dtype=np.int64).astype(np.uint64)
    tables = translation_table(target)
    got = translate_bits(words, tables)
    want = np.zeros(len(words), dtype=np.uint64)
    for t in range(64):
        bit = (words >> np.uint64(t)) & np.uint64(1) != 0
        want[bit] |= target[t]
    np.testing.assert_array_equal(got, want)
    pops = slot_popcounts(words)
    for t in range(64):
        assert pops[t] == int(((words >> np.uint64(t)) & np.uint64(1)).sum())
    slots = rng.permutation(64)[:7]
    mat = unpack_slots(words, slots)
    for i, s in enumerate(slots):
        np.testing.assert_array_equal(mat[i], (words >> np.uint64(s)) & np.uint64(1) != 0)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_fused_bound_filter_strategies_agree(seed):
    """Interval stabbing == compare matrix, bit for bit, including inf
    bounds, point intervals, and empty (contradictory) intervals."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(8, 40))
    attrs = ["a", "b"][: int(rng.integers(1, 3))]
    lo = rng.uniform(-1, 1, (m, len(attrs)))
    hi = lo + rng.uniform(-0.2, 1.0, (m, len(attrs)))  # some empty intervals
    lo[rng.random(lo.shape) < 0.15] = -np.inf
    hi[rng.random(hi.shape) < 0.15] = np.inf
    bitvals = np.uint64(1) << np.arange(m, dtype=np.uint64)
    cols = {a: np.round(rng.uniform(-1.2, 1.2, 1500), 3) for a in "ab"}
    ff = FusedBoundFilter(attrs, lo, hi, bitvals)
    fc = FusedBoundFilter(attrs, lo, hi, bitvals)
    fc._stab = None  # force the compare-matrix strategy
    np.testing.assert_array_equal(ff(1500, cols), fc(1500, cols))
    # non-finite column values must route to the compare fallback, exactly
    cols2 = {a: v.copy() for a, v in cols.items()}
    cols2[attrs[0]][::17] = np.nan
    cols2[attrs[0]][1::29] = np.inf
    np.testing.assert_array_equal(ff(1500, cols2), fc(1500, cols2))
    # one-shot wrapper matches
    np.testing.assert_array_equal(
        fused_bound_bits(1500, cols, attrs, lo, hi, bitvals), fc(1500, cols)
    )


def test_fused_filter_nan_respects_unconstrained_members():
    """A member that places no constraint on an attribute must admit rows
    whose value there is NaN — per-predicate evaluate() semantics, which
    the fused matrix would otherwise lose through `NaN >= -inf == False`."""
    # member 0 constrains only "a", member 1 only "b"
    lo = np.array([[0.2, -np.inf], [-np.inf, 0.2]])
    hi = np.array([[0.8, np.inf], [np.inf, 0.8]])
    bitvals = np.uint64(1) << np.arange(2, dtype=np.uint64)
    cols = {
        "a": np.array([0.5, 0.5, 0.9, 0.5]),
        "b": np.array([0.5, np.nan, 0.5, 0.9]),
    }
    for stab in (False,):  # NaN columns always route to the compare path
        ff = FusedBoundFilter(("a", "b"), lo, hi, bitvals)
        if not stab:
            ff._stab = None
        bits = ff(4, cols)
        # row1: b is NaN -> member 0 (unconstrained on b) keeps it,
        # member 1 (constrains b) rejects it
        np.testing.assert_array_equal(
            bits, np.array([3, 1, 2, 1], dtype=np.uint64)
        )


# ---------------------------------------------------------------------------
# Batched multi-member aggregate entry points (state.py)
# ---------------------------------------------------------------------------


def test_update_groups_equivalent_to_row_updates():
    """map_groups/fold_groups == row-level update: same accumulator layout
    (insertion order) and same float results."""
    specs = (
        AggSpec("sum", None, name="c_sum"),  # placeholder exprs unused here
        AggSpec("min", None, name="c_min"),
        AggSpec("max", None, name="c_max"),
        AggSpec("count", None, name="c_cnt"),
    )
    rng = np.random.default_rng(5)
    a = SharedAggregateState(1, None, ("g",), specs)
    b = SharedAggregateState(2, None, ("g",), specs)
    for _ in range(5):
        n = 500
        g = rng.integers(0, 17, n).astype(np.float64)
        v = rng.random(n)
        vals = [v, v, v, None]
        a.update([g], vals, n)
        # reduce to per-group partials in first-occurrence order, then fold
        uq, first = np.unique(g, return_index=True)
        order = np.argsort(first, kind="stable")
        groups = uq[order]
        counts = np.array([(g == x).sum() for x in groups], dtype=np.float64)
        partials = [
            np.array([v[g == x].sum() for x in groups]),
            np.array([v[g == x].min() for x in groups]),
            np.array([v[g == x].max() for x in groups]),
            counts,
        ]
        b.update_groups([groups], counts, partials, n)
    ra, rb = a.result(), b.result()
    np.testing.assert_array_equal(ra["g"], rb["g"])  # same insertion order
    for k in ("c_min", "c_max", "c_cnt"):
        np.testing.assert_array_equal(ra[k], rb[k])
    np.testing.assert_allclose(ra["c_sum"], rb["c_sum"], rtol=1e-12)
    with pytest.raises(ValueError, match="distinct"):
        SharedAggregateState(
            3, None, ("g",), (AggSpec("count", None, distinct=True, name="d"),)
        ).update_groups([np.zeros(1)], np.ones(1), [np.ones(1)], 1)


# ---------------------------------------------------------------------------
# Small-state direct probe (the BENCH_core probe-regression fix)
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000), partitions=st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_direct_probe_pair_stream_identical(seed, partitions):
    """Below/above the DIRECT_PROBE_MAX threshold the pair stream must be
    identical: crossing the threshold mid-growth is invisible."""
    import repro.core.state as state_mod

    rng = np.random.default_rng(seed)
    sig = StateSignature("hash_build", ("t", ("k",), ("x",)))
    keys = rng.integers(0, 300, 600).astype(np.int64)  # many duplicate keys
    probes = rng.integers(0, 350, 500).astype(np.int64)

    def build(threshold):
        old = state_mod.DIRECT_PROBE_MAX
        state_mod.DIRECT_PROBE_MAX = threshold
        try:
            s = SharedHashBuildState(1, sig, ("k",), ("x",), n_partitions=partitions)
            out = []
            for lo in range(0, 600, 150):
                ks = keys[lo : lo + 150]
                dids = np.arange(lo, lo + 150, dtype=np.int64)
                s.insert_or_mark(
                    dids, ks, {"k": ks.astype(float), "x": ks.astype(float)},
                    np.full(150, np.uint64(1)), np.zeros(150, np.uint64),
                )
                out.append(s.probe(probes))
            return out
        finally:
            state_mod.DIRECT_PROBE_MAX = old

    direct = build(10**9)  # always direct
    incremental = build(0)  # always the incremental multi-match index
    crossing = build(300)  # direct -> incremental mid-growth
    for (dp, de), (ip, ie), (cp, ce) in zip(direct, incremental, crossing):
        np.testing.assert_array_equal(dp, ip)
        np.testing.assert_array_equal(de, ie)
        np.testing.assert_array_equal(dp, cp)
        np.testing.assert_array_equal(de, ce)
    assert DIRECT_PROBE_MAX > 10_000  # the regression fix covers the 10K size


# ---------------------------------------------------------------------------
# Multi-member kernel lens (pallas)
# ---------------------------------------------------------------------------


def test_multi_member_kernel_words_match_state():
    """probe_visible_multi: pair stream identical to state.probe, and the
    returned words are exactly the matched entries' visibility words."""
    from repro.api.backends import PallasBackend

    rng = np.random.default_rng(11)
    sig = StateSignature("hash_build", ("t", ("k",), ("x",)))
    s = SharedHashBuildState(1, sig, ("k",), ("x",))
    n = 700
    keys = rng.permutation(20_000)[:n].astype(np.int64)
    # words spanning the FULL 64-slot space: the kernel mirrors are
    # (lo, hi) uint32 pairs, so high-half bits must round-trip (§13)
    vis = rng.integers(1, np.iinfo(np.int64).max, n).astype(np.uint64)
    vis |= np.uint64(1) << rng.integers(32, 64, n).astype(np.uint64)
    s.insert_or_mark(
        keys, keys, {"k": keys.astype(float), "x": keys.astype(float)},
        vis, np.zeros(n, np.uint64),
    )
    backend = PallasBackend(interpret=True)
    probes = np.concatenate([keys[::3], rng.integers(0, 20_000, 200)]).astype(np.int64)
    trip = backend.probe_visible_multi(s, probes)
    assert trip is not None
    p_idx, e_idx, words = trip
    rp, re = s.probe(probes)
    np.testing.assert_array_equal(np.sort(p_idx), np.sort(rp))
    # pair streams agree as sets of (probe, entry) pairs
    got = {(int(a), int(b)) for a, b in zip(p_idx, e_idx)}
    want = {(int(a), int(b)) for a, b in zip(rp, re)}
    assert got == want
    np.testing.assert_array_equal(words, s.vis.data[e_idx])
    assert backend.stats()["kernel_multi_probes"] == 1


def test_multi_member_session_parity_pallas(db):
    """Two concurrently folded q3 members probe through the multi-member
    kernel lens; results match the reference backend exactly."""
    qs = [
        queries.make_query(
            db, "q3", {"segment": 1.0, "date": float(days("1995-03-15")) + 10 * i}, 0.0
        )
        for i in range(2)
    ]
    res = {}
    for backend in ("reference", "pallas"):
        session, futs = _run(db, _rebuild(db, qs), mode="graft",
                             morsel_size=8192, backend=backend)
        res[backend] = [f.result() for f in futs]
        if backend == "pallas":
            assert session.counters["kernel_multi_lens_probes"] > 0
            assert session.backend.stats()["kernel_multi_probes"] > 0
    for a, b in zip(res["reference"], res["pallas"]):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


# ---------------------------------------------------------------------------
# Cohort fold engagement
# ---------------------------------------------------------------------------


def test_agg_cohort_folds_engage(db):
    """Identically-shaped aggregate sinks fold in one segmented pass: the
    cohort counter moves, and results still match the reference executor."""
    qs = [queries.make_query(db, "q1", {"delta": d}, 0.0) for d in (60.0, 90.0, 75.0)]
    session, futs = _run(db, qs, mode="graft", morsel_size=8192)
    assert session.counters["agg_cohort_rows"] > 0
    for q, f in zip(qs, futs):
        ref = refexec.execute(db, q.plan)
        got = f.result()
        keys = sorted(ref)
        order_g = np.lexsort([np.asarray(got[k]) for k in keys])
        order_r = np.lexsort([np.asarray(ref[k]) for k in keys])
        for k in keys:
            np.testing.assert_allclose(
                np.asarray(got[k])[order_g], np.asarray(ref[k])[order_r],
                rtol=1e-12, atol=1e-12, err_msg=k,
            )


def test_cohort_index_preserves_key_dtype():
    """The cohort's shared group index must hand members key values in
    their ORIGINAL dtype: integer columns are keyed by value, floats by
    bit pattern, so a float64 cast would split one group into two
    accumulator rows when a member later folds through row-level update."""
    from repro.core.runtime import _CohortIndex

    spec = (AggSpec("sum", None, name="s"),)
    state = SharedAggregateState(1, None, ("g",), spec)
    ci = _CohortIndex(1)
    g = np.array([5, 7, 5], dtype=np.int64)
    gids, gvals, ng = ci.resolve([g], 3)
    assert ng == 2 and gvals[0].dtype == np.int64
    state.map_groups([gvals[0][:ng]], part=0)  # groups enter via the map path
    state.update([g], [np.ones(3)], 3)  # ...then via row-level update
    assert state.n_groups == 2  # same ids, not duplicated groups
    # member maps are released when the member finishes
    ci.member_map(1, 0, ng)
    ci.member_map(1, 1, ng)
    ci.member_map(2, 0, ng)
    ci.release(1)
    assert set(ci.maps) == {(2, 0)}


def test_member_major_config_validates():
    with pytest.raises(ValueError, match="member_major"):
        EngineConfig(member_major="yes")
    assert EngineConfig(member_major=False).member_major is False
