"""Properties of the vectorized hash-index data plane (DESIGN.md §8):
dict parity of lookup_or_insert under duplicates/growth, tuple parity of
MultiKeyIndex, and multi-match probe parity between the incremental state
index and the old sort-based probe on random key multisets."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.descriptors import StateSignature
from repro.core.hashindex import EMPTY_KEY, HashIndex, MultiKeyIndex, float_key_codes
from repro.core.state import SharedHashBuildState


# ---------------------------------------------------------------------------
# HashIndex: dict parity
# ---------------------------------------------------------------------------


@given(
    batches=st.lists(
        st.lists(st.integers(-50, 50), min_size=1, max_size=40), min_size=1, max_size=6
    ),
)
@settings(max_examples=60, deadline=None)
def test_lookup_or_insert_dict_parity(batches):
    """ids and is_new match dict.setdefault(k, len(dict)) over the same
    stream — including in-batch duplicates and growth across batches."""
    idx = HashIndex(capacity=8)  # tiny: force rehash-under-growth
    oracle = {}
    for batch in batches:
        keys = np.array(batch, dtype=np.int64)
        ids, is_new = idx.lookup_or_insert(keys)
        for i, k in enumerate(batch):
            expect_new = k not in oracle
            if expect_new:
                oracle[k] = len(oracle)
            assert ids[i] == oracle[k]
            assert bool(is_new[i]) == expect_new
        assert idx.n == len(oracle)
    # lookups agree after all growth; absent keys miss
    probe = np.array(list(oracle) + [10_000, -10_000], dtype=np.int64)
    got = idx.lookup(probe)
    for i, k in enumerate(probe.tolist()):
        assert got[i] == oracle.get(k, -1)


def test_hashindex_growth_counts_rebuilds():
    counters = {"index_rebuilds": 0}
    idx = HashIndex(capacity=8, counters=counters)
    idx.lookup_or_insert(np.arange(1000, dtype=np.int64))
    assert idx.rebuilds > 0
    assert counters["index_rebuilds"] == idx.rebuilds
    # all ids dense and in order
    ids = idx.lookup(np.arange(1000, dtype=np.int64))
    np.testing.assert_array_equal(ids, np.arange(1000))


def test_hashindex_rejects_sentinel():
    idx = HashIndex()
    with pytest.raises(ValueError):
        idx.lookup_or_insert(np.array([EMPTY_KEY], dtype=np.int64))


def test_float_key_codes_negative_zero():
    codes = float_key_codes(np.array([0.0, -0.0, 1.5]))
    assert codes[0] == codes[1]  # -0.0 == 0.0 in float compare -> same code
    assert codes[0] != codes[2]


@given(
    batches=st.lists(
        st.lists(st.integers(0, 8), min_size=2, max_size=24), min_size=1, max_size=5
    ),
)
@settings(max_examples=40, deadline=None)
def test_multikey_index_tuple_parity(batches):
    """MultiKeyIndex over (int, float) column pairs matches a tuple dict."""
    idx = MultiKeyIndex(2)
    oracle = {}
    for batch in batches:
        g = np.array(batch, dtype=np.int64)
        v = (np.array(batch, dtype=np.float64) % 3) * 0.5
        ids, is_new = idx.lookup_or_insert([g, v])
        for i in range(len(batch)):
            t = (int(g[i]), float(v[i]))
            expect_new = t not in oracle
            if expect_new:
                oracle[t] = len(oracle)
            assert ids[i] == oracle[t]
            assert bool(is_new[i]) == expect_new
    assert idx.n == len(oracle)


# ---------------------------------------------------------------------------
# Incremental multi-match probe index vs the old sort-based probe
# ---------------------------------------------------------------------------


def _sort_probe_oracle(keys: np.ndarray, pk: np.ndarray):
    """The pre-PR probe: stable argsort + searchsorted expansion."""
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    lo = np.searchsorted(sk, pk, side="left")
    hi = np.searchsorted(sk, pk, side="right")
    counts = hi - lo
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    probe_idx = np.repeat(np.arange(len(pk), dtype=np.int64), counts)
    starts = np.repeat(lo, counts)
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.concatenate(([0], np.cumsum(counts)[:-1])), counts
    )
    return probe_idx, order[starts + offs]


def _mk_state():
    sig = StateSignature("hash_build", ("t", ("k",), ("x",)))
    return SharedHashBuildState(1, sig, ("k",), ("x",), did_domain=1 << 20)


@given(
    batches=st.lists(
        st.lists(st.integers(0, 12), min_size=1, max_size=30), min_size=1, max_size=5
    ),
    probes=st.lists(st.integers(-2, 14), min_size=1, max_size=40),
)
@settings(max_examples=60, deadline=None)
def test_incremental_probe_matches_sort_probe(batches, probes):
    """Random key multisets, delivered incrementally (so the duplicate run
    goes through delta merges), probe-identical to the old full-argsort
    index — same pairs in the same order."""
    s = _mk_state()
    base = 0
    for batch in batches:
        kc = np.array(batch, dtype=np.int64)
        dids = base + np.arange(len(kc), dtype=np.int64)  # unique: every row inserts
        base += len(kc)
        s.insert_or_mark(
            dids,
            kc,
            {"k": kc.astype(np.float64), "x": kc.astype(np.float64)},
            np.full(len(kc), np.uint64(1)),
            np.zeros(len(kc), np.uint64),
        )
        pk = np.array(probes, dtype=np.int64)
        got_p, got_e = s.probe(pk)
        want_p, want_e = _sort_probe_oracle(s.keycode.data, pk)
        np.testing.assert_array_equal(got_p, want_p)
        np.testing.assert_array_equal(got_e, want_e)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_incremental_probe_random_multisets(seed):
    """Larger random multisets: growth across many batches, skewed keys."""
    rng = np.random.default_rng(seed)
    s = _mk_state()
    base = 0
    for _ in range(4):
        nb = int(rng.integers(1, 200))
        kc = rng.integers(0, 50, nb).astype(np.int64)
        dids = base + np.arange(nb, dtype=np.int64)
        base += nb
        s.insert_or_mark(
            dids,
            kc,
            {"k": kc.astype(np.float64), "x": kc.astype(np.float64)},
            np.full(nb, np.uint64(1)),
            np.zeros(nb, np.uint64),
        )
    pk = rng.integers(-5, 60, 300).astype(np.int64)
    got_p, got_e = s.probe(pk)
    want_p, want_e = _sort_probe_oracle(s.keycode.data, pk)
    np.testing.assert_array_equal(got_p, want_p)
    np.testing.assert_array_equal(got_e, want_e)
