"""Import hypothesis, or fall back to a deterministic miniature shim.

The real dependency is declared in pyproject.toml (``pip install -e
.[test]`` / CI), but the tier-1 suite must also run in environments where
it cannot be installed. The shim reproduces the subset this suite uses —
``@given`` with positional/keyword strategies over ``integers`` /
``floats`` / ``sampled_from`` / ``lists`` / ``composite``, plus
``@settings(max_examples, deadline)`` — by enumerating seeded deterministic
examples: the first two examples pin scalar strategies at their bounds, the
rest sample from a fixed-seed RNG. No shrinking, no database — just a
deterministic property sweep.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def draw(self, rng):  # pragma: no cover - abstract
            raise NotImplementedError

        def boundary(self, which):
            """Value for the lo/hi pinned example, or None to sample."""
            return None

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

        def boundary(self, which):
            return self.lo if which == 0 else self.hi

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = float(lo), float(hi)

        def draw(self, rng):
            return float(rng.uniform(self.lo, self.hi))

        def boundary(self, which):
            return self.lo if which == 0 else self.hi

    class _SampledFrom(_Strategy):
        def __init__(self, elems):
            self.elems = list(elems)

        def draw(self, rng):
            return self.elems[int(rng.integers(0, len(self.elems)))]

        def boundary(self, which):
            return self.elems[0] if which == 0 else self.elems[-1]

    class _Lists(_Strategy):
        def __init__(self, elem, min_size=0, max_size=10):
            self.elem, self.min_size, self.max_size = elem, min_size, max_size

        def draw(self, rng):
            n = int(rng.integers(self.min_size, self.max_size + 1))
            return [self.elem.draw(rng) for _ in range(n)]

    class _Composite(_Strategy):
        def __init__(self, fn, args, kwargs):
            self.fn, self.args, self.kwargs = fn, args, kwargs

        def draw(self, rng):
            return self.fn(lambda s: s.draw(rng), *self.args, **self.kwargs)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(elems):
            return _SampledFrom(elems)

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Lists(elem, min_size=min_size, max_size=max_size)

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                return _Composite(fn, args, kwargs)

            return make

    st = _Strategies()

    def settings(max_examples: int = 10, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            n_examples = getattr(fn, "_max_examples", 10)
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            strategies = dict(zip(names, pos_strategies))
            strategies.update(kw_strategies)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0xC0FFEE)
                for idx in range(n_examples):
                    drawn = {}
                    for name, strat in strategies.items():
                        val = strat.boundary(idx) if idx in (0, 1) else None
                        drawn[name] = strat.draw(rng) if val is None else val
                    fn(*args, **kwargs, **drawn)

            # hide the generated params from pytest's fixture resolution
            kept = [p for p in sig.parameters.values() if p.name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=kept)
            return wrapper

        return deco
