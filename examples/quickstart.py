"""Quickstart: dynamic folding of two TPC-H Q3 queries (the paper's Fig. 3
running instance) through the unified Session API.

Q_A arrives first and builds the order-side hash state; Q_B arrives
mid-flight with a broader order-date predicate, observes the represented
extent through its state lens, contributes the missing date band as
residual production, and completes without rebuilding Q_A's work. The
grafting decision is surfaced as structured data by EXPLAIN GRAFT.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import graftdb
from graftdb import EngineConfig
from repro.relational import queries, refexec, tpch
from repro.relational.table import days


def main():
    db = tpch.get_database(0.02)
    print(f"TPC-H-derived instance: {db.nbytes()/1e6:.0f} MB, lineitem {db['lineitem'].nrows:,} rows")

    qa = queries.make_query(db, "q3", {"segment": 1.0, "date": float(days("1995-03-15"))}, arrival=0.0)
    qb = queries.make_query(db, "q3", {"segment": 1.0, "date": float(days("1995-03-20"))}, arrival=0.02)

    for mode in ("isolated", "graft"):
        session = graftdb.connect(db, EngineConfig(mode=mode, morsel_size=16384))
        session.submit_all([
            queries.make_query(db, "q3", qa.params, 0.0),
            queries.make_query(db, "q3", qb.params, 0.02),
        ])
        session.run()
        c = session.counters
        print(
            f"\n[{mode}] both done at t={session.now:.3f}s | "
            f"scan {c['scan_rows']:,.0f} rows | builds: ordinary {c['ordinary_build_rows']:,.0f}, "
            f"residual {c['residual_build_rows']:,.0f}, represented(observed) {c['represented_rows']:,.0f}"
        )

    # rerun with explain capture and verify against the reference executor
    ref = refexec.execute(db, qb.plan)
    session = graftdb.connect(
        db, EngineConfig(mode="graft", morsel_size=16384, capture_explain=True)
    )
    fa = session.submit(qa)
    fb = session.submit(qb)
    res = fb.result()  # drives the shared executor until Q_B completes
    ok = all(
        np.allclose(np.sort(np.asarray(res[k], float)), np.sort(np.asarray(ref[k], float)))
        for k in ref
    )
    print(f"\nQ_B result matches reference executor: {ok}")
    print("top revenue rows:", {k: np.round(v[:3], 2).tolist() for k, v in res.items()})
    print("\n" + fb.explain().render())


if __name__ == "__main__":
    main()
