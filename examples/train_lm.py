"""End-to-end training driver example: train a ~25M-parameter dense LM
(reduced stablelm family) for a few hundred steps on CPU with checkpointing
— the same code path the production launcher uses on a TPU mesh.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]

Loss falls from ~ln(V) toward the entropy of the structured synthetic
bigram stream. Interrupt and re-run to exercise restart-from-checkpoint.
"""

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    # ~25M params: a genuinely-training reduced config (not the 3B target)
    T.main(
        [
            "--arch", "stablelm-3b", "--smoke",
            "--steps", str(args.steps),
            "--batch", "16", "--seq", "128", "--lr", "3e-3",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        ]
    )


if __name__ == "__main__":
    main()
