"""Concurrent analytical workload: 16 closed-loop clients over the default
Zipf template mix, comparing Isolated / QPipe-OSP / GraftDB on identical
per-client sequences (paper §6.3 shape).

  PYTHONPATH=src python examples/concurrent_workload.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import client_sequences, get_db, run_closed_loop


def main():
    db = get_db(0.05)
    seqs = client_sequences(db, n_clients=16, n_per=10, seed=3)
    base = None
    grid = [("isolated", 1, 1), ("qpipe_osp", 1, 1), ("graft", 1, 1), ("graft", 4, 8)]
    for mode, workers, partitions in grid:
        r = run_closed_loop(db, mode, seqs, workers=workers, partitions=partitions)
        if base is None:
            base = r["throughput_qph"]
        label = mode if workers == 1 else f"{mode} {workers}w×{partitions}p"
        print(
            f"{label:16s} throughput {r['throughput_qph']:9.0f} q/h "
            f"({r['throughput_qph']/base:4.2f}x) median latency {r['median_latency_s']:6.3f}s "
            f"p95 {r['p95_latency_s']:6.3f}s"
        )


if __name__ == "__main__":
    main()
