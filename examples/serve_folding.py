"""Serving with dynamic folding over shared KV-prefix state — the paper's
mechanism (represented / residual / unattached extents, per-request lenses,
retention) transferred to LM serving (DESIGN.md §6).

Workload: 32 requests sharing one of 4 system prompts (1024 tokens) with
unique 64-token user suffixes, Poisson-ish arrivals.

  PYTHONPATH=src python examples/serve_folding.py
"""

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import graftdb
from repro.serve.folding import Request


def workload(n=32, n_prompts=4, prefix=1024, suffix=64, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [tuple(rng.integers(0, 32000, prefix).tolist()) for _ in range(n_prompts)]
    reqs = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(0.05))
        p = prompts[int(rng.integers(0, n_prompts))]
        reqs.append(Request(i, p + tuple(rng.integers(0, 32000, suffix).tolist()), 32, arrival=t))
    return reqs


def main():
    for fold in (False, True):
        session = graftdb.connect_serving(fold=fold)
        futures = session.submit_all(workload())
        res = session.run()
        mode = "folding " if fold else "isolated"
        tok = res["prefill_tokens"]
        print(
            f"{mode}: elapsed {res['elapsed']:6.2f}s mean latency {res['mean_latency']:5.2f}s "
            f"p95 {res['p95_latency']:5.2f}s | prefill tokens computed {tok.get('computed', 0):,}"
            + (
                f" (represented {tok['represented']:,}, residual {tok['residual']:,},"
                f" ordinary {tok['ordinary']:,})"
                if fold
                else ""
            )
        )
        if fold:
            r = futures[-1].result()
            print(
                f"  last request extents: represented {r['represented_tokens']}, "
                f"residual {r['residual_tokens']}, ordinary {r['ordinary_tokens']}"
            )


if __name__ == "__main__":
    main()
