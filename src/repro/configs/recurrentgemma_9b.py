"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427; unverified]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    attn_window=2048,  # local attention
    block_pattern=("rec", "rec", "attn"),
    lru_dim=4096,
    mlp_kind="swiglu",
    tied_embeddings=True,
    subquadratic=True,  # bounded window + O(1) recurrent state -> long_500k runs
)
