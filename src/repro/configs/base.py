"""Model configuration schema for the 10 assigned architectures.

Divisibility handling for the production mesh (model axis = 16):

* query heads are padded up to a multiple of 16 when needed (llama4 40->48,
  starcoder2 36->48); the MODEL_FLOPS / HLO_FLOPS ratio in §Roofline exposes
  the padding overhead,
* KV heads are never padded — when kv_heads % 16 != 0 the KV tensors are
  replicated across the model axis (GQA/MQA KV is small) and the decode KV
  cache is sharded on the *sequence* dim instead (split-KV decode),
* vocab is padded to a multiple of 16 (seamless 256206 -> 256208... next
  multiple handled in __post_init__).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared (always-on) experts
    capacity_factor: float = 1.25


@dataclass
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0

    # attention flavor
    attn_window: Optional[int] = None  # SWA / local-attention window
    rope_frac: float = 1.0  # fraction of head dims rotated (partial RoPE)
    rope_theta: float = 10_000.0

    # moe
    moe: Optional[MoECfg] = None
    moe_every: int = 1  # apply MoE FFN every k-th layer (1 = all layers)

    # hybrid (recurrentgemma): layer pattern, e.g. ("rec", "rec", "attn")
    block_pattern: Optional[Tuple[str, ...]] = None
    lru_dim: int = 0  # RG-LRU recurrence width (defaults to d_model)
    conv_width: int = 4

    # ssm (rwkv6)
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 64

    # enc-dec (audio)
    n_encoder_layers: int = 0  # >0 => encoder-decoder
    frontend: Optional[str] = None  # 'vision_stub' | 'audio_stub'
    n_prefix_embeds: int = 1024  # stub patch/frame positions in train shapes

    # activation / norm
    mlp_kind: str = "swiglu"  # swiglu | gelu
    tied_embeddings: bool = False

    # training
    optimizer: str = "adamw"  # adamw (fp32 master+moments) | adamw_bf16
    remat: bool = True
    seq_shard_activations: bool = True

    # long-context capability (sub-quadratic): run long_500k?
    subquadratic: bool = False

    # padded dims (filled in __post_init__)
    n_heads_padded: int = 0
    vocab_padded: int = 0

    def __post_init__(self):
        if self.d_head == 0:
            self.d_head = self.d_model // self.n_heads
        if self.lru_dim == 0:
            self.lru_dim = self.d_model
        self.n_heads_padded = _round_up(self.n_heads, 16)
        self.vocab_padded = _round_up(self.vocab, 16)

    # -- parameter counting (MODEL_FLOPS denominator) -----------------------
    def param_counts(self) -> Dict[str, float]:
        D, V = self.d_model, self.vocab_padded
        dh = self.d_head
        H, KV = self.n_heads_padded, self.n_kv_heads
        attn = D * H * dh + 2 * D * KV * dh + H * dh * D
        if self.mlp_kind == "swiglu":
            dense_ffn = 3 * D * self.d_ff
        else:
            dense_ffn = 2 * D * self.d_ff
        total = 0.0
        active = 0.0
        n_dec = self.n_layers
        pattern = self.block_pattern or ("attn",)
        for i in range(n_dec):
            kind = pattern[i % len(pattern)]
            if kind == "rec":
                R = self.lru_dim
                blk = 2 * D * R + R * D + self.conv_width * R + 2 * R * R + R
                blk += dense_ffn
                total += blk
                active += blk
            elif kind == "rwkv":
                tm = 4 * D * D + D * dh + 2 * (D * 64 + 64 * D)  # time-mix + decay lora
                cm = 2 * D * self.d_ff
                total += tm + cm
                active += tm + cm
            else:  # attn layer (kind 'attn' = MoE ffn when configured; 'attn_dense' = dense ffn)
                total += attn
                active += attn
                if self.moe is not None and not kind.startswith("attn_dense"):
                    e_ffn = 3 * D * self.moe.d_ff_expert
                    total += (self.moe.n_experts + self.moe.n_shared) * e_ffn
                    total += D * self.moe.n_experts  # router
                    active += (self.moe.top_k + self.moe.n_shared) * e_ffn
                else:
                    total += dense_ffn
                    active += dense_ffn
        if self.n_encoder_layers:
            enc = self.n_encoder_layers * (attn + dense_ffn)
            cross = n_dec * attn  # cross-attention in each decoder layer
            total += enc + cross
            active += enc + cross
        emb = V * D * (1 if self.tied_embeddings else 2)
        total += emb
        active += emb
        return {"total": total, "active": active}


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
