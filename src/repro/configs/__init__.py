"""Config registry: the 10 assigned architectures + reduced smoke variants."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import ModelConfig, MoECfg

from . import (  # noqa: E402
    chatglm3_6b,
    dbrx_132b,
    h2o_danube_3_4b,
    llama4_maverick_400b_a17b,
    pixtral_12b,
    recurrentgemma_9b,
    rwkv6_7b,
    seamless_m4t_large_v2,
    stablelm_3b,
    starcoder2_7b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        recurrentgemma_9b,
        llama4_maverick_400b_a17b,
        dbrx_132b,
        h2o_danube_3_4b,
        stablelm_3b,
        starcoder2_7b,
        chatglm3_6b,
        rwkv6_7b,
        pixtral_12b,
        seamless_m4t_large_v2,
    )
}

ARCH_IDS: List[str] = list(ARCHS)


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small layers/width, few experts, tiny
    embedding tables — runs a forward/train step on CPU."""
    cfg = ARCHS[name]
    pattern = cfg.block_pattern
    n_layers = max(2, len(pattern) if pattern else 2)
    updates = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_head=16,
        d_ff=128,
        vocab=512,
        lru_dim=64,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        n_prefix_embeds=8,
        n_heads_padded=0,
        vocab_padded=0,
    )
    if cfg.family == "ssm":
        updates.update(n_heads=4, n_kv_heads=4, rwkv_head_dim=16)
    if cfg.moe is not None:
        updates["moe"] = MoECfg(
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=128,
            n_shared=cfg.moe.n_shared,
        )
    if cfg.attn_window is not None:
        updates["attn_window"] = 16
    new = dataclasses.replace(cfg, **updates)
    new.__post_init__()
    return new


# ---------------------------------------------------------------------------
# Assigned input shapes (seq_len x global_batch). decode_*/long_* lower
# serve_step (one new token against a seq_len KV cache), not train_step.
# ---------------------------------------------------------------------------

SHAPES: Dict[str, Dict] = {
    "train_4k": {"kind": "train", "seq_len": 4_096, "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32_768, "global_batch": 32},
    "decode_32k": {"kind": "decode", "seq_len": 32_768, "global_batch": 128},
    "long_500k": {"kind": "decode", "seq_len": 524_288, "global_batch": 1},
}


def cells() -> List[tuple]:
    """All (arch, shape) cells. long_500k only for sub-quadratic archs
    (pure full-attention archs skip it — DESIGN.md §Arch-applicability)."""
    out = []
    for a, cfg in ARCHS.items():
        for s in SHAPES:
            if s == "long_500k" and not cfg.subquadratic:
                continue
            out.append((a, s))
    return out
