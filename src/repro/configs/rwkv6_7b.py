"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay linear
recurrence [arXiv:2404.05892; hf]. O(1) state -> long_500k RUNS."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # rwkv head_dim 64 -> 4096/64 heads
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
    rwkv_chunk=16,
    mlp_kind="rwkv_cm",  # rwkv channel-mix (relu^2 gated)
    subquadratic=True,
)
