"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB: input_specs supplies
precomputed patch embeddings) + mistral-nemo-style decoder backbone
[hf:mistralai/Pixtral-12B-2409; unverified]. Full attention ->
long_500k SKIPPED."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    frontend="vision_stub",
    n_prefix_embeds=1024,  # image patch positions inside the train sequence
    mlp_kind="swiglu",
)
