"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal
[arXiv:2308.11596; hf]. Speech frontend is a STUB (input_specs supplies
precomputed frame embeddings). Decode shapes lower the DECODER step with
stub encoder memory. Full attention both stacks -> long_500k SKIPPED."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,  # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,  # padded to 256208 for TP=16
    frontend="audio_stub",
    mlp_kind="gelu",
)
