"""chatglm3-6b [dense] — 2d/partial RoPE (half dims), GQA kv=2
[arXiv:2406.12793; hf]. Full attention -> long_500k SKIPPED."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    rope_frac=0.5,  # ChatGLM rotary applies to half the head dims
    mlp_kind="swiglu",
)
