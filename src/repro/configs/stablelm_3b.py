"""stablelm-3b [dense] — MHA (kv = heads), partial rotary (25%)
[hf:stabilityai/stablelm-2-1_6b; unverified]. Full attention ->
long_500k SKIPPED."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    rope_frac=0.25,
    mlp_kind="swiglu",
)
