"""dbrx-132b [moe] — 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified]. Full attention -> long_500k SKIPPED."""

from .base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoECfg(n_experts=16, top_k=4, d_ff_expert=10752),
    mlp_kind="swiglu",
    optimizer="adamw_bf16",
)
