"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Full attention -> long_500k is SKIPPED (DESIGN.md §Arch-applicability).
adamw_bf16 optimizer: 400B params with fp32 master+moments exceed v5e HBM on
a single pod; bf16 moments fit (§Dry-run memory analysis).
"""

from .base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,  # padded to 48 for the 16-way model axis
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    moe=MoECfg(n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1),
    # Maverick interleaves dense and MoE FFN layers 1:1 -> ~400B total / ~17B active
    block_pattern=("attn", "attn_dense"),
    mlp_kind="swiglu",
    optimizer="adamw_bf16",
)
