"""Training step: loss -> grads -> AdamW, with optional microbatch
(gradient-accumulation) scan. Params live in bf16; grads therefore
materialize in bf16; the fp32 master copy (when enabled) lives in opt_state
and is FSDP-sharded.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import loss_fn
from .optim import adamw_update, global_norm


def make_train_step(
    cfg: ModelConfig,
    act_spec=None,
    n_microbatches: int = 1,
    lr: float = 3e-4,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def compute_grads(params, batch):
        if n_microbatches == 1:
            return jax.value_and_grad(lambda p: loss_fn(cfg, p, batch, act_spec=act_spec))(params)

        def slice_mb(x, i):
            mb = x.shape[0] // n_microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def mb_step(carry, i):
            acc_loss, acc_g = carry
            mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
            l, g = jax.value_and_grad(lambda p: loss_fn(cfg, p, mb, act_spec=act_spec))(params)
            acc_g = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc_g, g)
            return (acc_loss + l, acc_g), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (tot_l, tot_g), _ = jax.lax.scan(
            mb_step, (jnp.zeros((), jnp.float32), zero_g), jnp.arange(n_microbatches)
        )
        inv = 1.0 / n_microbatches
        return tot_l * inv, jax.tree.map(lambda g: g * inv, tot_g)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        new_params, new_opt = adamw_update(
            params, grads, opt_state, kind=cfg.optimizer, lr=lr
        )
        metrics = {"loss": loss, "grad_norm": global_norm(grads)}
        return new_params, new_opt, metrics

    return train_step
