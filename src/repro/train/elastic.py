"""Elastic scaling and straggler mitigation — the control-plane decision
logic, deterministic and fully unit-testable in simulation.

Model (designed for 1000+ nodes, exercised here in simulation):

* every pod posts a heartbeat each step; the (replicated, deterministic)
  controller evaluates them at step boundaries,
* a pod whose heartbeat lags beyond ``straggler_factor`` x the healthy
  median for ``patience`` consecutive steps is marked DEGRADED; a pod
  missing ``dead_after`` heartbeats is DEAD,
* decisions: CONTINUE / DROP_POD (elastic restore onto the shrunk mesh at
  the next checkpoint boundary) / ABORT_RESTART (below min_pods),
* in-step, collectives are fixed-size, so a slow link delays but never
  deadlocks; the controller never interrupts mid-step — it re-meshes only
  at checkpoint boundaries, which the deterministic data pipeline makes
  exactly resumable (train/data.py).

Because every healthy host computes the same decision from the same
heartbeat log, no consensus protocol sits on the hot path (same argument as
the GraftDB control plane — DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple


class PodState(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    DEAD = "dead"


class Action(enum.Enum):
    CONTINUE = "continue"
    DROP_PODS = "drop_pods"
    ABORT_RESTART = "abort_restart"


@dataclasses.dataclass
class ElasticConfig:
    straggler_factor: float = 2.0
    patience: int = 3
    dead_after: int = 5
    min_pods: int = 1


@dataclasses.dataclass
class Decision:
    action: Action
    drop: Tuple[int, ...] = ()
    new_mesh_pods: int = 0
    reason: str = ""


class ElasticController:
    def __init__(self, n_pods: int, cfg: Optional[ElasticConfig] = None):
        self.cfg = cfg or ElasticConfig()
        self.n_pods = n_pods
        self.step_times: Dict[int, List[float]] = {p: [] for p in range(n_pods)}
        self.missed: Dict[int, int] = {p: 0 for p in range(n_pods)}
        self.slow_streak: Dict[int, int] = {p: 0 for p in range(n_pods)}
        self.active = set(range(n_pods))

    def heartbeat(self, pod: int, step_time: float) -> None:
        if pod in self.active:
            self.step_times[pod].append(step_time)
            self.missed[pod] = 0

    def miss(self, pod: int) -> None:
        if pod in self.active:
            self.missed[pod] += 1

    def evaluate(self) -> Decision:
        """Deterministic per-step-boundary decision."""
        cfg = self.cfg
        dead = {p for p in self.active if self.missed[p] >= cfg.dead_after}
        latest = {
            p: self.step_times[p][-1]
            for p in self.active
            if p not in dead and self.step_times[p]
        }
        if latest:
            healthy_sorted = sorted(latest.values())
            median = healthy_sorted[len(healthy_sorted) // 2]
            for p, t in latest.items():
                if t > cfg.straggler_factor * median:
                    self.slow_streak[p] += 1
                else:
                    self.slow_streak[p] = 0
        stragglers = {
            p for p in self.active if self.slow_streak[p] >= cfg.patience
        }
        drop = tuple(sorted(dead | stragglers))
        if not drop:
            return Decision(Action.CONTINUE)
        remaining = len(self.active) - len(drop)
        if remaining < cfg.min_pods:
            return Decision(
                Action.ABORT_RESTART,
                drop=drop,
                reason=f"{len(drop)} pods unhealthy, below min_pods={cfg.min_pods}",
            )
        for p in drop:
            self.active.discard(p)
        return Decision(
            Action.DROP_PODS,
            drop=drop,
            new_mesh_pods=remaining,
            reason="dead=" + ",".join(map(str, sorted(dead)))
            + " stragglers="
            + ",".join(map(str, sorted(stragglers))),
        )


def remesh_plan(old_pods: int, new_pods: int, data: int = 16, model: int = 16) -> Dict:
    """The elastic restore plan: target mesh + whether the global batch is
    preserved (batch is sharded over ('pod','data'); dropping pods shrinks
    the FSDP axis — the deterministic pipeline re-slices by global example
    index so content is unchanged)."""
    return {
        "old_mesh": (old_pods, data, model) if old_pods > 1 else (data, model),
        "new_mesh": (new_pods, data, model) if new_pods > 1 else (data, model),
        "restore": "checkpoint-boundary",
        "batch_reslice": f"{old_pods * data} -> {new_pods * data} FSDP shards",
    }
