"""Optimizers, implemented directly in JAX (no external deps).

* ``adamw``      — fp32 master weights + fp32 moments (default).
* ``adamw_bf16`` — bf16 moments, no separate master (params updated in their
  own dtype). Used by the 100B+ configs so optimizer state fits v5e HBM on a
  single pod; the §Dry-run memory analysis records both variants.

Optimizer state is sharded exactly like the parameters (ZeRO-3-style: the
FSDP axis shards both), via tree-prefix spec mapping in launch/sharding.py.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_opt_state(params, kind: str = "adamw"):
    if kind == "adamw":
        return {
            "step": jnp.zeros((), jnp.int32),
            # copy=True: fp32 params would otherwise alias the master buffer
            # and break donation in the jitted step
            "master": jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
    if kind == "adamw_bf16":
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
        }
    raise ValueError(kind)


def abstract_opt_state(abstract_p, kind: str = "adamw"):
    return jax.eval_shape(lambda p: init_opt_state(p, kind), abstract_p)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    params,
    grads,
    opt_state,
    *,
    kind: str = "adamw",
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Tuple[Any, Dict]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master=None):
        g = g.astype(jnp.float32) * scale
        m_ = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_ = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        base = (master if master is not None else p).astype(jnp.float32)
        new = base - lr * (u + weight_decay * base)
        return new, m_, v_

    if kind == "adamw":
        out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"], opt_state["master"])
        new_master = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(lambda mst, p: mst.astype(p.dtype), new_master, params)
        return new_params, {"step": step, "master": new_master, "m": new_m, "v": new_v}
    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_p32 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(
        lambda t, m: t[1].astype(m.dtype), out, opt_state["m"], is_leaf=lambda x: isinstance(x, tuple)
    )
    new_v = jax.tree.map(
        lambda t, v: t[2].astype(v.dtype), out, opt_state["v"], is_leaf=lambda x: isinstance(x, tuple)
    )
    new_params = jax.tree.map(lambda n, p: n.astype(p.dtype), new_p32, params)
    return new_params, {"step": step, "m": new_m, "v": new_v}
