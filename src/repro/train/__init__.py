"""Training substrate: optimizer, train step, data pipeline, checkpointing,
elastic/straggler logic, gradient compression."""
