"""Gradient compression: int8 error-feedback quantized all-reduce.

Optional (off by default). Each leaf is quantized to int8 with a per-leaf
fp32 scale before the reduce; the quantization error is carried in a
residual buffer and added back next step (error feedback keeps convergence
unbiased to first order). Saves ~4x gradient collective bytes when the
interconnect term dominates (§Perf measures the delta on the dry-run)."""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residuals(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, residuals) -> Tuple[Any, Any, Any]:
    """-> (int8 grads, fp32 scales, new residuals)."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_r = g - q.astype(jnp.float32) * scale
        return q, scale, new_r

    out = jax.tree.map(one, grads, residuals)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    r = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, r


def decompress(q, scales) -> Any:
    return jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss, q, scales)
