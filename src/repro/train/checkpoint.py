"""Checkpoint / restore with elastic re-sharding.

Format: a directory per step with one .npz per host-shard group plus a JSON
manifest (step, mesh shape, tree structure, data-pipeline cursor, RNG key).
Writes are double-buffered (tmp dir + atomic rename) and optionally async
(background thread), so a step's failure never corrupts the previous
checkpoint — the restart path always has a complete manifest to land on.

Elastic restore: arrays are saved UNSHARDED per leaf (gathered); restoring
onto a different mesh re-shards via the target sharding rules. At 1000+
node scale the same layout maps to per-host shard files keyed by
(leaf, shard-index) — the manifest already records the mesh so restore can
detect and re-slice; this container exercises the single-host path.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(k.key) if hasattr(k, "key") else str(k.idx) for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(
    ckpt_dir: str | Path,
    step: int,
    params,
    opt_state,
    *,
    data_cursor: int = 0,
    rng_key=None,
    mesh_shape: Tuple[int, ...] = (),
    extra: Optional[Dict] = None,
    async_write: bool = False,
) -> threading.Thread | None:
    """Write checkpoint for ``step``. Returns the writer thread if async."""
    ckpt_dir = Path(ckpt_dir)
    p_flat = _flatten(params)
    o_flat = _flatten(opt_state)
    manifest = {
        "step": int(step),
        "mesh_shape": list(mesh_shape),
        "data_cursor": int(data_cursor),
        "rng_key": np.asarray(rng_key).tolist() if rng_key is not None else None,
        "time": time.time(),
        "param_keys": sorted(p_flat),
        "opt_keys": sorted(o_flat),
        "extra": extra or {},
    }

    def write():
        tmp = ckpt_dir / f".tmp-{step}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / "params.npz", **p_flat)
        np.savez(tmp / "opt.npz", **o_flat)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = ckpt_dir / f"step-{step:08d}"
        if final.exists():
            import shutil

            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        latest = ckpt_dir / "LATEST"
        latest.write_text(str(step))

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    f = Path(ckpt_dir) / "LATEST"
    if not f.exists():
        return None
    step = int(f.read_text().strip())
    if (Path(ckpt_dir) / f"step-{step:08d}" / "manifest.json").exists():
        return step
    # LATEST pointer ahead of a completed checkpoint (crash mid-write):
    # fall back to newest complete directory.
    steps = sorted(
        int(p.name.split("-")[1])
        for p in Path(ckpt_dir).glob("step-*")
        if (p / "manifest.json").exists()
    )
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str | Path,
    step: Optional[int] = None,
    *,
    target_params=None,
    target_opt=None,
    shardings: Optional[Tuple[Any, Any]] = None,
):
    """Load a checkpoint. With ``target_*`` trees given, leaves are
    restored into the target tree structure (validating shapes) and, with
    ``shardings``, device_put onto the (possibly different) mesh — the
    elastic-rescale path."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step-{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    p_flat = dict(np.load(d / "params.npz"))
    o_flat = dict(np.load(d / "opt.npz"))

    def rebuild(flat, target, shard):
        if target is None:
            return flat
        out = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(target)[0]:
            key = "/".join(str(k.key) if hasattr(k, "key") else str(k.idx) for k in path)
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs target {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            out.append(arr)
        treedef = jax.tree_util.tree_structure(target)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shard is not None:
            tree = jax.device_put(tree, shard)
        return tree

    ps, os_ = (shardings if shardings is not None else (None, None))
    params = rebuild(p_flat, target_params, ps)
    opt = rebuild(o_flat, target_opt, os_)
    return params, opt, manifest
