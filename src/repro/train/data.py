"""Deterministic synthetic token pipeline.

Batches are a pure function of (seed, global step, shard index), so:

* restart resumes mid-epoch from the checkpointed cursor with no duplicated
  or skipped batches,
* elastic rescale is safe: a resharded job re-derives exactly the batches it
  would have seen (the cursor is in global steps, and per-step data is
  sliced by global example index, not by worker count),
* straggler-dropped pods change only which host materializes a slice, never
  the data content.

The generator is a counter-based hash (SplitMix64-style) — stateless,
O(1)-seekable, reproducible across hosts.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + _GOLDEN) * np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


def batch_at(
    step: int,
    *,
    seed: int,
    global_batch: int,
    seq_len: int,
    vocab: int,
    shard: int = 0,
    n_shards: int = 1,
    structured: bool = False,
) -> Dict[str, np.ndarray]:
    """The shard-local slice of the global batch for ``step``.

    ``structured=True`` draws from a learnable affine-bigram process
    (t_{i+1} = 31*t_i + 7 mod V with 10% noise) so example training runs
    show a falling loss; the default is uniform noise (throughput work)."""
    per = global_batch // n_shards
    ex0 = np.uint64(step) * np.uint64(global_batch) + np.uint64(shard * per)
    idx = ex0 + np.arange(per, dtype=np.uint64)
    base = _splitmix(idx * np.uint64(seed * 2 + 1))[:, None]
    pos = np.arange(seq_len + 1, dtype=np.uint64)[None, :]
    rnd = _splitmix(base + pos * _GOLDEN)
    toks = (rnd % np.uint64(vocab)).astype(np.int32)
    if structured:
        out = np.empty_like(toks)
        out[:, 0] = toks[:, 0]
        noise = (rnd % np.uint64(10)) == 0  # 10% resample
        for i in range(1, toks.shape[1]):
            pred = (out[:, i - 1].astype(np.int64) * 31 + 7) % vocab
            out[:, i] = np.where(noise[:, i], toks[:, i], pred.astype(np.int32))
        toks = out
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class Pipeline:
    """Prefetching iterator with a persistent cursor (checkpointable)."""

    def __init__(self, seed: int, global_batch: int, seq_len: int, vocab: int,
                 shard: int = 0, n_shards: int = 1, start_step: int = 0,
                 structured: bool = False):
        self.seed, self.global_batch, self.seq_len, self.vocab = seed, global_batch, seq_len, vocab
        self.shard, self.n_shards = shard, n_shards
        self.cursor = start_step
        self.structured = structured

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = batch_at(
            self.cursor,
            seed=self.seed,
            global_batch=self.global_batch,
            seq_len=self.seq_len,
            vocab=self.vocab,
            shard=self.shard,
            n_shards=self.n_shards,
            structured=self.structured,
        )
        self.cursor += 1
        return b
