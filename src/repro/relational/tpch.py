"""TPC-H-derived data generator (statistical reimplementation of dbgen).

Generates the eight TPC-H tables with dbgen's cardinalities, key structure,
and value distributions (uniform dates over the 1992-1998 window, segment /
priority / flag categoricals, FK joins), scaled by SF. Not byte-identical to
dbgen — the paper's workloads only need statistically-faithful instances
(template parameters are sampled uniformly from large domains; overlap comes
from operator requirements, not from exact rows).

Keys are dense 0..N-1 row indices (orderkey == orders row index etc.), which
gives collision-free derivation identifiers and mixed-radix join-key
encodings for the shared-state machinery.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from .table import Database, Table, days

SEGMENTS = ["AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD"]
ORDER_PRIORITIES = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"]
RETURN_FLAGS = ["R", "A", "N"]
LINE_STATUS = ["O", "F"]
SHIP_MODES = ["REG AIR", "AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB"]
REGIONS = ["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"]
NATIONS = [
    ("ALGERIA", 0), ("ARGENTINA", 1), ("BRAZIL", 1), ("CANADA", 1), ("EGYPT", 4),
    ("ETHIOPIA", 0), ("FRANCE", 3), ("GERMANY", 3), ("INDIA", 2), ("INDONESIA", 2),
    ("IRAN", 4), ("IRAQ", 4), ("JAPAN", 2), ("JORDAN", 4), ("KENYA", 0),
    ("MOROCCO", 0), ("MOZAMBIQUE", 0), ("PERU", 1), ("CHINA", 2), ("ROMANIA", 3),
    ("SAUDI ARABIA", 4), ("VIETNAM", 2), ("RUSSIA", 3), ("UNITED KINGDOM", 3),
    ("UNITED STATES", 1),
]
# part "colors" used by Q9's p_name LIKE '%<color>%' (dbgen draws part names
# from a 92-word list; we use 25 so the ~4% selectivity is comparable).
COLORS = [
    "almond", "antique", "aquamarine", "azure", "beige", "bisque", "black",
    "blanched", "blue", "blush", "brown", "burlywood", "burnished", "chartreuse",
    "chiffon", "chocolate", "coral", "cornflower", "cornsilk", "cream", "cyan",
    "dark", "deep", "dim", "green",
]
TYPE_SYLL1 = ["STANDARD", "SMALL", "MEDIUM", "LARGE", "ECONOMY", "PROMO"]
TYPE_SYLL2 = ["ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED"]
TYPE_SYLL3 = ["TIN", "NICKEL", "BRASS", "STEEL", "COPPER"]
TYPES = [f"{a} {b} {c}" for a in TYPE_SYLL1 for b in TYPE_SYLL2 for c in TYPE_SYLL3]

MIN_DATE = days("1992-01-01")  # == 0
MAX_ORDER_DATE = days("1998-08-02")
END_DATE = days("1998-12-31")


def generate(scale_factor: float = 0.05, seed: int = 7, clustered: bool = False) -> Database:
    """``clustered=True`` sorts orders by o_orderdate and lineitem by
    l_shipdate (time-ordered ingest, typical of real warehouses) — this is
    what makes zone-map morsel skipping effective (§Perf)."""
    rng = np.random.default_rng(seed)
    sf = scale_factor

    n_supp = max(int(10_000 * sf), 50)
    n_part = max(int(200_000 * sf), 200)
    n_cust = max(int(150_000 * sf), 150)
    n_ord = max(int(1_500_000 * sf), 1500)
    n_ps_per_part = 4

    tables: Dict[str, Table] = {}

    # -- region / nation ----------------------------------------------------
    tables["region"] = Table(
        "region",
        {
            "r_regionkey": np.arange(5, dtype=np.float64),
            "r_name": np.arange(5, dtype=np.float64),
        },
        {"r_name": REGIONS},
    )
    tables["nation"] = Table(
        "nation",
        {
            "n_nationkey": np.arange(25, dtype=np.float64),
            "n_name": np.arange(25, dtype=np.float64),
            "n_regionkey": np.array([r for _, r in NATIONS], dtype=np.float64),
        },
        {"n_name": [n for n, _ in NATIONS]},
    )

    # -- supplier -------------------------------------------------------------
    tables["supplier"] = Table(
        "supplier",
        {
            "s_suppkey": np.arange(n_supp, dtype=np.float64),
            "s_nationkey": rng.integers(0, 25, n_supp).astype(np.float64),
            "s_acctbal": rng.uniform(-999.99, 9999.99, n_supp),
        },
    )

    # -- part ------------------------------------------------------------------
    tables["part"] = Table(
        "part",
        {
            "p_partkey": np.arange(n_part, dtype=np.float64),
            "p_colorcode": rng.integers(0, len(COLORS), n_part).astype(np.float64),
            "p_type": rng.integers(0, len(TYPES), n_part).astype(np.float64),
            "p_size": rng.integers(1, 51, n_part).astype(np.float64),
            "p_retailprice": 900.0 + rng.uniform(0, 1200, n_part),
        },
        {"p_colorcode": COLORS, "p_type": TYPES},
    )

    # -- partsupp (each part has 4 suppliers) ----------------------------------
    ps_part = np.repeat(np.arange(n_part), n_ps_per_part)
    ps_supp = (
        (ps_part * 13 + np.tile(np.arange(n_ps_per_part), n_part) * (n_supp // n_ps_per_part + 1))
        % n_supp
    )
    tables["partsupp"] = Table(
        "partsupp",
        {
            "ps_partkey": ps_part.astype(np.float64),
            "ps_suppkey": ps_supp.astype(np.float64),
            "ps_supplycost": rng.uniform(1.0, 1000.0, len(ps_part)),
            "ps_availqty": rng.integers(1, 10_000, len(ps_part)).astype(np.float64),
        },
    )

    # -- customer ----------------------------------------------------------------
    tables["customer"] = Table(
        "customer",
        {
            "c_custkey": np.arange(n_cust, dtype=np.float64),
            "c_mktsegment": rng.integers(0, 5, n_cust).astype(np.float64),
            "c_nationkey": rng.integers(0, 25, n_cust).astype(np.float64),
            "c_acctbal": rng.uniform(-999.99, 9999.99, n_cust),
        },
        {"c_mktsegment": SEGMENTS},
    )

    # -- orders ---------------------------------------------------------------------
    o_orderdate = rng.integers(MIN_DATE, MAX_ORDER_DATE + 1, n_ord).astype(np.float64)
    tables["orders"] = Table(
        "orders",
        {
            "o_orderkey": np.arange(n_ord, dtype=np.float64),
            "o_custkey": rng.integers(0, n_cust, n_ord).astype(np.float64),
            "o_orderdate": o_orderdate,
            "o_orderyear": (1992 + o_orderdate // 365.25).astype(np.float64),
            "o_shippriority": np.zeros(n_ord),
            "o_orderpriority": rng.integers(0, 5, n_ord).astype(np.float64),
            "o_totalprice": rng.uniform(850.0, 560_000.0, n_ord),
        },
        {"o_orderpriority": ORDER_PRIORITIES},
    )

    # -- lineitem (1..7 lines per order) ----------------------------------------------
    lines_per_order = rng.integers(1, 8, n_ord)
    l_orderkey = np.repeat(np.arange(n_ord), lines_per_order)
    n_li = len(l_orderkey)
    l_partkey = rng.integers(0, n_part, n_li)
    # pick one of the 4 suppliers of the part (FK-consistent with partsupp)
    psi = rng.integers(0, n_ps_per_part, n_li)
    l_suppkey = (l_partkey * 13 + psi * (n_supp // n_ps_per_part + 1)) % n_supp
    ship_lag = rng.integers(1, 122, n_li)
    l_shipdate = o_orderdate[l_orderkey] + ship_lag
    l_commitdate = o_orderdate[l_orderkey] + rng.integers(30, 91, n_li)
    l_receiptdate = l_shipdate + rng.integers(1, 31, n_li)
    quantity = rng.integers(1, 51, n_li).astype(np.float64)
    extprice = quantity * (900.0 + rng.uniform(0, 1200, n_li)) / 10.0
    # dbgen: returnflag = R|A (50/50) when receipt <= 1995-06-17 else N
    cutoff = days("1995-06-17")
    rflag = np.where(
        l_receiptdate <= cutoff, rng.integers(0, 2, n_li), 2
    ).astype(np.float64)
    lstatus = np.where(l_shipdate > days("1995-06-17"), 0, 1).astype(np.float64)

    if clustered:
        operm = np.argsort(o_orderdate, kind="stable")
        inv = np.empty_like(operm)
        inv[operm] = np.arange(n_ord)
        ot = tables["orders"]
        ot.columns = {k: v[operm] for k, v in ot.columns.items()}
        ot.columns["o_orderkey"] = np.arange(n_ord, dtype=np.float64)
        l_orderkey = inv[l_orderkey]
        o_orderdate = o_orderdate[operm]
        lperm = np.argsort(l_shipdate, kind="stable")
        (l_orderkey, l_partkey, l_suppkey, l_shipdate, l_commitdate, l_receiptdate,
         quantity, extprice, rflag, lstatus, psi, ship_lag) = (
            a[lperm] for a in (
                l_orderkey, l_partkey, l_suppkey, l_shipdate, l_commitdate,
                l_receiptdate, quantity, extprice, rflag, lstatus, psi, ship_lag,
            )
        )

    tables["lineitem"] = Table(
        "lineitem",
        {
            "l_orderkey": l_orderkey.astype(np.float64),
            "l_partkey": l_partkey.astype(np.float64),
            "l_suppkey": l_suppkey.astype(np.float64),
            "l_quantity": quantity,
            "l_extendedprice": extprice,
            "l_discount": rng.integers(0, 11, n_li).astype(np.float64) / 100.0,
            "l_tax": rng.integers(0, 9, n_li).astype(np.float64) / 100.0,
            "l_returnflag": rflag,
            "l_linestatus": lstatus,
            "l_shipdate": l_shipdate.astype(np.float64),
            "l_shipyear": (1992 + l_shipdate // 365.25).astype(np.float64),
            "l_commitdate": l_commitdate.astype(np.float64),
            "l_receiptdate": l_receiptdate.astype(np.float64),
            "l_shipmode": rng.integers(0, 7, n_li).astype(np.float64),
        },
        {"l_returnflag": RETURN_FLAGS, "l_linestatus": LINE_STATUS, "l_shipmode": SHIP_MODES},
    )

    return Database(tables, sf)


_cache: Dict = {}


def get_database(scale_factor: float = 0.05, seed: int = 7, clustered: bool = False) -> Database:
    key = (scale_factor, seed, clustered)
    if key not in _cache:
        _cache[key] = generate(scale_factor, seed, clustered)
    return _cache[key]
