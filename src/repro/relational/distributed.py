"""Distributed relational data plane: shard_map-partitioned operators.

DESIGN.md §4 (GraftDB-on-mesh): base tables are row-partitioned over the
'data' mesh axis; equi-joins repartition both sides by join-key hash with a
fixed-capacity bucketed all_to_all (TPU-native: dense [P, C, W] exchange
tensors, no ragged communication); aggregations combine shard-local segment
sums with an all_to_all by group hash. The control plane (grafting
admission) stays replicated-deterministic on every host — only the data
plane communicates.

These operators are the scale-out twins of the single-worker engine's
morsel pipeline: the engine's shared states partition by key exactly like
`repartition_by_key`, so a 1000-node deployment shards every
SharedHashBuildState bucket-wise with the same math. Numerical correctness
is validated in tests on the single-device mesh; the production-mesh
lower+compile is part of the dry-run (`launch/dryrun.py --db-plane`).
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

FILL = jnp.int64(-1)


def _hash_dest(keys: jnp.ndarray, n: int) -> jnp.ndarray:
    return (keys.astype(jnp.uint32) * jnp.uint32(2654435761) >> jnp.uint32(8)).astype(
        jnp.int32
    ) % n


def repartition_by_key(
    keys: jnp.ndarray,  # [rows_local] int64 (FILL = invalid/padding)
    values: jnp.ndarray,  # [rows_local, W] f32 payload
    axis_name: str,
    n_shards: int,
    capacity: int,
):
    """Inside shard_map: route each local row to shard hash(key)%P via a
    dense [P, C, 1+W] all_to_all. Returns (keys', values', valid') with
    rows now partitioned by key hash. Overflowing a bucket drops rows into
    the FILL region — capacity is a static knob (asserted in tests)."""
    rows = keys.shape[0]
    valid = keys != FILL
    dest = jnp.where(valid, _hash_dest(keys, n_shards), n_shards)  # invalid -> overflow row
    order = jnp.argsort(dest)
    keys_s = keys[order]
    vals_s = values[order]
    dest_s = dest[order]
    # position within destination bucket
    onehot = dest_s[:, None] == jnp.arange(n_shards + 1)[None, :]
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, dest_s[:, None].astype(jnp.int32), axis=1)[:, 0]
    keep = (slot < capacity) & (dest_s < n_shards)
    safe_dest = jnp.where(keep, dest_s, 0)
    safe_slot = jnp.where(keep, slot, capacity - 1)
    buf_k = jnp.full((n_shards, capacity), FILL)
    buf_v = jnp.zeros((n_shards, capacity, values.shape[1]), values.dtype)
    buf_k = buf_k.at[safe_dest, safe_slot].set(jnp.where(keep, keys_s, FILL), mode="drop")
    buf_v = buf_v.at[safe_dest, safe_slot].set(
        jnp.where(keep[:, None], vals_s, 0.0), mode="drop"
    )
    # exchange: shard p sends buf[q] to shard q
    k_out = jax.lax.all_to_all(buf_k, axis_name, 0, 0, tiled=False)
    v_out = jax.lax.all_to_all(buf_v, axis_name, 0, 0, tiled=False)
    k_flat = k_out.reshape(-1)
    v_flat = v_out.reshape(-1, values.shape[1])
    return k_flat, v_flat, k_flat != FILL


def _local_join(bk, bv, pk, pv):
    """Sort-probe join of local partitions (unique build keys)."""
    order = jnp.argsort(bk)
    sbk = bk[order]
    idx = jnp.searchsorted(sbk, pk)
    idx = jnp.clip(idx, 0, sbk.shape[0] - 1)
    hit = (sbk[idx] == pk) & (pk != FILL)
    bsel = order[idx]
    out_v = jnp.concatenate([pv, bv[bsel]], axis=-1)
    return jnp.where(hit[:, None], out_v, 0.0), hit


def make_partitioned_join(
    mesh: Mesh,
    build_width: int,
    probe_width: int,
    capacity: int,
    axis_name: str = "data",
):
    """jit-able distributed hash join over row-partitioned inputs.

    build_keys/probe_keys: [R] int64 sharded over ``axis_name`` (FILL pads);
    build_vals/probe_vals: [R, W]. Output: joined rows [R_probe', W_p+W_b]
    + hit mask, partitioned by key hash."""
    n = mesh.shape[axis_name]
    spec_k = P(axis_name)
    spec_v = P(axis_name, None)

    def local(bk, bv, pk, pv):
        bk2, bv2, _ = repartition_by_key(bk, bv, axis_name, n, capacity)
        pk2, pv2, _ = repartition_by_key(pk, pv, axis_name, n, capacity)
        out, hit = _local_join(bk2, bv2, pk2, pv2)
        return out, hit, pk2

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec_k, spec_v, spec_k, spec_v),
        out_specs=(spec_v, spec_k, spec_k),
        check_rep=False,
    )
    return jax.jit(fn)


def make_partitioned_aggregate(
    mesh: Mesh,
    n_groups: int,
    width: int,
    axis_name: str = "data",
):
    """Distributed group-by sum: shard-local one-hot segment sums, then
    psum over the data axis (groups replicated; for huge group counts the
    same bucketed all_to_all as the join repartitions by group hash)."""
    spec_g = P(axis_name)
    spec_v = P(axis_name, None)

    def local(gids, vals):
        onehot = (gids[:, None] == jnp.arange(n_groups)[None, :]).astype(vals.dtype)
        partial = jnp.einsum("rg,rw->gw", onehot, vals)
        return jax.lax.psum(partial, axis_name)

    fn = shard_map(
        local, mesh=mesh, in_specs=(spec_g, spec_v), out_specs=P(None, None), check_rep=False
    )
    return jax.jit(fn)


# -- host-side helpers --------------------------------------------------------


def pad_partition(keys: np.ndarray, values: np.ndarray, n_shards: int):
    """Pad host arrays so rows split evenly across the data axis."""
    rows = len(keys)
    per = math.ceil(rows / n_shards)
    total = per * n_shards
    k = np.full(total, int(FILL), np.int64)
    v = np.zeros((total, values.shape[1]), np.float32)
    k[:rows] = keys
    v[:rows] = values
    return jnp.asarray(k), jnp.asarray(v)
