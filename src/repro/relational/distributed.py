"""Distributed relational data plane: shard_map-partitioned operators.

DESIGN.md §4 (GraftDB-on-mesh): base tables are row-partitioned over the
'data' mesh axis; equi-joins repartition both sides by join-key hash with a
fixed-capacity bucketed all_to_all (TPU-native: dense [P, C, W] exchange
tensors, no ragged communication); aggregations combine shard-local segment
sums with an all_to_all by group hash. The control plane (grafting
admission) stays replicated-deterministic on every host — only the data
plane communicates.

These operators are the scale-out twins of the single-worker engine's
morsel pipeline: the engine's shared states partition by key exactly like
`repartition_by_key` (pass ``dest=key_partition(keys, P)`` so the exchange
routes rows to the same shard that owns the state bucket), so a 1000-node
deployment shards every SharedHashBuildState bucket-wise with the same
math. Bucket overflow is never silent: each exchange reports the number of
valid rows that did not fit, and the host-side `exchange_by_key` wrapper
grows capacity (or hard-fails) instead of dropping. Numerical correctness
is validated in tests on the single-device mesh; the production-mesh
lower+compile is part of the dry-run (`launch/db_plane.py`).
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

FILL = jnp.int64(-1)

# The dense exchange carries keys as device integers; with jax x64 disabled
# those are int32, so keycodes must fit — same contract as the Pallas probe
# chain (PallasBackend._KEY_LIMIT). Callers with wider keys stay on the host
# data plane.
KEY_LIMIT = 2**31 - 2


class BucketOverflowError(RuntimeError):
    """A bucketed exchange would have dropped rows (capacity too small)."""


def _hash_dest(keys: jnp.ndarray, n: int) -> jnp.ndarray:
    return (keys.astype(jnp.uint32) * jnp.uint32(2654435761) >> jnp.uint32(8)).astype(
        jnp.int32
    ) % n


def repartition_by_key(
    keys: jnp.ndarray,  # [rows_local] int64 (FILL = invalid/padding)
    values: jnp.ndarray,  # [rows_local, W] f32 payload
    axis_name: str,
    n_shards: int,
    capacity: int,
    dest: Optional[jnp.ndarray] = None,
):
    """Inside shard_map: route each local row to shard hash(key)%P via a
    dense [P, C, 1+W] all_to_all. Returns (keys', values', valid',
    n_overflow) with rows now partitioned by key hash.

    ``dest`` overrides the destination shard per row (e.g. the engine's
    splitmix64 ``key_partition`` routing, computed host-side) so exchange
    placement matches shard-local state ownership; invalid (FILL) rows are
    never sent regardless.

    Capacity is a static knob; a destination bucket past capacity does NOT
    silently lose rows — ``n_overflow`` counts every valid row this shard
    failed to place, and callers must grow capacity or fail (see
    `exchange_by_key`)."""
    valid = keys != FILL
    if dest is None:
        dest = _hash_dest(keys, n_shards)
    dest = jnp.where(valid, dest, n_shards)  # invalid -> discard row
    order = jnp.argsort(dest)
    keys_s = keys[order]
    vals_s = values[order]
    dest_s = dest[order]
    # position within destination bucket
    onehot = dest_s[:, None] == jnp.arange(n_shards + 1)[None, :]
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, dest_s[:, None].astype(jnp.int32), axis=1)[:, 0]
    keep = (slot < capacity) & (dest_s < n_shards)
    # valid rows that did not fit their destination bucket: surfaced, never
    # silently dropped (satellite: bucket_overflow_rows)
    n_overflow = jnp.sum((~keep) & (dest_s < n_shards), dtype=jnp.int32)
    safe_dest = jnp.where(keep, dest_s, 0)
    safe_slot = jnp.where(keep, slot, capacity - 1)
    buf_k = jnp.full((n_shards, capacity), FILL)
    buf_v = jnp.zeros((n_shards, capacity, values.shape[1]), values.dtype)
    buf_k = buf_k.at[safe_dest, safe_slot].set(jnp.where(keep, keys_s, FILL), mode="drop")
    buf_v = buf_v.at[safe_dest, safe_slot].set(
        jnp.where(keep[:, None], vals_s, 0.0), mode="drop"
    )
    # exchange: shard p sends buf[q] to shard q
    k_out = jax.lax.all_to_all(buf_k, axis_name, 0, 0, tiled=False)
    v_out = jax.lax.all_to_all(buf_v, axis_name, 0, 0, tiled=False)
    k_flat = k_out.reshape(-1)
    v_flat = v_out.reshape(-1, values.shape[1])
    return k_flat, v_flat, k_flat != FILL, n_overflow


def _local_join(bk, bv, pk, pv):
    """Sort-probe join of local partitions (unique build keys)."""
    order = jnp.argsort(bk)
    sbk = bk[order]
    idx = jnp.searchsorted(sbk, pk)
    idx = jnp.clip(idx, 0, sbk.shape[0] - 1)
    hit = (sbk[idx] == pk) & (pk != FILL)
    bsel = order[idx]
    out_v = jnp.concatenate([pv, bv[bsel]], axis=-1)
    return jnp.where(hit[:, None], out_v, 0.0), hit


def make_partitioned_join(
    mesh: Mesh,
    build_width: int,
    probe_width: int,
    capacity: int,
    axis_name: str = "data",
):
    """jit-able distributed hash join over row-partitioned inputs.

    build_keys/probe_keys: [R] int64 sharded over ``axis_name`` (FILL pads);
    build_vals/probe_vals: [R, W]. Output: joined rows [R_probe', W_p+W_b]
    + hit mask, partitioned by key hash, + the total count of rows that
    overflowed an exchange bucket (psum over the axis — identical on every
    shard; nonzero means the result is incomplete and capacity must grow)."""
    n = mesh.shape[axis_name]
    spec_k = P(axis_name)
    spec_v = P(axis_name, None)

    def local(bk, bv, pk, pv):
        bk2, bv2, _, ob = repartition_by_key(bk, bv, axis_name, n, capacity)
        pk2, pv2, _, op_ = repartition_by_key(pk, pv, axis_name, n, capacity)
        out, hit = _local_join(bk2, bv2, pk2, pv2)
        overflow = jax.lax.psum(ob + op_, axis_name)
        return out, hit, pk2, overflow

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec_k, spec_v, spec_k, spec_v),
        out_specs=(spec_v, spec_k, spec_k, P()),
        check_rep=False,
    )
    return jax.jit(fn)


def make_partitioned_exchange(
    mesh: Mesh,
    width: int,
    capacity: int,
    axis_name: str = "data",
):
    """jit-able bucketed all_to_all alone: rows in row-partition order ->
    rows in key-shard order, with per-row ``dest`` routing (replicated in
    row-partition order alongside the rows) and the psum'd overflow count."""
    n = mesh.shape[axis_name]
    spec_k = P(axis_name)
    spec_v = P(axis_name, None)

    def local(keys, vals, dest):
        k2, v2, ok, ov = repartition_by_key(keys, vals, axis_name, n, capacity, dest=dest)
        return k2, v2, ok, jax.lax.psum(ov, axis_name)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec_k, spec_v, spec_k),
        out_specs=(spec_k, spec_v, spec_k, P()),
        check_rep=False,
    )
    return jax.jit(fn)


@functools.lru_cache(maxsize=32)
def _cached_exchange(mesh: Mesh, width: int, capacity: int, axis_name: str):
    return make_partitioned_exchange(mesh, width, capacity, axis_name)


def exchange_by_key(
    mesh: Mesh,
    keys: np.ndarray,
    values: np.ndarray,
    *,
    capacity: Optional[int] = None,
    dest: Optional[np.ndarray] = None,
    axis_name: str = "data",
    on_overflow: str = "grow",
    max_doublings: int = 6,
) -> Dict:
    """Host-facing bucketed exchange: pad, run the shard_map'd
    repartition, and grow capacity (never drop) on bucket overflow.

    Returns a dict with ``keys``/``values``/``valid`` (device arrays in
    key-shard order, [P*C(')] rows), ``capacity`` actually used,
    ``bucket_overflow_rows`` (total rows that overflowed across all
    attempts — every one was recovered by regrowing, none lost) and
    ``attempts``. ``on_overflow='raise'`` hard-fails with
    BucketOverflowError instead of growing."""
    keys = np.asarray(keys, np.int64)
    if keys.size and np.abs(keys).max() > KEY_LIMIT:
        raise ValueError(
            "device exchange carries int32 keycodes (jax x64 disabled); "
            f"|key| must be <= {KEY_LIMIT} — wider keys stay on the host plane"
        )
    if on_overflow not in ("grow", "raise"):
        raise ValueError(f"on_overflow must be 'grow' or 'raise', got {on_overflow!r}")
    n = int(mesh.shape[axis_name])
    values = np.asarray(values, np.float32)
    if values.ndim == 1:
        values = values[:, None]
    if dest is not None:
        dest = np.asarray(dest, np.int64)
        if dest.shape != keys.shape:
            raise ValueError(f"dest shape {dest.shape} != keys shape {keys.shape}")
        if dest.size and (dest.min() < 0 or dest.max() >= n):
            raise ValueError(f"dest out of range [0, {n}) for the {axis_name} axis")
    k_pad, v_pad, d_pad = pad_partition(keys, values, n, dest=dest)
    per_shard = k_pad.shape[0] // n
    if capacity is None:
        # expected per-destination load + slack; grown below if a skewed
        # key distribution still overflows
        capacity = max(8, 2 * math.ceil(max(1, len(keys)) / (n * n)))
    overflow_total = 0
    attempts = 0
    while True:
        attempts += 1
        fn = _cached_exchange(mesh, values.shape[1], int(capacity), axis_name)
        k2, v2, ok, ov = fn(k_pad, v_pad, d_pad)
        ov = int(ov)
        if ov == 0:
            return {
                "keys": k2,
                "values": v2,
                "valid": ok,
                "capacity": int(capacity),
                "n_shards": n,
                "bucket_overflow_rows": overflow_total,
                "attempts": attempts,
            }
        overflow_total += ov
        if on_overflow == "raise":
            raise BucketOverflowError(
                f"bucketed exchange overflowed {ov} row(s) at capacity {capacity} "
                f"over {n} shard(s); grow capacity or use on_overflow='grow'"
            )
        if attempts > max_doublings:
            raise BucketOverflowError(
                f"bucketed exchange still overflowing after {attempts} attempts "
                f"(capacity {capacity}, {ov} rows over) — key distribution too "
                "skewed for the dense exchange"
            )
        capacity = max(int(capacity) * 2, int(capacity) + ov)


def make_partitioned_aggregate(
    mesh: Mesh,
    n_groups: int,
    width: int,
    axis_name: str = "data",
):
    """Distributed group-by sum: shard-local one-hot segment sums, then
    psum over the data axis (groups replicated; for huge group counts the
    same bucketed all_to_all as the join repartitions by group hash).

    Sentinel rows (gid outside [0, n_groups), e.g. the -1 padding written
    by `pad_groups`) are masked shard-locally and contribute nothing."""
    spec_g = P(axis_name)
    spec_v = P(axis_name, None)

    def local(gids, vals):
        ok = (gids >= 0) & (gids < n_groups)
        onehot = (gids[:, None] == jnp.arange(n_groups)[None, :]).astype(vals.dtype)
        onehot = onehot * ok[:, None].astype(vals.dtype)
        partial = jnp.einsum("rg,rw->gw", onehot, vals)
        return jax.lax.psum(partial, axis_name)

    fn = shard_map(
        local, mesh=mesh, in_specs=(spec_g, spec_v), out_specs=P(None, None), check_rep=False
    )
    return jax.jit(fn)


# -- host-side helpers --------------------------------------------------------


def pad_partition(
    keys: np.ndarray,
    values: np.ndarray,
    n_shards: int,
    dest: Optional[np.ndarray] = None,
):
    """Pad host arrays so rows split evenly across the data axis.

    Padding rows carry the FILL sentinel in ``keys`` — the one invalid
    marker every shard-local consumer masks (the exchange discards them
    before sending, `_local_join` treats them as misses, the aggregate
    masks out-of-range gids), so the round trip is exact for ANY
    ``n_shards``: results over the padded arrays equal results over the
    originals. Returns (keys', values', dest') where dest' pads with 0
    (routing of a FILL row is irrelevant — it is never sent); dest' is a
    valid-everywhere array even when ``dest`` is None (hash routing
    placeholder) so shard_map signatures stay static."""
    rows = len(keys)
    keys = np.asarray(keys, np.int64)
    if rows and np.abs(keys).max() > KEY_LIMIT:
        raise ValueError(
            f"device exchange carries int32 keycodes; |key| must be <= {KEY_LIMIT}"
        )
    per = math.ceil(max(1, rows) / n_shards)
    total = per * n_shards
    k = np.full(total, int(FILL), np.int64)
    v = np.zeros((total, values.shape[1]), values.dtype)
    k[:rows] = keys
    v[:rows] = values
    d = np.zeros(total, np.int64)
    if dest is not None:
        d[:rows] = dest
    else:
        # match the device-side default hash so dest-less callers route the
        # same with or without padding
        kk = np.asarray(keys, np.int64)
        d[:rows] = ((kk.astype(np.uint32) * np.uint32(2654435761)) >> np.uint32(8)).astype(
            np.int64
        ) % n_shards
    return jnp.asarray(k), jnp.asarray(v), jnp.asarray(d)


def pad_groups(gids: np.ndarray, values: np.ndarray, n_shards: int):
    """Pad a group-by input so rows split evenly: padding rows carry gid -1,
    which `make_partitioned_aggregate` masks shard-locally."""
    rows = len(gids)
    per = math.ceil(max(1, rows) / n_shards)
    total = per * n_shards
    g = np.full(total, -1, np.int64)
    v = np.zeros((total, values.shape[1]), values.dtype)
    g[:rows] = gids
    v[:rows] = values
    return jnp.asarray(g), jnp.asarray(v)
