"""Columnar tables.

All columns are encoded into comparable scalar float64/int64 domains up
front (dates -> int days since 1992-01-01, strings -> dictionary codes), so
the predicate prover and the vectorized/Pallas data plane see numbers only.
Dictionaries are kept on the table for decoding results.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class Table:
    name: str
    columns: Dict[str, np.ndarray]
    dictionaries: Dict[str, List[str]] = field(default_factory=dict)
    _zones: Dict = field(default_factory=dict, repr=False)

    @property
    def nrows(self) -> int:
        return len(next(iter(self.columns.values())))

    def morsel(self, start: int, size: int) -> Dict[str, np.ndarray]:
        end = min(start + size, self.nrows)
        return {k: v[start:end] for k, v in self.columns.items()}

    def zone_map(self, morsel_size: int) -> Dict[str, "np.ndarray"]:
        """Per-morsel (min, max) per column — built lazily, cached per
        morsel size. Used by zone-map morsel skipping (beyond-paper)."""
        zm = self._zones.get(morsel_size)
        if zm is None:
            n = self.nrows
            nm = max(1, -(-n // morsel_size))
            bounds = np.arange(0, nm * morsel_size, morsel_size)
            zm = {}
            for k, col in self.columns.items():
                mins = np.minimum.reduceat(col, bounds)
                maxs = np.maximum.reduceat(col, bounds)
                zm[k] = (mins, maxs)
            self._zones[morsel_size] = zm
        return zm

    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.columns.values())

    def code(self, column: str, value: str) -> int:
        return self.dictionaries[column].index(value)


class Database:
    def __init__(self, tables: Dict[str, Table], scale_factor: float):
        self.tables = tables
        self.scale_factor = scale_factor

    def __getitem__(self, name: str) -> Table:
        return self.tables[name]

    def nbytes(self) -> int:
        return sum(t.nbytes() for t in self.tables.values())


DATE_EPOCH = "1992-01-01"


def days(datestr: str) -> int:
    """Encode 'YYYY-MM-DD' as int days since 1992-01-01."""
    y, m, d = map(int, datestr.split("-"))
    return (np.datetime64(f"{y:04d}-{m:02d}-{d:02d}") - np.datetime64(DATE_EPOCH)).astype(int)
