"""Reference plan executor — the correctness oracle for the engine.

Executes a physical plan directly with vectorized numpy (no sharing, no
morsels, no visibility machinery). Engine results in every mode must match
this executor exactly; the property tests assert it.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from ..core.plans import Aggregate, HashJoin, OrderBy, PlanNode, Scan, expr_eval
from ..core.predicates import TRUE, evaluate
from .table import Database


def execute(db: Database, plan: PlanNode) -> Dict[str, np.ndarray]:
    cols = _exec(db, plan)
    return cols


def _exec(db: Database, node: PlanNode) -> Dict[str, np.ndarray]:
    if isinstance(node, Scan):
        t = db[node.table]
        mask = evaluate(node.pred, t.columns)
        return {k: v[mask] for k, v in t.columns.items()}
    if isinstance(node, HashJoin):
        build = _exec(db, node.build)
        probe = _exec(db, node.probe)
        bkeys = _codes(build, node.build_keys)
        pkeys = _codes(probe, node.probe_keys)
        order = np.argsort(bkeys, kind="stable")
        sb = bkeys[order]
        lo = np.searchsorted(sb, pkeys, "left")
        hi = np.searchsorted(sb, pkeys, "right")
        counts = hi - lo
        total = int(counts.sum())
        pidx = np.repeat(np.arange(len(pkeys)), counts)
        starts = np.repeat(lo, counts)
        offs = np.arange(total) - np.repeat(np.concatenate(([0], np.cumsum(counts)[:-1])), counts)
        bidx = order[starts + offs]
        out = {k: v[pidx] for k, v in probe.items()}
        names = node.payload_as if node.payload_as is not None else node.payload
        for a, o in zip(node.payload, names):
            out[o] = build[a][bidx]
        if node.post_filter is not TRUE:
            m = evaluate(node.post_filter, out)
            out = {k: v[m] for k, v in out.items()}
        return out
    if isinstance(node, Aggregate):
        rows = _exec(db, node.input)
        n = len(next(iter(rows.values()))) if rows else 0
        if node.group_keys:
            stacked = np.stack([rows[k] for k in node.group_keys], axis=1)
            uniq, inv = np.unique(stacked, axis=0, return_inverse=True)
            inv = np.asarray(inv).ravel()
            ng = len(uniq)
        else:
            uniq = np.zeros((1, 0))
            inv = np.zeros(n, dtype=np.int64)
            ng = 1
        out: Dict[str, np.ndarray] = {}
        for i, k in enumerate(node.group_keys):
            out[k] = uniq[:, i]
        cnt = np.bincount(inv, minlength=ng).astype(np.float64)
        for spec in node.aggs:
            vals = None
            if spec.expr is not None:
                vals = np.broadcast_to(
                    np.asarray(expr_eval(spec.expr, rows), dtype=np.float64), (n,)
                )
            if spec.distinct:
                pairs = np.stack([inv.astype(np.float64), vals], axis=1)
                up = np.unique(pairs, axis=0)
                out[spec.name] = np.bincount(
                    up[:, 0].astype(np.int64), minlength=ng
                ).astype(np.float64)
            elif spec.func == "count":
                out[spec.name] = cnt.copy()
            elif spec.func == "sum":
                out[spec.name] = np.bincount(inv, weights=vals, minlength=ng)
            elif spec.func == "avg":
                s = np.bincount(inv, weights=vals, minlength=ng)
                out[spec.name] = s / np.maximum(cnt, 1e-300)
            elif spec.func == "min":
                acc = np.full(ng, np.inf)
                np.minimum.at(acc, inv, vals)
                out[spec.name] = acc
            elif spec.func == "max":
                acc = np.full(ng, -np.inf)
                np.maximum.at(acc, inv, vals)
                out[spec.name] = acc
            else:
                raise ValueError(spec.func)
        return out
    if isinstance(node, OrderBy):
        res = _exec(db, node.input)
        if not res:
            return res
        n = len(next(iter(res.values())))
        keys = []
        for k, asc in zip(reversed(node.keys), reversed(node.ascending)):
            keys.append(res[k] if asc else -res[k])
        order = np.lexsort(keys) if keys else np.arange(n)
        if node.limit is not None:
            order = order[: node.limit]
        return {k: v[order] for k, v in res.items()}
    raise TypeError(node)


def _codes(cols: Dict[str, np.ndarray], attrs: Tuple[str, ...]) -> np.ndarray:
    code = np.asarray(cols[attrs[0]], dtype=np.int64)
    for a in attrs[1:]:
        code = code * np.int64(1 << 21) + np.asarray(cols[a], dtype=np.int64)
    return code
