"""Relational substrate: columnar tables, TPC-H-derived data generation,
query templates, and vectorized operators."""
