"""TPC-H query templates Q1 and Q3-Q10 as parameterized physical plans.

A query instance is a template with concrete parameter values (paper §6.1).
Template parameters are sampled uniformly from the benchmark's domains, so
exact duplicates are rare — overlap comes from related templates and
compatible operator requirements. Q2 is omitted (correlated subquery,
outside the supported plan class — same as the paper).

Each builder returns a fixed physical plan (join order pinned per template,
mirroring the paper's PostgreSQL-pinned plans); workload parameters change
only predicates and constants.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from ..core.plans import (
    AggSpec,
    Aggregate,
    BinOp,
    Col,
    Const,
    HashJoin,
    OrderBy,
    Query,
    Scan,
    WhereEq,
)
from ..core.predicates import And, Cmp, ColCmp, InSet, TRUE, pred_and
from .table import Database, days
from .tpch import COLORS, NATIONS, REGIONS, SEGMENTS, TYPES

REVENUE = BinOp("*", Col("l_extendedprice"), BinOp("-", Const(1.0), Col("l_discount")))


def _first_of_month(year: int, month: int) -> int:
    return days(f"{year:04d}-{month:02d}-01")


# ---------------------------------------------------------------------------
# Template builders: (db, params) -> plan
# ---------------------------------------------------------------------------


def q1_plan(db: Database, p: Dict) -> object:
    cutoff = days("1998-12-01") - p["delta"]
    scan = Scan(
        "lineitem",
        Cmp("l_shipdate", "<=", cutoff),
        (
            "l_returnflag",
            "l_linestatus",
            "l_quantity",
            "l_extendedprice",
            "l_discount",
            "l_tax",
            "l_shipdate",
        ),
    )
    disc_price = REVENUE
    charge = BinOp("*", disc_price, BinOp("+", Const(1.0), Col("l_tax")))
    agg = Aggregate(
        scan,
        ("l_returnflag", "l_linestatus"),
        (
            AggSpec("sum", Col("l_quantity"), name="sum_qty"),
            AggSpec("sum", Col("l_extendedprice"), name="sum_base_price"),
            AggSpec("sum", disc_price, name="sum_disc_price"),
            AggSpec("sum", charge, name="sum_charge"),
            AggSpec("avg", Col("l_quantity"), name="avg_qty"),
            AggSpec("avg", Col("l_extendedprice"), name="avg_price"),
            AggSpec("avg", Col("l_discount"), name="avg_disc"),
            AggSpec("count", None, name="count_order"),
        ),
    )
    return OrderBy(agg, ("l_returnflag", "l_linestatus"), (True, True))


def q3_plan(db: Database, p: Dict) -> object:
    seg, date = p["segment"], p["date"]
    customer = Scan("customer", Cmp("c_mktsegment", "==", seg), ("c_custkey",))
    orders = Scan(
        "orders",
        Cmp("o_orderdate", "<", date),
        ("o_orderkey", "o_custkey", "o_orderdate", "o_shippriority"),
    )
    order_side = HashJoin(customer, orders, ("c_custkey",), ("o_custkey",), ())
    lineitem = Scan(
        "lineitem",
        Cmp("l_shipdate", ">", date),
        ("l_orderkey", "l_extendedprice", "l_discount", "l_shipdate"),
    )
    join = HashJoin(
        order_side, lineitem, ("o_orderkey",), ("l_orderkey",), ("o_orderdate", "o_shippriority")
    )
    agg = Aggregate(
        join,
        ("l_orderkey", "o_orderdate", "o_shippriority"),
        (AggSpec("sum", REVENUE, name="revenue"),),
    )
    return OrderBy(agg, ("revenue", "o_orderdate"), (False, True), limit=10)


def q4_plan(db: Database, p: Dict) -> object:
    d0 = p["date"]
    d1 = d0 + 92  # + 3 months
    orders = Scan(
        "orders",
        And((Cmp("o_orderdate", ">=", d0), Cmp("o_orderdate", "<", d1))),
        ("o_orderkey", "o_orderpriority"),
    )
    lineitem = Scan(
        "lineitem",
        ColCmp("l_commitdate", "<", "l_receiptdate"),
        ("l_orderkey", "l_commitdate", "l_receiptdate"),
    )
    join = HashJoin(orders, lineitem, ("o_orderkey",), ("l_orderkey",), ("o_orderpriority", "o_orderkey"))
    agg = Aggregate(
        join,
        ("o_orderpriority",),
        (AggSpec("count", Col("o_orderkey"), distinct=True, name="order_count"),),
    )
    return OrderBy(agg, ("o_orderpriority",), (True,))


def q5_plan(db: Database, p: Dict) -> object:
    region, d0 = p["region"], p["date"]
    d1 = d0 + 365
    nat_reg = HashJoin(
        Scan("region", Cmp("r_name", "==", region), ("r_regionkey",)),
        Scan("nation", TRUE, ("n_nationkey", "n_regionkey", "n_name")),
        ("r_regionkey",),
        ("n_regionkey",),
        (),
    )
    customer = Scan("customer", TRUE, ("c_custkey", "c_nationkey"))
    orders = Scan(
        "orders",
        And((Cmp("o_orderdate", ">=", d0), Cmp("o_orderdate", "<", d1))),
        ("o_orderkey", "o_custkey"),
    )
    order_side = HashJoin(
        customer, orders, ("c_custkey",), ("o_custkey",), ("c_nationkey",)
    )
    supplier = Scan("supplier", TRUE, ("s_suppkey", "s_nationkey"))
    lineitem = Scan(
        "lineitem", TRUE, ("l_orderkey", "l_suppkey", "l_extendedprice", "l_discount")
    )
    j1 = HashJoin(order_side, lineitem, ("o_orderkey",), ("l_orderkey",), ("c_nationkey",))
    j2 = HashJoin(
        supplier,
        j1,
        ("s_suppkey",),
        ("l_suppkey",),
        ("s_nationkey",),
        post_filter=ColCmp("c_nationkey", "==", "s_nationkey"),
    )
    j3 = HashJoin(nat_reg, j2, ("n_nationkey",), ("s_nationkey",), ("n_name",))
    agg = Aggregate(j3, ("n_name",), (AggSpec("sum", REVENUE, name="revenue"),))
    return OrderBy(agg, ("revenue",), (False,))


def q6_plan(db: Database, p: Dict) -> object:
    d0, disc, qty = p["date"], p["discount"], p["quantity"]
    scan = Scan(
        "lineitem",
        And(
            (
                Cmp("l_shipdate", ">=", d0),
                Cmp("l_shipdate", "<", d0 + 365),
                Cmp("l_discount", ">=", round(disc - 0.01, 4)),
                Cmp("l_discount", "<=", round(disc + 0.01, 4)),
                Cmp("l_quantity", "<", qty),
            )
        ),
        ("l_shipdate", "l_discount", "l_quantity", "l_extendedprice"),
    )
    agg = Aggregate(
        scan, (), (AggSpec("sum", BinOp("*", Col("l_extendedprice"), Col("l_discount")), name="revenue"),)
    )
    return OrderBy(agg, (), ())


def q7_plan(db: Database, p: Dict) -> object:
    n1, n2 = p["nation1"], p["nation2"]
    pair = InSet("n_name", frozenset((float(n1), float(n2))))
    supp_side = HashJoin(
        Scan("nation", pair, ("n_nationkey", "n_name")),
        Scan("supplier", TRUE, ("s_suppkey", "s_nationkey")),
        ("n_nationkey",),
        ("s_nationkey",),
        ("n_name",),
    )
    cust_side = HashJoin(
        Scan("nation", pair, ("n_nationkey", "n_name")),
        Scan("customer", TRUE, ("c_custkey", "c_nationkey")),
        ("n_nationkey",),
        ("c_nationkey",),
        ("n_name",),
    )
    orders = Scan("orders", TRUE, ("o_orderkey", "o_custkey"))
    lineitem = Scan(
        "lineitem",
        And(
            (
                Cmp("l_shipdate", ">=", days("1995-01-01")),
                Cmp("l_shipdate", "<=", days("1996-12-31")),
            )
        ),
        ("l_orderkey", "l_suppkey", "l_shipyear", "l_extendedprice", "l_discount"),
    )
    j1 = HashJoin(
        supp_side, lineitem, ("s_suppkey",), ("l_suppkey",), ("n_name",), payload_as=("supp_nation",)
    )
    j2 = HashJoin(orders, j1, ("o_orderkey",), ("l_orderkey",), ("o_custkey",))
    j3 = HashJoin(
        cust_side,
        j2,
        ("c_custkey",),
        ("o_custkey",),
        ("n_name",),
        payload_as=("cust_nation",),
        post_filter=ColCmp("supp_nation", "!=", "cust_nation"),
    )
    agg = Aggregate(
        j3,
        ("supp_nation", "cust_nation", "l_shipyear"),
        (AggSpec("sum", REVENUE, name="revenue"),),
    )
    return OrderBy(agg, ("supp_nation", "cust_nation", "l_shipyear"), (True, True, True))


def q8_plan(db: Database, p: Dict) -> object:
    ptype, nation, region = p["type"], p["nation"], p["region"]
    part = Scan("part", Cmp("p_type", "==", ptype), ("p_partkey",))
    supplier = Scan("supplier", TRUE, ("s_suppkey", "s_nationkey"))
    nat_reg = HashJoin(
        Scan("region", Cmp("r_name", "==", region), ("r_regionkey",)),
        Scan("nation", TRUE, ("n_nationkey", "n_regionkey")),
        ("r_regionkey",),
        ("n_regionkey",),
        (),
    )
    cust_region = HashJoin(
        nat_reg,
        Scan("customer", TRUE, ("c_custkey", "c_nationkey")),
        ("n_nationkey",),
        ("c_nationkey",),
        (),
    )
    orders = Scan(
        "orders",
        And(
            (
                Cmp("o_orderdate", ">=", days("1995-01-01")),
                Cmp("o_orderdate", "<=", days("1996-12-31")),
            )
        ),
        ("o_orderkey", "o_custkey", "o_orderyear"),
    )
    order_cust = HashJoin(
        cust_region, orders, ("c_custkey",), ("o_custkey",), ()
    )
    nation_name = Scan("nation", TRUE, ("n_nationkey", "n_name"))
    lineitem = Scan(
        "lineitem", TRUE, ("l_orderkey", "l_partkey", "l_suppkey", "l_extendedprice", "l_discount")
    )
    j1 = HashJoin(part, lineitem, ("p_partkey",), ("l_partkey",), ())
    j2 = HashJoin(supplier, j1, ("s_suppkey",), ("l_suppkey",), ("s_nationkey",))
    j3 = HashJoin(order_cust, j2, ("o_orderkey",), ("l_orderkey",), ("o_orderyear",))
    j4 = HashJoin(
        nation_name, j3, ("n_nationkey",), ("s_nationkey",), ("n_name",), payload_as=("supp_nation",)
    )
    vol = REVENUE
    agg = Aggregate(
        j4,
        ("o_orderyear",),
        (
            AggSpec("sum", WhereEq("supp_nation", float(nation), vol, Const(0.0)), name="nation_volume"),
            AggSpec("sum", vol, name="total_volume"),
        ),
    )
    return OrderBy(agg, ("o_orderyear",), (True,))


def q9_plan(db: Database, p: Dict) -> object:
    color = p["color"]
    part = Scan("part", Cmp("p_colorcode", "==", color), ("p_partkey",))
    supplier = Scan("supplier", TRUE, ("s_suppkey", "s_nationkey"))
    partsupp = Scan("partsupp", TRUE, ("ps_partkey", "ps_suppkey", "ps_supplycost"))
    orders = Scan("orders", TRUE, ("o_orderkey", "o_orderyear"))
    nation = Scan("nation", TRUE, ("n_nationkey", "n_name"))
    lineitem = Scan(
        "lineitem",
        TRUE,
        ("l_orderkey", "l_partkey", "l_suppkey", "l_quantity", "l_extendedprice", "l_discount"),
    )
    j1 = HashJoin(part, lineitem, ("p_partkey",), ("l_partkey",), ())
    j2 = HashJoin(
        partsupp, j1, ("ps_partkey", "ps_suppkey"), ("l_partkey", "l_suppkey"), ("ps_supplycost",)
    )
    j3 = HashJoin(supplier, j2, ("s_suppkey",), ("l_suppkey",), ("s_nationkey",))
    j4 = HashJoin(orders, j3, ("o_orderkey",), ("l_orderkey",), ("o_orderyear",))
    j5 = HashJoin(nation, j4, ("n_nationkey",), ("s_nationkey",), ("n_name",))
    profit = BinOp("-", REVENUE, BinOp("*", Col("ps_supplycost"), Col("l_quantity")))
    agg = Aggregate(j5, ("n_name", "o_orderyear"), (AggSpec("sum", profit, name="sum_profit"),))
    return OrderBy(agg, ("n_name", "o_orderyear"), (True, False))


def q10_plan(db: Database, p: Dict) -> object:
    d0 = p["date"]
    d1 = d0 + 92
    customer = Scan("customer", TRUE, ("c_custkey", "c_nationkey", "c_acctbal"))
    orders = Scan(
        "orders",
        And((Cmp("o_orderdate", ">=", d0), Cmp("o_orderdate", "<", d1))),
        ("o_orderkey", "o_custkey"),
    )
    cust_orders = HashJoin(
        customer,
        orders,
        ("c_custkey",),
        ("o_custkey",),
        ("c_custkey", "c_nationkey", "c_acctbal"),
    )
    nation = Scan("nation", TRUE, ("n_nationkey", "n_name"))
    lineitem = Scan(
        "lineitem",
        Cmp("l_returnflag", "==", 0.0),  # 'R'
        ("l_orderkey", "l_extendedprice", "l_discount", "l_returnflag"),
    )
    j1 = HashJoin(
        cust_orders,
        lineitem,
        ("o_orderkey",),
        ("l_orderkey",),
        ("c_custkey", "c_nationkey", "c_acctbal"),
    )
    j2 = HashJoin(nation, j1, ("n_nationkey",), ("c_nationkey",), ("n_name",))
    agg = Aggregate(
        j2,
        ("c_custkey", "n_name"),
        (AggSpec("sum", REVENUE, name="revenue"), AggSpec("max", Col("c_acctbal"), name="c_acctbal")),
    )
    return OrderBy(agg, ("revenue",), (False,), limit=20)


# ---------------------------------------------------------------------------
# Parameter samplers (uniform over benchmark domains, paper §6.1)
# ---------------------------------------------------------------------------


def _sample_params(template: str, rng: np.random.Generator) -> Dict:
    if template == "q1":
        return {"delta": int(rng.integers(60, 121))}
    if template == "q3":
        return {
            "segment": float(rng.integers(0, len(SEGMENTS))),
            "date": float(days("1995-03-01") + rng.integers(0, 31)),
        }
    if template == "q4":
        y = int(rng.integers(1993, 1998))
        m = int(rng.integers(1, 13)) if y < 1997 else int(rng.integers(1, 11))
        return {"date": float(_first_of_month(y, m))}
    if template == "q5":
        return {
            "region": float(rng.integers(0, len(REGIONS))),
            "date": float(_first_of_month(int(rng.integers(1993, 1998)), 1)),
        }
    if template == "q6":
        return {
            "date": float(_first_of_month(int(rng.integers(1993, 1998)), 1)),
            "discount": float(rng.integers(2, 10)) / 100.0,
            "quantity": float(rng.integers(24, 26)),
        }
    if template == "q7":
        n1 = int(rng.integers(0, 25))
        n2 = int(rng.integers(0, 24))
        if n2 >= n1:
            n2 += 1
        return {"nation1": float(n1), "nation2": float(n2)}
    if template == "q8":
        nation_idx = int(rng.integers(0, 25))
        region_idx = NATIONS[nation_idx][1]
        return {
            "nation": float(nation_idx),
            "region": float(region_idx),
            "type": float(rng.integers(0, len(TYPES))),
        }
    if template == "q9":
        return {"color": float(rng.integers(0, len(COLORS)))}
    if template == "q10":
        months = [(y, m) for y in (1993, 1994) for m in range(1, 13)] + [(1995, 1)]
        y, m = months[int(rng.integers(0, len(months)))]
        return {"date": float(_first_of_month(y, m))}
    raise KeyError(template)


BUILDERS = {
    "q1": q1_plan,
    "q3": q3_plan,
    "q4": q4_plan,
    "q5": q5_plan,
    "q6": q6_plan,
    "q7": q7_plan,
    "q8": q8_plan,
    "q9": q9_plan,
    "q10": q10_plan,
}

# Zipf rank order (the paper doesn't specify it). Q3 — the paper's running
# hash-join instance — ranks first, so higher template skew concentrates
# arrivals on join-state-compatible queries, matching the paper's Fig. 11
# narrative; the scan-only templates (Q1, Q6) rank mid/low.
DEFAULT_TEMPLATES = ["q3", "q10", "q1", "q5", "q4", "q7", "q8", "q6", "q9"]

_next_qid = [0]


def make_query(db: Database, template: str, params: Dict, arrival: float = 0.0) -> Query:
    _next_qid[0] += 1
    plan = BUILDERS[template](db, params)
    return Query(qid=_next_qid[0], template=template, plan=plan, params=params, arrival=arrival)


def sample_query(
    db: Database, rng: np.random.Generator, zipf_alpha: float = 1.0, arrival: float = 0.0,
    templates: Optional[List[str]] = None,
) -> Query:
    templates = templates or DEFAULT_TEMPLATES
    ranks = np.arange(1, len(templates) + 1, dtype=np.float64)
    w = ranks ** (-zipf_alpha)
    w /= w.sum()
    template = templates[int(rng.choice(len(templates), p=w))]
    return make_query(db, template, _sample_params(template, rng), arrival)
