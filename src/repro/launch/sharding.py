"""Sharding rules: name-pattern PartitionSpecs for params, optimizer state,
batches, and decode caches (DESIGN.md §4).

Scheme: 2-D FSDP x TP. The tensor-parallel ('model') axis shards heads /
d_ff / experts / vocab; the FSDP axis ('data', or ('pod','data') multi-pod)
shards the complementary dim of every weight. KV projections stay replicated
over 'model' when kv_heads isn't divisible (GQA/MQA); the decode KV cache
then shards its *sequence* dim instead (split-KV decode). Any dim not
divisible by its axis size falls back to replication (never a compile
failure) — the roofline report makes the cost of such fallbacks visible.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .mesh import data_axes


def _axsize(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def _spec(mesh: Mesh, shape: Tuple[int, ...], axes: Tuple) -> P:
    """PartitionSpec with divisibility fallback to replication per dim."""
    out = []
    for size, ax in zip(shape, axes):
        if ax is not None and size % _axsize(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


# leaf name -> (axes per dim, by ndim excluding the leading stack dim)
def _param_axes(name: str, ndim_tail: int, dp) -> Optional[Tuple]:
    mp = "model"
    table = {
        "embed": (mp, dp),
        "lm_head": (dp, mp),
        "wq": (dp, mp, None),
        "wk": (dp, None, None),
        "wv": (dp, None, None),
        "wo": (mp, None, dp),
        "cwq": (dp, mp, None),
        "cwk": (dp, None, None),
        "cwv": (dp, None, None),
        "cwo": (mp, None, dp),
        "router": (dp, None),
        "shared_gate": (dp, mp),
        "shared_up": (dp, mp),
        "shared_down": (mp, dp),
        "w_recept": (dp, mp),
        "w_gate_in": (dp, mp),
        "w_rec_in": (dp, mp),
        "w_out": (mp, dp),
        "w_a": (dp, mp),
        "w_x": (dp, mp),
        "conv_w": (None, mp),
        "conv_b": (mp,),
        "lam": (mp,),
        "w_r": (dp, mp),
        "w_k": (dp, mp),
        "w_v": (dp, mp),
        "w_g": (dp, mp),
        "w_o": (mp, dp),
        "w_dec0": (mp,),
        "w_dec1": (dp, None),
        "w_dec2": (None, mp),
        "u": (mp,),
        "ln_w": (mp, None),
        "ln_b": (mp, None),
    }
    if name in ("w_gate", "w_up", "w_down"):
        if ndim_tail == 3:  # MoE expert-stacked [E, D, F] / [E, F, D]
            return ("model", dp, None) if name != "w_down" else ("model", None, dp)
        return (dp, "model") if name != "w_down" else ("model", dp)
    return table.get(name)


def param_specs(cfg: ModelConfig, mesh: Mesh, abstract) -> Any:
    """Spec tree matching the (abstract) param tree."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def one(path, leaf):
        name = _leaf_name(path)
        stacked = _is_stacked(path)
        tail = leaf.ndim - (1 if stacked else 0)
        axes = _param_axes(name, tail, dp)
        if axes is None:
            return P()  # norms etc: replicate
        full = ((None,) + tuple(axes)) if stacked else tuple(axes)
        full = full[: leaf.ndim] + (None,) * (leaf.ndim - len(full))
        return _spec(mesh, leaf.shape, full)

    return _tree_map_with_path(one, abstract)


def opt_specs(cfg: ModelConfig, mesh: Mesh, abstract_opt, pspecs) -> Any:
    """Optimizer state shards exactly like its parameter (ZeRO-3)."""

    def one(path, leaf):
        if _leaf_name(path) == "step" or leaf.ndim == 0:
            return P()
        # path = opt_state[kind][...param path...]; strip the leading key
        sub = pspecs
        for k in path[1:]:
            key = k.key if hasattr(k, "key") else k.idx
            sub = sub[key]
        return sub

    return _tree_map_with_path(one, abstract_opt)


def batch_specs(mesh: Mesh, abstract_batch) -> Any:
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def one(path, leaf):
        if leaf.ndim == 0:
            return P()
        axes = (dp,) + (None,) * (leaf.ndim - 1)
        return _spec(mesh, leaf.shape, axes)

    return _tree_map_with_path(one, abstract_batch)


def cache_specs(cfg: ModelConfig, mesh: Mesh, abstract_cache) -> Any:
    """KV cache: batch over FSDP axis; heads over 'model' when divisible,
    otherwise the sequence dim (split-KV decode). Recurrent states shard
    their channel dim over 'model'."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    mp = "model"
    kv_div = cfg.n_kv_heads % _axsize(mesh, mp) == 0

    def one(path, leaf):
        name = _leaf_name(path)
        if name in ("k", "v", "ck", "cv"):  # [n, B, T, KV, dh]
            axes = (None, dp, None, mp, None) if kv_div else (None, dp, mp, None, None)
        elif name == "pos":
            axes = (None, None)
        elif name == "S":  # [n, B, H, dh, dh]
            axes = (None, dp, mp, None, None)
        elif name == "h":  # [n, B, R]
            axes = (None, dp, mp)
        elif name == "conv":  # [n, B, W-1, R]
            axes = (None, dp, None, mp)
        elif name == "x_prev":  # [n, B, D]
            axes = (None, dp, None)
        else:
            axes = (None,) * leaf.ndim
        return _spec(mesh, leaf.shape, axes)

    return _tree_map_with_path(one, abstract_cache)


def act_spec(cfg: ModelConfig, mesh: Mesh, seq_len: int) -> Optional[P]:
    """Residual-stream constraint between layers: sequence-sharded over
    'model' (Megatron sequence parallelism) when divisible."""
    if not cfg.seq_shard_activations:
        return None
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    if seq_len % _axsize(mesh, "model") != 0:
        return P(dp, None, None)
    return P(dp, "model", None)


def to_shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


# -- tree helpers ------------------------------------------------------------


def _leaf_name(path) -> str:
    for k in reversed(path):
        if hasattr(k, "key"):
            return str(k.key)
    return ""


def _is_stacked(path) -> bool:
    return any(hasattr(k, "key") and k.key in ("groups", "enc_groups") for k in path)


def _tree_map_with_path(fn, tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return jax.tree_util.tree_unflatten(treedef, [fn(p, l) for p, l in flat])
