"""Launchers: production mesh, sharding rules, multi-pod dry-run, roofline
analysis, training and serving drivers."""
