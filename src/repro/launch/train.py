"""Training driver.

Production path: build the mesh, shard params/opt/batches by the rules in
launch/sharding.py, jit the train step, stream the deterministic data
pipeline, checkpoint every --ckpt-every steps (async, atomic), restore
(elastically) from --ckpt-dir if present.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt

--smoke uses the reduced same-family config so the driver runs end-to-end
on this CPU container; on TPU the same code path takes the full config.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..models import model as M
from ..models.shardctx import set_shard_hints
from ..train import checkpoint as CKPT
from ..train.data import Pipeline, batch_at
from ..train.optim import init_opt_state
from ..train.train_step import make_train_step
from .mesh import make_smoke_mesh, make_production_mesh
from . import sharding as SH


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--structured-data", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_production_mesh() if args.production_mesh else make_smoke_mesh()
    set_shard_hints(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key, dtype=jnp.float32)
    opt = init_opt_state(params, cfg.optimizer)
    pspecs = SH.param_specs(cfg, mesh, params)
    ospecs = SH.opt_specs(cfg, mesh, opt, pspecs)
    psh = SH.to_shardings(mesh, pspecs)
    osh = SH.to_shardings(mesh, ospecs)
    params = jax.device_put(params, psh)
    opt = jax.device_put(opt, osh)

    start_step = 0
    if args.ckpt_dir and CKPT.latest_step(args.ckpt_dir) is not None:
        params, opt, manifest = CKPT.restore(
            args.ckpt_dir, target_params=params, target_opt=opt, shardings=(psh, osh)
        )
        start_step = manifest["step"]
        print(f"restored step {start_step} from {args.ckpt_dir} (mesh was {manifest['mesh_shape']})")

    step_fn = make_train_step(cfg, act_spec=None, n_microbatches=args.microbatches, lr=args.lr)
    with mesh:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        pipe = Pipeline(
            args.seed + 1, args.batch, args.seq, cfg.vocab, start_step=start_step,
            structured=args.structured_data,
        )
        t0 = time.time()
        writer = None
        for step in range(start_step, args.steps):
            raw = next(pipe)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            params, opt, metrics = jit_step(params, opt, batch)
            if step % 10 == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                print(
                    f"step {step:5d} loss {loss:8.4f} |grad| {gn:8.3f} "
                    f"({(time.time()-t0)/(step-start_step+1):.2f}s/step)",
                    flush=True,
                )
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                if writer is not None:
                    writer.join()
                writer = CKPT.save(
                    args.ckpt_dir,
                    step + 1,
                    params,
                    opt,
                    data_cursor=pipe.cursor,
                    rng_key=key,
                    mesh_shape=tuple(mesh.devices.shape),
                    async_write=True,
                )
        if writer is not None:
            writer.join()
        if args.ckpt_dir:
            CKPT.save(
                args.ckpt_dir, args.steps, params, opt,
                data_cursor=pipe.cursor, rng_key=key,
                mesh_shape=tuple(mesh.devices.shape),
            )
    return params, opt


if __name__ == "__main__":
    main()
