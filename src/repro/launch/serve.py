"""Serving driver: continuous batching with dynamic KV-prefix folding.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --smoke \
      --requests 16

Runs a REAL reduced model end-to-end: prefix states hold actual KV caches
(models.model prefill), folded requests fork from the shared prefix cache
and decode greedily; the isolated baseline re-prefills every prompt.
Demonstrates that folding preserves outputs exactly while skipping
represented prefill work.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..models import model as M


class RealExecutor:
    """Tiny-model executor: actual prefill/decode with KV-cache forking."""

    def __init__(self, cfg, params, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.prefill_tokens_computed = 0
        self._step = jax.jit(
            lambda params, cache, tok, pos: M.decode_step(cfg, params, cache, tok, pos)
        )

    def prefill_cache(self, tokens: np.ndarray, cache=None, start: int = 0):
        """Sequential decode-mode prefill from position ``start`` (reusing a
        forked cache below ``start``). Returns (cache, last_logits)."""
        if cache is None:
            cache = M.init_cache(self.cfg, 1, self.max_len, dtype=jnp.float32)
        logits = None
        for t in range(start, len(tokens)):
            tok = jnp.asarray(tokens[t : t + 1][None], jnp.int32)
            logits, cache = self._step(self.params, cache, tok, jnp.int32(t))
            self.prefill_tokens_computed += 1
        return cache, logits

    def decode(self, cache, last_logits, start_pos: int, n: int) -> List[int]:
        out = []
        logits = last_logits
        for i in range(n):
            tok = int(jnp.argmax(logits[0, -1]))
            out.append(tok)
            logits, cache = self._step(
                self.params, cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(start_pos + i)
            )
        return out


def fork(cache):
    return jax.tree.map(lambda x: x, cache)  # jax arrays are immutable — zero-copy fork


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=48)
    ap.add_argument("--suffix-len", type=int, default=8)
    ap.add_argument("--decode", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    shared = rng.integers(0, cfg.vocab, args.prefix_len)
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab, args.suffix_len)])
        for _ in range(args.requests)
    ]

    # isolated: full prefill per request
    ex = RealExecutor(cfg, params)
    t0 = time.time()
    iso_out = []
    for p in prompts:
        cache, logits = ex.prefill_cache(p)
        iso_out.append(ex.decode(cache, logits, len(p), args.decode))
    iso_tokens, iso_t = ex.prefill_tokens_computed, time.time() - t0

    # folded: prefill the shared prefix once, fork + suffix per request
    ex2 = RealExecutor(cfg, params)
    t0 = time.time()
    prefix_cache, _ = ex2.prefill_cache(shared)
    fold_out = []
    for p in prompts:
        cache, logits = ex2.prefill_cache(p, cache=fork(prefix_cache), start=len(shared))
        fold_out.append(ex2.decode(cache, logits, len(p), args.decode))
    fold_tokens, fold_t = ex2.prefill_tokens_computed, time.time() - t0

    match = iso_out == fold_out
    print(f"outputs identical: {match}")
    print(f"isolated: {iso_tokens} prefill tokens, {iso_t:.1f}s")
    print(f"folded:   {fold_tokens} prefill tokens, {fold_t:.1f}s "
          f"({iso_tokens/max(fold_tokens,1):.1f}x fewer)")
    if not match:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
