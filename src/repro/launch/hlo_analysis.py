"""Static analyzer for post-optimization (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts ``while`` loop bodies ONCE, which
under-reports scan-over-layers models by ~n_layers x. This analyzer walks
the HLO call graph (entry -> fusions/calls/while bodies), extracts static
trip counts from loop conditions, and accumulates:

* matmul FLOPs        — every ``dot`` op: 2 * prod(result) * prod(contracting)
* HBM byte traffic    — per top-level instruction: result + operand bytes
                        (fused elementwise subcomputations are free — they
                        never round-trip HBM)
* ICI collective traffic — per collective, ring-model bytes-on-link:
      all-reduce       2 (n-1)/n * result
      all-gather         (n-1)/n * result
      reduce-scatter     (n-1)   * result      (result is the scattered shard)
      all-to-all         (n-1)/n * result
      collective-permute           result

All quantities are PER DEVICE (the SPMD module is the per-device program).
This is a structural model derived from the compiled artifact, not a
wall-clock trace — exactly what the CPU-only dry-run can provide.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->\s*(.+?)\s*\{")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_OPCODE = re.compile(
    r"\b(dot|while|fusion|call|conditional|custom-call|"
    + "|".join(c for c in COLLECTIVES)
    + r"|convolution|get-tuple-element|parameter|constant|tuple)\b"
)


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) type string."""
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclass
class Instr:
    name: str
    opcode: str
    rhs: str
    result_type: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # value name -> type str


@dataclass
class Stats:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)

    def add(self, other: "Stats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.mem_bytes += other.mem_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + int(v * mult)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and ("{" in line) and ("=" not in line.split("(")[0]):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry_name = cur.name
            # parameter shapes from the signature
            for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))", hdr.group(2)):
                cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR.match(line)
        if im:
            name, rhs = im.group(1), im.group(2)
            opm = _OPCODE.search(rhs)
            opcode = opm.group(1) if opm else "other"
            # result type: prefix of rhs up to opcode
            rtype = rhs[: opm.start()] if opm else rhs
            cur.instrs.append(Instr(name, opcode, rhs, rtype))
            cur.shapes[name] = rtype
    if entry_name is None:
        raise ValueError("no ENTRY computation found")
    comps["__entry__"] = comps[entry_name]
    return comps


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for i in cond.instrs:
        consts += [int(v) for v in _CONST.findall(i.rhs)]
        # constants may also live in fused sub-computations of the cond
        cm = _CALL_ATTR.search(i.rhs)
        if cm and cm.group(1) in comps:
            for j in comps[cm.group(1)].instrs:
                consts += [int(v) for v in _CONST.findall(j.rhs)]
    return max(consts) if consts else 1


def _dot_flops(comp: Computation, ins: Instr) -> float:
    res = shape_dims(ins.result_type)
    if res is None:
        return 0.0
    _, rdims = res
    ops = _OPERAND.findall(ins.rhs)
    lhs_type = comp.shapes.get(ops[0]) if ops else None
    cm = _CONTRACT.search(ins.rhs)
    contract = 1
    if lhs_type and cm:
        ls = shape_dims(lhs_type)
        if ls:
            for d in cm.group(1).split(","):
                if d:
                    idx = int(d)
                    if idx < len(ls[1]):
                        contract *= ls[1][idx]
    n = 1
    for d in rdims:
        n *= d
    return 2.0 * n * contract


def _coll_traffic(kind: str, result_bytes: float, group: int) -> float:
    n = max(group, 2)
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if kind == "all-gather":
        return (n - 1) / n * result_bytes
    if kind == "reduce-scatter":
        return (n - 1) * result_bytes
    if kind == "all-to-all":
        return (n - 1) / n * result_bytes
    return result_bytes  # collective-permute


def analyze(text: str) -> Stats:
    comps = parse_module(text)
    memo: Dict[str, Stats] = {}

    def comp_stats(name: str, stack=()) -> Stats:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Stats()
        comp = comps[name]
        st = Stats()
        for ins in comp.instrs:
            if ins.opcode == "dot":
                st.flops += _dot_flops(comp, ins)
            if ins.opcode in COLLECTIVES:
                rb = shape_bytes(ins.result_type)
                gm = _GROUPS.search(ins.rhs)
                group = int(gm.group(2)) if gm else 2
                st.coll_bytes[ins.opcode] = st.coll_bytes.get(ins.opcode, 0.0) + _coll_traffic(
                    ins.opcode, rb, group
                )
                st.coll_count[ins.opcode] = st.coll_count.get(ins.opcode, 0) + 1
            # HBM traffic model: top-level instruction results are written
            # once; operands read once — EXCEPT slice-like ops, which touch
            # only the sliced/updated region, not the whole buffer (counting
            # full operands inflated scan-heavy models ~5x; §Perf iter 3).
            if ins.opcode not in ("parameter", "constant", "tuple", "get-tuple-element"):
                rb = shape_bytes(ins.result_type)
                slice_like = any(
                    kw in ins.rhs
                    for kw in (
                        " dynamic-slice(",
                        " dynamic-update-slice(",
                        " gather(",
                        " scatter(",
                        " slice(",
                    )
                )
                if slice_like:
                    sizes = [rb]
                    for op in _OPERAND.findall(ins.rhs):
                        t = comp.shapes.get(op)
                        if t:
                            b = shape_bytes(t)
                            if b > 0:
                                sizes.append(b)
                    st.mem_bytes += 2 * min(sizes)
                elif ins.opcode == "fusion":
                    # fusions absorb slices/broadcasts of big (often
                    # loop-carried) operands: cap each operand at the
                    # result size. Exact matmul traffic is counted at the
                    # dot ops; this keeps elementwise fusions ~2x result,
                    # which matches their real HBM behavior (§Perf iter 6).
                    st.mem_bytes += rb
                    for op in _OPERAND.findall(ins.rhs):
                        t = comp.shapes.get(op)
                        if t:
                            st.mem_bytes += min(shape_bytes(t), rb)
                else:
                    st.mem_bytes += rb
                    for op in _OPERAND.findall(ins.rhs):
                        t = comp.shapes.get(op)
                        if t:
                            st.mem_bytes += shape_bytes(t)
            # recursion
            if ins.opcode == "while":
                body = cond = None
                for cm in _CALL_ATTR.finditer(ins.rhs):
                    pass
                bm = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                cn = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                if bm:
                    trips = trip_count(comps, cn.group(1)) if cn else 1
                    st.add(comp_stats(bm.group(1), stack + (name,)), trips)
                    if cn:
                        st.add(comp_stats(cn.group(1), stack + (name,)), trips)
            elif ins.opcode in ("fusion", "call", "custom-call", "conditional"):
                for cm in _CALL_ATTR.finditer(ins.rhs):
                    sub = comp_stats(cm.group(1), stack + (name,))
                    # fused elementwise bodies don't touch HBM; only count
                    # their dots/collectives (rare but possible via calls)
                    st.flops += sub.flops
                    for k, v in sub.coll_bytes.items():
                        st.coll_bytes[k] = st.coll_bytes.get(k, 0.0) + v
                    for k, v in sub.coll_count.items():
                        st.coll_count[k] = st.coll_count.get(k, 0) + v
        memo[name] = st
        return st

    return comp_stats("__entry__")
