"""GraftDB data-plane dry-run as a validated record (DESIGN.md §14).

Promotes the former print-only ``dryrun.py --db-plane`` path into a
function: lower + compile the distributed data plane — the bucketed
all_to_all hash join, the psum aggregate, and the shard-local fused stage
chain — on an arbitrary mesh, and return one record that
``validate_db_plane_record`` checks structurally. The dry-run script and
the tier-1 smoke-mesh test share this code, so the path CI exercises on a
single device is byte-for-byte the path the 256-device dry-run compiles.

No XLA_FLAGS side effects here: callers choose the device count (the
dry-run script sets --xla_force_host_platform_device_count before any jax
import; tests run on the single real device via ``make_smoke_mesh``).
"""

from __future__ import annotations

import time
from typing import Dict, Optional

REQUIRED_FIELDS = (
    "arch",
    "shape",
    "mesh",
    "data_shards",
    "rows",
    "status",
    "hlo_stats",
    "aggregate",
    "chain",
    "total_s",
)
HLO_STAT_FIELDS = (
    "flops_per_device",
    "mem_bytes_per_device",
    "coll_bytes_per_device",
    "coll_count",
)


def _mesh_label(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def _chain_parity(mesh, rows: int) -> Dict:
    """Run one minimal fused stage chain both unsharded and shard-locally
    inside shard_map on ``mesh``, and compare every output bit-for-bit
    (stats/slot counts are psum'd global; row outputs gather in shard
    order, which is row order for row-partitioned inputs)."""
    import numpy as np

    from ..kernels.fused_chain import chain_launch
    from ..kernels.hash_probe import EMPTY

    d = int(mesh.shape["data"])
    rows = max(rows, d)
    rows = (rows // d) * d
    cap = 64
    ecap = 64
    rng = np.random.default_rng(7)
    n_entries = 40
    # open-addressed table: entry keys 1..n_entries at their probe slots
    keys_host = np.arange(1, n_entries + 1, dtype=np.int32)
    tkeys = np.full(cap, EMPTY, np.int32)
    tentry = np.zeros(cap, np.int32)
    from ..kernels.hash_probe import MULT

    for e, k in enumerate(keys_host):
        pos = (int(k) * MULT) & (cap - 1)
        while tkeys[pos] != EMPTY:
            pos = (pos + 1) & (cap - 1)
        tkeys[pos] = k
        tentry[pos] = e
    evlo = np.full(ecap, 0xFFFFFFFF, np.uint32)
    evhi = np.full(ecap, 0xFFFFFFFF, np.uint32)
    # identity byte translation tables
    ttlo = np.zeros((8, 256), np.uint32)
    tthi = np.zeros((8, 256), np.uint32)
    for b in range(4):
        ttlo[b] = np.arange(256, dtype=np.uint32) << np.uint32(8 * b)
        tthi[4 + b] = np.arange(256, dtype=np.uint32) << np.uint32(8 * b)
    probe_keys = rng.integers(1, 2 * n_entries, rows).astype(np.int32)
    bits_lo = np.ones(rows, np.uint32)
    bits_hi = np.zeros(rows, np.uint32)
    spec = (((-1, 0, 0, None),), False)
    arrays = (bits_lo, bits_hi, probe_keys, tkeys, tentry, evlo, evhi, ttlo, tthi)
    ref = chain_launch(spec, arrays)
    shd = chain_launch(spec, arrays, mesh=mesh)
    ok = all(
        np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(ref, shd)
    )
    return {
        "rows": int(rows),
        "data_shards": d,
        "parity": bool(ok),
        "matched_rows": int(np.asarray(ref[-2])[0, 1]),
    }


def db_plane_record(
    mesh,
    *,
    rows: int = 1 << 26,
    n_groups: int = 256,
    chain_rows: Optional[int] = 2048,
) -> Dict:
    """Lower+compile the distributed GraftDB data plane on ``mesh`` and
    return a validated record — proves the paper's engine itself shards
    across the pod (DESIGN.md §4/§14). ``chain_rows=None`` skips the
    executed fused-chain parity block (it RUNS the kernel; the join and
    aggregate only compile)."""
    import jax
    import jax.numpy as jnp

    from ..relational.distributed import make_partitioned_aggregate, make_partitioned_join
    from .hlo_analysis import analyze

    t0 = time.time()
    d = int(mesh.shape["data"])
    rec: Dict = {
        "arch": "graftdb-dataplane",
        "shape": f"join_{rows >> 20 if rows >= 1 << 20 else rows}"
        + ("M" if rows >= 1 << 20 else ""),
        "mesh": _mesh_label(mesh),
        "data_shards": d,
        "rows": int(rows),
        "status": "ok",
        "aggregate": "skipped",
        "chain": "skipped",
    }
    try:
        capacity = max(8, 2 * rows // d // max(d, 1))
        join = make_partitioned_join(
            mesh, build_width=2, probe_width=3, capacity=capacity
        )
        sds = jax.ShapeDtypeStruct
        bk = sds((rows,), jnp.int64)
        bv = sds((rows, 2), jnp.float32)
        pk = sds((rows,), jnp.int64)
        pv = sds((rows, 3), jnp.float32)
        compiled = join.lower(bk, bv, pk, pv).compile()
        st = analyze(compiled.as_text())
        rec["hlo_stats"] = {
            "flops_per_device": float(st.flops),
            "mem_bytes_per_device": float(st.mem_bytes),
            "coll_bytes_per_device": float(sum(st.coll_bytes.values())),
            "coll_count": int(sum(st.coll_count.values())),
            "coll_by_op": {k: int(v) for k, v in st.coll_count.items()},
        }
        agg = make_partitioned_aggregate(mesh, n_groups=n_groups, width=4)
        agg.lower(sds((rows,), jnp.int32), sds((rows, 4), jnp.float32)).compile()
        rec["aggregate"] = "ok"
        if chain_rows is not None:
            rec["chain"] = _chain_parity(mesh, chain_rows)
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def validate_db_plane_record(rec: Dict) -> Dict:
    """Structural + status validation of a db-plane record; raises
    ValueError with the first problem found, returns the record on
    success (so call sites can chain it)."""
    missing = [f for f in REQUIRED_FIELDS if f not in rec]
    if missing:
        raise ValueError(f"db-plane record missing fields: {missing}")
    if rec["status"] != "ok":
        raise ValueError(
            f"db-plane dry-run failed: {rec.get('error', 'unknown error')}"
        )
    hs = rec["hlo_stats"]
    bad = [f for f in HLO_STAT_FIELDS if not isinstance(hs.get(f), (int, float))]
    if bad:
        raise ValueError(f"db-plane hlo_stats malformed fields: {bad}")
    if rec["aggregate"] != "ok":
        raise ValueError(f"db-plane aggregate compile failed: {rec['aggregate']!r}")
    chain = rec["chain"]
    if chain != "skipped":
        if not isinstance(chain, dict) or not chain.get("parity"):
            raise ValueError(
                f"shard-local fused chain is not bit-identical to the "
                f"unsharded launch: {chain!r}"
            )
        if chain.get("matched_rows", 0) <= 0:
            raise ValueError(
                f"chain parity block matched no rows — vacuous check: {chain!r}"
            )
    if rec["data_shards"] > 1 and hs["coll_count"] <= 0:
        raise ValueError(
            "multi-shard join compiled to zero collectives — the exchange "
            "was elided, the plan is not actually distributed"
        )
    return rec
