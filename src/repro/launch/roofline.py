"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Reads benchmarks/results/dryrun.json (written by launch/dryrun.py, which
runs the trip-count-aware HLO analyzer) and derives, per (arch x shape x
mesh):

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = ICI_bytes_per_device / link_bw

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
MODEL_FLOPS = 6*N*D (train; N_active for MoE) or 2*N_active*tokens
(prefill/decode) — the MODEL/HLO ratio exposes remat, padding, and
dispatch waste.

  PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16] [--md out.md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from ..configs import ARCHS, SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s/link

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun.json"


def model_flops_per_device(arch: str, shape_name: str, n_chips: int) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    counts = cfg.param_counts()
    n_active = counts["active"]
    if shape["kind"] == "train":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 6.0 * n_active * tokens / n_chips
    if shape["kind"] == "prefill":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 2.0 * n_active * tokens / n_chips
    tokens = shape["global_batch"]  # decode: one new token per sequence
    return 2.0 * n_active * tokens / n_chips


def analyze_record(rec: dict) -> dict:
    n_chips = 512 if rec["mesh"] == "2x16x16" else 256
    hs = rec.get("hlo_stats") or {}
    flops = hs.get("flops_per_device", 0.0)
    mem = hs.get("mem_bytes_per_device", 0.0)
    coll = sum((hs.get("coll_bytes_per_device") or {}).values())
    t_c = flops / PEAK_FLOPS
    t_m = mem / HBM_BW
    t_i = coll / LINK_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_i)), key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(rec["arch"], rec["shape"], n_chips)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_i,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_frac": (
            max(t_c, 1e-30) / max(t_c, t_m, t_i, 1e-30)
        ),  # compute term share of the binding term
    }


def render_markdown(rows) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac |\n|---|---|---|---|---|---|---|---|---|\n"
    )
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} |\n"
        )
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16", help="16x16 | 2x16x16 | all")
    ap.add_argument("--md", default=None)
    ap.add_argument("--results", default=str(RESULTS))
    args = ap.parse_args()
    recs = json.loads(Path(args.results).read_text())
    rows = []
    for rec in recs:
        if rec["status"] != "ok" or not isinstance(rec.get("hlo_stats"), dict):
            continue
        if rec["arch"] not in ARCHS:
            continue  # auxiliary cells (graftdb-dataplane) have no 6ND model
        if args.mesh != "all" and rec["mesh"] != args.mesh:
            continue
        rows.append(analyze_record(rec))
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    md = render_markdown(rows)
    print(md)
    if args.md:
        Path(args.md).write_text(md)
    # summary: most interesting cells for the perf loop
    worst = sorted(rows, key=lambda r: r["roofline_frac"])[:5]
    print("\nworst roofline fraction (hillclimb candidates):")
    for r in worst:
        print(
            f"  {r['arch']}/{r['shape']}: dominant={r['dominant']} "
            f"frac={r['roofline_frac']:.2f} useful={r['useful_ratio']:.2f}"
        )
    coll_bound = sorted(rows, key=lambda r: -r["collective_s"])[:5]
    print("most collective-bound:")
    for r in coll_bound:
        print(f"  {r['arch']}/{r['shape']}: collective={r['collective_s']:.4f}s")


if __name__ == "__main__":
    main()
