"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run process sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else (tests, benches) sees the single real device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """Single-device mesh with the production axis names — lets the same
    sharding rules run in tests on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The compound FSDP/data-parallel axis: ('pod','data') on the multi-pod
    mesh, ('data',) on a single pod."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
