"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run process sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else (tests, benches) sees the single real device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """Single-device mesh with the production axis names — lets the same
    sharding rules run in tests on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_data: int):
    """Mesh with ``n_data`` shards on the 'data' axis and the production
    axis names. Used by the engine's mesh execution (P = data-axis size)
    and the host-device dry-runs (--xla_force_host_platform_device_count)."""
    n_data = int(n_data)
    if n_data < 1:
        raise ValueError(f"data-axis size must be >= 1, got {n_data}")
    return jax.make_mesh((n_data, 1), ("data", "model"))


def resolve_mesh(spec):
    """Resolve an EngineConfig ``mesh`` spec to a jax Mesh.

    Accepts: a Mesh (must carry a 'data' axis), the string 'smoke'
    (single-device smoke mesh), or an int n (n-way data mesh — requires n
    visible devices, e.g. via XLA_FLAGS=--xla_force_host_platform_device_count)."""
    if spec is None:
        raise ValueError("mesh spec is None — nothing to resolve")
    if isinstance(spec, str):
        if spec == "smoke":
            return make_smoke_mesh()
        raise ValueError(f"unknown mesh spec {spec!r}; expected 'smoke', an int, or a Mesh")
    if isinstance(spec, int):
        return make_data_mesh(spec)
    if "data" not in getattr(spec, "axis_names", ()):
        raise ValueError(
            f"mesh {spec!r} has no 'data' axis — the engine shards state over 'data'"
        )
    return spec


def mesh_data_size(spec) -> int:
    """The data-axis size a mesh spec resolves to, WITHOUT touching jax —
    safe to call from EngineConfig validation before any device init.
    ('smoke' -> 1, int n -> n, Mesh -> mesh.shape['data'].)"""
    if isinstance(spec, str):
        if spec == "smoke":
            return 1
        raise ValueError(f"unknown mesh spec {spec!r}; expected 'smoke', an int, or a Mesh")
    if isinstance(spec, int):
        if spec < 1:
            raise ValueError(f"data-axis size must be >= 1, got {spec}")
        return spec
    shape = getattr(spec, "shape", None)
    if shape is None or "data" not in shape:
        raise ValueError(
            f"mesh {spec!r} has no 'data' axis — the engine shards state over 'data'"
        )
    return int(shape["data"])


def data_axes(mesh) -> tuple:
    """The compound FSDP/data-parallel axis: ('pod','data') on the multi-pod
    mesh, ('data',) on a single pod."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
