import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax-importing import: jax locks the device count at
# first init, and the dry-run needs 512 placeholder host devices to build
# the production meshes. Never set this globally — smoke tests and benches
# see the single real device.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory/cost/collective statistics.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k --multi-pod both

Results append incrementally to benchmarks/results/dryrun.json so a long
sweep is resumable. Failures (sharding mismatch, OOM at compile,
unsupported collective) are bugs in the system — recorded, not swallowed.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCHS, SHAPES, cells, get_config  # noqa: E402
from ..models import model as M  # noqa: E402
from ..train.optim import abstract_opt_state  # noqa: E402
from ..train.train_step import make_train_step  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from . import sharding as SH  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun.json"

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
SHAPE_RE = re.compile(r"\b(f32|bf16|f16|s32|u32|s64|u64|pred|s8|u8|f64)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "pred": 1, "s8": 1, "u8": 1, "f64": 8,
}


def parse_collectives(hlo_text: str):
    """Sum result-operand sizes of every collective op in optimized HLO."""
    out = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # result shape(s): first type annotation(s) on the lhs
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(m.group(1))[0]
        nbytes = 0
        for sm in SHAPE_RE.finditer(lhs):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        ent = out.setdefault(kind, {"count": 0, "bytes": 0})
        ent["count"] += 1
        ent["bytes"] += nbytes
    return out


def _tree_bytes_per_device(abstract, specs, mesh) -> float:
    """Analytic bytes/device given shardings (fallback when the backend's
    memory_analysis is unavailable on CPU)."""
    total = 0.0
    flat_a = jax.tree.leaves(abstract)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    for a, s in zip(flat_a, flat_s):
        shards = 1
        for ax in s:
            if ax is None:
                continue
            for name in (ax if isinstance(ax, tuple) else (ax,)):
                shards *= mesh.shape[name]
        total += a.size * a.dtype.itemsize / shards
    return total


def build_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    from ..models.shardctx import set_shard_hints

    set_shard_hints(mesh)  # layer-internal constraints (MoE dispatch etc.)
    B, S = shape["global_batch"], shape["seq_len"]
    kind = shape["kind"]

    ap = M.abstract_params(cfg, jnp.bfloat16)
    pspecs = SH.param_specs(cfg, mesh, ap)
    psh = SH.to_shardings(mesh, pspecs)
    batch = M.input_specs(cfg, shape)
    bspecs = SH.batch_specs(mesh, batch)
    bsh = SH.to_shardings(mesh, bspecs)

    if kind == "train":
        aopt = abstract_opt_state(ap, cfg.optimizer)
        ospecs = SH.opt_specs(cfg, mesh, aopt, pspecs)
        osh = SH.to_shardings(mesh, ospecs)
        aspec = SH.act_spec(cfg, mesh, S)
        step = make_train_step(cfg, act_spec=aspec)
        with mesh:
            jitted = jax.jit(
                step,
                in_shardings=(psh, osh, bsh),
                out_shardings=(psh, osh, None),
            )
            lowered = jitted.lower(ap, aopt, batch)
        args_bytes = (
            _tree_bytes_per_device(ap, pspecs, mesh)
            + _tree_bytes_per_device(aopt, ospecs, mesh)
            + _tree_bytes_per_device(batch, bspecs, mesh)
        )
    elif kind == "prefill":
        aspec = SH.act_spec(cfg, mesh, S)

        def fn(params, b):
            return M.prefill(cfg, params, b, act_spec=aspec)

        with mesh:
            jitted = jax.jit(fn, in_shardings=(psh, bsh))
            lowered = jitted.lower(ap, batch)
        args_bytes = _tree_bytes_per_device(ap, pspecs, mesh) + _tree_bytes_per_device(
            batch, bspecs, mesh
        )
    else:  # decode
        acache = M.abstract_cache(cfg, B, S)
        cspecs = SH.cache_specs(cfg, mesh, acache)
        csh = SH.to_shardings(mesh, cspecs)

        def fn(params, cache, b):
            return M.decode_step(cfg, params, cache, b["token"], b["pos"])

        with mesh:
            jitted = jax.jit(fn, in_shardings=(psh, csh, bsh), out_shardings=(None, csh))
            lowered = jitted.lower(ap, acache, batch)
        args_bytes = (
            _tree_bytes_per_device(ap, pspecs, mesh)
            + _tree_bytes_per_device(acache, cspecs, mesh)
        )
    return lowered, args_bytes, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    t0 = time.time()
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
    }
    try:
        lowered, args_bytes, mesh = build_cell(arch, shape_name, multi_pod)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 1)
        rec["compile_s"] = round(t2 - t1, 1)
        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
            print(f"memory_analysis[{arch}/{shape_name}]: {rec['memory_analysis']}")
        except Exception as e:  # CPU backend may not implement it
            rec["memory_analysis"] = f"unavailable: {e}"
        rec["analytic_bytes_per_device"] = int(args_bytes)
        try:
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            rec["cost_analysis"] = {
                k: float(v)
                for k, v in ca.items()
                if k in ("flops", "bytes accessed", "transcendentals", "utilization operand")
                or k.startswith("bytes accessed")
            }
            print(f"cost_analysis[{arch}/{shape_name}]: flops={rec['cost_analysis'].get('flops')}")
        except Exception as e:
            rec["cost_analysis"] = f"unavailable: {e}"
        try:
            hlo = compiled.as_text()
            rec["collectives"] = parse_collectives(hlo)
            rec["hlo_bytes"] = len(hlo)
            # trip-count-aware static analysis (cost_analysis counts while
            # bodies once — see hlo_analysis.py)
            from .hlo_analysis import analyze

            st = analyze(hlo)
            rec["hlo_stats"] = {
                "flops_per_device": st.flops,
                "mem_bytes_per_device": st.mem_bytes,
                "coll_bytes_per_device": st.coll_bytes,
                "coll_count": st.coll_count,
            }
        except Exception as e:
            rec["collectives"] = f"unavailable: {e}"
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def load_results() -> list:
    if RESULTS.exists():
        return json.loads(RESULTS.read_text())
    return []


def save_results(res: list) -> None:
    RESULTS.parent.mkdir(parents=True, exist_ok=True)
    RESULTS.write_text(json.dumps(res, indent=1))


def run_db_plane(multi_pod: bool) -> dict:
    """Lower+compile the distributed GraftDB data plane (shard_map
    partitioned hash join + aggregate + shard-local fused chain) on the
    production mesh — proves the paper's engine itself shards across the
    pod (DESIGN.md §4/§14). Delegates to ``launch.db_plane`` so the
    validated-record path CI runs on the smoke mesh is the same code."""
    from .db_plane import db_plane_record

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = db_plane_record(mesh, rows=1 << 26)  # 64M rows global
    rec["mesh"] = "2x16x16" if multi_pod else "16x16"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", choices=["on", "off", "both"], default="off")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--redo", action="store_true")
    ap.add_argument("--db-plane", action="store_true")
    args = ap.parse_args()

    if args.db_plane:
        results = load_results()
        pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]
        from .db_plane import validate_db_plane_record

        for mp in pods:
            rec = run_db_plane(mp)
            key = (rec["arch"], rec["shape"], rec["mesh"])
            results = [r for r in results if (r["arch"], r["shape"], r["mesh"]) != key]
            results.append(rec)
            save_results(results)
            try:
                validate_db_plane_record(rec)
                valid = "valid"
            except ValueError as e:
                valid = f"INVALID ({e})"
            print(f"db-plane {rec['mesh']}: {rec['status']} ({valid}) "
                  f"coll={rec.get('hlo_stats',{}).get('coll_count')} "
                  f"chain={rec.get('chain')}", flush=True)
        return

    todo = []
    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]
    for a, s in cells():
        if args.arch and a != args.arch:
            continue
        if args.shape and s != args.shape:
            continue
        for mp in pods:
            todo.append((a, s, mp))

    results = load_results()
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r["status"] == "ok"}
    for a, s, mp in todo:
        key = (a, s, "2x16x16" if mp else "16x16")
        if key in done and not args.redo:
            print(f"skip {key} (cached)")
            continue
        print(f"=== dry-run {key} ===", flush=True)
        rec = run_cell(a, s, mp)
        results = [r for r in results if (r["arch"], r["shape"], r["mesh"]) != key]
        results.append(rec)
        save_results(results)
        print(
            f"--> {rec['status']} lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s"
            + (f" err={rec.get('error')}" if rec["status"] != "ok" else ""),
            flush=True,
        )


if __name__ == "__main__":
    main()
