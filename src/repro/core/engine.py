"""GraftEngine: the multi-query execution engine facade.

Execution modes (paper §6.1 / §6.4):

* ``isolated``     — same engine, all sharing disabled (private scans,
                     private pipelines, private states).
* ``qpipe_osp``    — QPipe's on-demand simultaneous pipelining: shared
                     scans + in-flight operator merge under *identical*
                     operator profiles (predicates included) with zero
                     progress; no coverage-based observation of built state.
* ``scan_sharing`` — shared cyclic scans only (+Scan Sharing variant).
* ``residual``     — + residual production into common shared state
                     (+Residual Production variant).
* ``graft``        — + represented-extent attachment through per-query
                     state lenses (full GraftDB).
"""

from __future__ import annotations

import heapq
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..relational.table import Database
from .descriptors import StateSignature, aggregate_signature
from .faults import FaultPlan, FaultPlane
from .grafting import all_boundaries, estimate_demand, plan_spine, resolve_boundary
from .plans import Aggregate, OrderBy, Query
from .predicates import TRUE, Conjunction
from .reuse import ReusePlane
from .runtime import AggGate, AggSink, Gate, Member, Pipeline, ProbeOp, ScanNode
from .state import SharedAggregateState, SharedHashBuildState, StateLifecycle


@dataclass(frozen=True)
class Mode:
    name: str
    share_scans: bool = False
    share_pipelines: bool = False
    share_state: bool = False
    allow_residual: bool = False
    allow_represented: bool = False
    agg_share: str = "none"  # 'none' | 'qpipe' | 'live' | 'full'
    qpipe: bool = False


MODES: Dict[str, Mode] = {
    "isolated": Mode("isolated"),
    "scan_sharing": Mode("scan_sharing", share_scans=True),
    "qpipe_osp": Mode("qpipe_osp", share_scans=True, qpipe=True, agg_share="qpipe"),
    "residual": Mode(
        "residual",
        share_scans=True,
        share_pipelines=True,
        share_state=True,
        allow_residual=True,
        agg_share="live",
    ),
    "graft": Mode(
        "graft",
        share_scans=True,
        share_pipelines=True,
        share_state=True,
        allow_residual=True,
        allow_represented=True,
        agg_share="full",
    ),
}

# Modeled per-row costs (seconds) of the paper's single-worker row engine
# (~100ns/row class, consistent with Q3@SF10 ≈ 14s in paper Fig.6);
# core/costmodel.py can recalibrate against the host. Ratios between engine
# modes come from row counts, not from these constants.
DEFAULT_COST_MODEL: Dict[str, float] = {
    "scan": 100e-9,
    "filter": 80e-9,
    "probe": 200e-9,
    "match": 150e-9,
    "insert": 600e-9,
    "mark": 250e-9,
    "agg": 400e-9,
    # per-entry cost of rehydrating a spilled state artifact (§12): bulk
    # SoA restore + amortized derived-index rebuild — far below the
    # scan+filter+insert cost of re-producing the same entry
    "rehydrate": 60e-9,
    # per-row cost of the bucketed all_to_all repartition (§14): charged at
    # every probe stage on a >1-device mesh — the dense [P, C, W] exchange
    # tensor transits the interconnect once per stage regardless of how
    # many rows stay resident. Zero-device-mesh (mesh=None) sessions never
    # pay it.
    "exchange": 40e-9,
}


class QueryHandle:
    def __init__(self, query: Query, t_submit: float):
        self.qid = query.qid
        self.query = query
        self.t_submit = t_submit
        self.t_complete: Optional[float] = None
        self.attached_states: List[SharedHashBuildState] = []
        self.members: List[Member] = []
        self.agg_state: Optional[SharedAggregateState] = None
        self.agg_gate: Optional[AggGate] = None
        self.orderby: Optional[OrderBy] = None
        self.result: Optional[Dict[str, np.ndarray]] = None
        self.done = False
        # boundaries this query served by rehydrating a cached artifact (§12)
        self.cache_hits = 0
        # lifecycle (§16): 'active' until completion or a terminal verdict —
        # 'cancelled' (QueryFuture.cancel / Session.close), 'deadline'
        # (submit(deadline=) expired), or 'failed' (fault escalation after
        # the query already unfolded once).
        self.status = "active"
        # the query unfolded to isolated execution after a fault (§16):
        # surfaced in stats() and as the EXPLAIN GRAFT ``degraded`` flag
        self.degraded = False

    @property
    def latency(self) -> float:
        return (self.t_complete or 0.0) - self.query.arrival


class GraftEngine:
    def __init__(
        self,
        db: Database,
        mode: str = "graft",
        morsel_size: int = 65536,
        cost_model: Optional[Dict[str, float]] = None,
        zone_maps: bool = False,
        backend=None,
        partitions: int = 1,
        retention: str = "refcount",
        memory_budget: Optional[int] = None,
        member_major: bool = True,
        reuse_cache_budget: Optional[int] = None,
        reuse_disk_budget: Optional[int] = None,
        mesh_plan=None,
        faults: Optional[FaultPlan] = None,
    ):
        self.db = db
        self.mode = MODES[mode]
        self.morsel_size = morsel_size
        self.cost_model = dict(cost_model or DEFAULT_COST_MODEL)
        # cost models predating §14 lack the exchange term; default it so a
        # mesh session over an older calibrated dict still charges it
        self.cost_model.setdefault("exchange", DEFAULT_COST_MODEL["exchange"])
        self.zone_maps = zone_maps  # beyond-paper morsel skipping (§Perf)
        # Data-plane backend (api/backends.py ExecutionBackend); None keeps
        # the built-in NumPy paths (state.probe / np.bincount reductions).
        self.backend = backend
        # Partition-parallel data plane (DESIGN.md §9): scans shard into
        # P morsel ranges, states shard their indexes / partial aggregates
        # P ways. P == 1 is byte-identical to the seed single-stream engine.
        if not isinstance(partitions, int) or partitions < 1:
            raise ValueError(f"partitions must be a positive int, got {partitions!r}")
        self.n_partitions = partitions
        # Mesh execution (DESIGN.md §14): a core.meshexec.MeshPlan mapping
        # the P key-partition shards onto 'data'-axis devices one-to-one.
        # None = single-host engine (no exchange cost, no device routing).
        if mesh_plan is not None and mesh_plan.n_shards != partitions:
            raise ValueError(
                f"mesh_plan has {mesh_plan.n_shards} data shard(s) but the "
                f"engine was built with partitions={partitions} — state "
                "shards and devices must map one-to-one"
            )
        self.mesh_plan = mesh_plan
        # Shared-state lifecycle (DESIGN.md §10): 'refcount' drops state at
        # zero refs (paper §6.1); 'epoch' retires it for later grafts under
        # a memory-budgeted evictor.
        if retention not in ("refcount", "epoch"):
            raise ValueError(f"retention must be 'refcount' or 'epoch', got {retention!r}")
        self.retention = retention
        self.memory_budget = memory_budget
        # Member-major fused morsel pipeline (DESIGN.md §11): packed-mask
        # passes make per-morsel data-plane cost independent of the folded
        # member count. False retains the per-member loops — the
        # differential oracle the fused path is verified against.
        self.member_major = bool(member_major)

        self.scans: Dict[object, ScanNode] = {}
        self.pipelines: Dict[object, Pipeline] = {}
        self.state_index: Dict[StateSignature, List[SharedHashBuildState]] = {}
        self.agg_index: Dict[StateSignature, SharedAggregateState] = {}
        self.qpipe_registry: Dict[object, Tuple[Member, SharedHashBuildState]] = {}
        self.handles: Dict[int, QueryHandle] = {}
        self.active_handles: List[QueryHandle] = []
        self.completed: List[QueryHandle] = []
        self.counters: Dict[str, float] = defaultdict(float)
        # data-plane perf counters surfaced via QueryFuture.stats — present
        # (zero) from the start so stats dicts are shape-stable
        for k in (
            "index_rebuilds",
            "kernel_lens_probes",
            "fused_filter_rows",
            # member-major fused data plane (§11) — present (zero) from the
            # start so stats dicts stay shape-stable
            "kernel_multi_lens_probes",
            "fused_vis_rows",
            "fused_stage_filter_rows",
            "fused_sink_rows",
            # device-resident fused chain (§13) — one launch per morsel
            # stage chain, with per-reason kernel-decline attribution
            "kernel_chain_launches",
            "fallback_probes_grants",
            "fallback_probes_slot_limit",
            "fallback_probes_keyrange",
            "fallback_probes_capacity",
            "fallback_probes_predicate",
            "agg_cohort_rows",
            "overflow_members",
            "partition_merges",
            "partition_probe_merges",
            # mesh execution (§14) — rows crossing the bucketed all_to_all
            # exchange per probe stage, and rows a device exchange ever
            # failed to place in a bucket (always recovered by regrowing
            # capacity — see relational.distributed.exchange_by_key)
            "mesh_exchange_rows",
            "bucket_overflow_rows",
            # batch planning (§15) — cohorts admitted through the joint
            # planner, and the §10 admission-memo evaluation count
            "batch_cohorts",
            "batch_planned_queries",
            "batch_coverage_gain_rows",
            "admission_evals",
            # lifecycle + admission counters (§10) — present (zero) from the
            # start so stats dicts stay shape-stable
            "evictions",
            "evicted_bytes",
            "state_revivals",
            "queued_admissions",
            "queue_delay_s_total",
            "forced_admissions",
            "retained_bytes",
            "retained_high_water_bytes",
            "state_bytes",
            "mem_high_water_bytes",
            # reuse plane (§12) — present (zero) from the start so stats
            # dicts stay shape-stable whether or not the cache is enabled
            "cache_hits",
            "cache_spills",
            "cache_evictions",
            "rehydrate_bytes",
            "cache_bytes",
            "cache_high_water_bytes",
            "cache_disk_bytes",
            "cache_disk_high_water_bytes",
            # fault plane + query lifecycle (§16) — present (zero) from the
            # start so stats dicts stay shape-stable with faults=None
            "faults_injected",
            "fault_retries",
            "producer_handoffs",
            "quarantined_states",
            "unfolds",
            "cancelled",
            "deadline_cancellations",
            "cache_corrupt",
        ):
            self.counters[k] = 0.0
        self.lifecycle = StateLifecycle(retention, memory_budget, self.counters)
        # Fault plane (§16): None keeps every hook compiled out of the hot
        # paths — the faults=None engine is fingerprint-identical to the
        # pre-fault-plane engine (locked by the chaos overhead leg).
        self.faults: Optional[FaultPlane] = None
        if faults is not None:
            if not isinstance(faults, FaultPlan):
                raise ValueError(
                    f"faults must be a FaultPlan or None, got {faults!r}"
                )
            self.faults = FaultPlane(faults, self.counters)
        # Reuse plane (DESIGN.md §12): evicted retired states spill into a
        # tiered artifact cache instead of being destroyed. Only meaningful
        # under epoch retention — refcount release never evicts.
        self.reuse: Optional[ReusePlane] = None
        if reuse_cache_budget is not None:
            if retention != "epoch":
                raise ValueError("reuse_cache_budget requires retention='epoch'")
            self.reuse = ReusePlane(
                self.cost_model,
                reuse_cache_budget,
                disk_budget=reuse_disk_budget,
                counters=self.counters,
                faults=self.faults,
            )
        elif reuse_disk_budget is not None:
            raise ValueError("reuse_disk_budget requires reuse_cache_budget")
        self.demand_cache: Dict = {}
        # Live-state generation counter (§10/§15): bumped whenever the
        # admission-visible indexes change (submission registers states /
        # rehydrates artifacts; release and eviction unregister them). The
        # AdmissionController memoizes per-arrival potentials on it, and the
        # batch planner's purity contract is scoped to one generation.
        self.state_gen = 0
        # §15 cohort admission context: non-None only while the batched
        # scheduler admits a >1-member cohort. Maps state_id -> list of
        # (eid, b_q, member) for extents cohort members registered this
        # decision step, so later members can attach deferred-represented
        # (grant + gate on the producer) instead of installing duplicate
        # residual producers. The greedy path never sets it.
        self.cohort_ctx: Optional[Dict[int, List]] = None
        self._domains: Dict[str, int] = {}
        self._next_state_id = 0
        self._agg_producers: Dict[int, SharedAggregateState] = {}  # member.mid -> agg
        # engine-scoped runtime-object ids (no class-counter leaks across
        # engine/session constructions — same fix class as PrefixState)
        self._next_mid = 0
        self._next_pid = 0
        self._next_sid = 0
        # §16 producer handoff: lens leases keep a dead query's attachment
        # (slot visibility + grants + ref) alive on the upstream states its
        # adopted replacement members still probe through ``lens_qid``.
        # (lens_qid, state_id) -> (state, {replacement members}); released
        # — detaching the dead lens — once every holder finishes.
        self._lens_leases: Dict[Tuple[int, int], Tuple[object, set]] = {}

        # clock is attached by the scheduler
        self.clock = None

    # -- helpers -------------------------------------------------------------
    def attach_shared(self, handle: QueryHandle, state: SharedHashBuildState) -> None:
        """Attach a query lens to a (possibly retired) shared hash state:
        the grafting admission path — revives retired states (§10)."""
        state.attach(handle.qid)
        handle.attached_states.append(state)
        self.lifecycle.revive(state)

    def next_member_id(self) -> int:
        self._next_mid += 1
        return self._next_mid

    def next_pipeline_id(self) -> int:
        self._next_pid += 1
        return self._next_pid

    def get_scan(self, table: str, qid: int) -> ScanNode:
        key = table if self.mode.share_scans else (table, qid)
        node = self.scans.get(key)
        if node is None:
            self._next_sid += 1
            node = ScanNode(
                self._next_sid,
                self.db[table],
                self.morsel_size,
                zone_maps=self.zone_maps,
                n_partitions=self.n_partitions,
            )
            self.scans[key] = node
        return node

    def new_hash_state(self, sig, join, did_domain: int) -> SharedHashBuildState:
        self._next_state_id += 1
        return SharedHashBuildState(
            self._next_state_id,
            sig,
            tuple(join.build_keys),
            tuple(join.payload),
            did_domain,
            counters=self.counters,
            n_partitions=self.n_partitions,
        )

    # -- submission (query grafting, §5.2) ------------------------------------
    def submit(self, query: Query) -> QueryHandle:
        now = self.clock.now if self.clock is not None else query.arrival
        handle = QueryHandle(query, now)
        self.handles[query.qid] = handle
        self.active_handles.append(handle)
        self.counters["submitted"] += 1
        self._install_query(handle)
        return handle

    def _install_query(self, handle: QueryHandle) -> None:
        """Resolve one active handle's plan against the engine's current
        shared state: the grafting admission body of ``submit``, factored
        so unfolding (§16) can re-install a torn-down query under a
        temporary isolated-mode override."""
        query = handle.query
        scan, joins, agg, orderby = plan_spine(query.plan)
        handle.orderby = orderby

        # -- aggregate identity: observe or live-share one aggregate state
        agg_sig = aggregate_signature(agg)
        if agg_sig is not None and self.mode.agg_share != "none":
            existing = self.agg_index.get(agg_sig)
            if existing is None and self.reuse is not None and self.mode.agg_share == "full":
                # reuse plane (§12): an evicted-but-cached aggregate identity
                # rehydrates and the plan collapses onto it exactly as onto a
                # never-evicted retained identity
                existing = self.reuse.try_rehydrate_agg(
                    self, handle, query.plan, agg, agg_sig
                )
            if existing is not None and self._agg_attachable(existing):
                existing.attach(handle.qid)
                self.lifecycle.revive(existing)
                handle.agg_state = existing
                handle.agg_gate = AggGate(existing)
                self.counters["agg_attaches"] += 1
                for b in all_boundaries(query.plan):
                    d = estimate_demand(self, b.build)
                    self.counters["demand_rows"] += d
                    self.counters["eliminated_rows"] += d
                self.state_gen += 1
                self._maybe_complete(handle)
                return

        # -- per-boundary grafting admission (Algorithm 1), bottom-up
        ops: List[ProbeOp] = []
        gates = []
        stage_filters: Dict[int, List] = {}
        for stage, j in enumerate(joins):
            att = resolve_boundary(self, handle, j)
            gates.append(att.gate)
            out_names = j.payload_as if j.payload_as is not None else j.payload
            ops.append(
                ProbeOp(att.state, tuple(j.probe_keys), tuple(j.payload), tuple(out_names))
            )
            if j.post_filter is not TRUE:
                stage_filters.setdefault(stage, []).append(j.post_filter)

        # -- aggregate state (private; becomes shared under its identity)
        self._next_state_id += 1
        agg_state = SharedAggregateState(
            self._next_state_id,
            agg_sig,
            tuple(agg.group_keys),
            tuple(agg.aggs),
            counters=self.counters,
            n_partitions=self.n_partitions,
        )
        agg_state.attach(handle.qid)
        handle.agg_state = agg_state
        handle.agg_gate = AggGate(agg_state)
        if agg_sig is not None and self.mode.agg_share != "none":
            self.agg_index[agg_sig] = agg_state

        # -- main (state-consuming) pipeline + member
        pkey = ("main", scan.table, tuple(op.state.state_id for op in ops))
        if not self.mode.share_pipelines:
            pkey = pkey + (handle.qid,)
        pipeline = self.pipelines.get(pkey)
        if pipeline is None:
            pipeline = Pipeline(
                self.next_pipeline_id(),
                pkey,
                self.get_scan(scan.table, handle.qid),
                ops,
                counters=self.counters,
            )
            self.pipelines[pkey] = pipeline
        member = Member(
            self.next_member_id(),
            handle.qid,
            scan.pred,
            gates,
            sink=AggSink(agg_state, tuple(agg.group_keys), tuple(agg.aggs)),
            stage_filters=stage_filters,
            kind="main",
        )
        member.pipeline = pipeline
        pipeline.add_member(member)
        handle.members.append(member)
        self._agg_producers[member.mid] = agg_state

        self.state_gen += 1
        self.check_activations()

    def _agg_attachable(self, agg_state: SharedAggregateState) -> bool:
        share = self.mode.agg_share
        if share == "full":
            return True
        if share == "live":
            return not agg_state.complete
        if share == "qpipe":
            return agg_state.rows_consumed == 0 and not agg_state.complete
        return False

    # -- events ----------------------------------------------------------------
    def on_member_part_finished(self, pipeline: Pipeline, m: Member, part: int) -> None:
        """One scan partition of a member's delivery cycle completed: push
        the per-partition extent frontier (§9) of its build target."""
        if pipeline.build_target is not None and m.eid >= 0:
            pipeline.build_target.state.complete_extent_partition(
                m.eid, part, pipeline.source.n_partitions
            )

    def on_member_finished(self, pipeline: Pipeline, m: Member) -> None:
        pipeline.slots.release(m.mid)
        pipeline.release_member(m)  # drop its cohort gid maps (§11)
        if pipeline.build_target is not None:
            pipeline.build_target.state.complete_extent(m.eid)
            for g in m.waiting_gates:
                g.pending.discard(m)
        else:
            agg = self._agg_producers.get(m.mid)
            if agg is not None:
                agg.complete = True
        if pipeline.all_done():
            self.pipelines.pop(pipeline.key, None)
            pipeline.source.detach(pipeline)
        self._dirty = True

    _dirty = False

    def check_activations(self) -> None:
        if self._lens_leases:
            self._release_lens_leases()
        now = self.clock.now if self.clock is not None else 0.0
        for pipeline in list(self.pipelines.values()):
            for m in pipeline.members:
                if m.activatable():
                    m.active = True
                    m.received = 0
                    m.need = pipeline.source.n_morsels
                    m.part_received = np.zeros(pipeline.source.n_partitions, dtype=np.int64)
                    m.part_need = pipeline.source.part_counts.copy()
                    # barrier timestamp: a worker picking this member's
                    # fragment first advances to the activation time (§9
                    # max-at-barrier clock merge)
                    m.t_activated = now

    def sweep_completions(self) -> List[QueryHandle]:
        done: List[QueryHandle] = []
        for h in list(self.active_handles):
            if self._maybe_complete(h):
                done.append(h)
        return done

    def _maybe_complete(self, handle: QueryHandle) -> bool:
        if handle.done or handle.agg_gate is None or not handle.agg_gate.open():
            return False
        result = handle.agg_state.result()
        if handle.orderby is not None:
            result = _apply_orderby(result, handle.orderby)
        handle.result = result
        handle.t_complete = self.clock.now if self.clock is not None else 0.0
        handle.done = True
        self.active_handles.remove(handle)
        self.completed.append(handle)
        self.counters["completed"] += 1
        self._release(handle)
        return True

    def _release(self, handle: QueryHandle) -> None:
        """Release a completed query's lenses. ``retention='refcount'`` is
        the evaluated prototype's policy — drop operator state the moment no
        query references it; ``retention='epoch'`` retires zero-pin states
        for later grafts and enforces the memory budget (§10)."""
        for s in handle.attached_states:
            s.detach(handle.qid)
            if not s.refs:
                if self.retention == "epoch":
                    self.lifecycle.retire(s)
                else:
                    self._remove_from_indexes(s)
        agg = handle.agg_state
        if agg is not None:
            agg.detach(handle.qid)
            if not agg.refs and agg.sig is not None and self.agg_index.get(agg.sig) is agg:
                if self.retention == "epoch":
                    self.lifecycle.retire(agg)
                else:
                    self._remove_from_indexes(agg)
        if self.retention == "epoch":
            self.enforce_memory_budget()

    # -- fault tolerance: cancellation, handoff, quarantine, unfold (§16) ----
    def cancel_query(self, handle: QueryHandle, reason: str = "cancelled",
                     doomed: Optional[set] = None) -> bool:
        """Terminate one active query at a morsel boundary: hand its
        incomplete shared-state producers to surviving folded beneficiaries
        (or seal the state at its last complete extent), detach its lenses
        (detach-clears-visibility keeps retained rows sound, §10), and mark
        the handle with a terminal status. ``doomed`` widens the
        no-adoption set (Session.close cancels everything at once). Riders
        of an aggregate this query was producing unfold to isolated
        execution — no beneficiary is ever stranded."""
        if handle.done or handle.status != "active":
            return False
        dm = set(doomed) if doomed is not None else set()
        dm.add(handle.qid)
        riders = self._teardown(handle, dm)
        handle.status = reason
        if handle in self.active_handles:
            self.active_handles.remove(handle)
        self.counters["cancelled"] += 1
        if reason == "deadline":
            self.counters["deadline_cancellations"] += 1
        self.state_gen += 1
        for rh in riders:
            self.unfold(rh)
        if self.retention == "epoch":
            self.enforce_memory_budget()
        self.check_activations()
        return True

    def unfold(self, handle: QueryHandle) -> bool:
        """Degrade one active query to isolated execution (§16): tear down
        its folded plan — producers hand off to surviving beneficiaries
        exactly as under cancellation, so the cohort keeps its coverage —
        and re-install it under a private-everything isolated plan. The §4
        soundness argument is preserved trivially: the unfolded plan
        observes only states it produces itself."""
        if handle.done or handle.status != "active":
            return False
        riders = self._teardown(handle, {handle.qid})
        handle.degraded = True
        self.counters["unfolds"] += 1
        self._install_isolated(handle)
        self.state_gen += 1
        for rh in riders:
            self.unfold(rh)
        self.check_activations()
        return True

    def quarantine_state(self, state) -> int:
        """Tombstone one shared hash state after fault escalation (§16):
        every impacted active query is torn down (their producers on OTHER
        states still hand off to outside beneficiaries), the state dies
        through the §10 eviction path — but never spills into the reuse
        plane, its fragments are suspect — and the impacted queries unfold
        to isolated execution. A query that already unfolded once fails
        instead (bounded degradation ⇒ chaos runs terminate). Returns the
        number of impacted queries."""
        if state.quarantined or state.evicted:
            return 0
        state.quarantined = True
        impacted = [
            h for h in self.active_handles
            if not h.done and h.status == "active" and state in h.attached_states
        ]
        impacted.sort(key=lambda h: h.qid)
        doomed = {h.qid for h in impacted}
        riders: List[QueryHandle] = []
        for h in impacted:
            riders.extend(self._teardown(h, doomed))
        self.lifecycle.drop(state)
        state.evicted = True
        self._remove_from_indexes(state)
        self.counters["quarantined_states"] += 1
        self.state_gen += 1
        for h in impacted:
            if h.done or h.status != "active":
                continue
            if h.degraded:
                self.cancel_query(h, "failed")
            else:
                h.degraded = True
                self.counters["unfolds"] += 1
                self._install_isolated(h)
        for rh in riders:
            if rh.qid not in doomed:
                self.unfold(rh)
        self.check_activations()
        return len(impacted)

    def _install_isolated(self, handle: QueryHandle) -> None:
        """Re-install a torn-down handle under a temporary isolated-mode
        override: private scan, private pipelines, private states, private
        aggregate — no index registration, so nothing later folds onto a
        degraded execution."""
        prev = self.mode
        self.mode = MODES["isolated"]
        try:
            self._install_query(handle)
        finally:
            self.mode = prev

    def _teardown(self, handle: QueryHandle, doomed: set) -> List[QueryHandle]:
        """Dismantle one active handle's execution. ``doomed`` is the set of
        qids dying in this event — adoption never targets them. Returns the
        surviving riders of an aggregate this handle was producing (the
        caller unfolds them once its own teardown settles)."""
        replaced: Dict[int, Member] = {}
        agg = handle.agg_state
        was_producer = agg is not None and any(
            self._agg_producers.get(m.mid) is agg and not m.done
            for m in handle.members
        )
        # outermost first (members are appended bottom-up): a downstream
        # producer adopts its doomed upstream chain before the loop reaches
        # those upstream members, so they are never wrongly sealed
        for m in reversed(list(handle.members)):
            if m.done:
                self._agg_producers.pop(m.mid, None)
                continue
            self._retire_member(m, doomed, replaced)
        # lens-owner tagging is only needed on target states a replacement
        # actually probes through the dead lens (= the leased states, all
        # registered by now); everywhere else it would re-allocate the dead
        # query a visibility slot at sink time and leak it
        for m2 in replaced.values():
            lq = m2.lens_qid
            tgt = m2.pipeline.build_target.state
            if lq in m2.beneficiaries and (lq, tgt.state_id) not in self._lens_leases:
                m2.beneficiaries.remove(lq)
        handle.members = []
        for s in list(handle.attached_states):
            if (handle.qid, s.state_id) in self._lens_leases:
                # a replacement member probes this state through the dying
                # query's lens: keep the attachment (slot, vis, grants, ref)
                # alive — the lease release detaches it once the
                # replacement finishes
                continue
            s.detach(handle.qid)
            if s.quarantined or s.evicted:
                continue
            if not s.refs:
                if self.retention == "epoch":
                    self.lifecycle.retire(s)
                else:
                    self._remove_from_indexes(s)
        handle.attached_states = []
        riders: List[QueryHandle] = []
        if agg is not None:
            agg.detach(handle.qid)
            if was_producer and not agg.complete:
                # the shared aggregate lost its producer mid-accumulation:
                # partial sums can never complete and redelivery would
                # double-count, so the identity leaves the index and its
                # surviving riders unfold
                self._remove_from_indexes(agg)
                for q in sorted(agg.refs):
                    if q in doomed:
                        continue
                    rh = self.handles.get(q)
                    if rh is not None and not rh.done and rh.status == "active":
                        riders.append(rh)
            if not agg.refs and agg.sig is not None and self.agg_index.get(agg.sig) is agg:
                if self.retention == "epoch":
                    self.lifecycle.retire(agg)
                else:
                    self._remove_from_indexes(agg)
            handle.agg_state = None
            handle.agg_gate = None
        return riders

    def _retire_member(self, m: Member, doomed: set, replaced: Dict[int, Member]) -> None:
        """Remove one incomplete member of a dying/unfolding query. A
        state-producing member with surviving beneficiaries is adopted
        (producer handoff); with none, its incomplete extent is voided —
        the state seals at its last complete extent."""
        pipeline = m.pipeline
        bt = pipeline.build_target if pipeline is not None else None
        if bt is not None:
            state = bt.state
            survivors = []
            for g in m.waiting_gates:
                if m not in g.pending or g.owner_qid is None or g.owner_qid in doomed:
                    continue
                oh = self.handles.get(g.owner_qid)
                if oh is None or oh.done or oh.status != "active":
                    continue
                survivors.append(g)
            m2 = replaced.get(m.mid)
            if m2 is None and survivors and not state.quarantined:
                adopter = self.handles[min(g.owner_qid for g in survivors)]
                m2 = self._adopt_producer(m, adopter, doomed, replaced)
                self.counters["producer_handoffs"] += 1
            if m2 is not None:
                for g in survivors:
                    g.pending.discard(m)
                    if m2 not in g.pending:
                        g.pending.add(m2)
                        m2.waiting_gates.append(g)
            else:
                for g in m.waiting_gates:
                    g.pending.discard(m)
                if not state.quarantined:
                    state.void_extent(m.eid)
        else:
            for g in m.waiting_gates:
                g.pending.discard(m)
        self._drop_member(m)

    def _adopt_producer(self, m: Member, adopter: QueryHandle, doomed: set,
                        replaced: Dict[int, Member]) -> Member:
        """Producer handoff (§16): the surviving beneficiary ``adopter``
        re-installs the doomed member's delivery obligation as its own.
        The replacement reuses the SAME extent id — redelivery of the full
        scan cycle dedups through ``insert_or_mark`` (existing rows are
        re-marked under the adopter's visibility bit, the extent's
        provenance bit is unchanged for every grant holder) and
        ``Gate.open`` re-proves coverage at completion, so adoption is
        sound and deterministic. Upstream gates are cloned for the adopter;
        doomed upstream producers are adopted recursively.

        The replacement probes upstream states through the DEAD query's
        lens (``lens_qid``): the adopter typically holds no slot or grant
        on the producer's upstream states, and any grant it does hold
        scopes a different visible set — only the dead lens reproduces the
        dead member's rows exactly. A lens lease keeps the dead query
        attached to those states until every replacement holding the lens
        finishes. The lens owner also stays a beneficiary so that sibling
        replacements downstream (which probe through the same dead lens)
        observe rows this replacement redelivers."""
        existing = replaced.get(m.mid)
        if existing is not None:
            return existing
        pipeline = m.pipeline
        state = pipeline.build_target.state
        new_gates = []
        for g in m.gates:
            if g.open():
                new_gates.append(g)  # immutable once open: share it
                continue
            g2 = Gate(g.state, g.conj, g.allowed_emask)
            g2.owner_qid = adopter.qid
            for p in sorted(g.pending, key=lambda x: x.mid):
                if p.qid in doomed and not p.done:
                    p2 = self._adopt_producer(p, adopter, doomed, replaced)
                    if p2 not in g2.pending:
                        g2.pending.add(p2)
                        p2.waiting_gates.append(g2)
                else:
                    g2.pending.add(p)
                    p.waiting_gates.append(g2)
            if g.state not in adopter.attached_states:
                self.attach_shared(adopter, g.state)
            new_gates.append(g2)
        benes = [q for q in m.beneficiaries if q not in doomed]
        if adopter.qid not in benes:
            benes.append(adopter.qid)
        if m.lens_qid not in benes:
            benes.append(m.lens_qid)
        m2 = Member(
            self.next_member_id(),
            adopter.qid,
            m.pred,
            new_gates,
            sink=None,
            stage_filters=m.stage_filters,
            kind=m.kind,
            eid=m.eid,
            conj=m.conj,
            beneficiaries=benes,
        )
        m2.waiting_gates = []
        m2.pipeline = pipeline
        m2.lens_qid = m.lens_qid
        pipeline.add_member(m2)
        adopter.members.append(m2)
        if state not in adopter.attached_states:
            self.attach_shared(adopter, state)
        for op in pipeline.ops:
            key = (m2.lens_qid, op.state.state_id)
            lease = self._lens_leases.get(key)
            if lease is None:
                self._lens_leases[key] = (op.state, {m2})
            else:
                lease[1].add(m2)
        replaced[m.mid] = m2
        return m2

    def _drop_member(self, m: Member) -> None:
        """Physically remove one member from its pipeline (empty pipelines
        die and detach from their scan, exactly as at completion)."""
        pipeline = m.pipeline
        self._agg_producers.pop(m.mid, None)
        if pipeline is not None and m in pipeline.members:
            pipeline.slots.release(m.mid)
            pipeline.release_member(m)
            pipeline.members.remove(m)
            if not pipeline.members:
                self.pipelines.pop(pipeline.key, None)
                pipeline.source.detach(pipeline)
        m.done = True
        m.active = False

    def _release_lens_leases(self) -> None:
        """Drop lens leases whose replacement members all finished (§16):
        detach the dead query's lens from the upstream state — clearing its
        visibility bit before the slot recycles, exactly as a live detach
        would — and retire the state if nothing else references it."""
        for key in list(self._lens_leases):
            state, members = self._lens_leases[key]
            live = {m for m in members if not m.done}
            if live:
                self._lens_leases[key] = (state, live)
                continue
            del self._lens_leases[key]
            state.detach(key[0])
            if state.quarantined or state.evicted:
                continue
            if not state.refs:
                if self.retention == "epoch":
                    self.lifecycle.retire(state)
                else:
                    self._remove_from_indexes(state)

    # -- lifecycle: eviction + memory accounting (§10) -----------------------
    def _remove_from_indexes(self, state) -> None:
        """Unregister a state from every admission-visible index — the one
        place refcount release and eviction share, so the invalidation rule
        cannot diverge between the two paths."""
        self.state_gen += 1
        if isinstance(state, SharedHashBuildState):
            lst = self.state_index.get(state.sig)
            if lst and state in lst:
                lst.remove(state)
            # drop stale qpipe registry entries targeting this state
            for k, (m, st) in list(self.qpipe_registry.items()):
                if st is state:
                    self.qpipe_registry.pop(k, None)
        else:
            if state.sig is not None and self.agg_index.get(state.sig) is state:
                self.agg_index.pop(state.sig, None)

    def enforce_memory_budget(self, budget: Optional[int] = None) -> int:
        """Evict retired states oldest-epoch-first until the retained bytes
        fit the budget (default: the configured ``memory_budget``; pass 0 to
        force-evict everything retired). Returns states evicted."""
        victims = self.lifecycle.victims(budget)
        for v in victims:
            self._evict(v)
        self._note_memory()
        return len(victims)

    def _evict(self, state) -> None:
        """Reclaim one retired state: only legal at zero pins — a live or
        admissible lens can never lose fragments it may still observe."""
        if not state.evictable:
            raise RuntimeError(
                f"evicting pinned state #{state.state_id}: "
                f"refs={state.refs} pins={state.pins}"
            )
        self.counters["evictions"] += 1
        self.counters["evicted_bytes"] += state.nbytes()
        if self.reuse is not None:
            # spill instead of destroy (§12): serialize the victim into the
            # artifact cache before tombstoning. The live object still dies
            # — §10's no-lens-observes-evicted invariant is untouched.
            self.reuse.spill(state)
        self.lifecycle.drop(state)
        state.evicted = True
        self._remove_from_indexes(state)

    def state_bytes(self) -> int:
        """Resident bytes of every live + retired shared state."""
        total = sum(s.nbytes() for lst in self.state_index.values() for s in lst)
        total += sum(a.nbytes() for a in self.agg_index.values())
        return total

    def _note_memory(self) -> None:
        """Refresh the memory gauges + high-water marks (epoch retention)."""
        rb = self.lifecycle.retired_bytes()
        self.counters["retained_bytes"] = rb
        if rb > self.counters["retained_high_water_bytes"]:
            self.counters["retained_high_water_bytes"] = rb
        tb = self.state_bytes()
        self.counters["state_bytes"] = tb
        if tb > self.counters["mem_high_water_bytes"]:
            self.counters["mem_high_water_bytes"] = tb

    # -- introspection -----------------------------------------------------------
    def has_active_work(self) -> bool:
        return bool(self.active_handles)

    def stats(self) -> Dict[str, float]:
        out = dict(self.counters)
        out["live_states"] = sum(len(v) for v in self.state_index.values())
        out["live_agg_states"] = len(self.agg_index)
        out["retained_states"] = len(self.lifecycle.retired)
        out["retention"] = self.retention
        out["cached_artifacts"] = len(self.reuse.store) if self.reuse is not None else 0
        out["mesh_data_shards"] = (
            self.mesh_plan.n_shards if self.mesh_plan is not None else 0
        )
        return out


def _apply_orderby(result: Dict[str, np.ndarray], ob: OrderBy) -> Dict[str, np.ndarray]:
    if not result:
        return result
    n = len(next(iter(result.values())))
    if n == 0:
        return result
    cols = []
    for k, asc in zip(reversed(ob.keys), reversed(ob.ascending)):
        c = result[k]
        cols.append(c if asc else -c)
    order = np.lexsort(cols) if cols else np.arange(n)
    if ob.limit is not None:
        order = order[: ob.limit]
    return {k: v[order] for k, v in result.items()}
