"""GraftEngine: the multi-query execution engine facade.

Execution modes (paper §6.1 / §6.4):

* ``isolated``     — same engine, all sharing disabled (private scans,
                     private pipelines, private states).
* ``qpipe_osp``    — QPipe's on-demand simultaneous pipelining: shared
                     scans + in-flight operator merge under *identical*
                     operator profiles (predicates included) with zero
                     progress; no coverage-based observation of built state.
* ``scan_sharing`` — shared cyclic scans only (+Scan Sharing variant).
* ``residual``     — + residual production into common shared state
                     (+Residual Production variant).
* ``graft``        — + represented-extent attachment through per-query
                     state lenses (full GraftDB).
"""

from __future__ import annotations

import heapq
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..relational.table import Database
from .descriptors import StateSignature, aggregate_signature
from .grafting import all_boundaries, estimate_demand, plan_spine, resolve_boundary
from .plans import Aggregate, OrderBy, Query
from .predicates import TRUE
from .reuse import ReusePlane
from .runtime import AggGate, AggSink, Member, Pipeline, ProbeOp, ScanNode
from .state import SharedAggregateState, SharedHashBuildState, StateLifecycle


@dataclass(frozen=True)
class Mode:
    name: str
    share_scans: bool = False
    share_pipelines: bool = False
    share_state: bool = False
    allow_residual: bool = False
    allow_represented: bool = False
    agg_share: str = "none"  # 'none' | 'qpipe' | 'live' | 'full'
    qpipe: bool = False


MODES: Dict[str, Mode] = {
    "isolated": Mode("isolated"),
    "scan_sharing": Mode("scan_sharing", share_scans=True),
    "qpipe_osp": Mode("qpipe_osp", share_scans=True, qpipe=True, agg_share="qpipe"),
    "residual": Mode(
        "residual",
        share_scans=True,
        share_pipelines=True,
        share_state=True,
        allow_residual=True,
        agg_share="live",
    ),
    "graft": Mode(
        "graft",
        share_scans=True,
        share_pipelines=True,
        share_state=True,
        allow_residual=True,
        allow_represented=True,
        agg_share="full",
    ),
}

# Modeled per-row costs (seconds) of the paper's single-worker row engine
# (~100ns/row class, consistent with Q3@SF10 ≈ 14s in paper Fig.6);
# core/costmodel.py can recalibrate against the host. Ratios between engine
# modes come from row counts, not from these constants.
DEFAULT_COST_MODEL: Dict[str, float] = {
    "scan": 100e-9,
    "filter": 80e-9,
    "probe": 200e-9,
    "match": 150e-9,
    "insert": 600e-9,
    "mark": 250e-9,
    "agg": 400e-9,
    # per-entry cost of rehydrating a spilled state artifact (§12): bulk
    # SoA restore + amortized derived-index rebuild — far below the
    # scan+filter+insert cost of re-producing the same entry
    "rehydrate": 60e-9,
    # per-row cost of the bucketed all_to_all repartition (§14): charged at
    # every probe stage on a >1-device mesh — the dense [P, C, W] exchange
    # tensor transits the interconnect once per stage regardless of how
    # many rows stay resident. Zero-device-mesh (mesh=None) sessions never
    # pay it.
    "exchange": 40e-9,
}


class QueryHandle:
    def __init__(self, query: Query, t_submit: float):
        self.qid = query.qid
        self.query = query
        self.t_submit = t_submit
        self.t_complete: Optional[float] = None
        self.attached_states: List[SharedHashBuildState] = []
        self.members: List[Member] = []
        self.agg_state: Optional[SharedAggregateState] = None
        self.agg_gate: Optional[AggGate] = None
        self.orderby: Optional[OrderBy] = None
        self.result: Optional[Dict[str, np.ndarray]] = None
        self.done = False
        # boundaries this query served by rehydrating a cached artifact (§12)
        self.cache_hits = 0

    @property
    def latency(self) -> float:
        return (self.t_complete or 0.0) - self.query.arrival


class GraftEngine:
    def __init__(
        self,
        db: Database,
        mode: str = "graft",
        morsel_size: int = 65536,
        cost_model: Optional[Dict[str, float]] = None,
        zone_maps: bool = False,
        backend=None,
        partitions: int = 1,
        retention: str = "refcount",
        memory_budget: Optional[int] = None,
        member_major: bool = True,
        reuse_cache_budget: Optional[int] = None,
        reuse_disk_budget: Optional[int] = None,
        mesh_plan=None,
    ):
        self.db = db
        self.mode = MODES[mode]
        self.morsel_size = morsel_size
        self.cost_model = dict(cost_model or DEFAULT_COST_MODEL)
        # cost models predating §14 lack the exchange term; default it so a
        # mesh session over an older calibrated dict still charges it
        self.cost_model.setdefault("exchange", DEFAULT_COST_MODEL["exchange"])
        self.zone_maps = zone_maps  # beyond-paper morsel skipping (§Perf)
        # Data-plane backend (api/backends.py ExecutionBackend); None keeps
        # the built-in NumPy paths (state.probe / np.bincount reductions).
        self.backend = backend
        # Partition-parallel data plane (DESIGN.md §9): scans shard into
        # P morsel ranges, states shard their indexes / partial aggregates
        # P ways. P == 1 is byte-identical to the seed single-stream engine.
        if not isinstance(partitions, int) or partitions < 1:
            raise ValueError(f"partitions must be a positive int, got {partitions!r}")
        self.n_partitions = partitions
        # Mesh execution (DESIGN.md §14): a core.meshexec.MeshPlan mapping
        # the P key-partition shards onto 'data'-axis devices one-to-one.
        # None = single-host engine (no exchange cost, no device routing).
        if mesh_plan is not None and mesh_plan.n_shards != partitions:
            raise ValueError(
                f"mesh_plan has {mesh_plan.n_shards} data shard(s) but the "
                f"engine was built with partitions={partitions} — state "
                "shards and devices must map one-to-one"
            )
        self.mesh_plan = mesh_plan
        # Shared-state lifecycle (DESIGN.md §10): 'refcount' drops state at
        # zero refs (paper §6.1); 'epoch' retires it for later grafts under
        # a memory-budgeted evictor.
        if retention not in ("refcount", "epoch"):
            raise ValueError(f"retention must be 'refcount' or 'epoch', got {retention!r}")
        self.retention = retention
        self.memory_budget = memory_budget
        # Member-major fused morsel pipeline (DESIGN.md §11): packed-mask
        # passes make per-morsel data-plane cost independent of the folded
        # member count. False retains the per-member loops — the
        # differential oracle the fused path is verified against.
        self.member_major = bool(member_major)

        self.scans: Dict[object, ScanNode] = {}
        self.pipelines: Dict[object, Pipeline] = {}
        self.state_index: Dict[StateSignature, List[SharedHashBuildState]] = {}
        self.agg_index: Dict[StateSignature, SharedAggregateState] = {}
        self.qpipe_registry: Dict[object, Tuple[Member, SharedHashBuildState]] = {}
        self.handles: Dict[int, QueryHandle] = {}
        self.active_handles: List[QueryHandle] = []
        self.completed: List[QueryHandle] = []
        self.counters: Dict[str, float] = defaultdict(float)
        # data-plane perf counters surfaced via QueryFuture.stats — present
        # (zero) from the start so stats dicts are shape-stable
        for k in (
            "index_rebuilds",
            "kernel_lens_probes",
            "fused_filter_rows",
            # member-major fused data plane (§11) — present (zero) from the
            # start so stats dicts stay shape-stable
            "kernel_multi_lens_probes",
            "fused_vis_rows",
            "fused_stage_filter_rows",
            "fused_sink_rows",
            # device-resident fused chain (§13) — one launch per morsel
            # stage chain, with per-reason kernel-decline attribution
            "kernel_chain_launches",
            "fallback_probes_grants",
            "fallback_probes_slot_limit",
            "fallback_probes_keyrange",
            "fallback_probes_capacity",
            "fallback_probes_predicate",
            "agg_cohort_rows",
            "overflow_members",
            "partition_merges",
            "partition_probe_merges",
            # mesh execution (§14) — rows crossing the bucketed all_to_all
            # exchange per probe stage, and rows a device exchange ever
            # failed to place in a bucket (always recovered by regrowing
            # capacity — see relational.distributed.exchange_by_key)
            "mesh_exchange_rows",
            "bucket_overflow_rows",
            # batch planning (§15) — cohorts admitted through the joint
            # planner, and the §10 admission-memo evaluation count
            "batch_cohorts",
            "batch_planned_queries",
            "batch_coverage_gain_rows",
            "admission_evals",
            # lifecycle + admission counters (§10) — present (zero) from the
            # start so stats dicts stay shape-stable
            "evictions",
            "evicted_bytes",
            "state_revivals",
            "queued_admissions",
            "queue_delay_s_total",
            "forced_admissions",
            "retained_bytes",
            "retained_high_water_bytes",
            "state_bytes",
            "mem_high_water_bytes",
            # reuse plane (§12) — present (zero) from the start so stats
            # dicts stay shape-stable whether or not the cache is enabled
            "cache_hits",
            "cache_spills",
            "cache_evictions",
            "rehydrate_bytes",
            "cache_bytes",
            "cache_high_water_bytes",
            "cache_disk_bytes",
            "cache_disk_high_water_bytes",
        ):
            self.counters[k] = 0.0
        self.lifecycle = StateLifecycle(retention, memory_budget, self.counters)
        # Reuse plane (DESIGN.md §12): evicted retired states spill into a
        # tiered artifact cache instead of being destroyed. Only meaningful
        # under epoch retention — refcount release never evicts.
        self.reuse: Optional[ReusePlane] = None
        if reuse_cache_budget is not None:
            if retention != "epoch":
                raise ValueError("reuse_cache_budget requires retention='epoch'")
            self.reuse = ReusePlane(
                self.cost_model,
                reuse_cache_budget,
                disk_budget=reuse_disk_budget,
                counters=self.counters,
            )
        elif reuse_disk_budget is not None:
            raise ValueError("reuse_disk_budget requires reuse_cache_budget")
        self.demand_cache: Dict = {}
        # Live-state generation counter (§10/§15): bumped whenever the
        # admission-visible indexes change (submission registers states /
        # rehydrates artifacts; release and eviction unregister them). The
        # AdmissionController memoizes per-arrival potentials on it, and the
        # batch planner's purity contract is scoped to one generation.
        self.state_gen = 0
        # §15 cohort admission context: non-None only while the batched
        # scheduler admits a >1-member cohort. Maps state_id -> list of
        # (eid, b_q, member) for extents cohort members registered this
        # decision step, so later members can attach deferred-represented
        # (grant + gate on the producer) instead of installing duplicate
        # residual producers. The greedy path never sets it.
        self.cohort_ctx: Optional[Dict[int, List]] = None
        self._domains: Dict[str, int] = {}
        self._next_state_id = 0
        self._agg_producers: Dict[int, SharedAggregateState] = {}  # member.mid -> agg
        # engine-scoped runtime-object ids (no class-counter leaks across
        # engine/session constructions — same fix class as PrefixState)
        self._next_mid = 0
        self._next_pid = 0
        self._next_sid = 0

        # clock is attached by the scheduler
        self.clock = None

    # -- helpers -------------------------------------------------------------
    def attach_shared(self, handle: QueryHandle, state: SharedHashBuildState) -> None:
        """Attach a query lens to a (possibly retired) shared hash state:
        the grafting admission path — revives retired states (§10)."""
        state.attach(handle.qid)
        handle.attached_states.append(state)
        self.lifecycle.revive(state)

    def next_member_id(self) -> int:
        self._next_mid += 1
        return self._next_mid

    def next_pipeline_id(self) -> int:
        self._next_pid += 1
        return self._next_pid

    def get_scan(self, table: str, qid: int) -> ScanNode:
        key = table if self.mode.share_scans else (table, qid)
        node = self.scans.get(key)
        if node is None:
            self._next_sid += 1
            node = ScanNode(
                self._next_sid,
                self.db[table],
                self.morsel_size,
                zone_maps=self.zone_maps,
                n_partitions=self.n_partitions,
            )
            self.scans[key] = node
        return node

    def new_hash_state(self, sig, join, did_domain: int) -> SharedHashBuildState:
        self._next_state_id += 1
        return SharedHashBuildState(
            self._next_state_id,
            sig,
            tuple(join.build_keys),
            tuple(join.payload),
            did_domain,
            counters=self.counters,
            n_partitions=self.n_partitions,
        )

    # -- submission (query grafting, §5.2) ------------------------------------
    def submit(self, query: Query) -> QueryHandle:
        now = self.clock.now if self.clock is not None else query.arrival
        handle = QueryHandle(query, now)
        self.handles[query.qid] = handle
        self.active_handles.append(handle)
        self.counters["submitted"] += 1

        scan, joins, agg, orderby = plan_spine(query.plan)
        handle.orderby = orderby

        # -- aggregate identity: observe or live-share one aggregate state
        agg_sig = aggregate_signature(agg)
        if agg_sig is not None and self.mode.agg_share != "none":
            existing = self.agg_index.get(agg_sig)
            if existing is None and self.reuse is not None and self.mode.agg_share == "full":
                # reuse plane (§12): an evicted-but-cached aggregate identity
                # rehydrates and the plan collapses onto it exactly as onto a
                # never-evicted retained identity
                existing = self.reuse.try_rehydrate_agg(
                    self, handle, query.plan, agg, agg_sig
                )
            if existing is not None and self._agg_attachable(existing):
                existing.attach(handle.qid)
                self.lifecycle.revive(existing)
                handle.agg_state = existing
                handle.agg_gate = AggGate(existing)
                self.counters["agg_attaches"] += 1
                for b in all_boundaries(query.plan):
                    d = estimate_demand(self, b.build)
                    self.counters["demand_rows"] += d
                    self.counters["eliminated_rows"] += d
                self.state_gen += 1
                self._maybe_complete(handle)
                return handle

        # -- per-boundary grafting admission (Algorithm 1), bottom-up
        ops: List[ProbeOp] = []
        gates = []
        stage_filters: Dict[int, List] = {}
        for stage, j in enumerate(joins):
            att = resolve_boundary(self, handle, j)
            gates.append(att.gate)
            out_names = j.payload_as if j.payload_as is not None else j.payload
            ops.append(
                ProbeOp(att.state, tuple(j.probe_keys), tuple(j.payload), tuple(out_names))
            )
            if j.post_filter is not TRUE:
                stage_filters.setdefault(stage, []).append(j.post_filter)

        # -- aggregate state (private; becomes shared under its identity)
        self._next_state_id += 1
        agg_state = SharedAggregateState(
            self._next_state_id,
            agg_sig,
            tuple(agg.group_keys),
            tuple(agg.aggs),
            counters=self.counters,
            n_partitions=self.n_partitions,
        )
        agg_state.attach(handle.qid)
        handle.agg_state = agg_state
        handle.agg_gate = AggGate(agg_state)
        if agg_sig is not None and self.mode.agg_share != "none":
            self.agg_index[agg_sig] = agg_state

        # -- main (state-consuming) pipeline + member
        pkey = ("main", scan.table, tuple(op.state.state_id for op in ops))
        if not self.mode.share_pipelines:
            pkey = pkey + (handle.qid,)
        pipeline = self.pipelines.get(pkey)
        if pipeline is None:
            pipeline = Pipeline(
                self.next_pipeline_id(),
                pkey,
                self.get_scan(scan.table, handle.qid),
                ops,
                counters=self.counters,
            )
            self.pipelines[pkey] = pipeline
        member = Member(
            self.next_member_id(),
            handle.qid,
            scan.pred,
            gates,
            sink=AggSink(agg_state, tuple(agg.group_keys), tuple(agg.aggs)),
            stage_filters=stage_filters,
            kind="main",
        )
        member.pipeline = pipeline
        pipeline.add_member(member)
        handle.members.append(member)
        self._agg_producers[member.mid] = agg_state

        self.state_gen += 1
        self.check_activations()
        return handle

    def _agg_attachable(self, agg_state: SharedAggregateState) -> bool:
        share = self.mode.agg_share
        if share == "full":
            return True
        if share == "live":
            return not agg_state.complete
        if share == "qpipe":
            return agg_state.rows_consumed == 0 and not agg_state.complete
        return False

    # -- events ----------------------------------------------------------------
    def on_member_part_finished(self, pipeline: Pipeline, m: Member, part: int) -> None:
        """One scan partition of a member's delivery cycle completed: push
        the per-partition extent frontier (§9) of its build target."""
        if pipeline.build_target is not None and m.eid >= 0:
            pipeline.build_target.state.complete_extent_partition(
                m.eid, part, pipeline.source.n_partitions
            )

    def on_member_finished(self, pipeline: Pipeline, m: Member) -> None:
        pipeline.slots.release(m.mid)
        pipeline.release_member(m)  # drop its cohort gid maps (§11)
        if pipeline.build_target is not None:
            pipeline.build_target.state.complete_extent(m.eid)
            for g in m.waiting_gates:
                g.pending.discard(m)
        else:
            agg = self._agg_producers.get(m.mid)
            if agg is not None:
                agg.complete = True
        if pipeline.all_done():
            self.pipelines.pop(pipeline.key, None)
            pipeline.source.detach(pipeline)
        self._dirty = True

    _dirty = False

    def check_activations(self) -> None:
        now = self.clock.now if self.clock is not None else 0.0
        for pipeline in list(self.pipelines.values()):
            for m in pipeline.members:
                if m.activatable():
                    m.active = True
                    m.received = 0
                    m.need = pipeline.source.n_morsels
                    m.part_received = np.zeros(pipeline.source.n_partitions, dtype=np.int64)
                    m.part_need = pipeline.source.part_counts.copy()
                    # barrier timestamp: a worker picking this member's
                    # fragment first advances to the activation time (§9
                    # max-at-barrier clock merge)
                    m.t_activated = now

    def sweep_completions(self) -> List[QueryHandle]:
        done: List[QueryHandle] = []
        for h in list(self.active_handles):
            if self._maybe_complete(h):
                done.append(h)
        return done

    def _maybe_complete(self, handle: QueryHandle) -> bool:
        if handle.done or handle.agg_gate is None or not handle.agg_gate.open():
            return False
        result = handle.agg_state.result()
        if handle.orderby is not None:
            result = _apply_orderby(result, handle.orderby)
        handle.result = result
        handle.t_complete = self.clock.now if self.clock is not None else 0.0
        handle.done = True
        self.active_handles.remove(handle)
        self.completed.append(handle)
        self.counters["completed"] += 1
        self._release(handle)
        return True

    def _release(self, handle: QueryHandle) -> None:
        """Release a completed query's lenses. ``retention='refcount'`` is
        the evaluated prototype's policy — drop operator state the moment no
        query references it; ``retention='epoch'`` retires zero-pin states
        for later grafts and enforces the memory budget (§10)."""
        for s in handle.attached_states:
            s.detach(handle.qid)
            if not s.refs:
                if self.retention == "epoch":
                    self.lifecycle.retire(s)
                else:
                    self._remove_from_indexes(s)
        agg = handle.agg_state
        if agg is not None:
            agg.detach(handle.qid)
            if not agg.refs and agg.sig is not None and self.agg_index.get(agg.sig) is agg:
                if self.retention == "epoch":
                    self.lifecycle.retire(agg)
                else:
                    self._remove_from_indexes(agg)
        if self.retention == "epoch":
            self.enforce_memory_budget()

    # -- lifecycle: eviction + memory accounting (§10) -----------------------
    def _remove_from_indexes(self, state) -> None:
        """Unregister a state from every admission-visible index — the one
        place refcount release and eviction share, so the invalidation rule
        cannot diverge between the two paths."""
        self.state_gen += 1
        if isinstance(state, SharedHashBuildState):
            lst = self.state_index.get(state.sig)
            if lst and state in lst:
                lst.remove(state)
            # drop stale qpipe registry entries targeting this state
            for k, (m, st) in list(self.qpipe_registry.items()):
                if st is state:
                    self.qpipe_registry.pop(k, None)
        else:
            if state.sig is not None and self.agg_index.get(state.sig) is state:
                self.agg_index.pop(state.sig, None)

    def enforce_memory_budget(self, budget: Optional[int] = None) -> int:
        """Evict retired states oldest-epoch-first until the retained bytes
        fit the budget (default: the configured ``memory_budget``; pass 0 to
        force-evict everything retired). Returns states evicted."""
        victims = self.lifecycle.victims(budget)
        for v in victims:
            self._evict(v)
        self._note_memory()
        return len(victims)

    def _evict(self, state) -> None:
        """Reclaim one retired state: only legal at zero pins — a live or
        admissible lens can never lose fragments it may still observe."""
        if not state.evictable:
            raise RuntimeError(
                f"evicting pinned state #{state.state_id}: "
                f"refs={state.refs} pins={state.pins}"
            )
        self.counters["evictions"] += 1
        self.counters["evicted_bytes"] += state.nbytes()
        if self.reuse is not None:
            # spill instead of destroy (§12): serialize the victim into the
            # artifact cache before tombstoning. The live object still dies
            # — §10's no-lens-observes-evicted invariant is untouched.
            self.reuse.spill(state)
        self.lifecycle.drop(state)
        state.evicted = True
        self._remove_from_indexes(state)

    def state_bytes(self) -> int:
        """Resident bytes of every live + retired shared state."""
        total = sum(s.nbytes() for lst in self.state_index.values() for s in lst)
        total += sum(a.nbytes() for a in self.agg_index.values())
        return total

    def _note_memory(self) -> None:
        """Refresh the memory gauges + high-water marks (epoch retention)."""
        rb = self.lifecycle.retired_bytes()
        self.counters["retained_bytes"] = rb
        if rb > self.counters["retained_high_water_bytes"]:
            self.counters["retained_high_water_bytes"] = rb
        tb = self.state_bytes()
        self.counters["state_bytes"] = tb
        if tb > self.counters["mem_high_water_bytes"]:
            self.counters["mem_high_water_bytes"] = tb

    # -- introspection -----------------------------------------------------------
    def has_active_work(self) -> bool:
        return bool(self.active_handles)

    def stats(self) -> Dict[str, float]:
        out = dict(self.counters)
        out["live_states"] = sum(len(v) for v in self.state_index.values())
        out["live_agg_states"] = len(self.agg_index)
        out["retained_states"] = len(self.lifecycle.retired)
        out["retention"] = self.retention
        out["cached_artifacts"] = len(self.reuse.store) if self.reuse is not None else 0
        out["mesh_data_shards"] = (
            self.mesh_plan.n_shards if self.mesh_plan is not None else 0
        )
        return out


def _apply_orderby(result: Dict[str, np.ndarray], ob: OrderBy) -> Dict[str, np.ndarray]:
    if not result:
        return result
    n = len(next(iter(result.values())))
    if n == 0:
        return result
    cols = []
    for k, asc in zip(reversed(ob.keys), reversed(ob.ascending)):
        c = result[k]
        cols.append(c if asc else -c)
    order = np.lexsort(cols) if cols else np.arange(n)
    if ob.limit is not None:
        order = order[: ob.limit]
    return {k: v[order] for k, v in result.items()}
