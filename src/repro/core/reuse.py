"""Reuse plane: completed operator state as a first-class cached artifact.

GraftDB's folding (§5) only exploits overlap with *live* executions: once
the §10 epoch evictor reclaims a retired state, a repeat arrival recomputes
from scratch even though the identical operator state was just
materialized. The reuse plane closes that gap (DESIGN.md §12):

* **Spill instead of destroy** — when the evictor would reclaim a retired
  zero-pin state, the engine first serializes its SoA into a tiered
  ``ArtifactStore`` (host-memory tier under ``reuse_cache_budget`` bytes,
  plus an optional on-disk tier under a temp dir). The live object is then
  tombstoned exactly as before — §10's invariant that no lens can observe
  an evicted *object* is untouched; only the bytes get a second life.
* **Semantic indexing** — artifacts are keyed by a canonical *plan
  fingerprint*: the state signature (operator class + structural input,
  ``descriptors.py``) extended with the canonical predicate intervals of
  the completed extents (hash builds) or the aggregate identity's input
  condition + group keys (which the aggregate signature already carries).
  Lookups are semantic, never pointer-based: a repeat arrival finds the
  artifact through the same signature selection ``resolve_boundary`` uses
  for live states.
* **Rehydration** — reconstructs a live ``SharedHashBuildState`` /
  ``SharedAggregateState`` that later grafts attach to exactly as if it
  had never left: the SoA columns, extent registry (predicate + completion
  + per-partition delivery frontiers), and provenance masks are restored
  bit-identically; per-query visibility words and slots come back empty
  (every lens that observed the state detached before retirement — §10
  clears its bits), and the did/probe indexes are derived structures that
  rebuild deterministically from the restored columns.
* **Three-way cost decision** — each arrival's boundary is scored across
  graft-onto-live-execution, rehydrate-a-cached-artifact (scan bytes saved
  minus rehydration cost), and isolated recompute (``reuse_scores``); the
  chosen class surfaces in EXPLAIN GRAFT as ``served_from_cache`` with
  represented/residual/unattached still summing exactly to demand.

The same ``ArtifactStore`` backs the serving plane: retired KV prefixes
spill into it and rehydrate as live ``PrefixState``s (serve/folding.py).
"""

from __future__ import annotations

import io
import os
import shutil
import tempfile
import time
import weakref
import zlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from .descriptors import StateSignature, aggregate_signature, hash_build_signature
from .plans import collect_subtree_pred
from .predicates import Conjunction, Coverage, evaluate_conj
from .state import ALL_EXTENTS, SharedAggregateState, SharedHashBuildState

#: Modeled per-row rehydration cost (seconds) — bulk SoA copy plus the
#: amortized share of the derived-index rebuild. Used when the engine's
#: cost model predates the ``rehydrate`` key (core/costmodel.py calibrates
#: it against the host).
REHYDRATE_COST_S = 60e-9


# ---------------------------------------------------------------------------
# Canonical plan fingerprints
# ---------------------------------------------------------------------------


def hash_state_fingerprint(sig: StateSignature, extents) -> tuple:
    """Fingerprint of a hash-build artifact: the structural signature key
    (operator class + build subtree skeleton + keys + payload layout)
    extended with the canonical predicate intervals of every *completed*
    extent. Two states with the same skeleton but different delivered
    predicate ranges therefore never collide — a near-miss (same keys,
    different intervals) is a distinct fingerprint, and reuse of it is
    decided by coverage, not by identity."""
    interval_keys = sorted(
        (conj.key() for conj, done in extents if done and conj is not None),
        key=repr,
    )
    return ("hash_build", sig.key, tuple(interval_keys))


def aggregate_fingerprint(sig: StateSignature) -> tuple:
    """Aggregate artifacts are exact identities (§4.5): the signature key
    already canonicalizes the input condition's predicate intervals, the
    group keys, and the aggregate specs."""
    return ("aggregate", sig.key)


def prefix_fingerprint(tokens: Tuple[int, ...]) -> tuple:
    """KV-prefix artifacts (serving plane): the token sequence IS the
    semantic identity; matching is longest-common-prefix at lookup."""
    return ("kv_prefix", tuple(tokens))


# ---------------------------------------------------------------------------
# Artifacts + the tiered store
# ---------------------------------------------------------------------------


class CorruptArtifact(Exception):
    """A disk-tier artifact failed its integrity check (checksum mismatch,
    truncation, or an unreadable archive). Never escapes the store: ``get``
    converts it to a cache miss (§16)."""


class StateArtifact:
    """One spilled state: small always-resident ``meta`` (fingerprint,
    signature, extent registry, scalar counters) plus the bulk ``arrays``
    payload, which the disk tier offloads to an ``.npz`` file."""

    __slots__ = ("fingerprint", "kind", "sig", "nbytes", "meta", "arrays", "seq")

    def __init__(self, fingerprint: tuple, kind: str, sig, nbytes: int,
                 meta: Dict, arrays: Dict[str, np.ndarray]):
        self.fingerprint = fingerprint
        self.kind = kind
        self.sig = sig
        self.nbytes = int(nbytes)
        self.meta = meta
        self.arrays = arrays
        self.seq = 0  # spill order, stamped by the store


class ArtifactStore:
    """Tiered artifact cache with oldest-spill-first eviction.

    * memory tier — artifacts resident in-process, bounded by ``budget``
      bytes. Insertion order is spill order, which under §10 is retirement-
      epoch order, so FIFO eviction preserves the evictor's oldest-first
      semantics.
    * disk tier (optional) — artifacts evicted from the memory tier demote
      to ``.npz`` files under a private temp dir, bounded by
      ``disk_budget`` bytes; metadata stays resident, only the array
      payload pages out. Disk overflow evicts (deletes) oldest-first.

    Counters (written into the shared engine/scheduler counter dict):
    ``cache_spills`` / ``cache_evictions`` increments, ``cache_bytes`` /
    ``cache_disk_bytes`` gauges, and their high-water marks. The budgets
    are enforced structurally — every ``put`` evicts to fit before
    inserting, so the gauges can never exceed them."""

    def __init__(self, budget: int, disk_budget: Optional[int] = None,
                 counters: Optional[Dict] = None):
        self.budget = int(budget)
        self.disk_budget = disk_budget
        self.counters = counters if counters is not None else {}
        self._mem: "OrderedDict[tuple, StateArtifact]" = OrderedDict()
        self._disk: "OrderedDict[tuple, StateArtifact]" = OrderedDict()  # arrays=None
        self._paths: Dict[tuple, str] = {}
        self._by_sig: Dict[tuple, List[tuple]] = {}  # (kind, sig.key) -> [fingerprint]
        self._dir: Optional[str] = None
        self._finalizer = None  # rmtree-on-GC guard for the temp dir
        self._sums: Dict[tuple, int] = {}  # fingerprint -> crc32 of the .npz bytes
        self._seq = 0
        self.mem_bytes = 0
        self.disk_bytes = 0
        self.closed = False
        if disk_budget is not None:
            self._sweep_stale()

    # -- bookkeeping ---------------------------------------------------------
    def _bump(self, key: str, v: float) -> None:
        self.counters[key] = self.counters.get(key, 0) + v

    def _gauge(self) -> None:
        c = self.counters
        c["cache_bytes"] = self.mem_bytes
        if self.mem_bytes > c.get("cache_high_water_bytes", 0):
            c["cache_high_water_bytes"] = self.mem_bytes
        c["cache_disk_bytes"] = self.disk_bytes
        if self.disk_bytes > c.get("cache_disk_high_water_bytes", 0):
            c["cache_disk_high_water_bytes"] = self.disk_bytes

    def _sig_key(self, fp: tuple) -> tuple:
        return (fp[0], fp[1])

    def _index_add(self, fp: tuple) -> None:
        self._by_sig.setdefault(self._sig_key(fp), []).append(fp)

    def _index_drop(self, fp: tuple) -> None:
        lst = self._by_sig.get(self._sig_key(fp))
        if lst and fp in lst:
            lst.remove(fp)
            if not lst:
                self._by_sig.pop(self._sig_key(fp), None)

    # -- disk tier -----------------------------------------------------------
    @staticmethod
    def _sweep_stale() -> None:
        """Best-effort reclamation of ``graftdb-reuse-*`` temp dirs whose
        owning process is gone (crashed or SIGKILLed before its finalizer
        ran). Each dir carries an ``owner.pid`` marker; a dir with no
        marker is only swept once comfortably stale, so a sibling store
        mid-``mkdtemp`` is never raced."""
        root = tempfile.gettempdir()
        try:
            names = os.listdir(root)
        except OSError:
            return
        for name in sorted(names):
            if not name.startswith("graftdb-reuse-"):
                continue
            d = os.path.join(root, name)
            if not os.path.isdir(d):
                continue
            try:
                with open(os.path.join(d, "owner.pid")) as f:
                    pid = int(f.read().strip())
            except (OSError, ValueError):
                try:
                    stale = time.time() - os.path.getmtime(d) > 3600.0
                except OSError:
                    continue
                if stale:
                    shutil.rmtree(d, ignore_errors=True)
                continue
            if pid == os.getpid():
                continue
            try:
                os.kill(pid, 0)
            except ProcessLookupError:
                shutil.rmtree(d, ignore_errors=True)
            except OSError:
                continue  # alive but not ours (EPERM) — leave it

    def _disk_path(self, art: StateArtifact) -> str:
        if self._dir is None:
            self._dir = tempfile.mkdtemp(prefix="graftdb-reuse-")
            with open(os.path.join(self._dir, "owner.pid"), "w") as f:
                f.write(str(os.getpid()))
            # the dir dies with the store even when close() is never
            # called (interpreter exit, store dropped without flush)
            self._finalizer = weakref.finalize(
                self, shutil.rmtree, self._dir, True
            )
        return os.path.join(self._dir, f"art{art.seq}.npz")

    def _demote(self, art: StateArtifact) -> bool:
        """Move one memory-tier artifact's payload to disk. Returns False
        (drop it instead) when the disk tier is off or cannot fit it."""
        if self.disk_budget is None or art.nbytes > self.disk_budget:
            return False
        while self.disk_bytes + art.nbytes > self.disk_budget and self._disk:
            self._evict_disk_oldest()
        path = self._disk_path(art)
        np.savez(path, **art.arrays)
        with open(path, "rb") as f:
            self._sums[art.fingerprint] = zlib.crc32(f.read())
        shadow = StateArtifact(art.fingerprint, art.kind, art.sig, art.nbytes,
                               art.meta, arrays=None)
        shadow.seq = art.seq
        self._disk[art.fingerprint] = shadow
        self._paths[art.fingerprint] = path
        self.disk_bytes += art.nbytes
        return True

    def _evict_disk_oldest(self) -> None:
        fp, art = next(iter(self._disk.items()))
        self._disk.pop(fp)
        self._remove_file(fp)
        self._index_drop(fp)
        self.disk_bytes -= art.nbytes
        self._bump("cache_evictions", 1)

    def _remove_file(self, fp: tuple) -> None:
        self._sums.pop(fp, None)
        path = self._paths.pop(fp, None)
        if path is not None and os.path.exists(path):
            os.unlink(path)

    def _load_arrays(self, fp: tuple) -> Dict[str, np.ndarray]:
        """Read one disk-tier payload, verified against its spill-time
        crc32. Truncation, bit flips, or an unreadable archive raise
        ``CorruptArtifact`` — callers convert that to a cache miss."""
        path = self._paths[fp]
        try:
            with open(path, "rb") as f:
                raw = f.read()
        except OSError as e:
            raise CorruptArtifact(f"unreadable artifact {path}: {e}") from None
        want = self._sums.get(fp)
        if want is not None and zlib.crc32(raw) != want:
            raise CorruptArtifact(f"checksum mismatch for {path}")
        try:
            with np.load(io.BytesIO(raw)) as z:
                return {k: z[k] for k in z.files}
        except Exception as e:
            raise CorruptArtifact(f"undecodable artifact {path}: {e}") from None

    def _drop_corrupt(self, fp: tuple, shadow: StateArtifact) -> None:
        """Integrity failure ⇒ cache miss (§16): the entry leaves both
        tiers and the miss falls through to recompute — never an error on
        the arrival path."""
        self._disk.pop(fp, None)
        self.disk_bytes -= shadow.nbytes
        self._remove_file(fp)
        self._index_drop(fp)
        self._bump("cache_corrupt", 1)
        self._gauge()

    # -- public surface ------------------------------------------------------
    def put(self, art: StateArtifact) -> bool:
        """Admit one artifact, evicting oldest-first to fit the memory
        budget (overflow demotes to the disk tier when enabled). Returns
        False when the store is closed or the artifact fits no tier."""
        if self.closed:
            return False
        self.remove(art.fingerprint)  # a re-spill replaces, never duplicates
        if art.nbytes > self.budget:
            self._seq += 1
            art.seq = self._seq
            if self._demote(art):
                self._index_add(art.fingerprint)
                self._bump("cache_spills", 1)
                self._gauge()
                return True
            self._bump("cache_evictions", 1)  # nowhere to keep it
            self._gauge()
            return False
        while self.mem_bytes + art.nbytes > self.budget and self._mem:
            old_fp, old = next(iter(self._mem.items()))
            self._mem.pop(old_fp)
            self.mem_bytes -= old.nbytes
            if self._demote(old):
                continue  # stays findable through the disk tier
            self._index_drop(old_fp)
            self._bump("cache_evictions", 1)
        self._seq += 1
        art.seq = self._seq
        self._mem[art.fingerprint] = art
        self.mem_bytes += art.nbytes
        self._index_add(art.fingerprint)
        self._bump("cache_spills", 1)
        self._gauge()
        return True

    def get(self, fp: tuple) -> Optional[StateArtifact]:
        """Artifact by exact fingerprint, payload loaded (the disk tier
        reads its file without promoting)."""
        art = self._mem.get(fp)
        if art is not None:
            return art
        shadow = self._disk.get(fp)
        if shadow is None:
            return None
        try:
            arrays = self._load_arrays(fp)
        except CorruptArtifact:
            self._drop_corrupt(fp, shadow)
            return None
        art = StateArtifact(shadow.fingerprint, shadow.kind, shadow.sig,
                            shadow.nbytes, shadow.meta, arrays)
        art.seq = shadow.seq
        return art

    def take(self, fp: tuple) -> Optional[StateArtifact]:
        """``get`` + remove — rehydration consumes the artifact (the state
        is live again; it will re-spill with fresh coverage when it next
        retires and ages out)."""
        art = self.get(fp)
        if art is not None:
            self.remove(fp)
        return art

    def remove(self, fp: tuple) -> None:
        art = self._mem.pop(fp, None)
        if art is not None:
            self.mem_bytes -= art.nbytes
            self._index_drop(fp)
        shadow = self._disk.pop(fp, None)
        if shadow is not None:
            self.disk_bytes -= shadow.nbytes
            self._remove_file(fp)
            self._index_drop(fp)
        if art is not None or shadow is not None:
            self._gauge()

    def by_sig(self, kind: str, sig_key) -> List[StateArtifact]:
        """Every cached artifact under one structural signature (both
        tiers; disk entries come back as metadata shadows — load on
        demand via ``get``). Order is spill order: deterministic."""
        out = []
        for fp in self._by_sig.get((kind, sig_key), ()):
            art = self._mem.get(fp) or self._disk.get(fp)
            if art is not None:
                out.append(art)
        out.sort(key=lambda a: a.seq)
        return out

    def iter_kind(self, kind: str):
        """All artifacts of one kind, metadata view, spill order."""
        arts = [a for a in self._mem.values() if a.kind == kind]
        arts += [a for a in self._disk.values() if a.kind == kind]
        arts.sort(key=lambda a: a.seq)
        return arts

    def __len__(self) -> int:
        return len(self._mem) + len(self._disk)

    def flush(self) -> None:
        """Drop every artifact (both tiers) and reset the gauges. The temp
        dir is removed here, not left for interpreter exit."""
        self._mem.clear()
        self._disk.clear()
        self._by_sig.clear()
        self._sums.clear()
        for fp in list(self._paths):
            self._remove_file(fp)
        if self._finalizer is not None:
            self._finalizer.detach()
            self._finalizer = None
        if self._dir is not None and os.path.isdir(self._dir):
            shutil.rmtree(self._dir, ignore_errors=True)
        self._dir = None
        self.mem_bytes = 0
        self.disk_bytes = 0
        self.counters["cache_bytes"] = 0
        self.counters["cache_disk_bytes"] = 0

    def close(self) -> None:
        """Flush and refuse further spills (Session.close)."""
        self.flush()
        self.closed = True


# ---------------------------------------------------------------------------
# Three-way cost scoring (graft / rehydrate / recompute)
# ---------------------------------------------------------------------------


def reuse_scores(cost_model: Dict[str, float], demand_rows: int,
                 covered_rows: int, artifact_entries: int) -> Dict[str, float]:
    """Modeled seconds of the three ways one boundary's build work can be
    served: ``recompute_s`` (isolated: scan + filter + insert every demand
    row), ``saved_s`` (build bytes a lens over the artifact's coverage
    would not re-produce), and ``rehydrate_s`` (bulk SoA restore of the
    artifact's entries). Reuse wins when the savings exceed the
    rehydration cost; grafting onto *live* state has no rehydration term
    and therefore always dominates when a live candidate exists."""
    row = cost_model["scan"] + cost_model["filter"] + cost_model["insert"]
    rehydrate = cost_model.get("rehydrate", REHYDRATE_COST_S)
    return {
        "recompute_s": demand_rows * row,
        "saved_s": covered_rows * row,
        "rehydrate_s": artifact_entries * rehydrate,
    }


def rehydrate_wins(cost_model: Dict[str, float], demand_rows: int,
                   covered_rows: int, artifact_entries: int) -> bool:
    if covered_rows <= 0:
        return False
    s = reuse_scores(cost_model, demand_rows, covered_rows, artifact_entries)
    return s["saved_s"] > s["rehydrate_s"]


# ---------------------------------------------------------------------------
# The reuse plane: spill / select / rehydrate
# ---------------------------------------------------------------------------


class ReusePlane:
    """Engine-side facade over the ArtifactStore: serializes victims on
    eviction, selects + cost-gates artifacts at admission, and rebuilds
    live states on a hit. All selection is deterministic (spill-order
    iteration, pure cost arithmetic), so admission verdicts stay a
    function of engine state alone — the scheduler's drain memoization
    and the PoolClock determinism argument both survive unchanged."""

    def __init__(self, cost_model: Dict[str, float], budget: int,
                 disk_budget: Optional[int] = None, counters: Optional[Dict] = None,
                 faults=None):
        self.cost_model = cost_model
        self.counters = counters if counters is not None else {}
        self.faults = faults  # engine's FaultPlane (rehydrate site), or None
        self.store = ArtifactStore(budget, disk_budget, counters=self.counters)
        # (fingerprint, b_q.key()) -> (fully_covered, granted_entries);
        # artifacts are immutable once spilled, so entries never go stale —
        # removal just orphans them (bounded by store size x predicates).
        self._covered_memo: Dict[tuple, Tuple[bool, int]] = {}

    # -- spill (called by GraftEngine._evict) --------------------------------
    def spill(self, state) -> bool:
        if isinstance(state, SharedHashBuildState):
            return self._spill_hash(state)
        if isinstance(state, SharedAggregateState):
            return self._spill_agg(state)
        return False

    def _spill_hash(self, st: SharedHashBuildState) -> bool:
        extents = [st.extents[eid] for eid in sorted(st.extents)]
        fp = hash_state_fingerprint(st.sig, extents)
        n = st.did.n
        arrays = {
            "did": st.did.data.copy(),
            "keycode": st.keycode.data.copy(),
            "emask": st.emask.data.copy(),
        }
        for a in st.retained_attrs:
            arrays[f"col::{a}"] = st.cols[a].data.copy()
        meta = {
            "state_id": st.state_id,
            "key_attrs": st.key_attrs,
            "payload": st.payload,
            "did_domain": st.did_domain,
            "extents": extents,  # (conj | None, complete) in eid order
            "extent_parts": {
                eid: (total, tuple(sorted(done)))
                for eid, (total, done) in st.extent_parts.items()
            },
            "n_entries": n,
        }
        return self.store.put(
            StateArtifact(fp, "hash_build", st.sig, st.nbytes(), meta, arrays)
        )

    def _spill_agg(self, st: SharedAggregateState) -> bool:
        # Only completed identities are reusable: an attaching lens reads
        # the merged result; incomplete accumulators would need their
        # producer (gone) and distinct seen-pair indexes (not serialized).
        if st.sig is None or not st.complete:
            return False
        fp = aggregate_fingerprint(st.sig)
        arrays: Dict[str, np.ndarray] = {}
        part_groups = []
        for i, p in enumerate(st._parts):
            part_groups.append(p.n_groups)
            for k, gc in enumerate(p.group_cols):
                arrays[f"p{i}_g{k}"] = gc.data.copy()
            for j, acc in enumerate(p._acc):
                arrays[f"p{i}_acc{j}"] = acc.data.copy()
            arrays[f"p{i}_counts"] = p._counts.data.copy()
        meta = {
            "state_id": st.state_id,
            "group_keys": st.group_keys,
            "aggs": st.aggs,
            "n_partitions": st.n_partitions,
            "rows_consumed": st.rows_consumed,
            "part_groups": part_groups,
        }
        return self.store.put(
            StateArtifact(fp, "aggregate", st.sig, st.nbytes(), meta, arrays)
        )

    # -- selection (shared by admission, EXPLAIN, and the controller) --------
    def _artifact_covered(self, art: StateArtifact, b_q: Optional[Conjunction],
                          demand: int) -> int:
        """Demand rows the artifact's coverage would serve as represented
        for build predicate ``b_q`` — the exact mirror of the live
        represented-extent check (§4.3) evaluated on the artifact."""
        if b_q is None:
            return 0
        memo_key = (art.fingerprint, b_q.key())
        hit = self._covered_memo.get(memo_key)
        if hit is not None:
            full, granted = hit
            return demand if full else min(granted, demand)
        retained = frozenset(art.meta["payload"]) | frozenset(art.meta["key_attrs"])
        b_ret = Conjunction({a: c for a, c in b_q.constraints.items() if a in retained})
        b_nonret = Conjunction(
            {a: c for a, c in b_q.constraints.items() if a not in retained}
        )
        completed = [
            (eid, conj)
            for eid, (conj, done) in enumerate(art.meta["extents"])
            if done and conj is not None
        ]
        if not b_nonret.constraints:
            allowed = ALL_EXTENTS
        else:
            allowed = np.uint64(0)
            for eid, conj in completed:
                if conj.implies(b_nonret):
                    allowed |= np.uint64(1) << np.uint64(eid)
        if not allowed:
            self._covered_memo[memo_key] = (False, 0)
            return 0
        cov = Coverage(
            conj for eid, conj in completed
            if (np.uint64(1) << np.uint64(eid)) & allowed
        )
        if cov.covers(b_q):
            self._covered_memo[memo_key] = (True, 0)
            return demand
        arrays = art.arrays
        if arrays is None:  # disk shadow: load for the count, don't promote
            loaded = self.store.get(art.fingerprint)
            arrays = loaded.arrays if loaded is not None else None
        if arrays is None or art.meta["n_entries"] == 0:
            self._covered_memo[memo_key] = (False, 0)
            return 0
        m = (arrays["emask"] & allowed) != 0
        if b_ret.attrs():
            cols = {a: arrays[f"col::{a}"] for a in b_ret.attrs()}
            m = m & evaluate_conj(b_ret, cols)
        granted = int(m.sum())
        self._covered_memo[memo_key] = (False, granted)
        return min(granted, demand)

    def select_hash(self, engine, sig: StateSignature, b_q: Optional[Conjunction],
                    demand: int) -> Optional[Tuple[StateArtifact, int]]:
        """Best cached hash-build artifact for one boundary, or None when
        no artifact passes the three-way cost gate. Deterministic: max
        covered rows, ties to the oldest spill."""
        best: Optional[Tuple[StateArtifact, int]] = None
        for art in self.store.by_sig("hash_build", sig.key):
            covered = self._artifact_covered(art, b_q, demand)
            if covered <= 0:
                continue
            if best is None or covered > best[1]:
                best = (art, covered)
        if best is None:
            return None
        art, covered = best
        if not rehydrate_wins(self.cost_model, demand, covered, art.meta["n_entries"]):
            return None
        return best

    def _agg_saved_rows(self, engine, plan, agg) -> int:
        """Rows an isolated execution of ``plan`` would process that an
        aggregate-identity cache hit eliminates: the aggregate's input
        cardinality plus every boundary's build demand."""
        from .grafting import all_boundaries, estimate_demand

        saved = 0
        # the full-plan input count is only estimable when probe keys live
        # on the spine scan; fall back to the boundary demands alone (a
        # lower bound on saved work, so the gate stays conservative)
        try:
            saved += estimate_demand(engine, agg.input)
        except (TypeError, KeyError):
            pass
        for b in all_boundaries(plan):
            try:
                saved += estimate_demand(engine, b.build)
            except (TypeError, KeyError):
                pass
        return saved

    def peek_agg(self, engine, plan, agg, agg_sig: StateSignature
                 ) -> Optional[StateArtifact]:
        """Cost-gated aggregate artifact peek (read-only; EXPLAIN + the
        admission controller's reuse potential)."""
        art = self.store.get(aggregate_fingerprint(agg_sig))
        if art is None or art.meta["n_partitions"] != engine.n_partitions:
            return None
        saved = self._agg_saved_rows(engine, plan, agg)
        entries = sum(art.meta["part_groups"])
        if not rehydrate_wins(self.cost_model, saved, saved, entries):
            return None
        return art

    # -- rehydration ---------------------------------------------------------
    def _build_hash(self, state_id: int, art: StateArtifact, n_partitions: int,
                    counters, index: bool = True) -> SharedHashBuildState:
        meta = art.meta
        st = SharedHashBuildState(
            state_id,
            art.sig,
            meta["key_attrs"],
            meta["payload"],
            did_domain=meta["did_domain"],
            counters=counters,
            n_partitions=n_partitions,
        )
        arrays = art.arrays
        n = meta["n_entries"]
        if n:
            dids = np.asarray(arrays["did"], dtype=np.int64)
            kcs = np.asarray(arrays["keycode"], dtype=np.int64)
            st.did.append(dids)
            st.keycode.append(kcs)
            st.vis.append(np.zeros(n, dtype=np.uint64))  # no lens survives retirement
            st.emask.append(np.asarray(arrays["emask"], dtype=np.uint64))
            for a in st.retained_attrs:
                st.cols[a].append(np.asarray(arrays[f"col::{a}"], dtype=np.float64))
            if index:
                # derived structure: ids assign 0..n-1 in array order (dids
                # are unique per entry), matching the original exactly
                if st.n_partitions == 1:
                    st._did_index.lookup_or_insert(dids)
                else:
                    st._sharded_did_resolve(dids, kcs, 0)
            st.rows_inserted = n
        for conj, done in meta["extents"]:
            eid = st.register_extent(conj)
            if done:
                st.complete_extent(eid)
        st.extent_parts = {
            eid: (total, set(parts))
            for eid, (total, parts) in meta["extent_parts"].items()
        }
        return st

    def ghost_hash(self, art: StateArtifact) -> Optional[SharedHashBuildState]:
        """Unregistered rehydration for EXPLAIN: a throwaway state object
        carrying the artifact's coverage + entries so the read-only
        decision ladder can score it exactly like a live candidate. Never
        touches the engine (fresh ids, no counters, no did index). None
        when the artifact turns out corrupt at load."""
        if art.arrays is None:
            art = self.store.get(art.fingerprint)
            if art is None:
                return None
        return self._build_hash(art.meta["state_id"], art, 1, None, index=False)

    def _rehydrate_faulted(self, fp: tuple) -> bool:
        """§16 ``rehydrate`` fault site: one draw per rehydration attempt.
        A hit simulates artifact corruption — the entry is dropped and
        counted exactly as a failed integrity check, and the caller falls
        through to recompute."""
        if self.faults is None or not self.faults.fire("rehydrate"):
            return False
        self.store.remove(fp)
        self.counters["cache_corrupt"] = self.counters.get("cache_corrupt", 0) + 1
        return True

    def try_rehydrate_hash(self, engine, handle, sig: StateSignature,
                           b_q: Optional[Conjunction], demand: int
                           ) -> Optional[SharedHashBuildState]:
        """Admission-time rehydration: on a cost-model win, rebuild the
        artifact as a live shared state and register it under its
        signature — ``resolve_boundary``'s ladder then attaches to it
        exactly as to a never-evicted retained state."""
        if not engine.mode.allow_represented:
            return None
        sel = self.select_hash(engine, sig, b_q, demand)
        if sel is None:
            return None
        art, _covered = sel
        if self._rehydrate_faulted(art.fingerprint):
            return None
        if art.arrays is None:
            art = self.store.get(art.fingerprint)
            if art is None:
                return None
        engine._next_state_id += 1
        st = self._build_hash(
            engine._next_state_id, art, engine.n_partitions, engine.counters
        )
        self.store.take(art.fingerprint)
        engine.state_index.setdefault(sig, []).append(st)
        c = engine.counters
        c["cache_hits"] += 1
        c["rehydrate_bytes"] += art.nbytes
        if handle is not None:
            handle.cache_hits += 1
        return st

    def try_rehydrate_agg(self, engine, handle, plan, agg,
                          agg_sig: StateSignature) -> Optional[SharedAggregateState]:
        """Aggregate-identity rehydration: rebuild the completed
        accumulator state and re-register it under its signature; the
        caller's attach path then collapses the whole plan onto it."""
        art = self.peek_agg(engine, plan, agg, agg_sig)
        if art is None:
            return None
        if self._rehydrate_faulted(art.fingerprint):
            return None
        if art.arrays is None:
            art = self.store.get(art.fingerprint)
            if art is None:
                return None
        meta = art.meta
        engine._next_state_id += 1
        st = SharedAggregateState(
            engine._next_state_id,
            agg_sig,
            meta["group_keys"],
            meta["aggs"],
            counters=engine.counters,
            n_partitions=meta["n_partitions"],
        )
        K = len(st.group_keys)
        for i, p in enumerate(st._parts):
            ng = meta["part_groups"][i]
            if ng == 0:
                continue
            for k in range(K):
                p.group_cols[k].append(np.asarray(art.arrays[f"p{i}_g{k}"]))
            for j in range(len(st.aggs)):
                p._acc[j].append(np.asarray(art.arrays[f"p{i}_acc{j}"]))
            p._counts.append(np.asarray(art.arrays[f"p{i}_counts"]))
            if K:
                # rebuild the derived group-id index in stored gid order
                p._gidx.lookup_or_insert([gc.data for gc in p.group_cols])
            else:
                p._global_ready = True
        st.rows_consumed = meta["rows_consumed"]
        st.complete = True
        self.store.take(art.fingerprint)
        engine.agg_index[agg_sig] = st
        c = engine.counters
        c["cache_hits"] += 1
        c["rehydrate_bytes"] += art.nbytes
        if handle is not None:
            handle.cache_hits += 1
        return st

    def close(self) -> None:
        self.store.close()
        self._covered_memo.clear()


# ---------------------------------------------------------------------------
# Admission-controller signal
# ---------------------------------------------------------------------------


def reuse_potential(engine, query) -> float:
    """Demand-weighted share of the query's plan a *cached artifact* would
    serve — the cache-side companion of ``grafting.graft_potential``
    (which scores live/retained state). 1.0 when the whole plan collapses
    onto a cached aggregate identity; otherwise the share of stateful
    boundaries with no live candidate but a cost-winning artifact.
    Read-only and deterministic."""
    reuse = getattr(engine, "reuse", None)
    if reuse is None:
        return 0.0
    from .grafting import all_boundaries, estimate_demand, plan_spine

    mode = engine.mode
    _, _, agg, _ = plan_spine(query.plan)
    agg_sig = aggregate_signature(agg)
    if agg_sig is not None and mode.agg_share == "full":
        if engine.agg_index.get(agg_sig) is None:
            if reuse.peek_agg(engine, query.plan, agg, agg_sig) is not None:
                return 1.0
    if not (mode.share_state and mode.allow_represented):
        return 0.0
    total = cached = 0
    for j in all_boundaries(query.plan):
        d = estimate_demand(engine, j.build)
        total += d
        sig = hash_build_signature(j)
        if engine.state_index.get(sig):
            continue  # live candidate: graft_potential already counts it
        b_q = Conjunction.from_pred(collect_subtree_pred(j.build))
        if reuse.select_hash(engine, sig, b_q, d) is not None:
            cached += d
    return cached / total if total else 0.0
