"""Predicate ASTs, canonicalization, and the containment prover Prove(P => Q).

Implements the sound-but-incomplete predicate fragment of GraftDB §4.2:

* conjunctions of deterministic comparisons between retained attributes and
  constants (plus dictionary-coded set membership, which subsumes equality),
* canonicalization of equality predicates and lower/upper bounds on each
  retained attribute,
* per-attribute range-containment rules applied independently over comparable
  scalar domains.

Anything outside the fragment (disjunctions, NULL-sensitive forms, cross
attribute expressions) canonicalizes to ``None`` and is treated as UNPROVEN.
Unproven obligations never classify an extent as represented — they fall to
residual production or ordinary-plan work (lost sharing, never unsafe
sharing).

All column values are encoded into comparable scalar domains up front
(dates -> int days, strings -> dictionary codes with membership-only
semantics), so the prover works on floats/ints only.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------

_OPS = ("<", "<=", ">", ">=", "==")


@dataclass(frozen=True)
class Cmp:
    """attr <op> constant."""

    attr: str
    op: str
    value: float

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unsupported comparison op {self.op!r}")


@dataclass(frozen=True)
class InSet:
    """attr IN {codes} — dictionary-coded membership (equality is a
    singleton set). Membership is the only meaningful relation on dictionary
    codes; range comparisons on coded columns are outside the fragment."""

    attr: str
    values: FrozenSet[float]


@dataclass(frozen=True)
class And:
    children: Tuple[object, ...]


_COL_OPS = ("<", "<=", ">", ">=", "==", "!=")


@dataclass(frozen=True)
class ColCmp:
    """attr_a <op> attr_b — cross-attribute comparison (e.g. TPC-H Q5's
    c_nationkey = s_nationkey, Q4's l_commitdate < l_receiptdate). Evaluable,
    but OUTSIDE the prover fragment: canonicalization returns None, so such
    predicates are never used to classify an extent as represented
    (unproven -> lost sharing, never unsafe sharing)."""

    lhs: str
    op: str
    rhs: str

    def __post_init__(self):
        if self.op not in _COL_OPS:
            raise ValueError(f"unsupported column comparison op {self.op!r}")


TRUE = And(())

Pred = object  # Cmp | InSet | And | ColCmp


def pred_and(*preds: Pred) -> Pred:
    """Conjunction constructor that flattens nested Ands and drops TRUE."""
    out: List[Pred] = []
    for p in preds:
        if p is None or p == TRUE:
            continue
        if isinstance(p, And):
            out.extend(p.children)
        else:
            out.append(p)
    if not out:
        return TRUE
    if len(out) == 1:
        return out[0]
    return And(tuple(out))


def free_attrs(pred: Pred) -> FrozenSet[str]:
    """FV(P): the attributes a predicate references (§4.2 evaluability)."""
    if isinstance(pred, (Cmp, InSet)):
        return frozenset((pred.attr,))
    if isinstance(pred, ColCmp):
        return frozenset((pred.lhs, pred.rhs))
    if isinstance(pred, And):
        out: FrozenSet[str] = frozenset()
        for c in pred.children:
            out = out | free_attrs(c)
        return out
    return frozenset()


# ---------------------------------------------------------------------------
# Canonical conjunctions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttrConstraint:
    """Canonical per-attribute constraint: an interval and/or a member set.

    ``members`` is ``None`` when no membership constraint applies. An empty
    members set means the constraint is unsatisfiable.
    """

    lo: float = -math.inf
    lo_inc: bool = True
    hi: float = math.inf
    hi_inc: bool = True
    members: Optional[FrozenSet[float]] = None

    # -- algebra ----------------------------------------------------------
    def intersect(self, other: "AttrConstraint") -> "AttrConstraint":
        lo, lo_inc = max(
            (self.lo, not self.lo_inc), (other.lo, not other.lo_inc)
        )
        lo_inc = not lo_inc
        hi, hi_inc = min(
            (self.hi, self.hi_inc), (other.hi, other.hi_inc)
        )
        if self.members is None:
            members = other.members
        elif other.members is None:
            members = self.members
        else:
            members = self.members & other.members
        return AttrConstraint(lo, lo_inc, hi, hi_inc, members)

    def contains(self, other: "AttrConstraint") -> bool:
        """True iff every value satisfying ``other`` satisfies ``self``.

        Sound under the encoded scalar domains. Mixed set/range reasoning is
        limited to the sound direction: a member set is contained in a range
        iff all members fall inside it.
        """
        if other.is_empty():
            return True
        # Membership side.
        if self.members is not None:
            if other.members is None:
                return False  # range cannot be proven inside a finite set
            if not other.members <= self.members:
                return False
        # Range side: other's effective range must sit inside self's range.
        o_lo, o_lo_inc, o_hi, o_hi_inc = other.lo, other.lo_inc, other.hi, other.hi_inc
        if other.members is not None and other.members:
            mlo, mhi = min(other.members), max(other.members)
            if mlo > o_lo or (mlo == o_lo and not o_lo_inc):
                o_lo, o_lo_inc = mlo, True
            if mhi < o_hi or (mhi == o_hi and not o_hi_inc):
                o_hi, o_hi_inc = mhi, True
        if o_lo < self.lo or (o_lo == self.lo and o_lo_inc and not self.lo_inc):
            return False
        if o_hi > self.hi or (o_hi == self.hi and o_hi_inc and not self.hi_inc):
            return False
        return True

    def is_empty(self) -> bool:
        if self.members is not None and not self.members:
            return True
        if self.lo > self.hi:
            return True
        if self.lo == self.hi and not (self.lo_inc and self.hi_inc):
            return True
        if self.members is not None:
            return not any(self._in_range(m) for m in self.members)
        return False

    def _in_range(self, v: float) -> bool:
        if v < self.lo or (v == self.lo and not self.lo_inc):
            return False
        if v > self.hi or (v == self.hi and not self.hi_inc):
            return False
        return True

    def is_unconstrained(self) -> bool:
        return (
            self.members is None
            and self.lo == -math.inf
            and self.hi == math.inf
        )

    def key(self):
        mem = None if self.members is None else tuple(sorted(self.members))
        return (self.lo, self.lo_inc, self.hi, self.hi_inc, mem)


class Conjunction:
    """Canonical conjunction: attr -> AttrConstraint. Hash/eq by content."""

    __slots__ = ("constraints",)

    def __init__(self, constraints: Optional[Dict[str, AttrConstraint]] = None):
        cons = dict(constraints or {})
        # Normalize away no-op constraints so TRUE has a unique form.
        self.constraints: Dict[str, AttrConstraint] = {
            a: c for a, c in cons.items() if not c.is_unconstrained()
        }

    # -- construction ------------------------------------------------------
    @staticmethod
    def from_pred(pred: Pred) -> Optional["Conjunction"]:
        """Canonicalize a predicate. Returns None outside the fragment."""
        cons: Dict[str, AttrConstraint] = {}

        def add(attr: str, c: AttrConstraint):
            cons[attr] = cons[attr].intersect(c) if attr in cons else c

        def walk(p: Pred) -> bool:
            if p is TRUE:
                return True
            if isinstance(p, And):
                return all(walk(c) for c in p.children)
            if isinstance(p, Cmp):
                v = float(p.value)
                if p.op == "<":
                    add(p.attr, AttrConstraint(hi=v, hi_inc=False))
                elif p.op == "<=":
                    add(p.attr, AttrConstraint(hi=v, hi_inc=True))
                elif p.op == ">":
                    add(p.attr, AttrConstraint(lo=v, lo_inc=False))
                elif p.op == ">=":
                    add(p.attr, AttrConstraint(lo=v, lo_inc=True))
                elif p.op == "==":
                    add(p.attr, AttrConstraint(members=frozenset((v,))))
                return True
            if isinstance(p, InSet):
                add(p.attr, AttrConstraint(members=frozenset(float(v) for v in p.values)))
                return True
            return False  # unsupported node -> outside the fragment

        if not walk(pred):
            return None
        return Conjunction(cons)

    # -- relations ----------------------------------------------------------
    def implies(self, other: "Conjunction") -> bool:
        """Prove(self => other): every attr constraint of ``other`` must
        contain the corresponding constraint of ``self``. Missing constraint
        on our side means we are weaker there -> unproven."""
        if self.is_empty():
            return True
        for attr, oc in other.constraints.items():
            sc = self.constraints.get(attr)
            if sc is None:
                return False
            if not oc.contains(sc):
                return False
        return True

    def intersect(self, other: "Conjunction") -> "Conjunction":
        cons = dict(self.constraints)
        for a, c in other.constraints.items():
            cons[a] = cons[a].intersect(c) if a in cons else c
        return Conjunction(cons)

    def is_empty(self) -> bool:
        return any(c.is_empty() for c in self.constraints.values())

    def attrs(self) -> FrozenSet[str]:
        return frozenset(self.constraints)

    # -- hashing ------------------------------------------------------------
    def key(self):
        return tuple(sorted((a, c.key()) for a, c in self.constraints.items()))

    def __eq__(self, other):
        return isinstance(other, Conjunction) and self.key() == other.key()

    def __hash__(self):
        return hash(self.key())

    def __repr__(self):
        if not self.constraints:
            return "Conjunction(TRUE)"
        parts = []
        for a, c in sorted(self.constraints.items()):
            s = a
            if c.members is not None:
                s += f" in {sorted(c.members)}"
            if c.lo != -math.inf:
                s += f" {'>=' if c.lo_inc else '>'} {c.lo}"
            if c.hi != math.inf:
                s += f" {'<=' if c.hi_inc else '<'} {c.hi}"
            parts.append(s)
        return "Conjunction(" + " & ".join(parts) + ")"


TRUE_CONJ = Conjunction()


# ---------------------------------------------------------------------------
# Coverage: union of conjunctions, with one-attribute interval merging
# ---------------------------------------------------------------------------


def _try_merge(a: Conjunction, b: Conjunction) -> Optional[Conjunction]:
    """Merge two conjunctions that agree on all attrs except at most one,
    where their intervals overlap or touch. Sound widening used only for
    coverage bookkeeping (the union of complete extents stays complete)."""
    attrs = set(a.constraints) | set(b.constraints)
    diff = [
        t
        for t in attrs
        if a.constraints.get(t, AttrConstraint()) != b.constraints.get(t, AttrConstraint())
    ]
    if not diff:
        return a
    if len(diff) > 1:
        return None
    t = diff[0]
    ca = a.constraints.get(t, AttrConstraint())
    cb = b.constraints.get(t, AttrConstraint())
    if ca.members is not None or cb.members is not None:
        if ca.members is not None and cb.members is not None and (
            ca.lo, ca.lo_inc, ca.hi, ca.hi_inc
        ) == (cb.lo, cb.lo_inc, cb.hi, cb.hi_inc):
            merged = AttrConstraint(ca.lo, ca.lo_inc, ca.hi, ca.hi_inc, ca.members | cb.members)
            cons = dict(a.constraints)
            cons[t] = merged
            return Conjunction(cons)
        return None
    lo_first, hi_first = (ca, cb) if (ca.lo, not ca.lo_inc) <= (cb.lo, not cb.lo_inc) else (cb, ca)
    # Overlap or touch: second interval must start at or before first's end.
    touch = lo_first.hi > hi_first.lo or (
        lo_first.hi == hi_first.lo and (lo_first.hi_inc or hi_first.lo_inc)
    )
    if not touch:
        return None
    hi, hi_inc = max((ca.hi, ca.hi_inc), (cb.hi, cb.hi_inc))
    merged = AttrConstraint(lo_first.lo, lo_first.lo_inc, hi, hi_inc, None)
    cons = dict(a.constraints)
    cons[t] = merged
    return Conjunction(cons)


class Coverage:
    """Coverage metadata: the extents for which a shared state is complete,
    kept as a merged union of canonical conjunctions (§4.3)."""

    def __init__(self, extents: Iterable[Conjunction] = ()):  # noqa: B008
        self.extents: List[Conjunction] = []
        for e in extents:
            self.add(e)

    def add(self, conj: Conjunction) -> None:
        if conj.is_empty():
            return
        # Drop extents subsumed by the new one, skip if subsumed ourselves.
        kept: List[Conjunction] = []
        for e in self.extents:
            if conj.implies(e):
                return self._merge_fixpoint()  # already covered
            if not e.implies(conj):
                kept.append(e)
        kept.append(conj)
        self.extents = kept
        self._merge_fixpoint()

    def _merge_fixpoint(self) -> None:
        changed = True
        while changed:
            changed = False
            n = len(self.extents)
            for i in range(n):
                for j in range(i + 1, n):
                    m = _try_merge(self.extents[i], self.extents[j])
                    if m is not None:
                        rest = [
                            e for k, e in enumerate(self.extents) if k not in (i, j)
                        ]
                        rest.append(m)
                        self.extents = rest
                        changed = True
                        break
                if changed:
                    break

    def covers(self, conj: Conjunction) -> bool:
        """Prove(conj => coverage): conj must be contained in a single merged
        extent. Sound; incompleteness only loses sharing."""
        return any(conj.implies(e) for e in self.extents)

    def snapshot(self) -> List[Conjunction]:
        return list(self.extents)

    def __repr__(self):
        return f"Coverage({self.extents!r})"


# ---------------------------------------------------------------------------
# Prover entry points (paper notation)
# ---------------------------------------------------------------------------


def prove_implies(p: Pred, q: Pred) -> bool:
    """Prove(P => Q) by canonical containment. Returns False when unproven
    (either predicate outside the supported fragment)."""
    cp = Conjunction.from_pred(p)
    cq = Conjunction.from_pred(q)
    if cp is None or cq is None:
        return False
    return cp.implies(cq)


# ---------------------------------------------------------------------------
# Vectorized evaluation over columnar data
# ---------------------------------------------------------------------------


def evaluate(pred: Pred, cols: Dict[str, np.ndarray]) -> np.ndarray:
    """Evaluate a predicate over columnar numpy data -> bool mask."""
    if pred is TRUE or (isinstance(pred, And) and not pred.children):
        n = len(next(iter(cols.values()))) if cols else 0
        return np.ones(n, dtype=bool)
    if isinstance(pred, And):
        mask = evaluate(pred.children[0], cols)
        for c in pred.children[1:]:
            mask &= evaluate(c, cols)
        return mask
    if isinstance(pred, Cmp):
        col = cols[pred.attr]
        if pred.op == "<":
            return col < pred.value
        if pred.op == "<=":
            return col <= pred.value
        if pred.op == ">":
            return col > pred.value
        if pred.op == ">=":
            return col >= pred.value
        return col == pred.value
    if isinstance(pred, InSet):
        col = cols[pred.attr]
        vals = np.fromiter(pred.values, dtype=np.float64, count=len(pred.values))
        return np.isin(col, vals)
    if isinstance(pred, ColCmp):
        a, b = cols[pred.lhs], cols[pred.rhs]
        if pred.op == "<":
            return a < b
        if pred.op == "<=":
            return a <= b
        if pred.op == ">":
            return a > b
        if pred.op == ">=":
            return a >= b
        if pred.op == "==":
            return a == b
        return a != b
    raise TypeError(f"cannot evaluate predicate node {pred!r}")


def evaluate_conj(conj: Conjunction, cols: Dict[str, np.ndarray]) -> np.ndarray:
    n = len(next(iter(cols.values()))) if cols else 0
    mask = np.ones(n, dtype=bool)
    for attr, c in conj.constraints.items():
        col = cols[attr]
        if c.lo != -math.inf:
            mask &= (col >= c.lo) if c.lo_inc else (col > c.lo)
        if c.hi != math.inf:
            mask &= (col <= c.hi) if c.hi_inc else (col < c.hi)
        if c.members is not None:
            vals = np.fromiter(c.members, dtype=np.float64, count=len(c.members))
            mask &= np.isin(col, vals)
    return mask
