"""Graft-aware batch planning (DESIGN.md §15).

Greedy grafting admits one arrival at a time: each queued query matches
against live state as-is, so two queued queries that could share a scan or
a hash build are folded independently. When the admission path holds
several due arrivals at one decision step, ``plan_cohort`` plans them
jointly over (queued demand × live state): it groups compatible scans,
detects intra-cohort providers — a member whose build extent contains
another member's build predicate, or whose aggregate identity other
members share — and orders the cohort provider-first so the narrower
members attach fully represented to state the wider member is about to
produce, instead of each installing its own residual producer.

Purity contract (the §10/§14 determinism invariants depend on it):

* ``plan_cohort`` is a pure function of (engine state, query set). It
  reads ``state_index`` / ``agg_index`` / the demand cache and mutates
  nothing — no attachment, no rehydration, no pipelines. Calling it twice
  on the same snapshot returns the same plan.
* The plan is invariant under permutation of the input order: members are
  canonicalized by ``(arrival, qid)`` before scoring, and every ordering
  key is an intrinsic property of the (snapshot, member) pair.
* Coverage never regresses: each member's planned coverage is scored
  against the live snapshot PLUS the extents earlier cohort members will
  register, so planned coverage >= the per-query greedy snapshot coverage
  by construction (the metamorphic suite pins this).

The planner scores with the same read-only ladder ``resolve_boundary``
admits with (``grafting.coverage_probe``), so "compatible" cannot drift
between planning and admission. Reuse-plane rehydration is intentionally
not simulated — it mutates the store, and the admission path performs it
identically in any order.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .descriptors import StateSignature, aggregate_signature
from .grafting import boundary_key, build_spine, coverage_probe, estimate_demand, plan_spine
from .plans import PlanNode, Query
from .predicates import Conjunction

# ---------------------------------------------------------------------------
# Read-only profiles of queued demand
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BoundaryProfile:
    """One stateful boundary of a queued plan: the (signature, build
    predicate) pair admission matches on, its isolated-plan demand, and the
    boundaries nested inside its build subtree (eliminated wholesale when
    this boundary attaches fully represented)."""

    sig: StateSignature
    b_q: Optional[Conjunction]
    demand: int
    children: Tuple["BoundaryProfile", ...] = ()

    @property
    def total(self) -> int:
        """Demand of this boundary plus everything a full-represented
        attachment here eliminates upstream."""
        return self.demand + sum(c.total for c in self.children)

    def flat(self) -> List["BoundaryProfile"]:
        out = [self]
        for c in self.children:
            out.extend(c.flat())
        return out


@dataclass(frozen=True)
class QueryProfile:
    """Everything the planner needs to know about one queued arrival,
    derived read-only from its plan + the engine's demand cache."""

    qid: int
    arrival: float
    template: str
    scan_table: str
    agg_sig: Optional[StateSignature]
    bounds: Tuple[BoundaryProfile, ...]

    @property
    def total_demand(self) -> int:
        return sum(b.total for b in self.bounds)

    def flat_bounds(self) -> List[BoundaryProfile]:
        out: List[BoundaryProfile] = []
        for b in self.bounds:
            out.extend(b.flat())
        return out


def _profile_join(engine, join) -> BoundaryProfile:
    sig, b_q = boundary_key(join)
    _, inner = build_spine(join.build)
    children = tuple(_profile_join(engine, ij) for ij in inner)
    return BoundaryProfile(sig, b_q, estimate_demand(engine, join.build), children)


def profile_query(engine, query: Query) -> QueryProfile:
    scan, joins, agg, _ = plan_spine(query.plan)
    agg_sig = aggregate_signature(agg) if engine.mode.agg_share != "none" else None
    return QueryProfile(
        qid=query.qid,
        arrival=query.arrival,
        template=getattr(query, "template", "?"),
        scan_table=scan.table,
        agg_sig=agg_sig,
        bounds=tuple(_profile_join(engine, j) for j in joins),
    )


# ---------------------------------------------------------------------------
# Coverage scoring: live snapshot + virtual in-cohort extents
# ---------------------------------------------------------------------------


def _agg_live(engine, agg_sig: Optional[StateSignature]) -> bool:
    if agg_sig is None or engine.mode.agg_share == "none":
        return False
    existing = engine.agg_index.get(agg_sig)
    return existing is not None and engine._agg_attachable(existing)


def _cover(engine, bp: BoundaryProfile, virtual, register: bool) -> int:
    """Rows of ``bp``'s subtree demand that ride shared state.

    ``virtual`` maps signature -> build predicates of extents earlier
    cohort members will register (their residual/ordinary producers); with
    ``virtual=None`` this scores the per-query greedy snapshot. Mirrors
    ``resolve_boundary``: a fully covered boundary (live or virtual)
    eliminates its whole subtree and registers nothing; a partial/ordinary
    attachment registers its own extent and resolves children bottom-up."""
    full, granted = coverage_probe(engine, bp.sig, bp.b_q, bp.demand)
    if full:
        return bp.total
    if virtual is not None and bp.b_q is not None:
        for wide in virtual.get(bp.sig, ()):
            if bp.b_q.implies(wide):
                return bp.total
    if register and bp.b_q is not None:
        virtual.setdefault(bp.sig, []).append(bp.b_q)
    cov = granted
    for c in bp.children:
        cov += _cover(engine, c, virtual, register)
    return cov


def snapshot_coverage(engine, prof: QueryProfile) -> int:
    """Represented coverage a per-query greedy admission would observe
    against the engine's current state — the baseline the planner must
    never fall below."""
    if _agg_live(engine, prof.agg_sig):
        return prof.total_demand
    return sum(_cover(engine, b, None, False) for b in prof.bounds)


def _simulate(engine, ordered: List[QueryProfile]) -> Dict[int, Tuple[int, bool]]:
    """Planned coverage per member when the cohort admits in ``ordered``
    order: each member sees the live snapshot plus the extents and
    aggregate identities earlier members will have registered."""
    virtual: Dict[StateSignature, List[Conjunction]] = {}
    virtual_aggs: set = set()
    out: Dict[int, Tuple[int, bool]] = {}
    for p in ordered:
        if _agg_live(engine, p.agg_sig) or p.agg_sig in virtual_aggs:
            out[p.qid] = (p.total_demand, True)
            continue
        cov = sum(_cover(engine, b, virtual, True) for b in p.bounds)
        if p.agg_sig is not None and engine.mode.agg_share != "none":
            virtual_aggs.add(p.agg_sig)
        out[p.qid] = (cov, False)
    return out


def _provider_weights(engine, profs: List[QueryProfile]) -> Dict[int, int]:
    """Rows of OTHER members' demand each member's admission would turn
    into represented coverage: boundary extents containing another
    member's build predicate, plus shared aggregate identities. Intrinsic
    to the (snapshot, member-set) pair — never to the input order."""
    flats = {p.qid: p.flat_bounds() for p in profs}
    full_memo: Dict[object, bool] = {}

    def live_full(bp: BoundaryProfile) -> bool:
        key = (bp.sig, bp.b_q.key() if bp.b_q is not None else None)
        hit = full_memo.get(key)
        if hit is None:
            hit = coverage_probe(engine, bp.sig, bp.b_q, bp.demand)[0]
            full_memo[key] = hit
        return hit

    weights = {p.qid: 0 for p in profs}
    for p in profs:
        for o in profs:
            if o.qid == p.qid:
                continue
            for bo in flats[o.qid]:
                if bo.b_q is None or live_full(bo):
                    continue
                for bp in flats[p.qid]:
                    if bp.sig == bo.sig and bp.b_q is not None and bo.b_q.implies(bp.b_q):
                        weights[p.qid] += bo.total
                        break
    groups: Dict[StateSignature, List[QueryProfile]] = defaultdict(list)
    for p in profs:
        if p.agg_sig is not None and not _agg_live(engine, p.agg_sig):
            groups[p.agg_sig].append(p)
    for members in groups.values():
        if len(members) > 1:
            tot = sum(m.total_demand for m in members)
            for m in members:
                weights[m.qid] += tot - m.total_demand
    return weights


# ---------------------------------------------------------------------------
# The cohort plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MemberPlan:
    qid: int
    arrival: float
    template: str
    scan_table: str
    demand_rows: int
    snapshot_rows: int  # per-query greedy coverage on the same snapshot
    planned_rows: int  # coverage in planned cohort order
    provider_weight: int
    agg_collapse: bool

    def to_dict(self) -> Dict[str, object]:
        return {
            "qid": self.qid,
            "arrival": self.arrival,
            "template": self.template,
            "scan_table": self.scan_table,
            "demand_rows": self.demand_rows,
            "snapshot_rows": self.snapshot_rows,
            "planned_rows": self.planned_rows,
            "provider_weight": self.provider_weight,
            "agg_collapse": self.agg_collapse,
        }


@dataclass(frozen=True)
class CohortPlan:
    """One jointly planned admission cohort, in planned admission order."""

    members: Tuple[MemberPlan, ...]

    @property
    def size(self) -> int:
        return len(self.members)

    @property
    def order(self) -> Tuple[int, ...]:
        return tuple(m.qid for m in self.members)

    @property
    def snapshot_rows(self) -> int:
        return sum(m.snapshot_rows for m in self.members)

    @property
    def planned_rows(self) -> int:
        return sum(m.planned_rows for m in self.members)

    @property
    def gain_rows(self) -> int:
        return max(0, self.planned_rows - self.snapshot_rows)

    def to_dict(self) -> Dict[str, object]:
        return {
            "size": self.size,
            "order": list(self.order),
            "snapshot_rows": self.snapshot_rows,
            "planned_rows": self.planned_rows,
            "gain_rows": self.gain_rows,
            "members": [m.to_dict() for m in self.members],
        }

    def render(self) -> str:
        """The EXPLAIN GRAFT COHORT block."""
        lines = [
            f"EXPLAIN GRAFT COHORT: {self.size} queries, planned coverage "
            f"{self.planned_rows} rows (greedy snapshot {self.snapshot_rows}, "
            f"gain +{self.gain_rows})"
        ]
        by_scan: Dict[str, List[MemberPlan]] = defaultdict(list)
        for m in self.members:
            by_scan[m.scan_table].append(m)
        for table in sorted(by_scan):
            qids = ", ".join(f"q{m.qid}" for m in by_scan[table])
            lines.append(f"  scan group {table}: {qids}")
        for i, m in enumerate(self.members):
            tags = []
            if m.agg_collapse:
                tags.append("agg-collapse")
            if m.provider_weight > 0:
                tags.append(f"provides {m.provider_weight} rows")
            tag = f" [{', '.join(tags)}]" if tags else ""
            lines.append(
                f"  {i + 1}. q{m.qid} [{m.template}] arrival={m.arrival:g} "
                f"demand={m.demand_rows} planned={m.planned_rows} "
                f"(snapshot {m.snapshot_rows}){tag}"
            )
        return "\n".join(lines)


def plan_cohort(engine, queries: List[Query]) -> CohortPlan:
    """Jointly plan one admission cohort against the engine's current
    state. Pure + read-only; see the module docstring for the contract."""
    profs = sorted(
        (profile_query(engine, q) for q in queries),
        key=lambda p: (p.arrival, p.qid),
    )
    weights = _provider_weights(engine, profs)
    ordered = sorted(profs, key=lambda p: (-weights[p.qid], p.arrival, p.qid))
    sim = _simulate(engine, ordered)
    members = tuple(
        MemberPlan(
            qid=p.qid,
            arrival=p.arrival,
            template=p.template,
            scan_table=p.scan_table,
            demand_rows=p.total_demand,
            snapshot_rows=snapshot_coverage(engine, p),
            planned_rows=sim[p.qid][0],
            provider_weight=weights[p.qid],
            agg_collapse=sim[p.qid][1],
        )
        for p in ordered
    )
    return CohortPlan(members)
