"""Ready-fragment extraction (Algorithm 2) and the single-worker executor.

The evaluated prototype (paper §6.1) uses one worker thread: inter-query
concurrency comes from interleaving ready fragments of the shared execution
DAG. We reproduce that model — the executor repeatedly extracts ready
fragments and advances one shared cyclic scan by one morsel, which pushes
the morsel through every attached pipeline for every active node-query pair.

Clocks:

* ``WorkClock`` — virtual time advanced by the modeled cost of each executed
  fragment (calibrated per-row constants). Makes the paper's hour-long
  open-loop sweeps reproducible in seconds, deterministically.
* ``WallClock`` — real time (used by the fig.6 two-query experiment).

Work-model counters (rows scanned / built / probed) are clock-independent.
"""

from __future__ import annotations

import heapq
import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from .engine import GraftEngine, QueryHandle
from .plans import Query
from .runtime import Member, Pipeline, ScanNode

# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class WorkClock:
    def __init__(self):
        self.now = 0.0

    def tick(self, cost: float) -> None:
        self.now += cost

    def advance_to(self, t: float) -> None:
        if t > self.now:
            self.now = t


class WallClock:
    def __init__(self):
        self._t0 = time.perf_counter()

    @property
    def now(self) -> float:
        return time.perf_counter() - self._t0

    def tick(self, cost: float) -> None:
        pass  # real work took real time

    def advance_to(self, t: float) -> None:
        dt = t - self.now
        if dt > 0:
            time.sleep(dt)


# ---------------------------------------------------------------------------
# Algorithm 2 — ExtractReadyFragments
# ---------------------------------------------------------------------------


def producer_inactive(n: Pipeline, m: Member) -> bool:
    """Lines 22-25: a state-producing node-query pair is inactive once no
    producer work assigned to q remains pending."""
    if n.build_target is None:
        return False
    return m.done or m.received >= m.need > 0


def state_consumer_blocked(m: Member) -> bool:
    """Lines 26-32: a state-consuming node-query pair passes only when every
    state-ref gate entering it is open."""
    return any(not g.open() for g in m.gates)


def active_at_node(n: Pipeline) -> List[Member]:
    """Lines 13-21 over one operator node (pipeline)."""
    out = []
    for m in n.members:
        if m.done:
            continue
        if producer_inactive(n, m):
            continue
        if state_consumer_blocked(m):
            continue
        if not m.active:
            # gate newly opened — activation assigns the delivery cycle
            continue
        out.append(m)
    return out


def extract_ready_fragments(engine: GraftEngine) -> List[ScanNode]:
    """Restrict the DAG to active node-query pairs, prune by data-edge
    reachability (a pipeline is reachable iff its source scan can still
    deliver morsels to it), group into weak components (pipelines sharing a
    source scan), and order along data edges (scan -> pipelines). Each
    fragment is executable by advancing its scan one morsel."""
    frags: List[ScanNode] = []
    for node in engine.scans.values():
        for p in node.pipelines:
            if active_at_node(p):
                frags.append(node)
                break
    frags.sort(key=lambda s: s.sid)
    return frags


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------


class Runner:
    """Drives one GraftEngine over an arrival trace.

    ``on_complete(handle) -> Optional[Query]`` implements closed-loop
    clients: returning a query enqueues it (arrival = completion time).
    """

    def __init__(self, engine: GraftEngine, clock=None):
        self.engine = engine
        self.clock = clock or WorkClock()
        engine.clock = self.clock
        self._rr = 0
        self._seq = 0
        self._heap: List[Tuple[float, int, Query]] = []
        # Called with the query right before each admission (the Session
        # facade captures EXPLAIN GRAFT snapshots through this).
        self.submit_hook: Optional[Callable[[Query], None]] = None

    def add_arrival(self, query: Query) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (query.arrival, self._seq, query))

    def submit_now(self, query: Query) -> QueryHandle:
        """Admit one query immediately (query grafting happens here)."""
        if self.submit_hook is not None:
            self.submit_hook(query)
        return self.engine.submit(query)

    def run(
        self,
        arrivals: Iterable[Query] = (),
        on_complete: Optional[Callable[[QueryHandle], Optional[Query]]] = None,
        max_steps: int = 50_000_000,
    ) -> List[QueryHandle]:
        engine = self.engine
        for q in arrivals:
            self.add_arrival(q)
        steps = 0
        while self._heap or engine.has_active_work():
            steps += 1
            if steps > max_steps:
                raise RuntimeError("executor exceeded max_steps — livelock?")
            # admit due arrivals (query grafting happens at submit)
            while self._heap and self._heap[0][0] <= self.clock.now:
                _, _, q = heapq.heappop(self._heap)
                self.submit_now(q)
                self._after_events(on_complete)
            frags = extract_ready_fragments(engine)
            if not frags:
                if self._heap:
                    self.clock.advance_to(self._heap[0][0])
                    continue
                if engine.has_active_work():
                    # all remaining handles must be completable observers
                    done = engine.sweep_completions()
                    if done:
                        self._after_events(on_complete, done)
                        continue
                    raise RuntimeError(
                        f"deadlock: {len(engine.active_handles)} active queries, no ready fragments"
                    )
                break
            # round-robin over ready fragments
            node = None
            for cand in frags:
                if cand.sid > self._rr:
                    node = cand
                    break
            if node is None:
                node = frags[0]
            self._rr = node.sid
            cost = node.advance(engine)
            self.clock.tick(cost)
            self._after_events(on_complete)
        return engine.completed

    def _after_events(self, on_complete, pre_done: Optional[List[QueryHandle]] = None) -> None:
        engine = self.engine
        engine.check_activations()
        done = list(pre_done or ())
        done += engine.sweep_completions()
        while done:
            h = done.pop()
            if on_complete is not None:
                nxt = on_complete(h)
                if nxt is not None:
                    self.add_arrival(nxt)
                    # admit immediately if due (closed loop)
                    while self._heap and self._heap[0][0] <= self.clock.now:
                        _, _, q = heapq.heappop(self._heap)
                        self.submit_now(q)
            engine.check_activations()
            done += engine.sweep_completions()
