"""Ready-unit extraction (Algorithm 2, partition-lifted) and the worker-pool
executor.

The evaluated prototype (paper §6.1) uses one worker thread: inter-query
concurrency comes from interleaving ready fragments of the shared execution
DAG. We reproduce that model and lift it to a partition-parallel pool
(DESIGN.md §9): the schedulable unit is a (shared scan × partition) pair,
and a ``WorkerPool`` of N logical workers repeatedly hands the next ready
unit to the least-advanced worker, which advances that scan shard by one
morsel — pushing the morsel through every attached pipeline for every
active node-query pair. ``workers=1, partitions=1`` reduces exactly to the
paper's single-worker round-robin loop.

Clocks:

* ``WorkClock`` — virtual time advanced by the modeled cost of each executed
  fragment (calibrated per-row constants). Makes the paper's hour-long
  open-loop sweeps reproducible in seconds, deterministically.
* ``WallClock`` — real time (used by the fig.6 two-query experiment). Sleeps
  are capped by ``max_sleep_s``: under virtual-dominant traces the remainder
  of a long idle gap is skipped by advancing an internal skew instead of
  blocking the process.
* ``PoolClock`` — the engine-visible facade over N per-worker ``WorkClock``s.
  Events (admission, activation, completion) are timestamped on the worker
  executing them; cross-worker dependencies merge with max-at-barrier
  semantics — a worker picking up a unit enabled at time t first advances
  its own clock to t. The merged makespan is the max over worker clocks.

Work-model counters (rows scanned / built / probed) are clock-independent,
and the whole pool is deterministic: unit choice depends only on clock
values and (sid, partition) order, never on host timing.
"""

from __future__ import annotations

import heapq
import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from .engine import GraftEngine, QueryHandle
from .grafting import candidate_states, graft_potential
from .plans import Query
from .reuse import reuse_potential
from .runtime import Member, Pipeline, ScanNode

# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class WorkClock:
    def __init__(self):
        self.now = 0.0

    def tick(self, cost: float) -> None:
        self.now += cost

    def advance_to(self, t: float) -> None:
        if t > self.now:
            self.now = t


class WallClock:
    """Real time. ``max_sleep_s`` caps each blocking sleep: when a trace is
    virtual-dominant (arrivals far apart relative to real work), the
    un-slept remainder is added to an internal skew so ``now`` still lands
    on the requested timestamp without blocking the process for it."""

    def __init__(self, max_sleep_s: Optional[float] = None):
        self._t0 = time.perf_counter()
        self._skew = 0.0
        self.max_sleep_s = max_sleep_s

    @property
    def now(self) -> float:
        return time.perf_counter() - self._t0 + self._skew

    def tick(self, cost: float) -> None:
        pass  # real work took real time

    def advance_to(self, t: float) -> None:
        dt = t - self.now
        if dt <= 0:
            return
        if self.max_sleep_s is not None and dt > self.max_sleep_s:
            time.sleep(self.max_sleep_s)
            rem = t - self.now
            if rem > 0:
                self._skew += rem  # skip the idle remainder virtually
        else:
            time.sleep(dt)


class PoolClock:
    """Engine-visible merge of the pool's per-worker clocks.

    While a worker executes, ``now`` is that worker's local time (events it
    causes are stamped on it); between steps ``now`` is the max over workers
    (the pool's barrier-merged frontier). With one worker this is exactly
    the seed single-clock behavior."""

    def __init__(self, clocks: List):
        self.clocks = clocks
        self.current = None  # the executing worker's clock, if any

    @property
    def now(self) -> float:
        if self.current is not None:
            return self.current.now
        return max(c.now for c in self.clocks)

    def tick(self, cost: float) -> None:
        (self.current or self.clocks[0]).tick(cost)

    def advance_to(self, t: float) -> None:
        for c in self.clocks:
            c.advance_to(t)


# ---------------------------------------------------------------------------
# Algorithm 2 — ExtractReadyFragments, lifted to (fragment × partition)
# ---------------------------------------------------------------------------


def producer_inactive(n: Pipeline, m: Member) -> bool:
    """Lines 22-25: a state-producing node-query pair is inactive once no
    producer work assigned to q remains pending."""
    if n.build_target is None:
        return False
    return m.done or m.received >= m.need > 0


def state_consumer_blocked(m: Member) -> bool:
    """Lines 26-32: a state-consuming node-query pair passes only when every
    state-ref gate entering it is open."""
    return any(not g.open() for g in m.gates)


def active_at_node(n: Pipeline, part: Optional[int] = None) -> List[Member]:
    """Lines 13-21 over one operator node (pipeline); with ``part`` the
    filter additionally requires the member to still be owed morsels from
    that scan partition."""
    out = []
    for m in n.members:
        if m.done:
            continue
        if part is not None and not m.pending_in(part):
            continue
        if producer_inactive(n, m):
            continue
        if state_consumer_blocked(m):
            continue
        if not m.active:
            # gate newly opened — activation assigns the delivery cycle
            continue
        out.append(m)
    return out


def extract_ready_fragments(engine: GraftEngine) -> List[ScanNode]:
    """Restrict the DAG to active node-query pairs, prune by data-edge
    reachability (a pipeline is reachable iff its source scan can still
    deliver morsels to it), group into weak components (pipelines sharing a
    source scan), and order along data edges (scan -> pipelines). Each
    fragment is executable by advancing its scan one morsel."""
    frags: List[ScanNode] = []
    for node in engine.scans.values():
        for p in node.pipelines:
            if active_at_node(p):
                frags.append(node)
                break
    frags.sort(key=lambda s: s.sid)
    return frags


def extract_ready_units(engine: GraftEngine) -> List[Tuple[ScanNode, int]]:
    """The partition-lifted fragment set: every (scan, partition) shard with
    at least one active member still owed morsels from it, ordered by
    (sid, partition). Each unit is executable by advancing that shard one
    morsel on any worker."""
    units: List[Tuple[ScanNode, int]] = []
    for node in engine.scans.values():
        for part in range(node.n_partitions):
            for p in node.pipelines:
                if active_at_node(p, part):
                    units.append((node, part))
                    break
    units.sort(key=lambda u: (u[0].sid, u[1]))
    return units


def unit_ready_time(node: ScanNode, part: int) -> float:
    """Barrier time of one unit: the latest activation among the members it
    would serve — a worker adopting the unit advances its clock here first
    (max-at-barrier merge of the producing workers' clocks)."""
    t = 0.0
    for p in node.pipelines:
        for m in p.active_members_for(part):
            if m.t_activated > t:
                t = m.t_activated
    return t


# ---------------------------------------------------------------------------
# Admission control (overload-aware open-loop serving, DESIGN.md §10)
# ---------------------------------------------------------------------------


class AdmissionController:
    """Per-arrival admission decision for the open-loop queue.

    ``decide(engine, query) -> (verdict, reason)`` where verdict is
    ``'admit'`` or ``'defer'`` and reason labels the admitted path — the
    arrival's three-way cost decision (§12): ``'graft'`` (rides live
    shared state), ``'cache'`` (a spilled artifact rehydrates and serves
    it), or ``'fresh'`` (isolated recompute through an ordinary plan). The
    adaptive policy admits freely below ``max_inflight`` active queries;
    past it, only arrivals whose sharing potential — the demand-weighted
    fraction of their isolated plan that existing shared state
    (``graft_potential``) or cost-winning cached artifacts
    (``reuse_potential``) would absorb — reaches ``share_threshold`` are
    admitted (their marginal work is small, and their lens pins state the
    evictor would otherwise reclaim / consumes an artifact before the
    cache ages it out). Everything else queues until load drops; the
    Runner pins a deferred arrival's candidate states
    (``candidate_states``) so the evictor cannot reclaim coverage a
    queued-but-admissible lens is waiting to observe.

    Decisions depend only on engine state (live indexes + the artifact
    cache, both of which change exactly at submissions/completions), so
    the whole pool stays a deterministic simulation under any
    ``PoolClock`` schedule and the Runner's drain memo stays valid.
    """

    def __init__(self, max_inflight: int = 8, share_threshold: float = 0.5):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight!r}")
        if not (0.0 < share_threshold <= 1.0):
            raise ValueError(
                f"share_threshold must be in (0, 1], got {share_threshold!r}"
            )
        self.max_inflight = max_inflight
        self.share_threshold = share_threshold
        # per-arrival potential memo keyed on the engine's live-state
        # generation (bumped at state attach/retire/evict): a deep FIFO
        # queue used to rescan every arrival's graft_potential on every
        # queue-length change even though its inputs were untouched
        self._pot_memo: Dict[int, Tuple[Tuple[int, float, float], float, float]] = {}

    def potentials(self, engine: GraftEngine, query: Query) -> Tuple[float, float]:
        """Memoized ``(graft_potential, reuse_potential)`` of one arrival.

        The memo key is ``(state_gen, submitted, completed)`` — exactly the
        state a verdict reads (live indexes + artifact cache + in-flight
        progress at the drain granularity), so a hit returns the same value
        a recomputation would. ``admission_evals`` counts only the real
        evaluations (the regression suite pins scan counts on it)."""
        gen = (
            engine.state_gen,
            engine.counters["submitted"],
            engine.counters["completed"],
        )
        hit = self._pot_memo.get(query.qid)
        if hit is not None and hit[0] == gen:
            return hit[1], hit[2]
        live = graft_potential(engine, query)
        cached = reuse_potential(engine, query)
        engine.counters["admission_evals"] += 1
        self._pot_memo[query.qid] = (gen, live, cached)
        return live, cached

    def decide(
        self,
        engine: GraftEngine,
        query: Query,
        active_count: Optional[int] = None,
    ) -> Tuple[str, str]:
        """``active_count`` overrides ``len(engine.active_handles)`` — the
        batched admission path (§15) passes the simulated in-flight count so
        selecting a whole cohort at one decision step keeps the greedy FIFO
        semantics."""
        live, cached = self.potentials(engine, query)
        potential = max(live, cached)
        if potential <= 0.0:
            reason = "fresh"
        elif cached > live:
            reason = "cache"
        else:
            reason = "graft"  # live state dominates: no rehydration cost
        n_active = len(engine.active_handles) if active_count is None else active_count
        if n_active < self.max_inflight:
            self._pot_memo.pop(query.qid, None)
            return ("admit", reason)
        if potential >= self.share_threshold:
            self._pot_memo.pop(query.qid, None)
            return ("admit", reason)
        return ("defer", "overload")


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------


class Runner:
    """Drives one GraftEngine over an arrival trace with N logical workers.

    ``on_complete(handle) -> Optional[Query]`` implements closed-loop
    clients: returning a query enqueues it (arrival = completion time).

    One worker with one partition is byte-identical to the seed
    single-worker executor: same unit order, same clock, same timestamps.
    """

    def __init__(
        self,
        engine: GraftEngine,
        clock=None,
        workers: int = 1,
        clock_factory: Optional[Callable[[], object]] = None,
        admission: Optional[AdmissionController] = None,
        batch_planning: bool = False,
        batch_window: float = 0.0,
    ):
        self.engine = engine
        self.workers = max(1, int(workers))
        if self.workers == 1:
            base = clock if clock is not None else (clock_factory or WorkClock)()
            self.clocks = [base]
        else:
            # N logical workers need N independent virtual clocks; a shared
            # wall/instance clock cannot model parallel speedup
            factory = clock_factory or WorkClock
            self.clocks = [factory() for _ in range(self.workers)]
        self.clock = PoolClock(self.clocks)
        self.busy_s = [0.0] * self.workers
        engine.clock = self.clock
        self._rr: Tuple[int, int] = (0, -1)  # last executed (sid, partition)
        self._heap: List[Tuple[float, int, Query]] = []
        # overload-aware admission (§10): None = admit every due arrival
        # (the seed open-loop behavior); a controller may defer arrivals
        # into the FIFO admit queue until load drops.
        self.admission = admission
        self._admit_queue: List[Tuple[float, int, Query, float]] = []
        self._queued_pins: Dict[int, List] = {}  # qid -> pinned candidate states
        # drain memo: controller verdicts depend only on engine state
        # (active handles + shared-state indexes), which changes exactly at
        # submissions and completions — skip replaying the queue through
        # decide()/graft_potential when neither has happened
        self._drain_ver: Optional[Tuple[float, float, int]] = None
        self.admission_log: Dict[int, Dict[str, object]] = {}
        # batch planning (§15): gather every arrival due at one decision
        # step, window them into cohorts, and admit each cohort in the
        # joint planner's provider-first order. False leaves the greedy
        # one-at-a-time path byte-identical to prior PRs.
        self.batch_planning = bool(batch_planning)
        self.batch_window = float(batch_window)
        self.cohort_log: List[Dict[str, object]] = []
        # Called with the query right before each admission (the Session
        # facade captures EXPLAIN GRAFT snapshots through this).
        self.submit_hook: Optional[Callable[[Query], None]] = None
        # fault tolerance + per-query lifecycle (§16): the engine's fault
        # plane (None = hooks disarmed, zero overhead), virtual-time
        # deadlines enforced at decision-step boundaries, and the terminal
        # reason of arrivals cancelled before they ever got a handle.
        self.faults = getattr(engine, "faults", None)
        self.deadlines: Dict[int, float] = {}
        self.cancelled_qids: Dict[int, str] = {}

    def add_arrival(self, query: Query) -> None:
        # keyed by (arrival, qid): permuted add_arrival orders of one trace
        # replay identically (qids are allocated in trace order)
        heapq.heappush(self._heap, (query.arrival, query.qid, query))

    def submit_now(self, query: Query) -> QueryHandle:
        """Admit one query immediately (query grafting happens here)."""
        if self.submit_hook is not None:
            self.submit_hook(query)
        return self.engine.submit(query)

    def submit_arrival(self, query: Query) -> Optional[QueryHandle]:
        """Admission-controlled immediate submission (the Session.submit
        path for due arrivals). Returns the handle, or None if deferred."""
        if self._try_admit(query, self.clock.now):
            return self.engine.handles[query.qid]
        return None

    # -- admission path (§10) ------------------------------------------------
    def _try_admit(self, q: Query, now: float, t_queued: Optional[float] = None) -> bool:
        """Run one query through the admission controller; submit on admit,
        enqueue first-time deferrals. Returns True iff submitted."""
        if self.admission is None:
            self.submit_now(q)
            return True
        verdict, reason = self.admission.decide(self.engine, q)
        if verdict == "admit":
            delay = (now - t_queued) if t_queued is not None else 0.0
            if t_queued is not None:
                self.engine.counters["queue_delay_s_total"] += delay
                self._unpin_candidates(q.qid)
            self.admission_log[q.qid] = {
                "decision": reason,
                "queued": t_queued is not None,
                "queue_delay_s": delay,
                "t_admitted": now,
            }
            self.submit_now(q)
            return True
        if t_queued is None:
            self.engine.counters["queued_admissions"] += 1
            self._admit_queue.append((q.arrival, q.qid, q, now))
            # pin the candidate states this arrival would graft onto: a
            # queued-but-admissible lens must not lose its coverage to the
            # evictor while it waits (§10)
            self._pin_candidates(q)
        return False

    def _pin_candidates(self, q: Query) -> None:
        """(Re-)snapshot the pins of one queued arrival: states that became
        candidates while it waited are pinned too, states that left the
        index drop off. Idempotent — called at defer and at every
        effective drain retry."""
        token = ("queued", q.qid)
        for s in self._queued_pins.pop(q.qid, ()):
            s.unpin(token)
        pinned = []
        for s in candidate_states(self.engine, q):
            s.pin(token)
            pinned.append(s)
        if pinned:
            self._queued_pins[q.qid] = pinned

    def _unpin_candidates(self, qid: int) -> None:
        token = ("queued", qid)
        for s in self._queued_pins.pop(qid, ()):
            s.unpin(token)

    def _drain_admit_queue(self, now: float, on_complete=None) -> None:
        """Retry deferred arrivals in FIFO order; keep the still-deferred.
        Memoized on (submitted, completed, queue length): re-deciding is
        pointless until the engine state a verdict reads has changed."""
        if not self._admit_queue:
            return
        c = self.engine.counters
        ver = (c["submitted"], c["completed"], len(self._admit_queue))
        if ver == self._drain_ver:
            return
        pending, self._admit_queue = self._admit_queue, []
        for arr, qid, q, t0 in pending:
            if self._try_admit(q, now, t_queued=t0):
                self._after_events(on_complete)
            else:
                self._admit_queue.append((arr, qid, q, t0))
                self._pin_candidates(q)  # re-snapshot against fresh state
        self._drain_ver = (c["submitted"], c["completed"], len(self._admit_queue))

    def _force_admit_head(self, now: float, on_complete=None) -> None:
        """Liveness valve: admit the queue head unconditionally (reached
        only if a policy defers while nothing can otherwise progress)."""
        arr, qid, q, t0 = self._admit_queue.pop(0)
        self._unpin_candidates(qid)
        delay = now - t0
        self.engine.counters["queue_delay_s_total"] += delay
        self.engine.counters["forced_admissions"] += 1
        self.admission_log[qid] = {
            "decision": "forced",
            "queued": True,
            "queue_delay_s": delay,
            "t_admitted": now,
        }
        self.submit_now(q)
        self._after_events(on_complete)

    # -- per-query lifecycle (§16) -------------------------------------------
    def _remove_queued(self, qid: int) -> bool:
        """Strip one not-yet-admitted arrival from the heap / admit queue
        (dropping its eviction pins). True iff it was found."""
        found = False
        kept = [e for e in self._heap if e[1] != qid]
        if len(kept) != len(self._heap):
            self._heap = kept
            heapq.heapify(self._heap)
            found = True
        kept_q = [e for e in self._admit_queue if e[1] != qid]
        if len(kept_q) != len(self._admit_queue):
            self._admit_queue = kept_q
            found = True
        if found:
            self._unpin_candidates(qid)
            self._drain_ver = None
        return found

    def cancel(self, qid: int, reason: str = "cancelled") -> bool:
        """Cancel one query. Queued arrivals are removed before they ever
        admit; an in-flight query tears down at this morsel boundary
        (engine.cancel_query: producer handoff / seal, detach, riders
        unfold). False for unknown or already-terminal qids — cancelling a
        completed query is a no-op, its result stays valid."""
        handle = self.engine.handles.get(qid)
        self.deadlines.pop(qid, None)
        if handle is None:
            if not self._remove_queued(qid):
                return False
            self.cancelled_qids[qid] = reason
            c = self.engine.counters
            c["cancelled"] += 1
            if reason == "deadline":
                c["deadline_cancellations"] += 1
            return True
        if handle.done or handle.status != "active":
            return False
        ok = self.engine.cancel_query(handle, reason)
        if ok:
            self._drain_ver = None
        return ok

    def _apply_deadlines(self, now: float, on_complete) -> bool:
        """Enforce due deadlines at a decision-step boundary — exactly an
        explicit ``cancel(qid, "deadline")`` per expired query. Returns
        True when anything was cancelled (the caller re-extracts its ready
        units: a torn-down pipeline must not execute)."""
        if not self.deadlines:
            return False
        expired = sorted(q for q, d in self.deadlines.items() if d <= now)
        acted = False
        for qid in expired:
            if self.cancel(qid, "deadline"):
                acted = True
        if acted:
            self._after_events(on_complete)
        return acted

    def _fault_gate(self, node, part, wclock, on_complete) -> bool:
        """§16 fault hooks around one morsel advance. True ⇒ the morsel may
        execute. A stall only delays the worker; a fault that survives the
        bounded retries escalates — the morsel never runs, no state
        mutates, and the impacted queries quarantine/unfold/fail."""
        fp = self.faults
        stall = fp.stall()
        if stall > 0.0:
            wclock.tick(stall)
        site = "exchange" if self.engine.mesh_plan is not None else "morsel"
        if fp.attempt(site, wclock):
            return True
        self._escalate(node, part, on_complete)
        return False

    def _escalate(self, node, part, on_complete) -> None:
        """Retry exhaustion at one (scan × partition) unit. Every pipeline
        that would have consumed the faulted morsel is affected: shared
        build targets are quarantined (their fragments are suspect — the
        engine tombstones them and unfolds the attached queries), and
        main-pipeline queries not already handled by a quarantine unfold
        to isolated execution (first escalation) or fail (second)."""
        engine = self.engine
        states: List = []
        qids = set()
        for pipeline in list(node.pipelines):
            if not pipeline.active_members_for(part):
                continue
            bt = pipeline.build_target
            if bt is not None:
                if bt.state not in states:
                    states.append(bt.state)
            else:
                qids.update(m.qid for m in pipeline.active_members_for(part))
        handled = set()
        for st in states:
            handled.update(
                h.qid for h in engine.active_handles if st in h.attached_states
            )
            engine.quarantine_state(st)
        for qid in sorted(qids - handled):
            h = engine.handles.get(qid)
            if h is None or h.done or h.status != "active":
                continue
            if h.degraded:
                engine.cancel_query(h, "failed")
            else:
                engine.unfold(h)
        self._drain_ver = None
        self._after_events(on_complete)

    def worker_stats(self) -> Dict[str, object]:
        """Per-worker utilization of the run so far (QueryFuture.stats)."""
        makespan = max(c.now for c in self.clocks)
        return {
            "n": self.workers,
            "busy_s": [round(b, 9) for b in self.busy_s],
            "makespan_s": makespan,
            "utilization": [
                (b / makespan if makespan > 0 else 0.0) for b in self.busy_s
            ],
        }

    def _admit_due(self, now: float, on_complete) -> None:
        if self.batch_planning:
            self._admit_due_batched(now, on_complete)
            return
        self._drain_admit_queue(now, on_complete)
        while self._heap and self._heap[0][0] <= now:
            _, _, q = heapq.heappop(self._heap)
            if self._try_admit(q, now):
                self._after_events(on_complete)

    # -- batched admission (§15) ---------------------------------------------
    def _admit_due_batched(self, now: float, on_complete) -> None:
        """Cohort admission: gather every candidate due at this decision
        step — the deferred FIFO queue first, then due heap arrivals — run
        the admission controller over them in FIFO order against a
        simulated in-flight count, window the admissible ones into arrival
        cohorts, and admit each cohort in the joint planner's order. A
        size-1 cohort takes exactly the greedy admission steps."""
        due: List[Tuple[float, int, Query]] = []
        while self._heap and self._heap[0][0] <= now:
            due.append(heapq.heappop(self._heap))
        if not due:
            if not self._admit_queue:
                return
            # no new arrivals: same memo as the greedy drain — verdicts
            # cannot change until a submission/completion/new deferral
            c = self.engine.counters
            if (c["submitted"], c["completed"], len(self._admit_queue)) == self._drain_ver:
                return
        # -- selection: admission semantics, FIFO order, simulated load
        selected: List[Tuple[Query, Optional[float], Optional[str]]] = []
        queued, self._admit_queue = self._admit_queue, []
        for arr, qid, q, t0 in queued:
            reason = self._select(q, len(selected))
            if reason is not None:
                selected.append((q, t0, reason))
            else:
                self._admit_queue.append((arr, qid, q, t0))
                self._pin_candidates(q)
        for arr, qid, q in due:
            reason = self._select(q, len(selected))
            if reason is not None:
                selected.append((q, None, reason))
            else:
                self.engine.counters["queued_admissions"] += 1
                self._admit_queue.append((arr, qid, q, now))
                self._pin_candidates(q)
        c = self.engine.counters
        self._drain_ver = (c["submitted"], c["completed"], len(self._admit_queue))
        if not selected:
            return
        # -- window the admissible arrivals into cohorts
        selected.sort(key=lambda e: (e[0].arrival, e[0].qid))
        cohorts: List[List[Tuple[Query, Optional[float], Optional[str]]]] = []
        for entry in selected:
            if cohorts and entry[0].arrival <= cohorts[-1][0][0].arrival + self.batch_window:
                cohorts[-1].append(entry)
            else:
                cohorts.append([entry])
        # -- admit each cohort in planned order
        from .batchplan import plan_cohort

        for cohort in cohorts:
            if len(cohort) == 1:
                q, t0, reason = cohort[0]
                self._admit_one(q, now, t0, reason, on_complete)
                continue
            plan = plan_cohort(self.engine, [e[0] for e in cohort])
            cid = len(self.cohort_log)
            self.cohort_log.append({"cohort": cid, "t": now, "plan": plan})
            self.engine.counters["batch_cohorts"] += 1
            self.engine.counters["batch_planned_queries"] += plan.size
            self.engine.counters["batch_coverage_gain_rows"] += plan.gain_rows
            by_qid = {e[0].qid: e for e in cohort}
            # §15 deferred representation: expose extents earlier cohort
            # members register to the later ones (resolve_boundary reads
            # cohort_ctx); cleared before control leaves the cohort so the
            # greedy path never sees it
            self.engine.cohort_ctx = {}
            try:
                for slot, qid in enumerate(plan.order):
                    q, t0, reason = by_qid[qid]
                    self._admit_one(
                        q,
                        now,
                        t0,
                        reason,
                        on_complete,
                        cohort_meta={"cohort": cid, "size": plan.size, "slot": slot},
                    )
            finally:
                self.engine.cohort_ctx = None

    def _select(self, q: Query, n_selected: int) -> Optional[str]:
        """Selection half of the batched path: the admission reason when the
        controller would admit ``q`` with ``n_selected`` cohort members
        already counted in-flight, else None (defer)."""
        if self.admission is None:
            return "always"
        verdict, reason = self.admission.decide(
            self.engine, q, active_count=len(self.engine.active_handles) + n_selected
        )
        return reason if verdict == "admit" else None

    def _admit_one(
        self,
        q: Query,
        now: float,
        t_queued: Optional[float],
        reason: Optional[str],
        on_complete,
        cohort_meta: Optional[Dict[str, int]] = None,
    ) -> None:
        """Admission half of the batched path: mirrors the admit branch of
        ``_try_admit`` (log record, unpin, queue-delay accounting) plus the
        cohort annotation, then submits and processes events."""
        if self.admission is not None or cohort_meta is not None:
            delay = (now - t_queued) if t_queued is not None else 0.0
            if t_queued is not None:
                self.engine.counters["queue_delay_s_total"] += delay
                self._unpin_candidates(q.qid)
            record: Dict[str, object] = {
                "decision": reason,
                "queued": t_queued is not None,
                "queue_delay_s": delay,
                "t_admitted": now,
            }
            if cohort_meta is not None:
                # recorded regardless of admission control: the cohort
                # membership of a planned admission is part of its stats
                record["cohort"] = cohort_meta
            self.admission_log[q.qid] = record
        self.submit_now(q)
        self._after_events(on_complete)

    def run(
        self,
        arrivals: Iterable[Query] = (),
        on_complete: Optional[Callable[[QueryHandle], Optional[Query]]] = None,
        max_steps: int = 50_000_000,
    ) -> List[QueryHandle]:
        engine = self.engine
        for q in arrivals:
            self.add_arrival(q)
        steps = 0
        try:
            while self._heap or self._admit_queue or engine.has_active_work():
                steps += 1
                if steps > max_steps:
                    raise RuntimeError("executor exceeded max_steps — livelock?")
                # least-advanced worker takes the next scheduling decision
                wi = min(range(self.workers), key=lambda i: self.clocks[i].now)
                wclock = self.clocks[wi]
                self.clock.current = wclock
                # due deadlines cancel before anything else at this step
                self._apply_deadlines(wclock.now, on_complete)
                # admit due arrivals (query grafting happens at submit)
                self._admit_due(wclock.now, on_complete)
                units = extract_ready_units(engine)
                if not units:
                    self.clock.current = None
                    if self._heap:
                        self.clock.advance_to(self._heap[0][0])
                        continue
                    if engine.has_active_work():
                        # all remaining handles must be completable observers
                        done = engine.sweep_completions()
                        if done:
                            self._after_events(on_complete, done)
                            continue
                        if self._admit_queue:
                            # nothing completable: free the admit queue head
                            self._force_admit_head(self.clock.now, on_complete)
                            continue
                        raise RuntimeError(
                            f"deadlock: {len(engine.active_handles)} active queries, no ready fragments"
                        )
                    if self._admit_queue:
                        self._force_admit_head(self.clock.now, on_complete)
                        continue
                    break
                # mesh execution (§14): device affinity — partition p's
                # state shard is resident on device p % workers, so only
                # that worker's clock may advance it. The least-advanced
                # worker defers to the least-advanced OWNER of a ready
                # shard when it owns none itself (deterministic: owners
                # sorted, ties resolve to the lowest device id).
                if engine.mesh_plan is not None and self.workers > 1:
                    owned = [u for u in units if u[1] % self.workers == wi]
                    if not owned:
                        owners = sorted({u[1] % self.workers for u in units})
                        wi = min(owners, key=lambda i: self.clocks[i].now)
                        wclock = self.clocks[wi]
                        self.clock.current = wclock
                        owned = [u for u in units if u[1] % self.workers == wi]
                    units = owned
                # round-robin over ready (scan × partition) units
                unit = None
                for cand in units:
                    if (cand[0].sid, cand[1]) > self._rr:
                        unit = cand
                        break
                if unit is None:
                    unit = units[0]
                node, part = unit
                self._rr = (node.sid, part)
                # max-at-barrier: wait for the unit's enabling events, then
                # re-admit anything that became due during the wait
                wclock.advance_to(unit_ready_time(node, part))
                self._admit_due(wclock.now, on_complete)
                if self._apply_deadlines(wclock.now, on_complete):
                    continue  # the unit may be gone: re-extract
                if self.faults is not None and not self._fault_gate(
                    node, part, wclock, on_complete
                ):
                    continue
                cost = node.advance(engine, part)
                wclock.tick(cost)
                self.busy_s[wi] += cost
                self._after_events(on_complete)
        finally:
            self.clock.current = None
        return engine.completed

    def _after_events(self, on_complete, pre_done: Optional[List[QueryHandle]] = None) -> None:
        engine = self.engine
        engine.check_activations()
        done = list(pre_done or ())
        done += engine.sweep_completions()
        while done:
            h = done.pop()
            if on_complete is not None:
                nxt = on_complete(h)
                if nxt is not None:
                    self.add_arrival(nxt)
                    # admit immediately if due (closed loop)
                    while self._heap and self._heap[0][0] <= self.clock.now:
                        _, _, q = heapq.heappop(self._heap)
                        self._try_admit(q, self.clock.now)
            engine.check_activations()
            done += engine.sweep_completions()
