"""Mesh execution plan: the replicated control-plane view of a data-axis
mesh (DESIGN.md §14).

One grafted execution spans the 'data' mesh axis by mapping the engine's
key-partition shards onto devices one-to-one: P (state partitions) = data-
axis size, worker clocks = devices, and every morsel's probe rows
repartition by join-key hash before touching shard-local state. The
MeshPlan holds what every host replica agrees on — the shard count, the
routing function (splitmix64 ``key_partition``, identical to the state's
did/probe shards), the modeled exchange accounting, and the per-device row
histogram — while the device data plane (bucketed all_to_all + shard-local
fused chain) lives in ``relational/distributed`` / ``kernels/fused_chain``.

Determinism contract: nothing here may depend on device identity or wall
time. Routing is a pure function of keycodes; counters advance in morsel
order under the virtual clocks; two replicas driving the same trace hold
bit-identical MeshPlan state.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .hashindex import key_partition


class MeshPlan:
    """Replicated per-engine record of one data-axis mesh execution."""

    def __init__(self, mesh, axis_name: str = "data"):
        self.mesh = mesh
        self.axis_name = axis_name
        self.n_shards = int(mesh.shape[axis_name])
        self.devices = [str(d) for d in np.asarray(mesh.devices).reshape(-1)]
        # first-stage routing histogram: rows each device received from the
        # morsel repartition (the data-plane balance signal)
        self.rows_by_device = np.zeros(self.n_shards, np.int64)

    def route(self, keycodes: np.ndarray) -> np.ndarray:
        """Destination device per row — the same splitmix64 shard the
        state's did-dedup and probe indexes use, so exchange placement and
        state ownership can never disagree."""
        return key_partition(np.asarray(keycodes, np.int64), self.n_shards)

    def note_morsel(self, keycodes: np.ndarray) -> None:
        """Record one morsel's first-stage repartition in the per-device
        histogram (stage-0 only: both the staged loop and the fused chain
        observe identical stage-0 keycodes, so the histogram is
        backend-independent)."""
        if len(keycodes) == 0 or self.n_shards <= 1:
            return
        parts = self.route(keycodes)
        self.rows_by_device += np.bincount(parts, minlength=self.n_shards)

    def exchange_rows(self, n_rows: int) -> int:
        """Rows crossing the exchange for one stage: on a 1-device mesh
        nothing moves; on P devices every row is routed (a row resident on
        its destination still transits the dense [P, C, W] buffer — the
        exchange tensor is what the cost model charges for)."""
        return int(n_rows) if self.n_shards > 1 else 0

    def stats(self) -> Dict:
        return {
            "axis": self.axis_name,
            "data_shards": self.n_shards,
            "devices": list(self.devices),
            "rows_by_device": self.rows_by_device.tolist(),
        }
