"""GraftDB core: dynamic folding of concurrent analytical queries.

The paper's contribution — state-centric execution, per-query state lenses,
and query grafting — implemented as a composable engine over a columnar
vectorized data plane (see DESIGN.md for the TPU adaptation notes).

INTERNAL LAYER. The supported public surface is the ``graftdb`` package
(``repro.api``): ``graftdb.connect(db, EngineConfig(...))`` returns a
Session; do not hand-assemble ``GraftEngine`` + ``Runner`` pairs outside
``repro.api`` and ``repro.core`` themselves. These exports remain importable
for mechanism-level tests and diagnostics only.
"""

from .engine import MODES, GraftEngine, QueryHandle
from .plans import (
    AggSpec,
    Aggregate,
    BinOp,
    Col,
    Const,
    HashJoin,
    OrderBy,
    Query,
    Scan,
    WhereEq,
)
from .predicates import (
    And,
    Cmp,
    ColCmp,
    Conjunction,
    Coverage,
    InSet,
    TRUE,
    evaluate,
    pred_and,
    prove_implies,
)
from .scheduler import PoolClock, Runner, WallClock, WorkClock

__all__ = [
    "GraftEngine",
    "QueryHandle",
    "MODES",
    "Runner",
    "WorkClock",
    "WallClock",
    "PoolClock",
    "Query",
    "Scan",
    "HashJoin",
    "Aggregate",
    "OrderBy",
    "AggSpec",
    "Col",
    "Const",
    "BinOp",
    "WhereEq",
    "And",
    "Cmp",
    "ColCmp",
    "InSet",
    "TRUE",
    "Conjunction",
    "Coverage",
    "evaluate",
    "pred_and",
    "prove_implies",
]
