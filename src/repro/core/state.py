"""Shared operator state: hash-build tables and aggregate accumulators.

State-centric execution (§3.1) treats this state as shared — any compatible
query may observe it through a per-query state lens or contribute to it
through an admitted producer path. A hash-build state records:

* its signature (exact non-predicate identity, descriptors.py),
* an *extent registry*: every producer path that contributes to the state
  registers the canonical predicate extent it delivers; entry-level
  provenance bitmasks record which extents produced/marked each entry,
* coverage = the union of completed extents (this is what makes no-match
  results meaningful, §4.3),
* entries with derivation identifiers, per-query visibility bitmasks, and
  extent provenance masks,
* extent-scoped state-level visibility grants (§4.3: a later query observing
  an already-represented extent does not rewrite existing entries — the lens
  combines extent provenance with a retained-attribute predicate).

Soundness of represented-extent observation (see DESIGN.md): a grant for
query q is (allowed_extents, B_ret) where B_ret is the retained-attribute
part of B_q and allowed_extents are completed extents whose predicate
implies the non-retained part of B_q. The state-readiness gate requires the
allowed extents alone to cover B_q; since insert-or-mark ORs provenance for
every extent that delivers a derivation, every entry of B_q then carries an
allowed bit — matches are complete, and absence is meaningful. When
FV(B_q) ⊆ RetainedAttrs(S) the provenance check degenerates to evaluating
B_q on the entry (allowed = ALL).

Layout is columnar SoA (TPU adaptation — DESIGN.md §2): dense append-only
arrays indexed by two batched hash structures (DESIGN.md §8):

* derivation ids dedup through a vectorized ``HashIndex`` (insert-or-mark
  is one batched lookup/insert plus one ``bitwise_or.at`` pass),
* probes resolve through an *incremental multi-match index*: a ``HashIndex``
  over keycodes routes unique keys in O(batch), while keys with multiple
  entries fall to a sorted duplicate run maintained by delta merge — no
  full re-argsort on growth.

The Pallas ``hash_probe`` kernel consumes the same SoA layout; aggregate
group ids and count(distinct) seen-pairs run on ``MultiKeyIndex``.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .descriptors import StateSignature
from .hashindex import HashIndex, MultiKeyIndex
from .predicates import Conjunction, Coverage, evaluate_conj
from .visibility import SlotAllocator, bit_of

ALL_EXTENTS = np.uint64(0xFFFFFFFFFFFFFFFF)

_EMPTY_PAIR = (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64))


def _bincount_segment_sum(gids, values, n_groups):
    if values is None:
        return np.bincount(gids, minlength=n_groups).astype(np.float64)
    return np.bincount(gids, weights=values, minlength=n_groups)

# ---------------------------------------------------------------------------


class GrowArray:
    """Amortized-append numpy array."""

    __slots__ = ("_buf", "n")

    def __init__(self, dtype, capacity: int = 1024):
        self._buf = np.empty(capacity, dtype=dtype)
        self.n = 0

    def append(self, values: np.ndarray) -> None:
        m = len(values)
        if self.n + m > len(self._buf):
            cap = max(len(self._buf) * 2, self.n + m)
            nb = np.empty(cap, dtype=self._buf.dtype)
            nb[: self.n] = self._buf[: self.n]
            self._buf = nb
        self._buf[self.n : self.n + m] = values
        self.n += m

    @property
    def data(self) -> np.ndarray:
        return self._buf[: self.n]


# ---------------------------------------------------------------------------


class SharedHashBuildState:
    """A shared hash-build state (§4.3): signature + coverage + SoA entries.

    Entries are identified by derivation id; insert-or-mark keeps one
    physical entry per derivation and ORs visibility/provenance bits (§4.3
    "GraftDB stores one build entry and records the visibility needed by
    those queries")."""

    def __init__(
        self,
        state_id: int,
        sig: StateSignature,
        key_attrs: Tuple[str, ...],
        payload: Tuple[str, ...],
        did_domain: int = 1 << 62,
        counters: Optional[Dict] = None,
    ):
        self.state_id = state_id
        self.sig = sig
        self.key_attrs = tuple(key_attrs)
        self.payload = tuple(payload)
        self.retained_attrs = frozenset(self.payload) | frozenset(self.key_attrs)
        self.did_domain = did_domain

        self.keycode = GrowArray(np.int64)
        self.did = GrowArray(np.int64)
        self.vis = GrowArray(np.uint64)
        self.emask = GrowArray(np.uint64)
        self.cols: Dict[str, GrowArray] = {a: GrowArray(np.float64) for a in self.retained_attrs}

        self._did_index = HashIndex(counters=counters)
        self.slots = SlotAllocator()

        # extent registry: eid -> (conj | None, complete)
        self.extents: Dict[int, Tuple[Optional[Conjunction], bool]] = {}
        self._next_eid = 0

        # grants: qid -> list of (allowed_emask, retained_pred_conj)
        self.grants: Dict[int, List[Tuple[np.uint64, Conjunction]]] = {}
        self.refs: set = set()

        # incremental multi-match probe index (DESIGN.md §8): hash index
        # for unique keys + sorted duplicate run with delta merge. Synced
        # lazily at probe time — build-only phases pay nothing for it.
        self._kindex = HashIndex(counters=counters)
        self._key_first = GrowArray(np.int64)  # key id -> first entry idx
        self._key_dup = GrowArray(np.bool_)  # key id -> key has >1 entry
        self._indexed_upto = 0  # entries registered with the probe index
        self._dup_keys = np.empty(0, dtype=np.int64)  # sorted by (key, entry)
        self._dup_entries = np.empty(0, dtype=np.int64)
        self._dup_pend_keys: List[np.ndarray] = []
        self._dup_pend_entries: List[np.ndarray] = []

        # counters
        self.rows_inserted = 0
        self.rows_marked = 0

    # -- extent registry -----------------------------------------------------
    def register_extent(self, conj: Optional[Conjunction]) -> int:
        """Register a producer extent; returns its provenance bit id.
        Returns -1 when provenance bits are exhausted (the extent still
        contributes rows via per-query visibility bits — only represented
        attachment against it is lost, never safety)."""
        if self._next_eid >= 64:
            return -1
        eid = self._next_eid
        self._next_eid += 1
        self.extents[eid] = (conj, False)
        return eid

    def complete_extent(self, eid: int) -> None:
        if eid >= 0:
            conj, _ = self.extents[eid]
            self.extents[eid] = (conj, True)

    def coverage(self) -> Coverage:
        """Coverage metadata = union of completed extents (§4.3)."""
        return Coverage(c for c, done in self.extents.values() if done and c is not None)

    def covers_with(self, conj: Conjunction, allowed_emask: np.uint64) -> bool:
        """Coverage restricted to the allowed provenance extents."""
        cov = Coverage(
            c
            for eid, (c, done) in self.extents.items()
            if done and c is not None and (np.uint64(1) << np.uint64(eid)) & allowed_emask
        )
        return cov.covers(conj)

    def allowed_extents_for(self, nonret: Conjunction) -> np.uint64:
        """Completed extents whose predicate implies the non-retained part of
        a query's build predicate."""
        mask = np.uint64(0)
        for eid, (c, done) in self.extents.items():
            if done and c is not None and c.implies(nonret):
                mask |= np.uint64(1) << np.uint64(eid)
        return mask

    # -- producer side -----------------------------------------------------
    def insert_or_mark(
        self,
        dids: np.ndarray,
        keycodes: np.ndarray,
        cols: Dict[str, np.ndarray],
        vismask: np.ndarray,
        emask: np.ndarray,
    ) -> Tuple[int, int]:
        """Insert rows absent by derivation id; OR visibility/provenance on
        present ones. Returns (inserted, marked).

        One batched ``HashIndex.lookup_or_insert`` resolves every row's
        entry position (deduping within the batch in first-occurrence
        order); a single ``bitwise_or.at`` pass then merges visibility and
        provenance for marks, fresh inserts, and in-batch duplicates alike.
        """
        if len(dids) == 0:
            return 0, 0
        dids = np.asarray(dids, dtype=np.int64)
        n0 = self.did.n
        ids, is_new = self._did_index.lookup_or_insert(dids)
        n_inserted = int(is_new.sum())
        n_marked = int((ids < n0).sum())
        if n_inserted:
            sel = np.flatnonzero(is_new)  # ids[sel] == n0 + arange(n_inserted)
            kc = np.asarray(keycodes, dtype=np.int64)[sel]
            self.did.append(dids[sel])
            self.keycode.append(kc)
            zeros = np.zeros(n_inserted, dtype=np.uint64)
            self.vis.append(zeros)
            self.emask.append(zeros)
            for a in self.retained_attrs:
                self.cols[a].append(np.asarray(cols[a], dtype=np.float64)[sel])
            self.rows_inserted += n_inserted
        np.bitwise_or.at(self.vis.data, ids, vismask)
        np.bitwise_or.at(self.emask.data, ids, emask)
        self.rows_marked += n_marked
        return n_inserted, n_marked

    # -- grants ---------------------------------------------------------------
    def add_grant(self, qid: int, allowed_emask: np.uint64, retained_conj: Conjunction) -> None:
        self.slots.get(qid)
        self.grants.setdefault(qid, []).append((allowed_emask, retained_conj))

    def grant_evaluable(self, conj: Conjunction) -> bool:
        """FV(P) ⊆ RetainedAttrs(S) (§4.2 evaluability)."""
        return conj.attrs() <= self.retained_attrs

    def count_granted(self, allowed_emask: np.uint64, retained_conj: Conjunction) -> int:
        """Entries currently observable through a grant (counters only)."""
        if self.did.n == 0:
            return 0
        m = (self.emask.data & allowed_emask) != 0
        if retained_conj.attrs():
            cols = {a: self.cols[a].data for a in retained_conj.attrs()}
            m = m & evaluate_conj(retained_conj, cols)
        return int(m.sum())

    # -- consumer side -------------------------------------------------------
    def _sync_index(self) -> None:
        """Register entries appended since the last probe (lazy: the probe
        index costs nothing while a state is only being built)."""
        n = self.keycode.n
        if self._indexed_upto < n:
            self._index_append(self.keycode.data[self._indexed_upto :], self._indexed_upto)
            self._indexed_upto = n

    def _index_append(self, new_keycodes: np.ndarray, base: int) -> None:
        """Register freshly appended entries with the incremental probe
        index: unique keys land in the hash index; entries of duplicated
        keys queue for the sorted-run delta merge."""
        ent = base + np.arange(len(new_keycodes), dtype=np.int64)
        kids, knew = self._kindex.lookup_or_insert(new_keycodes)
        if knew.any():
            ksel = np.flatnonzero(knew)
            self._key_first.append(ent[ksel])
            self._key_dup.append(np.zeros(len(ksel), dtype=np.bool_))
        dup = ~knew
        if dup.any():
            dsel = np.flatnonzero(dup)
            kd = kids[dsel]
            fresh = np.unique(kd)
            fresh = fresh[~self._key_dup.data[fresh]]
            if len(fresh):
                # key just became multi-entry: its first entry joins the run
                self._key_dup.data[fresh] = True
                first = self._key_first.data[fresh]
                self._dup_pend_keys.append(self.keycode.data[first])
                self._dup_pend_entries.append(first)
            self._dup_pend_keys.append(new_keycodes[dsel])
            self._dup_pend_entries.append(ent[dsel])

    def _flush_dups(self) -> None:
        """Merge the pending duplicate delta into the sorted run. Cost is
        O(run + delta) per growth episode, and zero for unique-key states."""
        if not self._dup_pend_keys:
            return
        dk = np.concatenate(self._dup_pend_keys)
        de = np.concatenate(self._dup_pend_entries)
        self._dup_pend_keys = []
        self._dup_pend_entries = []
        order = np.lexsort((de, dk))
        dk, de = dk[order], de[order]
        if len(self._dup_keys):
            # delta entries of an existing key are younger than the run's:
            # side='right' keeps within-key entry order = insertion order
            pos = np.searchsorted(self._dup_keys, dk, side="right")
            self._dup_keys = np.insert(self._dup_keys, pos, dk)
            self._dup_entries = np.insert(self._dup_entries, pos, de)
        else:
            self._dup_keys, self._dup_entries = dk, de

    def probe(self, probe_keycodes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized probe: returns (probe_row_idx, entry_idx) match pairs
        — before any visibility filtering. Unique keys resolve through the
        hash index in O(batch); multi-entry keys expand from the sorted
        duplicate run. Match pairs are emitted probe-row-major with entries
        in insertion order, matching the old sort-based probe exactly."""
        if self.keycode.n == 0 or len(probe_keycodes) == 0:
            return _EMPTY_PAIR
        self._sync_index()
        self._flush_dups()
        pk = np.asarray(probe_keycodes, dtype=np.int64)
        kids = self._kindex.lookup(pk)
        midx = np.flatnonzero(kids >= 0)
        if len(midx) == 0:
            return _EMPTY_PAIR
        mk = kids[midx]
        isdup = self._key_dup.data[mk]
        single = midx[~isdup]
        dup_rows = midx[isdup]
        counts = np.zeros(len(pk), dtype=np.int64)
        counts[single] = 1
        if len(dup_rows):
            lo = np.searchsorted(self._dup_keys, pk[dup_rows], side="left")
            hi = np.searchsorted(self._dup_keys, pk[dup_rows], side="right")
            counts[dup_rows] = hi - lo
        total = int(counts.sum())
        probe_idx = np.repeat(np.arange(len(pk), dtype=np.int64), counts)
        entry_idx = np.empty(total, dtype=np.int64)
        offs = np.concatenate(([0], np.cumsum(counts)[:-1]))
        entry_idx[offs[single]] = self._key_first.data[mk[~isdup]]
        if len(dup_rows):
            c = hi - lo
            nd = int(c.sum())
            within = np.arange(nd, dtype=np.int64) - np.repeat(
                np.concatenate(([0], np.cumsum(c)[:-1])), c
            )
            dpos = np.repeat(offs[dup_rows], c) + within
            entry_idx[dpos] = self._dup_entries[np.repeat(lo, c) + within]
        return probe_idx, entry_idx

    def visible_mask(self, qid: int, entry_idx: np.ndarray) -> np.ndarray:
        """Per-query state lens on entries: per-entry visibility bit OR an
        extent-scoped grant the entry's provenance+retained attrs satisfy."""
        slot = self.slots.peek(qid)
        if slot is None:
            vis = np.zeros(len(entry_idx), dtype=bool)
        else:
            vis = bit_of(self.vis.data[entry_idx], slot)
        for allowed_emask, conj in self.grants.get(qid, ()):
            g = (self.emask.data[entry_idx] & allowed_emask) != 0
            if conj.attrs():
                cols = {a: self.cols[a].data[entry_idx] for a in conj.attrs()}
                g = g & evaluate_conj(conj, cols)
            vis |= g
        return vis

    def entry_cols(self, entry_idx: np.ndarray, attrs: Sequence[str]) -> Dict[str, np.ndarray]:
        return {a: self.cols[a].data[entry_idx] for a in attrs}

    # -- lifecycle ------------------------------------------------------------
    def attach(self, qid: int) -> None:
        self.refs.add(qid)
        self.slots.get(qid)

    def detach(self, qid: int) -> None:
        self.refs.discard(qid)
        self.slots.release(qid)
        self.grants.pop(qid, None)

    @property
    def n_entries(self) -> int:
        return self.did.n

    def nbytes(self) -> int:
        per_entry = 8 * (3 + len(self.retained_attrs)) + 8
        return self.did.n * per_entry


# ---------------------------------------------------------------------------


class SharedAggregateState:
    """Shared aggregate state under exact aggregate identity (§4.5).

    Input occurrences collapse into group accumulators, so the state cannot
    be repartitioned under a different predicate/grouping — sharing is
    all-or-nothing per identity, enforced by the signature. Supports
    sum/count/avg/min/max; group-id assignment and the count(distinct expr)
    seen-pairs both run on batched ``MultiKeyIndex`` lookups (DESIGN.md §8)."""

    def __init__(
        self,
        state_id: int,
        sig: Optional[StateSignature],
        group_keys: Tuple[str, ...],
        aggs,
        counters: Optional[Dict] = None,
    ):
        self.state_id = state_id
        self.sig = sig
        self.group_keys = tuple(group_keys)
        self.aggs = tuple(aggs)

        self._gidx = (
            MultiKeyIndex(len(self.group_keys), counters=counters)
            if self.group_keys
            else None
        )
        self._global_ready = False  # global aggregate: single group, lazily init
        self.group_cols: List[GrowArray] = [GrowArray(np.float64) for _ in self.group_keys]
        self._acc: List[GrowArray] = [GrowArray(np.float64) for _ in self.aggs]
        self._counts = GrowArray(np.float64)
        self._distinct_idx: List[Optional[MultiKeyIndex]] = [
            MultiKeyIndex(2, counters=counters) if a.distinct else None for a in self.aggs
        ]

        self.complete = False
        self.refs: set = set()
        self.rows_consumed = 0

    def _new_groups(self, n_new: int) -> None:
        for acc, spec in zip(self._acc, self.aggs):
            init = math.inf if spec.func == "min" else (-math.inf if spec.func == "max" else 0.0)
            acc.append(np.full(n_new, init))
        self._counts.append(np.zeros(n_new))

    def _group_ids(self, keys: List[np.ndarray], n: int) -> np.ndarray:
        if not keys:
            # global aggregate: single group
            if not self._global_ready:
                self._global_ready = True
                self._new_groups(1)
            return np.zeros(n, dtype=np.int64)
        gids, is_new = self._gidx.lookup_or_insert(keys)
        n_new = int(is_new.sum())
        if n_new:
            sel = np.flatnonzero(is_new)  # gids[sel] == old n_groups + arange
            for k, gc in enumerate(self.group_cols):
                gc.append(np.asarray(keys[k], dtype=np.float64)[sel])
            self._new_groups(n_new)
        return gids

    def update(
        self,
        key_cols: List[np.ndarray],
        agg_values: List[Optional[np.ndarray]],
        n: int,
        segment_sum=None,
    ) -> None:
        """Fold one morsel of rows into the accumulators (segment reduce).

        ``segment_sum(gids, values_or_None, n_groups)`` lets an execution
        backend (api/backends.py) supply the grouped reduction — e.g. the
        Pallas one-hot MXU kernel; defaults to ``np.bincount``."""
        if n == 0:
            return
        gids = self._group_ids(key_cols, n)
        ngroups = self._counts.n
        self.rows_consumed += n
        if segment_sum is None:
            segment_sum = _bincount_segment_sum
        cnt = segment_sum(gids, None, ngroups)
        self._counts.data[:] += cnt
        for j, (acc, spec) in enumerate(zip(self._acc, self.aggs)):
            vals = agg_values[j]
            if spec.distinct:
                # count(distinct expr): one batched lookup flags the
                # never-seen (group, value) pairs
                _, fresh = self._distinct_idx[j].lookup_or_insert([gids, vals])
                if fresh.any():
                    acc.data[:] += np.bincount(gids[fresh], minlength=ngroups)
            elif spec.func == "count":
                acc.data[:] += cnt
            elif spec.func in ("sum", "avg"):
                acc.data[:] += segment_sum(gids, vals, ngroups)
            elif spec.func == "min":
                np.minimum.at(acc.data, gids, vals)
            elif spec.func == "max":
                np.maximum.at(acc.data, gids, vals)
            else:
                raise ValueError(spec.func)

    def result(self) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {}
        for k, name in enumerate(self.group_keys):
            out[name] = self.group_cols[k].data.copy()
        for acc, spec in zip(self._acc, self.aggs):
            if spec.func == "avg":
                with np.errstate(invalid="ignore", divide="ignore"):
                    out[spec.name] = acc.data / np.maximum(self._counts.data, 1e-300)
            else:
                out[spec.name] = acc.data.copy()
        return out

    def attach(self, qid: int) -> None:
        self.refs.add(qid)

    def detach(self, qid: int) -> None:
        self.refs.discard(qid)

    @property
    def n_groups(self) -> int:
        return self._counts.n
