"""Shared operator state: hash-build tables and aggregate accumulators.

State-centric execution (§3.1) treats this state as shared — any compatible
query may observe it through a per-query state lens or contribute to it
through an admitted producer path. A hash-build state records:

* its signature (exact non-predicate identity, descriptors.py),
* an *extent registry*: every producer path that contributes to the state
  registers the canonical predicate extent it delivers; entry-level
  provenance bitmasks record which extents produced/marked each entry,
* coverage = the union of completed extents (this is what makes no-match
  results meaningful, §4.3),
* entries with derivation identifiers, per-query visibility bitmasks, and
  extent provenance masks,
* extent-scoped state-level visibility grants (§4.3: a later query observing
  an already-represented extent does not rewrite existing entries — the lens
  combines extent provenance with a retained-attribute predicate).

Soundness of represented-extent observation (see DESIGN.md): a grant for
query q is (allowed_extents, B_ret) where B_ret is the retained-attribute
part of B_q and allowed_extents are completed extents whose predicate
implies the non-retained part of B_q. The state-readiness gate requires the
allowed extents alone to cover B_q; since insert-or-mark ORs provenance for
every extent that delivers a derivation, every entry of B_q then carries an
allowed bit — matches are complete, and absence is meaningful. When
FV(B_q) ⊆ RetainedAttrs(S) the provenance check degenerates to evaluating
B_q on the entry (allowed = ALL).

Layout is columnar SoA (TPU adaptation — DESIGN.md §2): dense append-only
arrays + a sort-based probe index rebuilt lazily when a lens observation
opens. The Pallas `hash_probe` kernel consumes the same SoA layout.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .descriptors import StateSignature
from .predicates import Conjunction, Coverage, evaluate_conj
from .visibility import SlotAllocator, bit_of

ALL_EXTENTS = np.uint64(0xFFFFFFFFFFFFFFFF)


def _bincount_segment_sum(gids, values, n_groups):
    if values is None:
        return np.bincount(gids, minlength=n_groups).astype(np.float64)
    return np.bincount(gids, weights=values, minlength=n_groups)

# ---------------------------------------------------------------------------


class GrowArray:
    """Amortized-append numpy array."""

    __slots__ = ("_buf", "n")

    def __init__(self, dtype, capacity: int = 1024):
        self._buf = np.empty(capacity, dtype=dtype)
        self.n = 0

    def append(self, values: np.ndarray) -> None:
        m = len(values)
        if self.n + m > len(self._buf):
            cap = max(len(self._buf) * 2, self.n + m)
            nb = np.empty(cap, dtype=self._buf.dtype)
            nb[: self.n] = self._buf[: self.n]
            self._buf = nb
        self._buf[self.n : self.n + m] = values
        self.n += m

    @property
    def data(self) -> np.ndarray:
        return self._buf[: self.n]


# ---------------------------------------------------------------------------


class SharedHashBuildState:
    """A shared hash-build state (§4.3): signature + coverage + SoA entries.

    Entries are identified by derivation id; insert-or-mark keeps one
    physical entry per derivation and ORs visibility/provenance bits (§4.3
    "GraftDB stores one build entry and records the visibility needed by
    those queries")."""

    def __init__(
        self,
        state_id: int,
        sig: StateSignature,
        key_attrs: Tuple[str, ...],
        payload: Tuple[str, ...],
        did_domain: int = 1 << 62,
    ):
        self.state_id = state_id
        self.sig = sig
        self.key_attrs = tuple(key_attrs)
        self.payload = tuple(payload)
        self.retained_attrs = frozenset(self.payload) | frozenset(self.key_attrs)
        self.did_domain = did_domain

        self.keycode = GrowArray(np.int64)
        self.did = GrowArray(np.int64)
        self.vis = GrowArray(np.uint64)
        self.emask = GrowArray(np.uint64)
        self.cols: Dict[str, GrowArray] = {a: GrowArray(np.float64) for a in self.retained_attrs}

        self._did_index: Dict[int, int] = {}
        self.slots = SlotAllocator()

        # extent registry: eid -> (conj | None, complete)
        self.extents: Dict[int, Tuple[Optional[Conjunction], bool]] = {}
        self._next_eid = 0

        # grants: qid -> list of (allowed_emask, retained_pred_conj)
        self.grants: Dict[int, List[Tuple[np.uint64, Conjunction]]] = {}
        self.refs: set = set()

        # probe index (sorted keycode + permutation), rebuilt lazily
        self._index_built_upto = -1
        self._order: Optional[np.ndarray] = None
        self._sorted_keys: Optional[np.ndarray] = None

        # counters
        self.rows_inserted = 0
        self.rows_marked = 0

    # -- extent registry -----------------------------------------------------
    def register_extent(self, conj: Optional[Conjunction]) -> int:
        """Register a producer extent; returns its provenance bit id.
        Returns -1 when provenance bits are exhausted (the extent still
        contributes rows via per-query visibility bits — only represented
        attachment against it is lost, never safety)."""
        if self._next_eid >= 64:
            return -1
        eid = self._next_eid
        self._next_eid += 1
        self.extents[eid] = (conj, False)
        return eid

    def complete_extent(self, eid: int) -> None:
        if eid >= 0:
            conj, _ = self.extents[eid]
            self.extents[eid] = (conj, True)

    def coverage(self) -> Coverage:
        """Coverage metadata = union of completed extents (§4.3)."""
        return Coverage(c for c, done in self.extents.values() if done and c is not None)

    def covers_with(self, conj: Conjunction, allowed_emask: np.uint64) -> bool:
        """Coverage restricted to the allowed provenance extents."""
        cov = Coverage(
            c
            for eid, (c, done) in self.extents.items()
            if done and c is not None and (np.uint64(1) << np.uint64(eid)) & allowed_emask
        )
        return cov.covers(conj)

    def allowed_extents_for(self, nonret: Conjunction) -> np.uint64:
        """Completed extents whose predicate implies the non-retained part of
        a query's build predicate."""
        mask = np.uint64(0)
        for eid, (c, done) in self.extents.items():
            if done and c is not None and c.implies(nonret):
                mask |= np.uint64(1) << np.uint64(eid)
        return mask

    # -- producer side -----------------------------------------------------
    def insert_or_mark(
        self,
        dids: np.ndarray,
        keycodes: np.ndarray,
        cols: Dict[str, np.ndarray],
        vismask: np.ndarray,
        emask: np.ndarray,
    ) -> Tuple[int, int]:
        """Insert rows absent by derivation id; OR visibility/provenance on
        present ones. Returns (inserted, marked)."""
        if len(dids) == 0:
            return 0, 0
        idx_map = self._did_index
        pos = np.empty(len(dids), dtype=np.int64)
        is_new = np.zeros(len(dids), dtype=bool)
        for i, d in enumerate(dids.tolist()):
            j = idx_map.get(d, -1)
            if j < 0:
                is_new[i] = True
            else:
                pos[i] = j
        n_marked = 0
        old = ~is_new
        if old.any():
            p = pos[old]
            np.bitwise_or.at(self.vis.data, p, vismask[old])
            np.bitwise_or.at(self.emask.data, p, emask[old])
            n_marked = int(old.sum())
            self.rows_marked += n_marked
        n_inserted = 0
        if is_new.any():
            sel_all = np.flatnonzero(is_new)
            nd = dids[sel_all]
            uniq, first = np.unique(nd, return_index=True)
            sel = sel_all[np.sort(first)]
            if len(uniq) != len(sel_all):
                # OR together vis/emask of duplicate dids within the batch
                vis_new = np.zeros(len(sel), dtype=np.uint64)
                em_new = np.zeros(len(sel), dtype=np.uint64)
                order = {int(d): k for k, d in enumerate(dids[sel].tolist())}
                for i in sel_all.tolist():
                    k = order[int(dids[i])]
                    vis_new[k] |= vismask[i]
                    em_new[k] |= emask[i]
            else:
                vis_new = vismask[sel]
                em_new = emask[sel]
            base = self.did.n
            self.did.append(dids[sel])
            self.keycode.append(keycodes[sel])
            self.vis.append(vis_new)
            self.emask.append(em_new)
            for a in self.retained_attrs:
                self.cols[a].append(np.asarray(cols[a][sel], dtype=np.float64))
            for k, d in enumerate(dids[sel].tolist()):
                idx_map[int(d)] = base + k
            n_inserted = len(sel)
            self.rows_inserted += n_inserted
        return n_inserted, n_marked

    # -- grants ---------------------------------------------------------------
    def add_grant(self, qid: int, allowed_emask: np.uint64, retained_conj: Conjunction) -> None:
        self.slots.get(qid)
        self.grants.setdefault(qid, []).append((allowed_emask, retained_conj))

    def grant_evaluable(self, conj: Conjunction) -> bool:
        """FV(P) ⊆ RetainedAttrs(S) (§4.2 evaluability)."""
        return conj.attrs() <= self.retained_attrs

    def count_granted(self, allowed_emask: np.uint64, retained_conj: Conjunction) -> int:
        """Entries currently observable through a grant (counters only)."""
        if self.did.n == 0:
            return 0
        m = (self.emask.data & allowed_emask) != 0
        if retained_conj.attrs():
            cols = {a: self.cols[a].data for a in retained_conj.attrs()}
            m = m & evaluate_conj(retained_conj, cols)
        return int(m.sum())

    # -- consumer side -------------------------------------------------------
    def _ensure_index(self) -> None:
        if self._index_built_upto == self.keycode.n and self._order is not None:
            return
        keys = self.keycode.data
        self._order = np.argsort(keys, kind="stable")
        self._sorted_keys = keys[self._order]
        self._index_built_upto = self.keycode.n

    def probe(self, probe_keycodes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized probe: returns (probe_row_idx, entry_idx) match pairs
        — before any visibility filtering."""
        if self.keycode.n == 0 or len(probe_keycodes) == 0:
            return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
        self._ensure_index()
        sk, order = self._sorted_keys, self._order
        lo = np.searchsorted(sk, probe_keycodes, side="left")
        hi = np.searchsorted(sk, probe_keycodes, side="right")
        counts = hi - lo
        total = int(counts.sum())
        if total == 0:
            return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
        probe_idx = np.repeat(np.arange(len(probe_keycodes), dtype=np.int64), counts)
        starts = np.repeat(lo, counts)
        offs = np.arange(total, dtype=np.int64) - np.repeat(
            np.concatenate(([0], np.cumsum(counts)[:-1])), counts
        )
        entry_idx = order[starts + offs]
        return probe_idx, entry_idx

    def visible_mask(self, qid: int, entry_idx: np.ndarray) -> np.ndarray:
        """Per-query state lens on entries: per-entry visibility bit OR an
        extent-scoped grant the entry's provenance+retained attrs satisfy."""
        slot = self.slots.peek(qid)
        if slot is None:
            vis = np.zeros(len(entry_idx), dtype=bool)
        else:
            vis = bit_of(self.vis.data[entry_idx], slot)
        for allowed_emask, conj in self.grants.get(qid, ()):
            g = (self.emask.data[entry_idx] & allowed_emask) != 0
            if conj.attrs():
                cols = {a: self.cols[a].data[entry_idx] for a in conj.attrs()}
                g = g & evaluate_conj(conj, cols)
            vis |= g
        return vis

    def entry_cols(self, entry_idx: np.ndarray, attrs: Sequence[str]) -> Dict[str, np.ndarray]:
        return {a: self.cols[a].data[entry_idx] for a in attrs}

    # -- lifecycle ------------------------------------------------------------
    def attach(self, qid: int) -> None:
        self.refs.add(qid)
        self.slots.get(qid)

    def detach(self, qid: int) -> None:
        self.refs.discard(qid)
        self.slots.release(qid)
        self.grants.pop(qid, None)

    @property
    def n_entries(self) -> int:
        return self.did.n

    def nbytes(self) -> int:
        per_entry = 8 * (3 + len(self.retained_attrs)) + 8
        return self.did.n * per_entry


# ---------------------------------------------------------------------------


class SharedAggregateState:
    """Shared aggregate state under exact aggregate identity (§4.5).

    Input occurrences collapse into group accumulators, so the state cannot
    be repartitioned under a different predicate/grouping — sharing is
    all-or-nothing per identity, enforced by the signature. Supports
    sum/count/avg/min/max and count(distinct expr) via a seen-set."""

    def __init__(self, state_id: int, sig: Optional[StateSignature], group_keys: Tuple[str, ...], aggs):
        self.state_id = state_id
        self.sig = sig
        self.group_keys = tuple(group_keys)
        self.aggs = tuple(aggs)

        self._gid_of: Dict[Tuple, int] = {}
        self.group_cols: List[GrowArray] = [GrowArray(np.float64) for _ in self.group_keys]
        self._acc: List[GrowArray] = [GrowArray(np.float64) for _ in self.aggs]
        self._counts = GrowArray(np.float64)
        self._distinct_seen: List[set] = [set() if a.distinct else None for a in self.aggs]

        self.complete = False
        self.refs: set = set()
        self.rows_consumed = 0

    def _group_ids(self, keys: List[np.ndarray], n: int) -> np.ndarray:
        if not keys:
            # global aggregate: single group
            if not self._gid_of:
                self._gid_of[()] = 0
                for acc, spec in zip(self._acc, self.aggs):
                    init = math.inf if spec.func == "min" else (-math.inf if spec.func == "max" else 0.0)
                    acc.append(np.array([init]))
                self._counts.append(np.zeros(1))
            return np.zeros(n, dtype=np.int64)
        stacked = np.stack(keys, axis=1)
        uniq, inv = np.unique(stacked, axis=0, return_inverse=True)
        gids = np.empty(len(uniq), dtype=np.int64)
        for i, row in enumerate(uniq):
            t = tuple(row.tolist())
            g = self._gid_of.get(t)
            if g is None:
                g = len(self._gid_of)
                self._gid_of[t] = g
                for k, gc in enumerate(self.group_cols):
                    gc.append(np.array([row[k]], dtype=np.float64))
                for acc, spec in zip(self._acc, self.aggs):
                    init = math.inf if spec.func == "min" else (-math.inf if spec.func == "max" else 0.0)
                    acc.append(np.array([init]))
                self._counts.append(np.zeros(1))
            gids[i] = g
        return gids[np.asarray(inv).ravel()]

    def update(
        self,
        key_cols: List[np.ndarray],
        agg_values: List[Optional[np.ndarray]],
        n: int,
        segment_sum=None,
    ) -> None:
        """Fold one morsel of rows into the accumulators (segment reduce).

        ``segment_sum(gids, values_or_None, n_groups)`` lets an execution
        backend (api/backends.py) supply the grouped reduction — e.g. the
        Pallas one-hot MXU kernel; defaults to ``np.bincount``."""
        if n == 0:
            return
        gids = self._group_ids(key_cols, n)
        ngroups = len(self._gid_of)
        self.rows_consumed += n
        if segment_sum is None:
            segment_sum = _bincount_segment_sum
        cnt = segment_sum(gids, None, ngroups)
        self._counts.data[:] += cnt
        for j, (acc, spec) in enumerate(zip(self._acc, self.aggs)):
            vals = agg_values[j]
            if spec.distinct:
                # count(distinct expr): dedupe (group, value) pairs
                pairs = np.stack([gids.astype(np.float64), vals], axis=1)
                uniq = np.unique(pairs, axis=0)
                seen = self._distinct_seen[j]
                for g, v in uniq.tolist():
                    if (g, v) not in seen:
                        seen.add((g, v))
                        acc.data[int(g)] += 1.0
            elif spec.func == "count":
                acc.data[:] += cnt
            elif spec.func in ("sum", "avg"):
                acc.data[:] += segment_sum(gids, vals, ngroups)
            elif spec.func == "min":
                np.minimum.at(acc.data, gids, vals)
            elif spec.func == "max":
                np.maximum.at(acc.data, gids, vals)
            else:
                raise ValueError(spec.func)

    def result(self) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {}
        for k, name in enumerate(self.group_keys):
            out[name] = self.group_cols[k].data.copy()
        for acc, spec in zip(self._acc, self.aggs):
            if spec.func == "avg":
                with np.errstate(invalid="ignore", divide="ignore"):
                    out[spec.name] = acc.data / np.maximum(self._counts.data, 1e-300)
            else:
                out[spec.name] = acc.data.copy()
        return out

    def attach(self, qid: int) -> None:
        self.refs.add(qid)

    def detach(self, qid: int) -> None:
        self.refs.discard(qid)

    @property
    def n_groups(self) -> int:
        return len(self._gid_of)
