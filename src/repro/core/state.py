"""Shared operator state: hash-build tables and aggregate accumulators.

State-centric execution (§3.1) treats this state as shared — any compatible
query may observe it through a per-query state lens or contribute to it
through an admitted producer path. A hash-build state records:

* its signature (exact non-predicate identity, descriptors.py),
* an *extent registry*: every producer path that contributes to the state
  registers the canonical predicate extent it delivers; entry-level
  provenance bitmasks record which extents produced/marked each entry,
* coverage = the union of completed extents (this is what makes no-match
  results meaningful, §4.3),
* entries with derivation identifiers, per-query visibility bitmasks, and
  extent provenance masks,
* extent-scoped state-level visibility grants (§4.3: a later query observing
  an already-represented extent does not rewrite existing entries — the lens
  combines extent provenance with a retained-attribute predicate).

Soundness of represented-extent observation (see DESIGN.md): a grant for
query q is (allowed_extents, B_ret) where B_ret is the retained-attribute
part of B_q and allowed_extents are completed extents whose predicate
implies the non-retained part of B_q. The state-readiness gate requires the
allowed extents alone to cover B_q; since insert-or-mark ORs provenance for
every extent that delivers a derivation, every entry of B_q then carries an
allowed bit — matches are complete, and absence is meaningful. When
FV(B_q) ⊆ RetainedAttrs(S) the provenance check degenerates to evaluating
B_q on the entry (allowed = ALL).

Layout is columnar SoA (TPU adaptation — DESIGN.md §2): dense append-only
arrays indexed by two batched hash structures (DESIGN.md §8):

* derivation ids dedup through a vectorized ``HashIndex`` (insert-or-mark
  is one batched lookup/insert plus one ``bitwise_or.at`` pass),
* probes resolve through an *incremental multi-match index*: a ``HashIndex``
  over keycodes routes unique keys in O(batch), while keys with multiple
  entries fall to a sorted duplicate run maintained by delta merge — no
  full re-argsort on growth.

Partition-parallel sharding (DESIGN.md §9): under ``n_partitions > 1`` both
index structures split into P shards routed by ``key_partition`` (splitmix64
of the entry keycode). Entry *storage* stays one global SoA with ids
assigned in batch-stream first-occurrence order, which makes the resident
arrays (and therefore per-partition visibility words: each entry's packed
word belongs to exactly one key shard) bit-identical for every P — only the
index routing shards, so grafting/admission and the 1×1 oracle are
untouched while (fragment × partition) units touch disjoint shards.

The Pallas ``hash_probe`` kernel consumes the same SoA layout; aggregate
group ids and count(distinct) seen-pairs run on ``MultiKeyIndex``.

Lifecycle (DESIGN.md §10): every shared state carries *pin counts* — the
active lenses (attached queries, ``refs``) plus external admission pins
(``pins``, held by the admission controller for queued-but-admissible
lenses). Under the ``epoch`` retention policy a state whose pins drop to
zero is *retired* (stamped with a monotonically increasing retention epoch
and kept observable for later grafts) rather than dropped; the
``StateLifecycle`` evictor reclaims retired states oldest-epoch-first when
their bytes exceed the session's ``memory_budget``. Eviction is safe by
construction — only zero-pin states are evictable, and every observation
path (``probe`` / ``visible_mask`` / ``insert_or_mark`` / ``attach``)
hard-fails on an evicted state, so no lens can ever read reclaimed
fragments.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .descriptors import StateSignature
from .hashindex import HashIndex, MultiKeyIndex, key_partition
from .predicates import Conjunction, Coverage, evaluate_conj
from .visibility import SlotAllocator, bit_of

ALL_EXTENTS = np.uint64(0xFFFFFFFFFFFFFFFF)

_EMPTY_PAIR = (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64))

#: Entry count below which ``SharedHashBuildState.probe`` skips the
#: incremental multi-match index (lazy dup-run sync, hash rounds) and uses
#: a direct cached-argsort probe — at small occupancy the full stable sort
#: is cheaper than the incremental machinery's fixed overheads (§8).
DIRECT_PROBE_MAX = 32768

#: Mark-dirty-log cap (DESIGN.md §13): past this many logged re-ORed entry
#: ids the log compacts away and bumps its epoch — one mirror regather then
#: beats replaying an unbounded patch list.
MARK_LOG_LIMIT = 1 << 16


def _bincount_segment_sum(gids, values, n_groups):
    if values is None:
        return np.bincount(gids, minlength=n_groups).astype(np.float64)
    return np.bincount(gids, weights=values, minlength=n_groups)

# ---------------------------------------------------------------------------


class GrowArray:
    """Amortized-append numpy array."""

    __slots__ = ("_buf", "n")

    def __init__(self, dtype, capacity: int = 1024):
        self._buf = np.empty(capacity, dtype=dtype)
        self.n = 0

    def append(self, values: np.ndarray) -> None:
        m = len(values)
        if self.n + m > len(self._buf):
            cap = max(len(self._buf) * 2, self.n + m)
            nb = np.empty(cap, dtype=self._buf.dtype)
            nb[: self.n] = self._buf[: self.n]
            self._buf = nb
        self._buf[self.n : self.n + m] = values
        self.n += m

    @property
    def data(self) -> np.ndarray:
        return self._buf[: self.n]


# ---------------------------------------------------------------------------
# One shard of the incremental multi-match probe index (DESIGN.md §8/§9)
# ---------------------------------------------------------------------------


class _KeyProbeIndex:
    """Incremental multi-match probe index over one key shard.

    Hash index for unique keys + sorted duplicate run with delta merge;
    entry ids are *global* SoA positions, so shards compose without any id
    translation. The unpartitioned state owns exactly one shard — this
    class is the seed implementation moved verbatim."""

    __slots__ = (
        "_kindex",
        "_key_first",
        "_key_dup",
        "_dup_keys",
        "_dup_entries",
        "_dup_pend_keys",
        "_dup_pend_entries",
    )

    def __init__(self, counters: Optional[Dict] = None):
        self._kindex = HashIndex(counters=counters)
        self._key_first = GrowArray(np.int64)  # key id -> first entry idx
        self._key_dup = GrowArray(np.bool_)  # key id -> key has >1 entry
        self._dup_keys = np.empty(0, dtype=np.int64)  # sorted by (key, entry)
        self._dup_entries = np.empty(0, dtype=np.int64)
        self._dup_pend_keys: List[np.ndarray] = []
        self._dup_pend_entries: List[np.ndarray] = []

    def append(self, new_keycodes: np.ndarray, ent: np.ndarray, all_keycodes: np.ndarray) -> None:
        """Register freshly appended entries: unique keys land in the hash
        index; entries of duplicated keys queue for the sorted-run delta
        merge. ``ent`` carries the entries' global SoA positions and
        ``all_keycodes`` the state's full keycode column (for promoting a
        key's first entry when it turns multi-entry)."""
        kids, knew = self._kindex.lookup_or_insert(new_keycodes)
        if knew.any():
            ksel = np.flatnonzero(knew)
            self._key_first.append(ent[ksel])
            self._key_dup.append(np.zeros(len(ksel), dtype=np.bool_))
        dup = ~knew
        if dup.any():
            dsel = np.flatnonzero(dup)
            kd = kids[dsel]
            fresh = np.unique(kd)
            fresh = fresh[~self._key_dup.data[fresh]]
            if len(fresh):
                # key just became multi-entry: its first entry joins the run
                self._key_dup.data[fresh] = True
                first = self._key_first.data[fresh]
                self._dup_pend_keys.append(all_keycodes[first])
                self._dup_pend_entries.append(first)
            self._dup_pend_keys.append(new_keycodes[dsel])
            self._dup_pend_entries.append(ent[dsel])

    def _flush_dups(self) -> None:
        """Merge the pending duplicate delta into the sorted run. Cost is
        O(run + delta) per growth episode, and zero for unique-key states."""
        if not self._dup_pend_keys:
            return
        dk = np.concatenate(self._dup_pend_keys)
        de = np.concatenate(self._dup_pend_entries)
        self._dup_pend_keys = []
        self._dup_pend_entries = []
        order = np.lexsort((de, dk))
        dk, de = dk[order], de[order]
        if len(self._dup_keys):
            # delta entries of an existing key are younger than the run's:
            # side='right' keeps within-key entry order = insertion order
            pos = np.searchsorted(self._dup_keys, dk, side="right")
            self._dup_keys = np.insert(self._dup_keys, pos, dk)
            self._dup_entries = np.insert(self._dup_entries, pos, de)
        else:
            self._dup_keys, self._dup_entries = dk, de

    def probe(self, pk: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Match pairs (probe_row_idx, entry_idx) for this shard's keys —
        probe-row-major, entries in insertion order."""
        if self._key_first.n == 0 or len(pk) == 0:
            return _EMPTY_PAIR
        self._flush_dups()
        kids = self._kindex.lookup(pk)
        midx = np.flatnonzero(kids >= 0)
        if len(midx) == 0:
            return _EMPTY_PAIR
        mk = kids[midx]
        isdup = self._key_dup.data[mk]
        single = midx[~isdup]
        dup_rows = midx[isdup]
        counts = np.zeros(len(pk), dtype=np.int64)
        counts[single] = 1
        if len(dup_rows):
            lo = np.searchsorted(self._dup_keys, pk[dup_rows], side="left")
            hi = np.searchsorted(self._dup_keys, pk[dup_rows], side="right")
            counts[dup_rows] = hi - lo
        total = int(counts.sum())
        probe_idx = np.repeat(np.arange(len(pk), dtype=np.int64), counts)
        entry_idx = np.empty(total, dtype=np.int64)
        offs = np.concatenate(([0], np.cumsum(counts)[:-1]))
        entry_idx[offs[single]] = self._key_first.data[mk[~isdup]]
        if len(dup_rows):
            c = hi - lo
            nd = int(c.sum())
            within = np.arange(nd, dtype=np.int64) - np.repeat(
                np.concatenate(([0], np.cumsum(c)[:-1])), c
            )
            dpos = np.repeat(offs[dup_rows], c) + within
            entry_idx[dpos] = self._dup_entries[np.repeat(lo, c) + within]
        return probe_idx, entry_idx


# ---------------------------------------------------------------------------


class SharedHashBuildState:
    """A shared hash-build state (§4.3): signature + coverage + SoA entries.

    Entries are identified by derivation id; insert-or-mark keeps one
    physical entry per derivation and ORs visibility/provenance bits (§4.3
    "GraftDB stores one build entry and records the visibility needed by
    those queries"). Under ``n_partitions > 1`` the did and probe indexes
    shard by key hash (DESIGN.md §9) while entry storage stays one global
    SoA with P-independent entry ids."""

    def __init__(
        self,
        state_id: int,
        sig: StateSignature,
        key_attrs: Tuple[str, ...],
        payload: Tuple[str, ...],
        did_domain: int = 1 << 62,
        counters: Optional[Dict] = None,
        n_partitions: int = 1,
    ):
        self.state_id = state_id
        self.sig = sig
        self.key_attrs = tuple(key_attrs)
        self.payload = tuple(payload)
        self.retained_attrs = frozenset(self.payload) | frozenset(self.key_attrs)
        self.did_domain = did_domain
        self.n_partitions = max(1, int(n_partitions))
        self._counters = counters

        self.keycode = GrowArray(np.int64)
        self.did = GrowArray(np.int64)
        self.vis = GrowArray(np.uint64)
        self.emask = GrowArray(np.uint64)
        self.cols: Dict[str, GrowArray] = {a: GrowArray(np.float64) for a in self.retained_attrs}

        if self.n_partitions == 1:
            self._did_index = HashIndex(counters=counters)
        else:
            # key-hash shards: a derivation's keycode determines its shard
            # (a did always carries one keycode), so per-shard dedup is
            # exact. Shard-dense ids map to global SoA positions.
            self._did_shards = [HashIndex(counters=counters) for _ in range(self.n_partitions)]
            self._did_gid = [GrowArray(np.int64) for _ in range(self.n_partitions)]
        self.slots = SlotAllocator()

        # extent registry: eid -> (conj | None, complete)
        self.extents: Dict[int, Tuple[Optional[Conjunction], bool]] = {}
        self._next_eid = 0
        # per-extent per-scan-partition delivery frontier (§9): which of a
        # producer's scan partitions have fully delivered. Introspection for
        # per-partition gate views; extent *completion* stays all-partitions
        # (probe rows hash across every key shard, so a partial frontier
        # cannot soundly open a lens).
        self.extent_parts: Dict[int, Tuple[int, set]] = {}

        # grants: qid -> list of (allowed_emask, retained_pred_conj)
        self.grants: Dict[int, List[Tuple[np.uint64, Conjunction]]] = {}
        self.refs: set = set()
        # lifecycle (DESIGN.md §10): external admission pins, retirement
        # epoch stamp (None while any lens or pin holds the state), and the
        # evicted tombstone every observation path checks.
        self.pins: set = set()
        self.retired_epoch: Optional[int] = None
        self.evicted = False
        # fault plane (§16): a quarantined state is mid-tombstone — its
        # fragments may be corrupt, so teardown must neither retire it for
        # later grafts nor spill it into the reuse plane.
        self.quarantined = False

        # incremental multi-match probe index shards (DESIGN.md §8/§9),
        # synced lazily at probe time — build-only phases pay nothing.
        self._kidx = [_KeyProbeIndex(counters=counters) for _ in range(self.n_partitions)]
        self._indexed_upto = 0  # entries registered with the probe index
        # small-state direct probe cache: (n, order, sorted_keys, unique)
        self._direct_cache: Optional[tuple] = None

        # counters
        self.rows_inserted = 0
        self.rows_marked = 0

        # device-residency hook (DESIGN.md §13): entry ids whose packed
        # vis/emask words were re-ORed after their initial insert. Device
        # mirrors patch exactly these entries instead of regathering the
        # whole SoA; when the log would outgrow MARK_LOG_LIMIT it is
        # compacted away and the epoch bump tells consumers to regather
        # once. Appends need no log — mirrors track them by entry count.
        self.mark_log = GrowArray(np.int64)
        self.mark_log_epoch = 0
        # detach() clears a slot's bit across ALL vis words without going
        # through insert_or_mark — neither rows_marked nor the mark log sees
        # it. The epoch below is the mirrors' staleness signal for that bulk
        # clear (bump -> consumers regather once).
        self.vis_epoch = 0

    # -- lifecycle guards ----------------------------------------------------
    def _check_live(self) -> None:
        """Eviction-vs-lens soundness (§10): an evicted state's fragments
        are reclaimed — any observation attempt is a lifecycle bug, never a
        silently wrong (empty) answer."""
        if self.evicted:
            raise RuntimeError(
                f"state #{self.state_id} was evicted — no lens may observe it"
            )

    def pin(self, token) -> None:
        """External admission pin: a queued-but-admissible lens holds the
        state out of the evictor's reach until it attaches or withdraws."""
        self._check_live()
        self.pins.add(token)

    def unpin(self, token) -> None:
        self.pins.discard(token)

    @property
    def evictable(self) -> bool:
        """No live lens (refs) and no admission pin observes this state."""
        return not self.refs and not self.pins and not self.evicted

    # -- extent registry -----------------------------------------------------
    def register_extent(self, conj: Optional[Conjunction]) -> int:
        """Register a producer extent; returns its provenance bit id.
        Returns -1 when provenance bits are exhausted (the extent still
        contributes rows via per-query visibility bits — only represented
        attachment against it is lost, never safety)."""
        if self._next_eid >= 64:
            return -1
        eid = self._next_eid
        self._next_eid += 1
        self.extents[eid] = (conj, False)
        return eid

    def complete_extent(self, eid: int) -> None:
        if eid >= 0 and eid in self.extents:  # voided extents (§16) are gone
            conj, _ = self.extents[eid]
            self.extents[eid] = (conj, True)

    def void_extent(self, eid: int) -> None:
        """Seal the state at its last complete extent (§16): a cancelled or
        failed producer with no surviving adopter withdraws its incomplete
        extent from the registry. Sound because provenance bit ids are
        monotonic (``_next_eid`` never reuses a voided bit), coverage and
        grant evaluation iterate the registry (a missing eid simply grants
        nothing), and the producer's partially delivered rows stay physical
        but carry only the voided emask bit + doomed vis bits — invisible
        to every lens."""
        if eid >= 0:
            self.extents.pop(eid, None)
            self.extent_parts.pop(eid, None)

    def complete_extent_partition(self, eid: int, part: int, n_parts: int) -> None:
        """Record one scan partition of a producer extent as fully
        delivered (the per-partition visibility frontier of §9)."""
        if eid < 0:
            return
        total, done = self.extent_parts.get(eid, (n_parts, set()))
        done.add(part)
        self.extent_parts[eid] = (n_parts, done)

    def extent_partition_frontier(self, eid: int) -> Tuple[int, int]:
        """(partitions delivered, partitions total) for one extent."""
        if eid < 0:
            return (0, 0)
        total, done = self.extent_parts.get(eid, (0, set()))
        return (len(done), total)

    # -- device views (DESIGN.md §14) ----------------------------------------
    def device_frontiers(self) -> Dict[int, Tuple[int, int]]:
        """Per-extent delivery frontiers keyed by extent id. Under mesh
        execution scan partitions ARE devices, so this is the replicated
        control plane's per-device commit view of every producer extent —
        `complete_extent_partition` committed per shard."""
        return {eid: self.extent_partition_frontier(eid) for eid in self.extents}

    def shard_entry_counts(self, n_shards: Optional[int] = None) -> np.ndarray:
        """Entries resident on each key shard — the device layout of the
        entry SoA under §14 (shard p of the mesh owns exactly the entries
        whose ``key_partition`` is p; entry ids stay global and
        P-independent)."""
        P = self.n_partitions if n_shards is None else int(n_shards)
        P = max(1, P)
        n = len(self.keycode.data)
        if n == 0:
            return np.zeros(P, np.int64)
        parts = key_partition(self.keycode.data, P)
        return np.bincount(parts, minlength=P).astype(np.int64)

    def device_layout(self) -> Dict:
        """Replicated summary of this state's per-device residency: entry
        counts and (proportional) bytes per shard, plus the frontier view."""
        counts = self.shard_entry_counts()
        total = int(counts.sum())
        nb = self.nbytes()
        bytes_by = (
            [int(nb * c / total) for c in counts] if total else [0] * len(counts)
        )
        return {
            "state_id": self.state_id,
            "n_shards": int(self.n_partitions),
            "entries_by_device": counts.tolist(),
            "bytes_by_device": bytes_by,
            "extent_frontiers": {
                eid: list(f) for eid, f in self.device_frontiers().items()
            },
        }

    def coverage(self) -> Coverage:
        """Coverage metadata = union of completed extents (§4.3)."""
        return Coverage(c for c, done in self.extents.values() if done and c is not None)

    def covers_with(self, conj: Conjunction, allowed_emask: np.uint64) -> bool:
        """Coverage restricted to the allowed provenance extents."""
        cov = Coverage(
            c
            for eid, (c, done) in self.extents.items()
            if done and c is not None and (np.uint64(1) << np.uint64(eid)) & allowed_emask
        )
        return cov.covers(conj)

    def allowed_extents_for(self, nonret: Conjunction) -> np.uint64:
        """Completed extents whose predicate implies the non-retained part of
        a query's build predicate."""
        mask = np.uint64(0)
        for eid, (c, done) in self.extents.items():
            if done and c is not None and c.implies(nonret):
                mask |= np.uint64(1) << np.uint64(eid)
        return mask

    def covers_with_pending(
        self,
        conj: Conjunction,
        allowed_emask: np.uint64,
        pending: List[Conjunction],
    ) -> bool:
        """Coverage proof over completed extents plus ``pending`` — extent
        predicates a cohort-mate's producer registered this decision step but
        has not yet delivered (§15 deferred representation). The admission
        grant gates on those producers, and ``Gate.open`` re-proves coverage
        with ``covers_with`` once they complete, so this predicts exactly the
        post-completion verdict."""
        cov = Coverage(
            [
                c
                for eid, (c, done) in self.extents.items()
                if done
                and c is not None
                and (np.uint64(1) << np.uint64(eid)) & allowed_emask
            ]
            + list(pending)
        )
        return cov.covers(conj)

    # -- producer side -----------------------------------------------------
    def insert_or_mark(
        self,
        dids: np.ndarray,
        keycodes: np.ndarray,
        cols: Dict[str, np.ndarray],
        vismask: np.ndarray,
        emask: np.ndarray,
    ) -> Tuple[int, int]:
        """Insert rows absent by derivation id; OR visibility/provenance on
        present ones. Returns (inserted, marked).

        One batched ``HashIndex.lookup_or_insert`` per shard resolves every
        row's entry position (deduping within the batch in first-occurrence
        order); a single ``bitwise_or.at`` pass then merges visibility and
        provenance for marks, fresh inserts, and in-batch duplicates alike.
        Global entry ids are assigned in batch-stream first-occurrence
        order for every P, so the resident SoA is partition-independent.

        Sharding invariant: a derivation id always arrives with the same
        keycode (the did identifies a row; the keycode is a function of
        that row), so per-shard dedup by did is exact.
        """
        if len(dids) == 0:
            return 0, 0
        self._check_live()
        dids = np.asarray(dids, dtype=np.int64)
        keycodes = np.asarray(keycodes, dtype=np.int64)
        n0 = self.did.n
        if self.n_partitions == 1:
            ids, is_new = self._did_index.lookup_or_insert(dids)
            sel = np.flatnonzero(is_new)  # ids[sel] == n0 + arange(n_inserted)
        else:
            ids, sel = self._sharded_did_resolve(dids, keycodes, n0)
        n_inserted = len(sel)
        marked = ids < n0
        n_marked = int(marked.sum())
        if n_marked:
            if n_marked > MARK_LOG_LIMIT:
                # pathological batch: never logged, consumers regather once
                self.mark_log = GrowArray(np.int64)
                self.mark_log_epoch += 1
            else:
                if self.mark_log.n + n_marked > MARK_LOG_LIMIT:
                    self.mark_log = GrowArray(np.int64)
                    self.mark_log_epoch += 1
                self.mark_log.append(ids[marked])
        if n_inserted:
            self.did.append(dids[sel])
            self.keycode.append(keycodes[sel])
            zeros = np.zeros(n_inserted, dtype=np.uint64)
            self.vis.append(zeros)
            self.emask.append(zeros)
            for a in self.retained_attrs:
                self.cols[a].append(np.asarray(cols[a], dtype=np.float64)[sel])
            self.rows_inserted += n_inserted
        np.bitwise_or.at(self.vis.data, ids, vismask)
        np.bitwise_or.at(self.emask.data, ids, emask)
        self.rows_marked += n_marked
        return n_inserted, n_marked

    def _sharded_did_resolve(
        self, dids: np.ndarray, keycodes: np.ndarray, n0: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Resolve a batch against the key-hash did shards: global entry id
        per row plus the ascending batch positions of new first occurrences
        (identical to the unsharded path's ``flatnonzero(is_new)``)."""
        parts = key_partition(keycodes, self.n_partitions)
        ids = np.empty(len(dids), dtype=np.int64)
        pending: List[Tuple[int, np.ndarray, np.ndarray, np.ndarray]] = []
        new_srcs: List[np.ndarray] = []
        for s in range(self.n_partitions):
            sub = np.flatnonzero(parts == s)
            if not len(sub):
                continue
            sids, snew = self._did_shards[s].lookup_or_insert(dids[sub])
            src = sub[np.flatnonzero(snew)]  # ascending batch positions
            pending.append((s, sub, sids, src))
            if len(src):
                new_srcs.append(src)
        if new_srcs:
            allsrc = np.sort(np.concatenate(new_srcs))
        else:
            allsrc = np.empty(0, dtype=np.int64)
        for s, sub, sids, src in pending:
            if len(src):
                # shard-dense new ids were handed out in sub-batch
                # first-occurrence order == ascending src order, matching
                # this append order exactly
                self._did_gid[s].append(n0 + np.searchsorted(allsrc, src))
            ids[sub] = self._did_gid[s].data[sids]
        return ids, allsrc

    # -- grants ---------------------------------------------------------------
    def add_grant(self, qid: int, allowed_emask: np.uint64, retained_conj: Conjunction) -> None:
        self._check_live()
        self.slots.get(qid)
        self.grants.setdefault(qid, []).append((allowed_emask, retained_conj))

    def grant_evaluable(self, conj: Conjunction) -> bool:
        """FV(P) ⊆ RetainedAttrs(S) (§4.2 evaluability)."""
        return conj.attrs() <= self.retained_attrs

    def _granted_mask(self, allowed_emask: np.uint64, retained_conj: Conjunction) -> np.ndarray:
        m = (self.emask.data & allowed_emask) != 0
        if retained_conj.attrs():
            cols = {a: self.cols[a].data for a in retained_conj.attrs()}
            m = m & evaluate_conj(retained_conj, cols)
        return m

    def count_granted(self, allowed_emask: np.uint64, retained_conj: Conjunction) -> int:
        """Entries currently observable through a grant (counters only)."""
        if self.did.n == 0:
            return 0
        return int(self._granted_mask(allowed_emask, retained_conj).sum())

    def count_granted_by_part(
        self, allowed_emask: np.uint64, retained_conj: Conjunction, n_parts: int
    ) -> np.ndarray:
        """Per-key-partition split of ``count_granted`` (EXPLAIN GRAFT's
        per-partition represented accounting)."""
        if self.did.n == 0:
            return np.zeros(n_parts, dtype=np.int64)
        m = self._granted_mask(allowed_emask, retained_conj)
        parts = key_partition(self.keycode.data, n_parts)
        return np.bincount(parts[m], minlength=n_parts).astype(np.int64)

    # -- consumer side -------------------------------------------------------
    def _sync_index(self) -> None:
        """Register entries appended since the last probe (lazy: the probe
        index costs nothing while a state is only being built)."""
        n = self.keycode.n
        if self._indexed_upto < n:
            new = self.keycode.data[self._indexed_upto : n]
            ent = self._indexed_upto + np.arange(len(new), dtype=np.int64)
            allkc = self.keycode.data
            if self.n_partitions == 1:
                self._kidx[0].append(new, ent, allkc)
            else:
                parts = key_partition(new, self.n_partitions)
                for s in range(self.n_partitions):
                    sub = np.flatnonzero(parts == s)
                    if len(sub):
                        self._kidx[s].append(new[sub], ent[sub], allkc)
            self._indexed_upto = n

    def probe(self, probe_keycodes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized probe: returns (probe_row_idx, entry_idx) match pairs
        — before any visibility filtering. Unique keys resolve through the
        hash index in O(batch); multi-entry keys expand from the sorted
        duplicate run. Match pairs are emitted probe-row-major with entries
        in insertion order, independent of the shard count (each probe key
        lives in exactly one shard, so a stable row-major gather of the
        per-shard results reproduces the unsharded order exactly)."""
        self._check_live()
        if self.keycode.n == 0 or len(probe_keycodes) == 0:
            return _EMPTY_PAIR
        pk = np.asarray(probe_keycodes, dtype=np.int64)
        if self.keycode.n <= DIRECT_PROBE_MAX and self._indexed_upto == 0:
            # size/occupancy threshold (§8): small states skip the lazy
            # dup-run sync entirely; once the state outgrows the threshold
            # the incremental index syncs from scratch in one batch append
            return self._probe_direct(pk)
        self._direct_cache = None  # outgrown: drop the small-state cache
        self._sync_index()
        if self.n_partitions == 1:
            return self._kidx[0].probe(pk)
        parts = key_partition(pk, self.n_partitions)
        pidx_parts: List[np.ndarray] = []
        eidx_parts: List[np.ndarray] = []
        for s in range(self.n_partitions):
            sub = np.flatnonzero(parts == s)
            if not len(sub):
                continue
            lp, le = self._kidx[s].probe(pk[sub])
            if len(lp):
                pidx_parts.append(sub[lp])
                eidx_parts.append(le)
        if not pidx_parts:
            return _EMPTY_PAIR
        probe_idx = np.concatenate(pidx_parts)
        entry_idx = np.concatenate(eidx_parts)
        if len(pidx_parts) > 1:
            order = np.argsort(probe_idx, kind="stable")
            probe_idx, entry_idx = probe_idx[order], entry_idx[order]
            if self._counters is not None:
                self._counters["partition_probe_merges"] += 1
        return probe_idx, entry_idx

    def _probe_direct(self, pk: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Small-state probe: one cached stable argsort over all keycodes +
        binary search. Pair stream is bit-identical to the incremental
        index for every partition count — probe-row-major with entries in
        insertion order (stable sort) — so the threshold crossing is
        invisible to consumers."""
        n = self.keycode.n
        cache = self._direct_cache
        if cache is None or cache[0] != n:
            keys = self.keycode.data
            order = np.argsort(keys, kind="stable")
            skeys = keys[order]
            unique = not bool((skeys[1:] == skeys[:-1]).any())
            self._direct_cache = cache = (n, order, skeys, unique)
        _, order, skeys, unique = cache
        if unique:
            pos = np.searchsorted(skeys, pk, side="left")
            hit = skeys[np.minimum(pos, n - 1)] == pk
            probe_idx = np.flatnonzero(hit).astype(np.int64)
            return probe_idx, order[pos[probe_idx]]
        lo = np.searchsorted(skeys, pk, side="left")
        hi = np.searchsorted(skeys, pk, side="right")
        counts = hi - lo
        total = int(counts.sum())
        if total == 0:
            return _EMPTY_PAIR
        probe_idx = np.repeat(np.arange(len(pk), dtype=np.int64), counts)
        starts = np.repeat(lo, counts)
        offs = np.arange(total, dtype=np.int64) - np.repeat(
            np.concatenate(([0], np.cumsum(counts)[:-1])), counts
        )
        return probe_idx, order[starts + offs]

    def visible_mask(self, qid: int, entry_idx: np.ndarray) -> np.ndarray:
        """Per-query state lens on entries: per-entry visibility bit OR an
        extent-scoped grant the entry's provenance+retained attrs satisfy."""
        self._check_live()
        slot = self.slots.peek(qid)
        if slot is None:
            vis = np.zeros(len(entry_idx), dtype=bool)
        else:
            vis = bit_of(self.vis.data[entry_idx], slot)
        for allowed_emask, conj in self.grants.get(qid, ()):
            g = (self.emask.data[entry_idx] & allowed_emask) != 0
            if conj.attrs():
                cols = {a: self.cols[a].data[entry_idx] for a in conj.attrs()}
                g = g & evaluate_conj(conj, cols)
            vis |= g
        return vis

    def entry_cols(self, entry_idx: np.ndarray, attrs: Sequence[str]) -> Dict[str, np.ndarray]:
        return {a: self.cols[a].data[entry_idx] for a in attrs}

    # -- lifecycle ------------------------------------------------------------
    def attach(self, qid: int) -> None:
        self._check_live()
        self.refs.add(qid)
        self.slots.get(qid)

    def detach(self, qid: int) -> None:
        self.refs.discard(qid)
        # Clear the query's visibility bit before its slot recycles: a state
        # that outlives the query (live co-refs, or §10 epoch retention)
        # must not leak its rows to the slot's next owner through a stale
        # bit — the lens of a later query is exactly its own slot + grants.
        slot = self.slots.peek(qid)
        if slot is not None and self.vis.n:
            v = self.vis.data
            v &= ~(np.uint64(1) << np.uint64(slot))
            # bulk mutation outside insert_or_mark: invalidate device/host
            # visibility mirrors stamped on (rows_inserted, rows_marked)
            self.vis_epoch += 1
        self.slots.release(qid)
        self.grants.pop(qid, None)

    @property
    def n_entries(self) -> int:
        return self.did.n

    def nbytes(self) -> int:
        # floored at the fixed per-state overhead (object + index headers):
        # a zero-entry state still occupies memory, which keeps force-evict
        # (budget 0) able to select it
        per_entry = 8 * (3 + len(self.retained_attrs)) + 8
        return 64 + self.did.n * per_entry


# ---------------------------------------------------------------------------


class _AggPartial:
    """One partition's partial accumulators — exactly the seed engine's
    accumulator layout (partition 0 of an unpartitioned state IS the seed
    path; P > 1 states merge partials deterministically in partition-id
    order, DESIGN.md §9)."""

    __slots__ = (
        "group_keys",
        "aggs",
        "distinct_global",
        "_gidx",
        "_global_ready",
        "group_cols",
        "_acc",
        "_counts",
    )

    def __init__(self, group_keys, aggs, counters: Optional[Dict] = None, distinct_global=False):
        self.group_keys = group_keys
        self.aggs = aggs
        # distinct-pair keying: the seed path keys on (partial-local gid,
        # value) — bijective with the key tuple inside one partial;
        # partitioned states key on the actual group-key values + value so
        # dedup is global across partials.
        self.distinct_global = distinct_global
        self._gidx = (
            MultiKeyIndex(len(group_keys), counters=counters) if group_keys else None
        )
        self._global_ready = False  # global aggregate: single group, lazily init
        self.group_cols: List[GrowArray] = [GrowArray(np.float64) for _ in group_keys]
        self._acc: List[GrowArray] = [GrowArray(np.float64) for _ in aggs]
        self._counts = GrowArray(np.float64)

    @staticmethod
    def _init_of(spec) -> float:
        return math.inf if spec.func == "min" else (-math.inf if spec.func == "max" else 0.0)

    def _new_groups(self, n_new: int) -> None:
        for acc, spec in zip(self._acc, self.aggs):
            acc.append(np.full(n_new, self._init_of(spec)))
        self._counts.append(np.zeros(n_new))

    def _group_ids(self, keys: List[np.ndarray], n: int) -> np.ndarray:
        if not keys:
            # global aggregate: single group
            if not self._global_ready:
                self._global_ready = True
                self._new_groups(1)
            return np.zeros(n, dtype=np.int64)
        gids, is_new = self._gidx.lookup_or_insert(keys)
        n_new = int(is_new.sum())
        if n_new:
            sel = np.flatnonzero(is_new)  # gids[sel] == old n_groups + arange
            for k, gc in enumerate(self.group_cols):
                gc.append(np.asarray(keys[k], dtype=np.float64)[sel])
            self._new_groups(n_new)
        return gids

    def fold_partials(self, gids: np.ndarray, counts, agg_partials) -> None:
        """Scatter pre-reduced per-group partials onto already-assigned
        accumulator ids (§11 cohort steady state: no hashing at all).
        ``gids`` must be distinct (one row per touched group), which lets
        every scatter use buffered fancy indexing instead of ``ufunc.at``."""
        cd = self._counts.data
        cd[gids] += counts
        for acc, spec, partial in zip(self._acc, self.aggs, agg_partials):
            if spec.distinct:
                raise ValueError("distinct aggregates cannot fold from partials")
            ad = acc.data
            if spec.func == "min":
                ad[gids] = np.minimum(ad[gids], partial)
            elif spec.func == "max":
                ad[gids] = np.maximum(ad[gids], partial)
            else:  # sum / avg / count partials add
                ad[gids] += partial

    def update(self, key_cols, agg_values, n, segment_sum, distinct_idx) -> None:
        gids = self._group_ids(key_cols, n)
        ngroups = self._counts.n
        cnt = segment_sum(gids, None, ngroups)
        self._counts.data[:] += cnt
        for j, (acc, spec) in enumerate(zip(self._acc, self.aggs)):
            vals = agg_values[j]
            if spec.distinct:
                # count(distinct expr): one batched lookup flags the
                # never-seen pairs (state-level index: dedup is global
                # across partitions, so merged counts stay exact)
                dkey = list(key_cols) + [vals] if self.distinct_global else [gids, vals]
                _, fresh = distinct_idx[j].lookup_or_insert(dkey)
                if fresh.any():
                    acc.data[:] += np.bincount(gids[fresh], minlength=ngroups)
            elif spec.func == "count":
                acc.data[:] += cnt
            elif spec.func in ("sum", "avg"):
                acc.data[:] += segment_sum(gids, vals, ngroups)
            elif spec.func == "min":
                np.minimum.at(acc.data, gids, vals)
            elif spec.func == "max":
                np.maximum.at(acc.data, gids, vals)
            else:
                raise ValueError(spec.func)

    @property
    def n_groups(self) -> int:
        return self._counts.n


class SharedAggregateState:
    """Shared aggregate state under exact aggregate identity (§4.5).

    Input occurrences collapse into group accumulators, so the state cannot
    be repartitioned under a different predicate/grouping — sharing is
    all-or-nothing per identity, enforced by the signature. Supports
    sum/count/avg/min/max; group-id assignment and the count(distinct expr)
    seen-pairs both run on batched ``MultiKeyIndex`` lookups (DESIGN.md §8).

    Under ``n_partitions > 1`` each scan partition folds into its own
    partial accumulator; ``result()`` merges partials in partition-id order
    (DESIGN.md §9) — deterministic under any worker interleaving because
    each partition's morsel stream is fixed. count(distinct) seen-pairs
    dedup through one state-level index keyed on the actual group-key
    values (not partial-local gids), so cross-partition duplicates count
    once no matter which partial observed them first."""

    def __init__(
        self,
        state_id: int,
        sig: Optional[StateSignature],
        group_keys: Tuple[str, ...],
        aggs,
        counters: Optional[Dict] = None,
        n_partitions: int = 1,
    ):
        self.state_id = state_id
        self.sig = sig
        self.group_keys = tuple(group_keys)
        self.aggs = tuple(aggs)
        self.n_partitions = max(1, int(n_partitions))
        self._counters = counters

        self._parts = [
            _AggPartial(
                self.group_keys, self.aggs, counters, distinct_global=self.n_partitions > 1
            )
            for _ in range(self.n_partitions)
        ]
        if self.n_partitions == 1:
            # seed layout: (partial-local gid, value) pairs — bijective with
            # the key tuple inside one partial
            self._distinct_idx: List[Optional[MultiKeyIndex]] = [
                MultiKeyIndex(2, counters=counters) if a.distinct else None
                for a in self.aggs
            ]
        else:
            self._distinct_idx = [
                MultiKeyIndex(len(self.group_keys) + 1, counters=counters)
                if a.distinct
                else None
                for a in self.aggs
            ]
        self._merge_cache = None  # (stamp, gcols, accs, counts)

        self.complete = False
        self.refs: set = set()
        self.rows_consumed = 0
        # lifecycle (§10): same pin/epoch/tombstone surface as hash states
        self.pins: set = set()
        self.retired_epoch: Optional[int] = None
        self.evicted = False
        # fault plane (§16): a quarantined state is mid-tombstone — its
        # fragments may be corrupt, so teardown must neither retire it for
        # later grafts nor spill it into the reuse plane.
        self.quarantined = False

    def update(
        self,
        key_cols: List[np.ndarray],
        agg_values: List[Optional[np.ndarray]],
        n: int,
        segment_sum=None,
        part: int = 0,
    ) -> None:
        """Fold one morsel of rows into partition ``part``'s accumulators
        (segment reduce).

        ``segment_sum(gids, values_or_None, n_groups)`` lets an execution
        backend (api/backends.py) supply the grouped reduction — e.g. the
        Pallas one-hot MXU kernel; defaults to ``np.bincount``."""
        if n == 0:
            return
        self._check_live()
        self.rows_consumed += n
        if segment_sum is None:
            segment_sum = _bincount_segment_sum
        self._parts[part].update(key_cols, agg_values, n, segment_sum, self._distinct_idx)

    # -- batched multi-member entry points (§11) ------------------------------
    def map_groups(self, key_cols: List[np.ndarray], part: int = 0) -> np.ndarray:
        """Accumulator id per group-key row (one row per group), assigning
        unseen groups new ids *in the given row order* — the caller passes
        a member's unseen groups in its first-occurrence order, which makes
        accumulator layout bit-identical to row-level ``update``. For
        global aggregates (no group keys) returns the single group's id."""
        self._check_live()
        part_acc = self._parts[part]
        if not key_cols:
            return part_acc._group_ids([], 1)
        return part_acc._group_ids(list(key_cols), len(key_cols[0]))

    def fold_groups(
        self,
        gids: np.ndarray,
        counts: np.ndarray,
        agg_partials: List[np.ndarray],
        n_rows: int,
        part: int = 0,
    ) -> None:
        """Fold pre-reduced per-group partials onto mapped accumulator ids
        (§11 cohort pass): sum/count/avg partials add, min/max merge —
        exactly equivalent to ``update`` over the member's selected rows
        because the partials were accumulated in the same row order.
        Distinct aggregates cannot fold this way (their dedup is
        per-state); the runtime routes them through ``update``."""
        if n_rows == 0:
            return
        self._check_live()
        self.rows_consumed += n_rows
        self._parts[part].fold_partials(gids, counts, agg_partials)

    def update_groups(
        self,
        key_cols: List[np.ndarray],
        counts: np.ndarray,
        agg_partials: List[np.ndarray],
        n_rows: int,
        part: int = 0,
    ) -> None:
        """``map_groups`` + ``fold_groups`` in one call (one row per
        touched group, in the member's first-occurrence order)."""
        if n_rows == 0:
            return
        gids = self.map_groups(key_cols, part=part)
        self.fold_groups(gids, counts, agg_partials, n_rows, part=part)

    # -- deterministic partial merge (DESIGN.md §9) ---------------------------
    def _merged(self):
        """Merge partials in partition-id order; cached by a consumption
        stamp. Only reached when n_partitions > 1."""
        stamp = (self.rows_consumed, tuple(p.n_groups for p in self._parts))
        if self._merge_cache is not None and self._merge_cache[0] == stamp:
            return self._merge_cache[1:]
        K = len(self.group_keys)
        midx = MultiKeyIndex(K) if K else None
        gcols = [GrowArray(np.float64) for _ in range(K)]
        accs = [GrowArray(np.float64) for _ in self.aggs]
        counts = GrowArray(np.float64)
        for p in self._parts:
            npg = p.n_groups
            if npg == 0:
                continue
            if K:
                keys = [gc.data for gc in p.group_cols]
                gids, is_new = midx.lookup_or_insert(keys)
                n_new = int(is_new.sum())
                if n_new:
                    sel = np.flatnonzero(is_new)
                    for k in range(K):
                        gcols[k].append(keys[k][sel])
                    for acc, spec in zip(accs, self.aggs):
                        acc.append(np.full(n_new, _AggPartial._init_of(spec)))
                    counts.append(np.zeros(n_new))
            else:
                gids = np.zeros(1, dtype=np.int64)
                if counts.n == 0:
                    for acc, spec in zip(accs, self.aggs):
                        acc.append(np.full(1, _AggPartial._init_of(spec)))
                    counts.append(np.zeros(1))
            np.add.at(counts.data, gids, p._counts.data)
            for acc, pacc, spec in zip(accs, p._acc, self.aggs):
                if spec.func == "min":
                    np.minimum.at(acc.data, gids, pacc.data)
                elif spec.func == "max":
                    np.maximum.at(acc.data, gids, pacc.data)
                else:  # sum / avg / count / count-distinct partials add
                    np.add.at(acc.data, gids, pacc.data)
        if self._counters is not None:
            self._counters["partition_merges"] += 1
        self._merge_cache = (stamp, gcols, accs, counts)
        return gcols, accs, counts

    def result(self) -> Dict[str, np.ndarray]:
        if self.n_partitions == 1:
            p = self._parts[0]
            gcols, accs, counts = p.group_cols, p._acc, p._counts
        else:
            gcols, accs, counts = self._merged()
        out: Dict[str, np.ndarray] = {}
        for k, name in enumerate(self.group_keys):
            out[name] = gcols[k].data.copy()
        for acc, spec in zip(accs, self.aggs):
            if spec.func == "avg":
                with np.errstate(invalid="ignore", divide="ignore"):
                    out[spec.name] = acc.data / np.maximum(counts.data, 1e-300)
            else:
                out[spec.name] = acc.data.copy()
        return out

    def attach(self, qid: int) -> None:
        self._check_live()
        self.refs.add(qid)

    def detach(self, qid: int) -> None:
        self.refs.discard(qid)

    # -- lifecycle (§10, shared with SharedHashBuildState) -------------------
    def _check_live(self) -> None:
        if self.evicted:
            raise RuntimeError(
                f"aggregate state #{self.state_id} was evicted — no lens may observe it"
            )

    def pin(self, token) -> None:
        self._check_live()
        self.pins.add(token)

    def unpin(self, token) -> None:
        self.pins.discard(token)

    @property
    def evictable(self) -> bool:
        return not self.refs and not self.pins and not self.evicted

    def nbytes(self) -> int:
        """Accumulator footprint estimate: per-group key + agg + count
        columns (float64) summed over partials, plus the fixed per-state
        overhead (floor — keeps empty states selectable by force-evict)."""
        per_group = 8 * (len(self.group_keys) + len(self.aggs) + 1)
        groups = sum(p.n_groups for p in self._parts)
        return 64 + groups * per_group

    @property
    def n_groups(self) -> int:
        if self.n_partitions == 1:
            return self._parts[0].n_groups
        return self._merged()[2].n


# ---------------------------------------------------------------------------
# Retention lifecycle (DESIGN.md §10)
# ---------------------------------------------------------------------------


class StateLifecycle:
    """Retention lifecycle of shared operator state.

    ``refcount`` — the evaluated prototype's policy (paper §6.1): the engine
    drops a state the moment no query references it; the lifecycle manager
    is inert. ``epoch`` — zero-pin states are *retired* instead: stamped
    with the next retention epoch and kept in the shared-state index so
    later arrivals can graft represented extents onto their coverage. A
    memory-budgeted evictor reclaims retired states oldest-epoch-first
    whenever their total bytes exceed ``memory_budget`` (None = retain
    without bound).

    Invariants (asserted throughout):

    * retirement tracks *lenses*: a state retires when its last ref
      detaches, whether or not admission pins are held — pins block
      EVICTION, not retirement (``victims`` skips non-evictable states,
      and a pinned retired state resumes eviction eligibility, at its
      original epoch, the moment its pins drop);
    * pinned ⇒ not evictable: a state with a live lens (``refs``) or an
      admission pin (``pins``) is never handed to the evictor;
    * retired ⇔ ``retired_epoch is not None`` ⇔ present in ``retired``;
    * the budget governs the *evictable* retained bytes: pinned-retired
      bytes belong to the admission-bounded working set (no evictor can
      reclaim what a queued-but-admissible lens may still observe);
    * evicted states are tombstoned (``evicted``) and removed from every
      index — re-observation raises instead of answering from reclaimed
      fragments.
    """

    def __init__(self, policy: str = "refcount", memory_budget: Optional[int] = None,
                 counters: Optional[Dict] = None):
        self.policy = policy
        self.memory_budget = memory_budget
        self.counters = counters if counters is not None else {}
        self._epoch = 0
        # state_id -> state, values ordered by retirement epoch (dicts are
        # insertion-ordered and every retire stamps a fresh epoch)
        self.retired: Dict[int, object] = {}

    @property
    def epoch(self) -> int:
        return self._epoch

    def retire(self, state) -> None:
        """Stamp a zero-ref state with the next retention epoch. Admission
        pins do not block retirement — only eviction (``victims`` skips
        pinned states until their pins drop)."""
        if state.refs or state.evicted:
            raise RuntimeError(
                f"retiring state #{state.state_id} with live lenses: refs={state.refs}"
            )
        if state.retired_epoch is not None:
            return
        self._epoch += 1
        state.retired_epoch = self._epoch
        self.retired[state.state_id] = state

    def revive(self, state) -> None:
        """A new lens attached (or pinned) a retired state: back to live."""
        if state.retired_epoch is not None:
            state.retired_epoch = None
            self.retired.pop(state.state_id, None)
            self.counters["state_revivals"] = self.counters.get("state_revivals", 0) + 1

    def drop(self, state) -> None:
        self.retired.pop(state.state_id, None)
        state.retired_epoch = None

    def retired_bytes(self) -> int:
        """Bytes of *evictable* retained state — the budget's domain.
        Pinned-retired states (a queued-but-admissible lens holds them)
        count toward the admission-bounded working set instead."""
        return sum(s.nbytes() for s in self.retired.values() if s.evictable)

    def victims(self, budget: Optional[int] = None) -> List:
        """Retired states to evict, oldest epoch first, until the evictable
        retained bytes fit ``budget`` (defaults to the configured memory
        budget). Pinned states are skipped — never evicted."""
        budget = self.memory_budget if budget is None else budget
        if budget is None:
            return []
        total = self.retired_bytes()
        out: List = []
        for s in list(self.retired.values()):  # epoch order by construction
            if total <= budget:
                break
            if not s.evictable:
                continue
            out.append(s)
            total -= s.nbytes()
        return out
