"""Work-clock cost-model calibration.

The default constants (engine.DEFAULT_COST_MODEL) model the paper's
~100 ns/row single-worker row engine. ``calibrate()`` measures THIS host's
vectorized data plane instead (numpy filter / sort-probe / insert / segment
sum throughput) and returns a cost model for wall-clock-faithful virtual
time. Benchmarks use the fixed defaults so results are machine-independent;
calibration is exposed for deployments that want host-accurate queueing.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from .engine import DEFAULT_COST_MODEL


def _time(fn, reps: int = 3) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def calibrate(n: int = 1 << 20, seed: int = 0) -> Dict[str, float]:
    rng = np.random.default_rng(seed)
    col = rng.uniform(0, 1000, n)
    keys = rng.integers(0, n, n)
    skeys = np.sort(rng.choice(2 * n, n // 4, replace=False))
    vals = rng.normal(size=n)
    gids = rng.integers(0, 1024, n)

    t_scan = _time(lambda: col.copy()) / n
    t_filter = _time(lambda: (col > 500.0) & (col < 900.0)) / n
    t_probe = _time(lambda: np.searchsorted(skeys, keys)) / n
    idx = np.searchsorted(skeys, keys).clip(0, len(skeys) - 1)
    t_match = _time(lambda: skeys[idx] == keys) / n
    t_insert = _time(lambda: np.sort(keys[: n // 4], kind="stable")) / (n // 4)
    t_agg = _time(lambda: np.bincount(gids, weights=vals, minlength=1024)) / n
    # rehydration (§12) ~= bulk copy of the SoA columns + an index-rebuild
    # share comparable to one more copy pass
    t_rehydrate = _time(lambda: (col.copy(), keys.copy())) / n

    return {
        "scan": max(t_scan, 1e-10),
        "filter": max(t_filter, 1e-10),
        "probe": max(t_probe, 1e-10),
        "match": max(t_match, 1e-10),
        "insert": max(t_insert * 2, 1e-10),  # insert ~= sort share + dict upkeep
        "mark": max(t_match * 2, 1e-10),
        "agg": max(t_agg, 1e-10),
        "rehydrate": max(t_rehydrate * 2, 1e-10),
    }


def scaled_default(target_row_ns: float = 100.0) -> Dict[str, float]:
    """DEFAULT_COST_MODEL rescaled so 'scan' hits target ns/row."""
    k = target_row_ns * 1e-9 / DEFAULT_COST_MODEL["scan"]
    return {name: v * k for name, v in DEFAULT_COST_MODEL.items()}


def score_arrival(engine, query) -> Dict[str, object]:
    """Three-way per-arrival decision (§12): modeled boundary-build seconds
    under isolated recompute, grafting onto live shared state, and
    rehydrating cached artifacts, plus the source the admission path would
    pick. ``graft`` has no rehydration term, so live state always dominates
    a cached artifact for the same coverage; ``cache`` wins only where no
    live candidate exists and the artifact's saved build work exceeds its
    rehydration cost. Read-only — shares ``engine.demand_cache`` and the
    reuse plane's coverage memo with EXPLAIN GRAFT."""
    from .grafting import graft_potential
    from .reuse import reuse_potential, reuse_scores

    cm = engine.cost_model
    row = cm["scan"] + cm["filter"] + cm["insert"]
    live = graft_potential(engine, query)
    cached = reuse_potential(engine, query)

    from .grafting import all_boundaries, estimate_demand

    demand = sum(estimate_demand(engine, b.build) for b in all_boundaries(query.plan))
    recompute_s = demand * row
    scores = {
        "recompute_s": recompute_s,
        "graft_s": recompute_s * (1.0 - live),
        "cache_s": None,
        "choice": "recompute",
    }
    if cached > 0.0:
        s = reuse_scores(cm, demand, int(round(cached * demand)), int(round(cached * demand)))
        scores["cache_s"] = recompute_s - s["saved_s"] + s["rehydrate_s"]
    if live >= cached and live > 0.0:
        scores["choice"] = "graft"
    elif cached > 0.0:
        scores["choice"] = "cache"
    return scores
