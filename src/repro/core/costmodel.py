"""Work-clock cost-model calibration.

The default constants (engine.DEFAULT_COST_MODEL) model the paper's
~100 ns/row single-worker row engine. ``calibrate()`` measures THIS host's
vectorized data plane instead (numpy filter / sort-probe / insert / segment
sum throughput) and returns a cost model for wall-clock-faithful virtual
time. Benchmarks use the fixed defaults so results are machine-independent;
calibration is exposed for deployments that want host-accurate queueing.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from .engine import DEFAULT_COST_MODEL


def _time(fn, reps: int = 3) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def calibrate(n: int = 1 << 20, seed: int = 0) -> Dict[str, float]:
    rng = np.random.default_rng(seed)
    col = rng.uniform(0, 1000, n)
    keys = rng.integers(0, n, n)
    skeys = np.sort(rng.choice(2 * n, n // 4, replace=False))
    vals = rng.normal(size=n)
    gids = rng.integers(0, 1024, n)

    t_scan = _time(lambda: col.copy()) / n
    t_filter = _time(lambda: (col > 500.0) & (col < 900.0)) / n
    t_probe = _time(lambda: np.searchsorted(skeys, keys)) / n
    idx = np.searchsorted(skeys, keys).clip(0, len(skeys) - 1)
    t_match = _time(lambda: skeys[idx] == keys) / n
    t_insert = _time(lambda: np.sort(keys[: n // 4], kind="stable")) / (n // 4)
    t_agg = _time(lambda: np.bincount(gids, weights=vals, minlength=1024)) / n

    return {
        "scan": max(t_scan, 1e-10),
        "filter": max(t_filter, 1e-10),
        "probe": max(t_probe, 1e-10),
        "match": max(t_match, 1e-10),
        "insert": max(t_insert * 2, 1e-10),  # insert ~= sort share + dict upkeep
        "mark": max(t_match * 2, 1e-10),
        "agg": max(t_agg, 1e-10),
    }


def scaled_default(target_row_ns: float = 100.0) -> Dict[str, float]:
    """DEFAULT_COST_MODEL rescaled so 'scan' hits target ns/row."""
    k = target_row_ns * 1e-9 / DEFAULT_COST_MODEL["scan"]
    return {name: v * k for name, v in DEFAULT_COST_MODEL.items()}
