"""Read-only snapshot of the shared execution DAG (§5.1) for tests,
debugging, and the Algorithm-2 invariant checks.

Nodes are operator instances with their assigned queries; DataEdge carries
row flow (scan -> pipeline -> sink), StateRefEdge connects state-consuming
members to shared state through their state-readiness gates.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple


@dataclass
class DagNode:
    kind: str  # 'scan' | 'pipeline' | 'state' | 'agg'
    ident: object
    queries: Tuple[int, ...] = ()


@dataclass
class DagSnapshot:
    nodes: List[DagNode] = field(default_factory=list)
    data_edges: List[Tuple[object, object]] = field(default_factory=list)
    state_ref_edges: List[Tuple[object, object, int, bool, Tuple[int, int]]] = field(
        default_factory=list
    )
    # (consumer pipeline, state, qid, gate_open, partition_frontier):
    # the frontier is (delivered, total) producer scan-partition units
    # still gating this edge (DESIGN.md §9) — (0, 0) once nothing pends

    def dep_edges(self):
        return [(a, b) for a, b in self.data_edges] + [
            (s, p) for p, s, *_ in self.state_ref_edges
        ]


def snapshot(engine) -> DagSnapshot:
    snap = DagSnapshot()
    seen_states: Set[int] = set()
    for key, scan in engine.scans.items():
        snap.nodes.append(DagNode("scan", key))
        for p in scan.pipelines:
            qs = tuple(sorted({m.qid for m in p.members if not m.done}))
            snap.nodes.append(DagNode("pipeline", p.key, qs))
            snap.data_edges.append((key, p.key))
            if p.build_target is not None:
                sid = p.build_target.state.state_id
                if sid not in seen_states:
                    seen_states.add(sid)
                    snap.nodes.append(DagNode("state", sid))
                snap.data_edges.append((p.key, sid))
            for m in p.members:
                if m.done:
                    continue
                for g in m.gates:
                    sid = g.state.state_id
                    if sid not in seen_states:
                        seen_states.add(sid)
                        snap.nodes.append(DagNode("state", sid))
                    snap.state_ref_edges.append(
                        (p.key, sid, m.qid, g.open(), g.partition_frontier())
                    )
    return snap


def check_invariants(engine) -> List[str]:
    """Core correctness conditions of §5.4: active node-query pairs never
    have a closed gate; producers pending on a gate are live members of a
    pipeline targeting that gate's state; states referenced by active
    queries are retained."""
    errors: List[str] = []
    for key, scan in engine.scans.items():
        for p in scan.pipelines:
            for m in p.members:
                if m.active and not m.done:
                    for g in m.gates:
                        if not g.open():
                            errors.append(
                                f"member q{m.qid} active on {p.key} with closed gate on state {g.state.state_id}"
                            )
    for h in engine.active_handles:
        for s in h.attached_states:
            if h.qid not in s.refs:
                errors.append(f"query {h.qid} attached to state {s.state_id} without a ref")
    return errors
