"""Query grafting: admission of an arriving query onto shared state (§5).

``admit_boundary`` mirrors Algorithm 1: for one stateful boundary and one
candidate state it either rejects the candidate, leaves the boundary as
ordinary-plan work, or installs a state-ref edge (a Gate) over the
represented ∪ residual extents, plus residual producer members and
ordinary-plan assignments. ``resolve_boundary`` drives it per boundary,
recursing bottom-up through the build subtree so that producer paths are
themselves admitted (AdmissibleProducerPaths).

The partition of the state-side extent (PartitionStateExtent):

* represented — proven by predicate containment against coverage restricted
  to provenance extents that imply the non-retained part of B_q (§4.2
  evaluability + §4.3 extent-scoped state-level visibility),
* residual — a producer member installed on the (shared, cyclic) source
  scan, gated on its own upstream state-refs,
* unattached — ordinary-plan work: a fresh state (which immediately becomes
  shared state itself) plus an ordinary producer member.

Unproven obligations (predicates outside the fragment, non-evaluable lens
predicates) only ever lose sharing — they fall to residual/ordinary paths
whose per-row visibility tagging is semantics-preserving by construction.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .descriptors import StateSignature, hash_build_signature
from .plans import Aggregate, HashJoin, OrderBy, PlanNode, Scan, collect_subtree_pred
from .predicates import Conjunction, evaluate
from .runtime import (
    ALL_EXTENTS,
    BuildTarget,
    Gate,
    Member,
    Pipeline,
    ProbeOp,
    encode_keys,
)
from .state import SharedHashBuildState

# ---------------------------------------------------------------------------
# Plan walking
# ---------------------------------------------------------------------------


def plan_spine(plan: PlanNode) -> Tuple[Scan, List[HashJoin], Aggregate, Optional[OrderBy]]:
    """Decompose a query plan into probe-side spine scan, the hash-join
    boundaries bottom-up, the aggregate, and the final order-by."""
    node = plan
    ob = None
    if isinstance(node, OrderBy):
        ob, node = node, node.input
    if not isinstance(node, Aggregate):
        raise TypeError("plan must end in an Aggregate")
    agg, node = node, node.input
    joins: List[HashJoin] = []
    while isinstance(node, HashJoin):
        joins.append(node)
        node = node.probe
    if not isinstance(node, Scan):
        raise TypeError("plan spine must bottom out at a Scan")
    joins.reverse()
    return node, joins, agg, ob


def build_spine(subtree: PlanNode) -> Tuple[Scan, List[HashJoin]]:
    """Probe-side spine of a build subtree (its producer path skeleton)."""
    node = subtree
    joins: List[HashJoin] = []
    while isinstance(node, HashJoin):
        joins.append(node)
        node = node.probe
    if not isinstance(node, Scan):
        raise TypeError("build subtree must bottom out at a Scan")
    joins.reverse()
    return node, joins


def all_boundaries(plan: PlanNode) -> List[HashJoin]:
    """Every stateful hash-build boundary in the plan (spine + nested)."""
    out: List[HashJoin] = []

    def walk(node: PlanNode):
        if isinstance(node, (Aggregate, OrderBy)):
            walk(node.input)
        elif isinstance(node, HashJoin):
            out.append(node)
            walk(node.build)
            walk(node.probe)

    walk(plan)
    return out


# ---------------------------------------------------------------------------
# Isolated-plan demand estimation (counters for the Fig.9c decomposition)
# ---------------------------------------------------------------------------


def estimate_demand(engine, node: PlanNode) -> int:
    """Rows an isolated execution would feed into the hash-build at this
    subtree's enclosing boundary = |sigma(build subtree)|."""
    count, _ = _subtree_count(engine, node, need_keys=None)
    return count


def _subtree_count(engine, node: PlanNode, need_keys: Optional[Tuple[str, ...]]):
    key = (id(node.__class__), _node_cache_key(node), need_keys)
    cached = engine.demand_cache.get(key)
    if cached is not None:
        return cached
    if isinstance(node, Scan):
        table = engine.db[node.table]
        mask = evaluate(node.pred, table.columns)
        count = int(mask.sum())
        keys = None
        if need_keys:
            keys = np.unique(
                encode_keys({a: table.columns[a][mask] for a in need_keys}, need_keys)
            )
        result = (count, keys)
    elif isinstance(node, HashJoin):
        _, inner_keys = _subtree_count(engine, node.build, tuple(node.build_keys))
        pt = _probe_side_table(engine, node)
        # probe-side scan pred then semijoin against the build-side key set
        scan, _joins = build_spine(node)
        mask = evaluate(scan.pred, pt.columns)
        codes = encode_keys(
            {a: pt.columns[a][mask] for a in node.probe_keys}, tuple(node.probe_keys)
        )
        sem = np.isin(codes, inner_keys)
        count = int(sem.sum())
        keys = None
        if need_keys:
            kcodes = encode_keys(
                {a: pt.columns[a][mask][sem] for a in need_keys}, need_keys
            )
            keys = np.unique(kcodes)
        result = (count, keys)
    else:
        raise TypeError(node)
    engine.demand_cache[key] = result
    return result


def demand_keycodes(engine, node: PlanNode, key_attrs: Tuple[str, ...]) -> np.ndarray:
    """Per-row key codes (``key_attrs``) of every row an isolated execution
    would feed into the enclosing boundary's hash build — the non-unique
    companion of ``estimate_demand`` (len(codes) == demand). EXPLAIN GRAFT
    splits these by ``key_partition`` for the per-partition demand
    accounting (DESIGN.md §9)."""
    key = ("demand_codes", id(node.__class__), _node_cache_key(node), key_attrs)
    cached = engine.demand_cache.get(key)
    if cached is not None:
        return cached
    if isinstance(node, Scan):
        table = engine.db[node.table]
        mask = evaluate(node.pred, table.columns)
        codes = encode_keys({a: table.columns[a][mask] for a in key_attrs}, key_attrs)
    elif isinstance(node, HashJoin):
        _, inner_keys = _subtree_count(engine, node.build, tuple(node.build_keys))
        pt = _probe_side_table(engine, node)
        scan, _joins = build_spine(node)
        mask = evaluate(scan.pred, pt.columns)
        pcodes = encode_keys(
            {a: pt.columns[a][mask] for a in node.probe_keys}, tuple(node.probe_keys)
        )
        sem = np.isin(pcodes, inner_keys)
        codes = encode_keys({a: pt.columns[a][mask][sem] for a in key_attrs}, key_attrs)
    else:
        raise TypeError(node)
    engine.demand_cache[key] = codes
    return codes


def graft_potential(engine, query) -> float:
    """Fraction of the query's isolated-plan demand that would ride existing
    shared state if admitted right now (the admission controller's
    cost-model signal, §10).

    1.0 when the whole plan collapses onto an attachable shared aggregate
    (exact identity); otherwise the demand-weighted share of stateful
    boundaries with a live or retained candidate state under the exact
    signature (represented and residual attachment both count — either way
    the boundary's build work rides the shared execution). Read-only and
    cached through ``engine.demand_cache`` like EXPLAIN GRAFT."""
    from .descriptors import aggregate_signature, hash_build_signature

    scan, joins, agg, _ = plan_spine(query.plan)
    agg_sig = aggregate_signature(agg)
    if agg_sig is not None and engine.mode.agg_share != "none":
        existing = engine.agg_index.get(agg_sig)
        if existing is not None and engine._agg_attachable(existing):
            return 1.0
    if not engine.mode.share_state:
        return 0.0
    total = shared = 0
    for j in all_boundaries(query.plan):
        d = estimate_demand(engine, j.build)
        total += d
        if engine.state_index.get(hash_build_signature(j)):
            shared += d
    return shared / total if total else 0.0


def candidate_states(engine, query) -> List:
    """The shared states an admission of ``query`` would select right now —
    the admission controller pins these for deferred-but-admissible
    arrivals so the evictor cannot reclaim coverage a queued lens is
    waiting to observe (§10). Read-only; mirrors the signature selection of
    ``resolve_boundary`` and the aggregate-identity attach."""
    from .descriptors import aggregate_signature, hash_build_signature

    out: List = []
    _, _, agg, _ = plan_spine(query.plan)
    agg_sig = aggregate_signature(agg)
    if agg_sig is not None and engine.mode.agg_share != "none":
        existing = engine.agg_index.get(agg_sig)
        if existing is not None and engine._agg_attachable(existing):
            out.append(existing)
    if engine.mode.share_state:
        for j in all_boundaries(query.plan):
            lst = engine.state_index.get(hash_build_signature(j))
            if lst:
                out.append(lst[0])
    return out


def boundary_key(join: HashJoin) -> Tuple[StateSignature, Optional[Conjunction]]:
    """The (signature, build-predicate) pair grafting admission matches on.
    Shared by ``resolve_boundary`` and the §15 batch planner so the two can
    never diverge on what boundary compatibility means."""
    return hash_build_signature(join), Conjunction.from_pred(collect_subtree_pred(join.build))


def coverage_probe(engine, sig: StateSignature, b_q: Optional[Conjunction], demand: int) -> Tuple[bool, int]:
    """Read-only represented-extent probe: what the first live candidate
    under ``sig`` would grant a boundary with build predicate ``b_q`` right
    now, as ``(fully_covered, granted_rows)`` with ``granted_rows`` clamped
    to the boundary's isolated demand. Mirrors the resolve_boundary ladder
    without attaching, installing producers, or rehydrating — the §15 batch
    planner scores cohorts with it."""
    mode = engine.mode
    if not mode.share_state or not mode.allow_represented or b_q is None:
        return False, 0
    candidate = None
    for s in engine.state_index.get(sig, ()):
        candidate = s
        break
    if candidate is None:
        return False, 0
    retained = candidate.retained_attrs
    b_ret = Conjunction({a: c for a, c in b_q.constraints.items() if a in retained})
    b_nonret = Conjunction({a: c for a, c in b_q.constraints.items() if a not in retained})
    allowed = ALL_EXTENTS if not b_nonret.constraints else candidate.allowed_extents_for(b_nonret)
    if not allowed:
        return False, 0
    if candidate.covers_with(b_q, allowed):
        return True, demand
    return False, min(int(candidate.count_granted(allowed, b_ret)), demand)


def _probe_side_table(engine, join: HashJoin):
    scan, _ = build_spine(join)
    return engine.db[scan.table]


def _node_cache_key(node: PlanNode):
    from .plans import strip_pred_subtree
    from .predicates import Conjunction

    conj = Conjunction.from_pred(collect_subtree_pred(node))
    return (strip_pred_subtree(node), conj.key() if conj is not None else id(node))


# ---------------------------------------------------------------------------
# Boundary attachment result
# ---------------------------------------------------------------------------


@dataclass
class Attachment:
    state: SharedHashBuildState
    gate: Gate
    created: bool  # state freshly created (ordinary-plan work)
    producer_member: Optional[Member] = None


# ---------------------------------------------------------------------------
# Algorithm 1 — AdmitBoundary / PartitionStateExtent
# ---------------------------------------------------------------------------


def resolve_boundary(engine, handle, join: HashJoin) -> Attachment:
    """Resolve one stateful boundary of query ``handle`` bottom-up:
    select-or-create the shared state, partition the state-side extent, and
    install producer obligations and the state-readiness gate."""
    qid = handle.qid
    mode = engine.mode
    sig, b_q = boundary_key(join)

    # counters: isolated-plan demand at this boundary
    demand = estimate_demand(engine, join.build)
    engine.counters["demand_rows"] += demand

    # -- CheckLensCompatibility: exact non-predicate identity via signature
    candidate: Optional[SharedHashBuildState] = None
    if mode.share_state:
        for s in engine.state_index.get(sig, ()):  # exact signature match
            candidate = s
            break
        if candidate is None and mode.allow_represented and engine.reuse is not None:
            # reuse plane (§12): no live candidate — a cached artifact under
            # the same signature may rehydrate (cost-gated). The rehydrated
            # state registers under the signature and the ladder below
            # treats it exactly like a never-evicted retained state.
            candidate = engine.reuse.try_rehydrate_hash(engine, handle, sig, b_q, demand)

    # -- Represented extent: proven containment against allowed coverage
    if candidate is not None and mode.allow_represented and b_q is not None:
        retained = candidate.retained_attrs
        b_ret = Conjunction(
            {a: c for a, c in b_q.constraints.items() if a in retained}
        )
        b_nonret = Conjunction(
            {a: c for a, c in b_q.constraints.items() if a not in retained}
        )
        if not b_nonret.constraints:
            allowed = ALL_EXTENTS
        else:
            allowed = candidate.allowed_extents_for(b_nonret)
        # §15 deferred representation: extents cohort-mates registered at
        # this decision step but have not produced yet. Only the batched
        # admission path populates cohort_ctx, so greedy admission never
        # takes this branch.
        pend_mask = np.uint64(0)
        pend_members: List[Member] = []
        pend_conjs: List[Conjunction] = []
        if engine.cohort_ctx is not None:
            for p_eid, p_conj, p_member in engine.cohort_ctx.get(
                candidate.state_id, ()
            ):
                if not b_nonret.constraints or p_conj.implies(b_nonret):
                    pend_mask |= np.uint64(1) << np.uint64(p_eid)
                    pend_members.append(p_member)
                    pend_conjs.append(p_conj)
        if allowed and candidate.covers_with(b_q, allowed):
            # Fully represented: state-ref edge only, gate open now.
            engine.attach_shared(handle, candidate)
            candidate.add_grant(qid, allowed, b_ret)
            engine.counters["represented_rows"] += candidate.count_granted(allowed, b_ret)
            # upstream producer work eliminated by this state-lens obs.
            for up in all_boundaries(join.build):
                d = estimate_demand(engine, up.build)
                engine.counters["demand_rows"] += d
                engine.counters["eliminated_rows"] += d
            gate = Gate(candidate, b_q, allowed)
            gate.owner_qid = qid
            return Attachment(candidate, gate, created=False)
        if pend_mask and candidate.covers_with_pending(b_q, allowed, pend_conjs):
            # Fully represented once the cohort-mates' producers complete:
            # grant the pending provenance bits now, gate on the producers.
            # No producer of our own — this is the §15 win: the narrower
            # member rides the state a wider member is about to build
            # instead of re-delivering its own extent. ``Gate.open``
            # re-proves coverage against the completed extents, so a
            # producer that under-delivers can never unblock us unsoundly.
            engine.attach_shared(handle, candidate)
            candidate.add_grant(qid, allowed | pend_mask, b_ret)
            engine.counters["represented_rows"] += candidate.count_granted(allowed, b_ret)
            for up in all_boundaries(join.build):
                d = estimate_demand(engine, up.build)
                engine.counters["demand_rows"] += d
                engine.counters["eliminated_rows"] += d
            gate = Gate(candidate, b_q, allowed | pend_mask)
            gate.owner_qid = qid
            for p_member in pend_members:
                gate.pending.add(p_member)
                p_member.waiting_gates.append(gate)
            return Attachment(candidate, gate, created=False)
        if allowed:
            # Partially represented: grant what is covered, install a
            # residual producer for the rest (its extent bit joins the
            # allowed set so the gate can open on its completion).
            engine.attach_shared(handle, candidate)
            candidate.add_grant(qid, allowed, b_ret)
            engine.counters["represented_rows"] += candidate.count_granted(allowed, b_ret)
            member, eid = _install_producer(engine, handle, join, candidate, b_q, kind="residual")
            _record_cohort_extent(engine, candidate, eid, b_q, member)
            if eid >= 0:
                gate_allowed = allowed | (np.uint64(1) << np.uint64(eid))
                gate = Gate(candidate, b_q, gate_allowed)
            else:
                # provenance bits exhausted (long-retained state, §10): the
                # residual producer re-delivers every B_q row under the
                # query's own visibility bit, so its completion alone is a
                # sound gate — only coverage-based accounting is lost.
                gate = Gate(candidate, None)
            gate.owner_qid = qid
            gate.pending.add(member)
            member.waiting_gates.append(gate)
            return Attachment(candidate, gate, created=False, producer_member=member)

    # -- Residual-only attachment (no coverage observation)
    if candidate is not None and mode.allow_residual:
        engine.attach_shared(handle, candidate)
        member, eid = _install_producer(engine, handle, join, candidate, b_q, kind="residual")
        _record_cohort_extent(engine, candidate, eid, b_q, member)
        gate = Gate(candidate, None)  # own producer completion suffices
        gate.owner_qid = qid
        gate.pending.add(member)
        member.waiting_gates.append(gate)
        return Attachment(candidate, gate, created=False, producer_member=member)

    # -- QPipe-OSP: merge identical in-flight profiles (no coverage logic)
    if mode.qpipe and candidate is None:
        att = _qpipe_try_merge(engine, handle, join, sig, b_q)
        if att is not None:
            return att

    # -- Ordinary-plan work: fresh state (which becomes shared state itself)
    state = engine.new_hash_state(sig, join, did_domain=_did_domain(engine, join.build))
    state.attach(qid)
    handle.attached_states.append(state)
    if mode.share_state:
        engine.state_index.setdefault(sig, []).append(state)
    member, eid = _install_producer(engine, handle, join, state, b_q, kind="ordinary")
    _record_cohort_extent(engine, state, eid, b_q, member)
    gate = Gate(state, None)
    gate.owner_qid = qid
    gate.pending.add(member)
    member.waiting_gates.append(gate)
    if mode.qpipe:
        engine.qpipe_registry[_qpipe_key(sig, join, b_q)] = (member, state)
    return Attachment(state, gate, created=True, producer_member=member)


def _record_cohort_extent(engine, state, eid: int, b_q, member) -> None:
    """§15: while a batched cohort admission is in flight, expose this
    producer's registered extent to later cohort members so they can attach
    deferred-represented instead of installing duplicate producers."""
    if engine.cohort_ctx is not None and eid >= 0 and b_q is not None:
        engine.cohort_ctx.setdefault(state.state_id, []).append((eid, b_q, member))


def _install_producer(
    engine, handle, join: HashJoin, state: SharedHashBuildState, b_q, kind: str
) -> Tuple[Member, int]:
    """Install residual/ordinary producer edges: a member on the (shared)
    build pipeline targeting ``state``, gated on its own upstream
    state-refs (AdmissibleProducerPaths — recursion admits the upstream
    boundaries first)."""
    scan, inner_joins = build_spine(join.build)
    inner_ops: List[ProbeOp] = []
    inner_gates: List[Gate] = []
    stage_filters: Dict[int, List] = {}
    for stage, ij in enumerate(inner_joins):
        att = resolve_boundary(engine, handle, ij)  # bottom-up recursion
        inner_gates.append(att.gate)
        out_names = ij.payload_as if ij.payload_as is not None else ij.payload
        inner_ops.append(
            ProbeOp(att.state, tuple(ij.probe_keys), tuple(ij.payload), tuple(out_names))
        )
        from .predicates import TRUE

        if ij.post_filter is not TRUE:
            stage_filters.setdefault(stage, []).append(ij.post_filter)

    pkey = ("build", scan.table, tuple(op.state.state_id for op in inner_ops), state.state_id)
    if not engine.mode.share_pipelines:
        pkey = pkey + (handle.qid,)
    pipeline = engine.pipelines.get(pkey)
    if pipeline is None:
        pipeline = Pipeline(
            engine.next_pipeline_id(),
            pkey,
            engine.get_scan(scan.table, handle.qid),
            inner_ops,
            build_target=BuildTarget(state, tuple(join.build_keys)),
            compose_did=bool(inner_ops),
            counters=engine.counters,
        )
        engine.pipelines[pkey] = pipeline

    eid = state.register_extent(b_q)
    member = Member(
        engine.next_member_id(),
        handle.qid,
        scan.pred,
        inner_gates,
        sink=None,
        stage_filters=stage_filters,
        kind=kind,
        eid=eid,
        conj=b_q,
    )
    member.waiting_gates = []
    member.pipeline = pipeline
    pipeline.add_member(member)
    handle.members.append(member)
    return member, eid


def _did_domain(engine, subtree: PlanNode) -> int:
    if isinstance(subtree, Scan):
        return engine.db[subtree.table].nrows
    if isinstance(subtree, HashJoin):
        scan, joins = build_spine(subtree)
        d = engine.db[scan.table].nrows
        for j in joins:
            d *= _did_domain(engine, j.build)
        return d
    raise TypeError(subtree)


# ---------------------------------------------------------------------------
# QPipe-OSP merge: identical operator profiles, in-flight, zero progress
# ---------------------------------------------------------------------------


def _qpipe_key(sig: StateSignature, join: HashJoin, b_q):
    from .plans import strip_pred_subtree

    pred_key = b_q.key() if b_q is not None else repr(collect_subtree_pred(join.build))
    return (sig, pred_key)


def _qpipe_try_merge(engine, handle, join, sig, b_q) -> Optional[Attachment]:
    entry = engine.qpipe_registry.get(_qpipe_key(sig, join, b_q))
    if entry is None:
        return None
    member, state = entry
    if member.done or member.received > 0 or state.n_entries > 0:
        return None  # OSP window closed — only near-simultaneous arrivals merge
    # Merge: the existing physical producer also tags this query's bit.
    engine.attach_shared(handle, state)
    member.beneficiaries.append(handle.qid)
    gate = Gate(state, None)
    gate.owner_qid = handle.qid
    gate.pending.add(member)
    member.waiting_gates.append(gate)
    engine.counters["qpipe_merges"] += 1
    return Attachment(state, gate, created=False, producer_member=None)
