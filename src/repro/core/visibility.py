"""Per-query visibility metadata (§4.2) and packed-mask primitives (§11).

Rows and state entries carry per-query visibility as packed uint64 bitmasks.
A per-state slot allocator maps attached query ids to bit positions; slots
are recycled on query completion. One physical row/entry therefore serves
every attached query whose bit (or extent-scoped grant, see state.py) is set
— the runtime never materializes per-query copies.

The member-major data plane (DESIGN.md §11) additionally needs two
member-count-independent bulk operations on packed word columns:

* ``translate_bits`` — map each row's word through an arbitrary
  slot -> uint64 target table (state-slot lens words to pipeline ownership
  bits, pipeline bits to beneficiary visibility masks). Implemented as
  byte-wise table lookups: 8 gathers per row regardless of how many slots
  are live, with empty byte lanes skipped so small waves pay ~1 gather.
* ``slot_popcounts`` — per-slot set-bit counts of a word column via byte
  histograms × a bit matrix, replacing one popcount pass per member.
"""

from __future__ import annotations

import sys
from typing import Dict, List, Optional

import numpy as np

MAX_SLOTS = 64

U64_0 = np.uint64(0)
_U8_MASK = np.uint64(0xFF)

#: [256, 8] — bit i of byte value v (shared by the translate/popcount passes)
_BYTE_BITS = ((np.arange(256, dtype=np.int64)[:, None] >> np.arange(8)) & 1)
_BYTE_BITS_BOOL = _BYTE_BITS.astype(bool)


def translation_table(target: np.ndarray) -> np.ndarray:
    """Byte-lookup tables for :func:`translate_bits`.

    ``target`` is a ``uint64[64]`` map from slot to an arbitrary output
    word; the result ``tables[b][v]`` ORs ``target[8b + i]`` over the bits
    ``i`` set in byte value ``v``, so a full 64-bit word translates in 8
    byte gathers. Build cost is O(8 × 256), paid once per member wave."""
    tables = np.zeros((8, 256), dtype=np.uint64)
    for b in range(8):
        seg = target[8 * b : 8 * b + 8]
        if not seg.any():
            continue
        tables[b] = np.bitwise_or.reduce(
            np.where(_BYTE_BITS_BOOL, seg[None, :], U64_0), axis=1
        )
    return tables


def translate_bits(words: np.ndarray, tables: np.ndarray) -> np.ndarray:
    """Per-row OR of the targets of every bit set in ``words``.

    One byte-table gather per non-empty lane — member-count independent
    (the per-member alternative is one shift/AND/OR triple per member)."""
    out = None
    for b in range(8):
        lane = tables[b]
        if not lane.any():
            continue
        idx = ((words >> np.uint64(8 * b)) & _U8_MASK).astype(np.intp)
        out = lane[idx] if out is None else out | lane[idx]
    if out is None:
        return np.zeros(len(words), dtype=np.uint64)
    return out


_LITTLE_ENDIAN = sys.byteorder == "little"


def unpack_slots(words: np.ndarray, slots: np.ndarray) -> np.ndarray:
    """Bool matrix [len(slots), len(words)] of the selected slot bits —
    one byte-unpack pass regardless of how many slots are asked for
    (big-endian hosts fall back to one shift pass per slot)."""
    if _LITTLE_ENDIAN:
        unpacked = np.unpackbits(
            words.view(np.uint8).reshape(-1, 8), axis=1, bitorder="little"
        )  # [rows, 64], column j = bit j of the uint64 word
        return unpacked.T[slots] != 0
    out = np.empty((len(slots), len(words)), dtype=bool)
    for i, s in enumerate(slots):
        out[i] = (words >> np.uint64(s)) & np.uint64(1) != 0
    return out


def slot_popcounts(words: np.ndarray) -> np.ndarray:
    """Set-bit count per slot over a packed word column, in one
    member-count-independent pass (byte histograms × bit matrix)."""
    out = np.zeros(MAX_SLOTS, dtype=np.int64)
    for b in range(8):
        vals = ((words >> np.uint64(8 * b)) & _U8_MASK).astype(np.intp)
        hist = np.bincount(vals, minlength=256)
        out[8 * b : 8 * b + 8] = hist @ _BYTE_BITS
    return out


class SlotAllocator:
    """query id -> bit slot, with recycling. Capacity 64 concurrent queries
    per state; the engine serializes admission beyond that (never reached in
    the evaluated workloads — 32 clients max)."""

    def __init__(self):
        self._slot_of: Dict[int, int] = {}
        self._free: List[int] = list(range(MAX_SLOTS - 1, -1, -1))

    def get(self, qid: int) -> int:
        s = self.try_get(qid)
        if s is None:
            raise RuntimeError("visibility slots exhausted (>64 concurrent queries on one state)")
        return s

    def try_get(self, qid: int) -> Optional[int]:
        """Slot if one is available, else None — the packed-word overflow
        signal: the caller must route the owner through a slow lane that
        never drops rows (runtime.py overflow members, §11)."""
        if qid in self._slot_of:
            return self._slot_of[qid]
        if not self._free:
            return None
        s = self._free.pop()
        self._slot_of[qid] = s
        return s

    def peek(self, qid: int):
        return self._slot_of.get(qid)

    def release(self, qid: int) -> None:
        s = self._slot_of.pop(qid, None)
        if s is not None:
            self._free.append(s)

    def mask(self, qid: int) -> np.uint64:
        return np.uint64(1) << np.uint64(self.get(qid))

    def attached(self) -> List[int]:
        return list(self._slot_of)


def split_words(words: np.ndarray):
    """Split packed uint64 words into (lo, hi) uint32 halves.

    The device data plane (DESIGN.md §13) carries every 64-bit lens /
    ownership / provenance word as two uint32 lanes — TPU-native width, no
    64-bit integer support required in-kernel — so the full 64-slot
    ``SlotAllocator`` space fits the kernel path."""
    w = np.asarray(words, dtype=np.uint64)
    lo = (w & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (w >> np.uint64(32)).astype(np.uint32)
    return lo, hi


def join_words(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Inverse of :func:`split_words`."""
    return (np.asarray(hi, np.uint64) << np.uint64(32)) | np.asarray(lo, np.uint64)


def bit_of(mask: np.ndarray, slot: int) -> np.ndarray:
    """Extract one query's visibility bit from a packed mask array."""
    return (mask >> np.uint64(slot)) & np.uint64(1) != 0


def or_bit(mask: np.ndarray, rows: np.ndarray, slot: int) -> None:
    """Set one query's bit on the selected rows, in place."""
    np.bitwise_or.at(mask, rows, np.uint64(1) << np.uint64(slot))
