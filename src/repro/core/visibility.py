"""Per-query visibility metadata (§4.2).

Rows and state entries carry per-query visibility as packed uint64 bitmasks.
A per-state slot allocator maps attached query ids to bit positions; slots
are recycled on query completion. One physical row/entry therefore serves
every attached query whose bit (or extent-scoped grant, see state.py) is set
— the runtime never materializes per-query copies.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

MAX_SLOTS = 64


class SlotAllocator:
    """query id -> bit slot, with recycling. Capacity 64 concurrent queries
    per state; the engine serializes admission beyond that (never reached in
    the evaluated workloads — 32 clients max)."""

    def __init__(self):
        self._slot_of: Dict[int, int] = {}
        self._free: List[int] = list(range(MAX_SLOTS - 1, -1, -1))

    def get(self, qid: int) -> int:
        if qid in self._slot_of:
            return self._slot_of[qid]
        if not self._free:
            raise RuntimeError("visibility slots exhausted (>64 concurrent queries on one state)")
        s = self._free.pop()
        self._slot_of[qid] = s
        return s

    def peek(self, qid: int):
        return self._slot_of.get(qid)

    def release(self, qid: int) -> None:
        s = self._slot_of.pop(qid, None)
        if s is not None:
            self._free.append(s)

    def mask(self, qid: int) -> np.uint64:
        return np.uint64(1) << np.uint64(self.get(qid))

    def attached(self) -> List[int]:
        return list(self._slot_of)


def bit_of(mask: np.ndarray, slot: int) -> np.ndarray:
    """Extract one query's visibility bit from a packed mask array."""
    return (mask >> np.uint64(slot)) & np.uint64(1) != 0


def or_bit(mask: np.ndarray, rows: np.ndarray, slot: int) -> None:
    """Set one query's bit on the selected rows, in place."""
    np.bitwise_or.at(mask, rows, np.uint64(1) << np.uint64(slot))
