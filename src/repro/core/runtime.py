"""Operational runtime objects for state-centric execution.

The shared execution DAG (§5.1) is realized by three kinds of live objects:

* ``ScanNode`` — a cyclic shared scan over one base table (§4.4). One
  cursor; every attached pipeline receives each emitted morsel. Paths
  attach mid-cycle and complete when the cursor wraps back to their start.
* ``Pipeline`` — a producer or consumer path: source scan -> zero or more
  hash-probe ops -> sink (build into shared state / per-query aggregates).
  One physical pipeline serves many queries ("members"): per-row packed
  visibility bitmasks route every row to exactly the queries whose
  predicates and state lenses admit it (§4.2, §4.6).
* ``Gate`` — a state-readiness gate (§5.3) guarding a member's activation:
  open when the selected state covers the member's assigned extent and all
  residual producer members installed for it have completed.

Morsels are the TPU adaptation of the paper's row fragments (DESIGN.md §2):
every step is a vectorized column-batch operation. The per-member source
predicates of one pipeline are fused into a single SoA bound-check pass
(members × attrs lo/hi matrices -> packed visibility bitmask), and
single-member probes route through the backend's fused-lens kernel so
visibility resolves in-kernel (DESIGN.md §8).

Partition-parallel execution (DESIGN.md §9): each scan splits its morsel
cycle into P contiguous partition shards with independent cyclic cursors;
the schedulable unit becomes (scan × partition), and members account
delivery per partition (``part_received`` / ``part_need``) so a shard that
wraps early for one member never re-delivers to it. One logical ScanNode
per table is preserved, so grafting/admission is partition-blind; P == 1
degenerates to the seed single-cursor scan exactly.

Member / Pipeline / ScanNode ids are engine-scoped (allocated by the owning
GraftEngine), so repeated engine constructions are isolated — ids never
leak across sessions.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..relational.table import Table
from .plans import AggSpec, expr_eval
from .predicates import AttrConstraint, Conjunction, Pred, TRUE, evaluate
from .state import ALL_EXTENTS, SharedAggregateState, SharedHashBuildState
from .visibility import SlotAllocator, bit_of

U64_1 = np.uint64(1)
U64_0 = np.uint64(0)


def _member_conj(m: "Member"):
    """Cached canonical conjunction of a member's source predicate (None
    when outside the prover fragment)."""
    if not hasattr(m, "_conj_cache"):
        m._conj_cache = Conjunction.from_pred(m.pred)
    return m._conj_cache


# ---------------------------------------------------------------------------
# Key encoding: composite equi-join keys -> single int64 (mixed radix)
# ---------------------------------------------------------------------------


KEY_RADIX = np.int64(1 << 21)  # per-component domain bound (asserted in datagen scale)


def encode_keys(cols: Dict[str, np.ndarray], attrs: Sequence[str]) -> np.ndarray:
    code = np.asarray(cols[attrs[0]], dtype=np.int64)
    for a in attrs[1:]:
        code = code * KEY_RADIX + np.asarray(cols[a], dtype=np.int64)
    return code


# ---------------------------------------------------------------------------
# Fused multi-member source filter (DESIGN.md §8)
# ---------------------------------------------------------------------------


def member_bound_matrices(members: Sequence["Member"]):
    """SoA bound matrices for the fused source-predicate pass.

    A member fuses when its predicate canonicalizes into per-attribute
    intervals (membership sets of size one become point intervals;
    exclusive bounds tighten by one float64 ulp so a single inclusive
    compare is exact). Returns ``(attrs, lo[M,A], hi[M,A], fused, slow)``
    where ``slow`` members fall back to per-member evaluation."""
    fused: List["Member"] = []
    slow: List["Member"] = []
    per_member: List[Dict[str, Tuple[float, float]]] = []
    for m in members:
        conj = _member_conj(m)
        if conj is None:
            slow.append(m)
            continue
        bounds: Dict[str, Tuple[float, float]] = {}
        ok = True
        for attr, c in conj.constraints.items():
            if c.members is not None and len(c.members) != 1:
                ok = False
                break
            lo = c.lo if c.lo_inc else np.nextafter(c.lo, math.inf)
            hi = c.hi if c.hi_inc else np.nextafter(c.hi, -math.inf)
            if c.members is not None:
                v = next(iter(c.members))
                lo, hi = max(lo, v), min(hi, v)
            bounds[attr] = (lo, hi)
        if not ok:
            slow.append(m)
            continue
        fused.append(m)
        per_member.append(bounds)
    attrs = sorted({a for b in per_member for a in b})
    lo = np.full((len(fused), len(attrs)), -math.inf)
    hi = np.full((len(fused), len(attrs)), math.inf)
    for i, bounds in enumerate(per_member):
        for j, a in enumerate(attrs):
            if a in bounds:
                lo[i, j], hi[i, j] = bounds[a]
    return attrs, lo, hi, fused, slow


def fused_bound_bits(
    n: int,
    cols: Dict[str, np.ndarray],
    attrs: Sequence[str],
    lo: np.ndarray,
    hi: np.ndarray,
    bitvals: np.ndarray,
) -> np.ndarray:
    """One SoA pass: per-row packed visibility bitmask over all fused
    members — ``bits[r]`` ORs ``bitvals[m]`` for every member whose bounds
    admit row r on every attribute. Member-major layout keeps every
    compare a contiguous scalar-bound sweep (row-major broadcasting is
    ~3x slower: stride-0 inner loops and (rows, members) temporaries)."""
    m = len(bitvals)
    if not m:
        return np.zeros(n, dtype=np.uint64)
    ok = np.ones((m, n), dtype=bool)
    for j, a in enumerate(attrs):
        col = cols[a]
        np.logical_and(ok, col >= lo[:, j, None], out=ok)
        np.logical_and(ok, col <= hi[:, j, None], out=ok)
    bits = np.zeros(n, dtype=np.uint64)
    for i in range(m):
        bits |= ok[i] * bitvals[i]
    return bits


# ---------------------------------------------------------------------------
# Gates (§5.3)
# ---------------------------------------------------------------------------


class Gate:
    """State-readiness gate for one admitted state-ref edge r=(q, b, v).

    open iff stateReady(S, r, R): the selected state covers the assigned
    extent (coverage restricted to the grant's allowed provenance extents
    when the attachment is represented) and every residual producer member
    installed for this edge has completed."""

    def __init__(
        self,
        state: SharedHashBuildState,
        conj: Optional[Conjunction],
        allowed_emask: Optional[np.uint64] = None,
    ):
        self.state = state
        self.conj = conj
        self.allowed_emask = allowed_emask
        self.pending: set = set()  # producer Member objects still owed
        self._open_cache = False

    def open(self) -> bool:
        if self._open_cache:
            return True
        if self.pending:
            return False
        if self.conj is not None and self.allowed_emask is not None:
            if not self.state.covers_with(self.conj, self.allowed_emask):
                return False
        self._open_cache = True
        return True

    def partition_frontier(self) -> Tuple[int, int]:
        """(delivered, total) scan-partition units across this gate's
        pending producers — the per-partition visibility frontier of §9.
        A closed gate at (k, n) has k of n producer shards fully delivered;
        (n, n) means only the coverage check remains. Open gates report
        their last frontier as fully delivered."""
        done = total = 0
        for m in self.pending:
            d, t = self.state.extent_partition_frontier(m.eid)
            # a producer that has not begun reports its shard count as owed
            if t == 0 and m.part_need is not None:
                t = len(m.part_need)
            done += d
            total += t
        return (done, total)


class AggGate:
    """Readiness of a shared aggregate state under exact identity (§4.5)."""

    def __init__(self, agg_state: SharedAggregateState):
        self.agg_state = agg_state

    def open(self) -> bool:
        return self.agg_state.complete


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------


@dataclass
class BuildTarget:
    """Pipeline-level sink: insert produced rows into a shared hash-build
    state, with visibility + extent provenance combined across members."""

    state: SharedHashBuildState
    key_attrs: Tuple[str, ...]


@dataclass
class AggSink:
    """Per-member sink: fold the member's visible rows into (possibly
    shared) aggregate state."""

    agg_state: SharedAggregateState
    group_keys: Tuple[str, ...]
    aggs: Tuple[AggSpec, ...]


# ---------------------------------------------------------------------------
# Members
# ---------------------------------------------------------------------------


class Member:
    """One query's participation in a pipeline (an active node-query pair in
    Algorithm 2's sense). ``beneficiaries`` supports QPipe-style merged
    identical profiles: one physical member tagging several queries.
    ``mid`` is allocated by the owning engine (no class-counter leaks)."""

    def __init__(
        self,
        mid: int,
        qid: int,
        pred: Pred,
        gates: List[Gate],
        sink: Optional[AggSink] = None,
        stage_filters: Optional[Dict[int, List[Pred]]] = None,
        kind: str = "main",  # 'main' | 'ordinary' | 'residual'
        eid: int = -1,
        conj: Optional[Conjunction] = None,
        beneficiaries: Optional[List[int]] = None,
    ):
        self.mid = mid
        self.qid = qid
        self.pred = pred
        self.gates = gates
        self.sink = sink
        self.stage_filters = stage_filters or {}
        self.kind = kind
        self.eid = eid
        self.conj = conj
        self.beneficiaries = beneficiaries or [qid]

        self.active = False
        self.done = False
        self.received = 0
        self.need = 0
        # per-partition delivery accounting (set at activation; §9): the
        # member finishes partition p after part_need[p] morsels from shard
        # p, and finishes overall when received reaches need (their sum)
        self.part_received: Optional[np.ndarray] = None
        self.part_need: Optional[np.ndarray] = None
        self.t_activated = 0.0  # activation barrier time (worker-clock merge)
        self.slot = -1  # pipeline-local bit slot
        self.rows_sunk = 0
        self.waiting_gates: List[Gate] = []  # gates whose pending set holds us
        self.pipeline: Optional["Pipeline"] = None

    @property
    def bitval(self) -> np.uint64:
        return U64_1 << np.uint64(self.slot)

    def activatable(self) -> bool:
        return (not self.active) and (not self.done) and all(g.open() for g in self.gates)

    def pending_in(self, part: int) -> bool:
        """Still owed morsels from scan partition ``part``."""
        if self.part_received is None:
            return True
        return self.part_received[part] < self.part_need[part]


# ---------------------------------------------------------------------------
# Probe op
# ---------------------------------------------------------------------------


@dataclass
class ProbeOp:
    state: SharedHashBuildState
    probe_attrs: Tuple[str, ...]
    payload: Tuple[str, ...]  # entry attrs (canonical names in the state)
    out_names: Tuple[str, ...] = ()  # names in the row stream (renames)

    def __post_init__(self):
        if not self.out_names:
            self.out_names = tuple(self.payload)


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


class Pipeline:
    def __init__(
        self,
        pid: int,
        key,
        source: "ScanNode",
        ops: List[ProbeOp],
        build_target: Optional[BuildTarget] = None,
        compose_did: bool = False,
    ):
        self.pid = pid
        self.key = key
        self.source = source
        self.ops = ops
        self.build_target = build_target
        self.compose_did = compose_did
        self.members: List[Member] = []
        self.slots = SlotAllocator()
        # per-wave bound-matrix cache, keyed by the active member set (with
        # partitions the set differs per shard near completion)
        self._filter_plans: Dict[tuple, tuple] = {}
        source.attach(self)

    # -- membership ---------------------------------------------------------
    def add_member(self, m: Member) -> None:
        m.slot = self.slots.get(m.mid)
        self.members.append(m)

    def active_members(self) -> List[Member]:
        return [m for m in self.members if m.active and not m.done]

    def active_members_for(self, part: int) -> List[Member]:
        """Active members still owed morsels from scan partition ``part``."""
        return [m for m in self.members if m.active and not m.done and m.pending_in(part)]

    def progress(self) -> int:
        return max((m.received for m in self.members), default=0)

    def all_done(self) -> bool:
        return all(m.done for m in self.members)

    # -- execution ----------------------------------------------------------
    def _source_bits(self, act: List[Member], cols, n: int, engine) -> np.ndarray:
        """Per-member source predicates -> packed row bitmask, via one fused
        SoA bound-check pass (per-wave matrices cached on the pipeline);
        members outside the interval fragment evaluate individually."""
        key = tuple((m.mid, m.slot) for m in act)
        plan = self._filter_plans.get(key)
        if plan is None:
            attrs, lo, hi, fused, slow = member_bound_matrices(act)
            bitvals = np.array([m.bitval for m in fused], dtype=np.uint64)
            plan = (attrs, lo, hi, bitvals, fused, slow)
            if len(self._filter_plans) > 64:  # bounded: waves churn members
                self._filter_plans.clear()
            self._filter_plans[key] = plan
        attrs, lo, hi, bitvals, fused, slow = plan
        bits = fused_bound_bits(n, cols, attrs, lo, hi, bitvals)
        engine.counters["fused_filter_rows"] += n * len(fused)
        for m in slow:
            mask = evaluate(m.pred, cols)
            bits |= np.where(mask, m.bitval, U64_0)
        return bits

    def process(
        self, engine, cols: Dict[str, np.ndarray], row_ids: np.ndarray, part: int = 0
    ) -> float:
        """Run one morsel of scan partition ``part`` through the pipeline
        for every member still owed that shard. Returns the modeled cost
        (seconds) of the work performed."""
        act = self.active_members_for(part)
        if not act:
            return 0.0
        n = len(row_ids)
        cm = engine.cost_model
        cost = 0.0

        bits = self._source_bits(act, cols, n, engine)
        cost += cm["filter"] * n * len(act)

        keep = np.flatnonzero(bits)
        cols = {k: v[keep] for k, v in cols.items()}
        bits = bits[keep]
        did = row_ids[keep].astype(np.int64)

        # hash-probe ops (§4.3: one physical probe step serves all queries
        # whose visibility check succeeds)
        backend = engine.backend
        for stage, op in enumerate(self.ops):
            if len(did) == 0:
                break
            keycodes = encode_keys(cols, op.probe_attrs)
            # single-member probes resolve the state lens in-kernel when the
            # backend can serve it; the runtime then skips visible_mask
            lens_fused = False
            if backend is not None:
                if len(act) == 1:
                    probe_visible = getattr(backend, "probe_visible", None)
                    if probe_visible is not None:
                        fused_pair = probe_visible(op.state, keycodes, act[0].qid)
                        if fused_pair is not None:
                            probe_idx, entry_idx = fused_pair
                            lens_fused = True
                            engine.counters["kernel_lens_probes"] += 1
                if not lens_fused:
                    probe_idx, entry_idx = backend.probe(op.state, keycodes)
            else:
                probe_idx, entry_idx = op.state.probe(keycodes)
            cost += cm["probe"] * len(keycodes) + cm["match"] * len(probe_idx)
            engine.counters["probe_rows"] += len(keycodes)
            bits_in = bits[probe_idx]
            new_bits = np.zeros(len(probe_idx), dtype=np.uint64)
            for m in act:
                if lens_fused:
                    bm = bit_of(bits_in, m.slot)
                else:
                    vis = op.state.visible_mask(m.qid, entry_idx)
                    bm = bit_of(bits_in, m.slot) & vis
                new_bits |= np.where(bm, m.bitval, U64_0)
            cols = {k: v[probe_idx] for k, v in cols.items()}
            for a, out in zip(op.payload, op.out_names):
                cols[out] = op.state.cols[a].data[entry_idx]
            if self.compose_did:
                did = did[probe_idx] * np.int64(op.state.did_domain) + op.state.did.data[entry_idx]
            else:
                did = did[probe_idx]
            bits = new_bits
            # member post-join filters at this stage
            for m in act:
                for p in m.stage_filters.get(stage, ()):  # e.g. Q5 ColEq
                    bm = bit_of(bits, m.slot) & evaluate(p, cols)
                    bits = (bits & ~m.bitval) | np.where(bm, m.bitval, U64_0)
            keep = np.flatnonzero(bits)
            if len(keep) != len(bits):
                cols = {k: v[keep] for k, v in cols.items()}
                did = did[keep]
                bits = bits[keep]

        # sinks
        if self.build_target is not None and len(did) > 0:
            bt = self.build_target
            vismask = np.zeros(len(did), dtype=np.uint64)
            emask = np.zeros(len(did), dtype=np.uint64)
            member_rows: List[Tuple[Member, int]] = []
            for m in act:
                sel = bit_of(bits, m.slot)
                nsel = int(sel.sum())
                if nsel:
                    for b in m.beneficiaries:
                        vismask[sel] |= bt.state.slots.mask(b)
                    if m.eid >= 0:
                        emask[sel] |= U64_1 << np.uint64(m.eid)
                member_rows.append((m, nsel))
            any_rows = vismask != 0
            idx = np.flatnonzero(any_rows)
            if len(idx):
                keycodes = encode_keys(cols, bt.key_attrs)
                ins, mrk = bt.state.insert_or_mark(
                    did[idx],
                    keycodes[idx],
                    {a: cols[a][idx] for a in bt.state.retained_attrs},
                    vismask[idx],
                    emask[idx],
                )
                cost += cm["insert"] * ins + cm["mark"] * mrk
            for m, nsel in member_rows:
                m.rows_sunk += nsel
                key = "residual_build_rows" if m.kind == "residual" else "ordinary_build_rows"
                engine.counters[key] += nsel * len(m.beneficiaries)
        else:
            for m in act:
                if m.sink is None:
                    continue
                sel = bit_of(bits, m.slot)
                nsel = int(sel.sum())
                if nsel == 0:
                    continue
                sink = m.sink
                scols = {k: v[sel] for k, v in cols.items()}
                key_cols = [scols[k] for k in sink.group_keys]
                vals = [
                    expr_eval(a.expr, scols) if a.expr is not None else None
                    for a in sink.aggs
                ]
                vals = [
                    np.broadcast_to(np.asarray(v, dtype=np.float64), (nsel,))
                    if v is not None
                    else None
                    for v in vals
                ]
                sink.agg_state.update(
                    key_cols,
                    vals,
                    nsel,
                    segment_sum=backend.segment_sum if backend is not None else None,
                    part=part,
                )
                m.rows_sunk += nsel
                cost += cm["agg"] * nsel
                engine.counters["agg_rows"] += nsel
        # morsel accounting (per partition, §9)
        finished: List[Member] = []
        for m in act:
            m.received += 1
            if m.part_received is not None:
                m.part_received[part] += 1
                if m.part_received[part] >= m.part_need[part]:
                    engine.on_member_part_finished(self, m, part)
            if m.received >= m.need:
                m.done = True
                m.active = False
                finished.append(m)
        for m in finished:
            engine.on_member_finished(self, m)
        return cost


# ---------------------------------------------------------------------------
# Scan node (§4.4 shared cyclic scans)
# ---------------------------------------------------------------------------


class ScanNode:
    """One shared cyclic scan, split into ``n_partitions`` contiguous
    morsel-range shards with independent cyclic cursors (§9). The node
    keeps ONE logical scan identity per table — attachment, zone maps, and
    grafting see a single scan; only delivery is sharded."""

    def __init__(
        self,
        sid: int,
        table: Table,
        morsel_size: int,
        zone_maps: bool = False,
        n_partitions: int = 1,
    ):
        self.sid = sid
        self.table = table
        self.morsel_size = morsel_size
        self.n_morsels = max(1, math.ceil(table.nrows / morsel_size))
        p = max(1, min(int(n_partitions), self.n_morsels))
        self.n_partitions = p
        base, rem = divmod(self.n_morsels, p)
        self.part_counts = np.array(
            [base + (1 if i < rem else 0) for i in range(p)], dtype=np.int64
        )
        self.part_starts = np.concatenate(([0], np.cumsum(self.part_counts)[:-1]))
        # per-partition cyclic cursor (absolute morsel index within the shard)
        self.cursors = [int(s) for s in self.part_starts]
        self.pipelines: List[Pipeline] = []
        self.row_bytes = table.nbytes() / max(table.nrows, 1)
        self.zone_maps = zone_maps
        self._zone_cache: Optional[Tuple[tuple, np.ndarray]] = None

    @property
    def cursor(self) -> int:
        """Partition-0 cursor (seed-compatible view for P == 1)."""
        return self.cursors[0]

    def attach(self, p: Pipeline) -> None:
        self.pipelines.append(p)

    def has_active_work(self) -> bool:
        return any(p.active_members() for p in self.pipelines)

    def _wave_possible(self) -> np.ndarray:
        """Beyond-paper zone-map skipping, hoisted per activation wave: one
        vectorized pass over ALL morsels' [min,max] zones per distinct set
        of active members, instead of per-morsel per-member re-derivation.
        ``possible[i]`` is False only when no active member's canonical
        predicate can match morsel i."""
        act = [m for p in self.pipelines for m in p.active_members()]
        key = tuple(m.mid for m in act)
        cached = self._zone_cache
        if cached is not None and cached[0] == key:
            return cached[1]
        zm = self.table.zone_map(self.morsel_size)
        possible = np.zeros(self.n_morsels, dtype=bool)
        for m in act:
            conj = _member_conj(m)
            if conj is None:
                possible[:] = True  # unprovable predicate -> must read
                break
            ok = np.ones(self.n_morsels, dtype=bool)
            for attr, c in conj.constraints.items():
                if attr not in zm:
                    continue
                mins, maxs = zm[attr]
                if c.lo != -math.inf:
                    ok &= (maxs > c.lo) if not c.lo_inc else (maxs >= c.lo)
                if c.hi != math.inf:
                    ok &= (mins < c.hi) if not c.hi_inc else (mins <= c.hi)
                if c.members is not None:
                    anym = np.zeros(self.n_morsels, dtype=bool)
                    for v in c.members:
                        anym |= (mins <= v) & (maxs >= v)
                    ok &= anym
                if not ok.any():
                    break
            possible |= ok
            if possible.all():
                break
        self._zone_cache = (key, possible)
        return possible

    def _bump_cursor(self, part: int) -> None:
        lo = int(self.part_starts[part])
        self.cursors[part] = lo + (self.cursors[part] + 1 - lo) % int(self.part_counts[part])

    def advance(self, engine, part: int = 0) -> float:
        """Emit partition ``part``'s next morsel to every attached pipeline
        with members still owed that shard. Physical read counted once
        (shared scan)."""
        idx = self.cursors[part]
        if self.zone_maps and not self._wave_possible()[idx]:
            engine.counters["morsels_skipped"] += 1
            cost = engine.cost_model["scan"] * 8  # zone check, not a read
            # the morsel still counts toward every member's delivery cycle
            # (zero rows pass their filters by construction)
            for p in list(self.pipelines):
                finished = []
                for m in p.active_members_for(part):
                    m.received += 1
                    if m.part_received is not None:
                        m.part_received[part] += 1
                        if m.part_received[part] >= m.part_need[part]:
                            engine.on_member_part_finished(p, m, part)
                    if m.received >= m.need:
                        m.done = True
                        m.active = False
                        finished.append(m)
                for m in finished:
                    engine.on_member_finished(p, m)
            self._bump_cursor(part)
            return cost
        start = idx * self.morsel_size
        cols = self.table.morsel(start, self.morsel_size)
        n = len(next(iter(cols.values())))
        row_ids = np.arange(start, start + n, dtype=np.int64)

        engine.counters["scan_rows"] += n
        engine.counters["scan_bytes"] += n * self.row_bytes
        cost = engine.cost_model["scan"] * n

        for p in list(self.pipelines):
            cost += p.process(engine, cols, row_ids, part)
        self._bump_cursor(part)
        return cost

    def detach(self, p: Pipeline) -> None:
        if p in self.pipelines:
            self.pipelines.remove(p)
