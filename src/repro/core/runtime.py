"""Operational runtime objects for state-centric execution.

The shared execution DAG (§5.1) is realized by three kinds of live objects:

* ``ScanNode`` — a cyclic shared scan over one base table (§4.4). One
  cursor; every attached pipeline receives each emitted morsel. Paths
  attach mid-cycle and complete when the cursor wraps back to their start.
* ``Pipeline`` — a producer or consumer path: source scan -> zero or more
  hash-probe ops -> sink (build into shared state / per-query aggregates).
  One physical pipeline serves many queries ("members"): per-row packed
  visibility bitmasks route every row to exactly the queries whose
  predicates and state lenses admit it (§4.2, §4.6).
* ``Gate`` — a state-readiness gate (§5.3) guarding a member's activation:
  open when the selected state covers the member's assigned extent and all
  residual producer members installed for it have completed.

Morsels are the TPU adaptation of the paper's row fragments (DESIGN.md §2):
every step is a vectorized column-batch operation. The data plane is
*member-major and mask-packed end to end* (DESIGN.md §11): each morsel
carries one ``uint64`` per-row ownership word through every stage, and
per-stage work is independent of the folded member count —

* source + post-join stage filters fuse into interval matrices
  (``FusedBoundFilter``: SIMD compare sweeps, or per-attribute interval
  stabbing past ~8 members/attr);
* probe-stage semijoin visibility is one gather of the matched entries'
  packed lens words + one byte-table translation into pipeline ownership
  bits (``core.visibility.translate_bits``); single-member probes resolve
  the lens in-kernel, multi-member probes take the ``probe_visible_multi``
  kernel that returns the packed words in one launch;
* build-sink tagging for all beneficiaries is two translations feeding the
  single ``bitwise_or.at`` scatter inside ``insert_or_mark``;
* identically-shaped aggregate sinks fold as a cohort in one segmented
  pass keyed by (group id × member bit), scattering per-member partials
  through cached cohort-gid -> accumulator-id maps (``_CohortIndex``).

The pre-§11 per-member loop is retained verbatim
(``EngineConfig(member_major=False)``) as the differential oracle — the
fused path is bit-identical to it in results, pair streams, counters, and
modeled cost. Members beyond the 64-bit packed word (slot overflow) run a
member-at-a-time slow lane that never drops rows.

Partition-parallel execution (DESIGN.md §9): each scan splits its morsel
cycle into P contiguous partition shards with independent cyclic cursors;
the schedulable unit becomes (scan × partition), and members account
delivery per partition (``part_received`` / ``part_need``) so a shard that
wraps early for one member never re-delivers to it. One logical ScanNode
per table is preserved, so grafting/admission is partition-blind; P == 1
degenerates to the seed single-cursor scan exactly.

Member / Pipeline / ScanNode ids are engine-scoped (allocated by the owning
GraftEngine), so repeated engine constructions are isolated — ids never
leak across sessions.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..relational.table import Table
from .hashindex import MultiKeyIndex
from .plans import AggSpec, expr_attrs, expr_eval
from .predicates import AttrConstraint, Conjunction, Pred, TRUE, evaluate, pred_and
from .state import (
    ALL_EXTENTS,
    GrowArray,
    SharedAggregateState,
    SharedHashBuildState,
    _bincount_segment_sum,
)
from .visibility import (
    SlotAllocator,
    bit_of,
    slot_popcounts,
    translate_bits,
    translation_table,
    unpack_slots,
)

U64_1 = np.uint64(1)
U64_0 = np.uint64(0)

# de Bruijn single-bit -> bit-index table (branch-free vectorized log2 for
# the disjoint-ownership fast path of the cohort fold, §11)
_DB64 = np.uint64(0x03F79D71B4CB0A89)
_DB_SHIFT = np.uint64(58)
_DB_TABLE = np.zeros(64, dtype=np.int64)
for _i in range(64):
    _DB_TABLE[(((1 << _i) * 0x03F79D71B4CB0A89) & ((1 << 64) - 1)) >> 58] = _i


def _member_conj(m: "Member"):
    """Cached canonical conjunction of a member's source predicate (None
    when outside the prover fragment)."""
    if not hasattr(m, "_conj_cache"):
        m._conj_cache = Conjunction.from_pred(m.pred)
    return m._conj_cache


# ---------------------------------------------------------------------------
# Key encoding: composite equi-join keys -> single int64 (mixed radix)
# ---------------------------------------------------------------------------


KEY_RADIX = np.int64(1 << 21)  # per-component domain bound (asserted in datagen scale)


def encode_keys(cols: Dict[str, np.ndarray], attrs: Sequence[str]) -> np.ndarray:
    code = np.asarray(cols[attrs[0]], dtype=np.int64)
    for a in attrs[1:]:
        code = code * KEY_RADIX + np.asarray(cols[a], dtype=np.int64)
    return code


def _backend_probe(backend, state, keycodes, counters):
    """Generic pre-visibility probe, handing the engine counter dict to
    backends that attribute their fallbacks by reason (DESIGN.md §13)."""
    if getattr(backend, "probe_accepts_counters", False):
        return backend.probe(state, keycodes, counters=counters)
    return backend.probe(state, keycodes)


def _chain_grant_bounds(conj: Conjunction):
    """Compile a grant's retained conjunction to closed per-attribute
    intervals for the fused-chain kernel, mirroring ``evaluate_conj``
    EXACTLY (§13): a bound equal to its own infinity is *skipped* there
    regardless of inclusivity, so it compiles to the unconstrained band
    rather than an ulp-tightened one; exclusive finite bounds tighten by
    one float64 ulp (``col > v`` == ``col >= nextafter(v)``); membership
    sets compile only at size one. Returns the constrained-attr tuple
    ``((attr, lo, hi), ...)`` or None when the conjunction is not
    interval-compilable in-kernel (the chain then declines with reason
    ``grants``)."""
    bounds = []
    for attr, c in conj.constraints.items():
        lo, hi = -math.inf, math.inf
        if c.lo != -math.inf:
            if math.isnan(c.lo) or (not c.lo_inc and c.lo == math.inf):
                return None
            lo = c.lo if c.lo_inc else float(np.nextafter(c.lo, math.inf))
        if c.hi != math.inf:
            if math.isnan(c.hi) or (not c.hi_inc and c.hi == -math.inf):
                return None
            hi = c.hi if c.hi_inc else float(np.nextafter(c.hi, -math.inf))
        if c.members is not None:
            if len(c.members) != 1:
                return None
            v = float(next(iter(c.members)))
            if math.isnan(v):
                return None  # isin never admits NaN; not an interval
            lo, hi = max(lo, v), min(hi, v)
        if lo == -math.inf and hi == math.inf:
            continue  # evaluate_conj skips both checks: unconstrained
        bounds.append((attr, lo, hi))
    return tuple(bounds)


# ---------------------------------------------------------------------------
# Fused multi-member source filter (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _bounds_of_conj(conj: Optional[Conjunction]):
    """Per-attribute inclusive [lo, hi] intervals of a canonical
    conjunction (membership sets of size one become point intervals;
    exclusive bounds tighten by one float64 ulp so a single inclusive
    compare is exact), or None when any constraint is not an interval.

    Bounds live in float64 — exact over the engine's float64 column
    domain (every table column, see relational.table). Integer columns
    with values beyond 2^53 would lose the int-exact comparison the
    per-predicate ``evaluate`` path performs; such domains must not fuse.
    """
    if conj is None:
        return None
    bounds: Dict[str, Tuple[float, float]] = {}
    for attr, c in conj.constraints.items():
        if c.members is not None and len(c.members) != 1:
            return None
        lo = c.lo if c.lo_inc else np.nextafter(c.lo, math.inf)
        hi = c.hi if c.hi_inc else np.nextafter(c.hi, -math.inf)
        if c.members is not None:
            v = next(iter(c.members))
            lo, hi = max(lo, v), min(hi, v)
        bounds[attr] = (lo, hi)
    return bounds


def _pack_bound_matrices(pairs):
    """[(member, bounds)] -> (attrs, lo[M, A], hi[M, A]) SoA matrices."""
    attrs = sorted({a for _, b in pairs for a in b})
    lo = np.full((len(pairs), len(attrs)), -math.inf)
    hi = np.full((len(pairs), len(attrs)), math.inf)
    for i, (_, bounds) in enumerate(pairs):
        for j, a in enumerate(attrs):
            if a in bounds:
                lo[i, j], hi[i, j] = bounds[a]
    return attrs, lo, hi


def member_bound_matrices(members: Sequence["Member"]):
    """SoA bound matrices for the fused source-predicate pass.

    A member fuses when its predicate canonicalizes into per-attribute
    intervals. Returns ``(attrs, lo[M,A], hi[M,A], fused, slow)`` where
    ``slow`` members fall back to per-member evaluation."""
    pairs = []
    slow: List["Member"] = []
    for m in members:
        bounds = _bounds_of_conj(_member_conj(m))
        if bounds is None:
            slow.append(m)
        else:
            pairs.append((m, bounds))
    attrs, lo, hi = _pack_bound_matrices(pairs)
    return attrs, lo, hi, [m for m, _ in pairs], slow


def stage_filter_matrices(members: Sequence["Member"], stage: int):
    """Fused bound matrices for the members' post-join filters at one probe
    stage — the §11 generalization of ``member_bound_matrices`` beyond the
    source stage. Members whose filter conjunction does not canonicalize to
    intervals (e.g. Q5's column-equality) fall back to per-member
    evaluation; members with no filter at this stage are ignored."""
    pairs = []
    slow: List["Member"] = []
    for m in members:
        preds = m.stage_filters.get(stage, ())
        if not preds:
            continue
        bounds = _bounds_of_conj(Conjunction.from_pred(pred_and(*preds)))
        if bounds is None:
            slow.append(m)
        else:
            pairs.append((m, bounds))
    attrs, lo, hi = _pack_bound_matrices(pairs)
    return attrs, lo, hi, [m for m, _ in pairs], slow


class FusedBoundFilter:
    """Compiled fused member filter over per-attribute interval bounds.

    Two evaluation strategies, bit-identical on every finite input:

    * **Interval stabbing** (member count >= STAB_FACTOR × attrs): each
      attribute's [lo, hi] intervals become a sorted boundary array + a
      prefix-XOR segment-mask table (closed intervals turned half-open by
      one float64 ulp, so coverage is exact); a row's admitted-member word
      is one ``searchsorted`` + one gather — per-row cost O(log members),
      not O(members). Columns containing non-finite values fall back (NaN
      ordering under searchsorted differs from comparison semantics).
    * **SoA compare matrix** (small member counts / fallback): scalar-bound
      sweeps per attribute with a per-member OR-reduction. SIMD compares
      have a far lower per-element constant than binary search, so the
      crossover grows with the attribute count (measured ~8 members/attr).
    """

    STAB_FACTOR = 8

    __slots__ = ("attrs", "lo", "hi", "bitvals", "_all_mask", "_stab", "_con")

    def __init__(self, attrs: Sequence[str], lo: np.ndarray, hi: np.ndarray,
                 bitvals: np.ndarray):
        self.attrs = tuple(attrs)
        self.lo = lo
        self.hi = hi
        self.bitvals = bitvals
        self._all_mask = np.uint64(np.bitwise_or.reduce(bitvals)) if len(bitvals) else np.uint64(0)
        # which (member, attr) cells carry a real constraint: a member with
        # no constraint on an attribute admits every row of it — including
        # NaN, matching per-predicate ``evaluate`` semantics
        self._con = (lo != -math.inf) | (hi != math.inf)
        self._stab = None
        m = len(bitvals)
        if self.attrs and m >= self.STAB_FACTOR * len(self.attrs):
            stab = []
            for j in range(len(self.attrs)):
                lo_j = lo[:, j]
                # closed [lo, hi] == half-open [lo, nextafter(hi)); empty
                # intervals collapse (toggle on+off at one coordinate)
                hi_plus = np.maximum(np.nextafter(hi[:, j], math.inf), lo_j)
                coords = np.concatenate([lo_j, hi_plus])
                masks = np.concatenate([bitvals, bitvals])
                order = np.argsort(coords, kind="stable")
                seg = np.zeros(len(coords) + 1, dtype=np.uint64)
                np.bitwise_xor.accumulate(masks[order], out=seg[1:])
                stab.append((coords[order], seg))
            self._stab = stab

    def __call__(self, n: int, cols: Dict[str, np.ndarray]) -> np.ndarray:
        m = len(self.bitvals)
        if not m:
            return np.zeros(n, dtype=np.uint64)
        if not self.attrs:
            return np.full(n, self._all_mask, dtype=np.uint64)
        if self._stab is not None:
            bits = None
            for j, a in enumerate(self.attrs):
                col = cols[a]
                if not np.isfinite(col).all():
                    break
                bounds, seg = self._stab[j]
                w = seg[np.searchsorted(bounds, col, side="right")]
                bits = w if bits is None else bits & w
            else:
                return bits
        ok = None
        buf = np.empty((m, n), dtype=bool)
        for j, a in enumerate(self.attrs):
            col = cols[a]
            aj = np.greater_equal(col, self.lo[:, j, None])
            np.less_equal(col, self.hi[:, j, None], out=buf)
            np.logical_and(aj, buf, out=aj)
            if not self._con[:, j].all() and np.isnan(col).any():
                # NaN fails every compare, but members that do not
                # constrain this attribute must still admit the row
                np.logical_or(aj, ~self._con[:, j, None], out=aj)
            ok = aj if ok is None else np.logical_and(ok, aj, out=ok)
        bits = np.zeros(n, dtype=np.uint64)
        for i in range(m):
            bits |= ok[i] * self.bitvals[i]
        return bits


def fused_bound_bits(
    n: int,
    cols: Dict[str, np.ndarray],
    attrs: Sequence[str],
    lo: np.ndarray,
    hi: np.ndarray,
    bitvals: np.ndarray,
) -> np.ndarray:
    """One-shot form of :class:`FusedBoundFilter` (the pipeline caches the
    compiled filter per wave; standalone callers pay the compile per call)."""
    return FusedBoundFilter(attrs, lo, hi, bitvals)(n, cols)


# ---------------------------------------------------------------------------
# Gates (§5.3)
# ---------------------------------------------------------------------------


class Gate:
    """State-readiness gate for one admitted state-ref edge r=(q, b, v).

    open iff stateReady(S, r, R): the selected state covers the assigned
    extent (coverage restricted to the grant's allowed provenance extents
    when the attachment is represented) and every residual producer member
    installed for this edge has completed."""

    def __init__(
        self,
        state: SharedHashBuildState,
        conj: Optional[Conjunction],
        allowed_emask: Optional[np.uint64] = None,
    ):
        self.state = state
        self.conj = conj
        self.allowed_emask = allowed_emask
        self.pending: set = set()  # producer Member objects still owed
        self._open_cache = False
        # owning query (stamped at resolve_boundary): producer handoff
        # (§16) reads it to find the surviving beneficiaries of a doomed
        # producer — a gate's owner is the query its edge serves.
        self.owner_qid: Optional[int] = None

    def open(self) -> bool:
        if self._open_cache:
            return True
        if self.pending:
            return False
        if self.conj is not None and self.allowed_emask is not None:
            if not self.state.covers_with(self.conj, self.allowed_emask):
                return False
        self._open_cache = True
        return True

    def partition_frontier(self) -> Tuple[int, int]:
        """(delivered, total) scan-partition units across this gate's
        pending producers — the per-partition visibility frontier of §9.
        A closed gate at (k, n) has k of n producer shards fully delivered;
        (n, n) means only the coverage check remains. Open gates report
        their last frontier as fully delivered."""
        done = total = 0
        for m in self.pending:
            d, t = self.state.extent_partition_frontier(m.eid)
            # a producer that has not begun reports its shard count as owed
            if t == 0 and m.part_need is not None:
                t = len(m.part_need)
            done += d
            total += t
        return (done, total)


class AggGate:
    """Readiness of a shared aggregate state under exact identity (§4.5)."""

    def __init__(self, agg_state: SharedAggregateState):
        self.agg_state = agg_state

    def open(self) -> bool:
        return self.agg_state.complete


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------


@dataclass
class BuildTarget:
    """Pipeline-level sink: insert produced rows into a shared hash-build
    state, with visibility + extent provenance combined across members."""

    state: SharedHashBuildState
    key_attrs: Tuple[str, ...]


@dataclass
class AggSink:
    """Per-member sink: fold the member's visible rows into (possibly
    shared) aggregate state."""

    agg_state: SharedAggregateState
    group_keys: Tuple[str, ...]
    aggs: Tuple[AggSpec, ...]


# ---------------------------------------------------------------------------
# Members
# ---------------------------------------------------------------------------


class Member:
    """One query's participation in a pipeline (an active node-query pair in
    Algorithm 2's sense). ``beneficiaries`` supports QPipe-style merged
    identical profiles: one physical member tagging several queries.
    ``mid`` is allocated by the owning engine (no class-counter leaks)."""

    def __init__(
        self,
        mid: int,
        qid: int,
        pred: Pred,
        gates: List[Gate],
        sink: Optional[AggSink] = None,
        stage_filters: Optional[Dict[int, List[Pred]]] = None,
        kind: str = "main",  # 'main' | 'ordinary' | 'residual'
        eid: int = -1,
        conj: Optional[Conjunction] = None,
        beneficiaries: Optional[List[int]] = None,
    ):
        self.mid = mid
        self.qid = qid
        self.pred = pred
        self.gates = gates
        self.sink = sink
        self.stage_filters = stage_filters or {}
        self.kind = kind
        self.eid = eid
        self.conj = conj
        self.beneficiaries = beneficiaries or [qid]
        # §16 producer handoff: the qid whose state lens this member probes
        # with. Equal to ``qid`` except for adopted replacement members,
        # which continue a dead query's delivery obligation and must
        # observe upstream states through the dead query's exact lens
        # (slot visibility + grants) to reproduce its rows bit-identically.
        self.lens_qid = qid

        self.active = False
        self.done = False
        self.received = 0
        self.need = 0
        # per-partition delivery accounting (set at activation; §9): the
        # member finishes partition p after part_need[p] morsels from shard
        # p, and finishes overall when received reaches need (their sum)
        self.part_received: Optional[np.ndarray] = None
        self.part_need: Optional[np.ndarray] = None
        self.t_activated = 0.0  # activation barrier time (worker-clock merge)
        self.slot = -1  # pipeline-local bit slot
        self.rows_sunk = 0
        self.waiting_gates: List[Gate] = []  # gates whose pending set holds us
        self.pipeline: Optional["Pipeline"] = None

    @property
    def bitval(self) -> np.uint64:
        return U64_1 << np.uint64(self.slot)

    def activatable(self) -> bool:
        return (not self.active) and (not self.done) and all(g.open() for g in self.gates)

    def pending_in(self, part: int) -> bool:
        """Still owed morsels from scan partition ``part``."""
        if self.part_received is None:
            return True
        return self.part_received[part] < self.part_need[part]


# ---------------------------------------------------------------------------
# Probe op
# ---------------------------------------------------------------------------


@dataclass
class ProbeOp:
    state: SharedHashBuildState
    probe_attrs: Tuple[str, ...]
    payload: Tuple[str, ...]  # entry attrs (canonical names in the state)
    out_names: Tuple[str, ...] = ()  # names in the row stream (renames)

    def __post_init__(self):
        if not self.out_names:
            self.out_names = tuple(self.payload)


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


class _CohortIndex:
    """Pipeline-persistent shared group index for one aggregate cohort
    (§11): one batched lookup per morsel maps the cohort's group-key rows
    to cohort-local dense gids; per-(member, partition) translation arrays
    then turn cohort gids into member-local accumulator ids, so the
    steady-state per-member residue is a gather + scatter — no hashing."""

    __slots__ = ("_idx", "_gvals", "maps")

    def __init__(self, n_keys: int):
        self._idx = MultiKeyIndex(n_keys) if n_keys else None
        # per-gid key values, created lazily with the columns' ORIGINAL
        # dtypes: a member's accumulator index keys integer columns by
        # value and floats by bit pattern, so a float64 cast here would
        # assign different ids than the row-level `update` path
        self._gvals: Optional[List[GrowArray]] = None
        self.maps: Dict[tuple, np.ndarray] = {}  # (mid, part) -> local gid

    def resolve(self, key_cols: List[np.ndarray], n: int):
        """(cohort gids for the rows, per-gid key values, n groups)."""
        if self._idx is None:
            return np.zeros(n, dtype=np.int64), [], 1
        gids, is_new = self._idx.lookup_or_insert(key_cols)
        if self._gvals is None:
            self._gvals = [GrowArray(np.asarray(c).dtype) for c in key_cols]
        if is_new.any():
            firsts = np.flatnonzero(is_new)
            for c, gv in zip(key_cols, self._gvals):
                gv.append(np.asarray(c)[firsts])
        return gids, [gv.data for gv in self._gvals], self._idx.n

    def member_map(self, mid: int, part: int, ng: int) -> np.ndarray:
        """Cohort gid -> member-local accumulator id (-1 = unmapped)."""
        key = (mid, part)
        cur = self.maps.get(key)
        if cur is None or len(cur) < ng:
            grown = np.full(ng, -1, dtype=np.int64)
            if cur is not None:
                grown[: len(cur)] = cur
            self.maps[key] = cur = grown
        return cur

    def release(self, mid: int) -> None:
        """Drop a finished member's gid maps (all partitions)."""
        for key in [k for k in self.maps if k[0] == mid]:
            del self.maps[key]


class Pipeline:
    def __init__(
        self,
        pid: int,
        key,
        source: "ScanNode",
        ops: List[ProbeOp],
        build_target: Optional[BuildTarget] = None,
        compose_did: bool = False,
        counters: Optional[Dict] = None,
    ):
        self.pid = pid
        self.key = key
        self.source = source
        self.ops = ops
        self.build_target = build_target
        self.compose_did = compose_did
        self.members: List[Member] = []
        self.slots = SlotAllocator()
        self._counters = counters
        # per-wave plan caches, keyed by the active member set (with
        # partitions the set differs per shard near completion)
        self._filter_plans: Dict[tuple, tuple] = {}
        self._mm_plans: Dict[tuple, dict] = {}
        # shared cohort group indexes + member gid maps (§11) — persistent
        # across waves (a member's accumulator mapping outlives wave churn)
        self._cohort_state: Dict[tuple, _CohortIndex] = {}
        source.attach(self)

    # -- membership ---------------------------------------------------------
    def add_member(self, m: Member) -> None:
        """Assign the member a packed-word bit slot, or route it to the
        overflow slow lane (slot == -1) when all 64 bits of the pipeline
        word are taken (§11: overflow members are processed member-at-a-time
        on a plain boolean mask — sound, never silently dropped)."""
        slot = self.slots.try_get(m.mid)
        if slot is None:
            m.slot = -1
            if self._counters is not None:
                self._counters["overflow_members"] += 1
        else:
            m.slot = slot
        self.members.append(m)

    def release_member(self, m: Member) -> None:
        """Drop a finished member's cohort gid maps (§11): long-lived
        shared pipelines (open-loop serving) must not accumulate
        per-member cache state. A cohort index with no mapped members is
        dropped entirely (rebuilt on demand), bounding ``_cohort_state``
        by the live membership."""
        for ck, ci in list(self._cohort_state.items()):
            ci.release(m.mid)
            if not ci.maps:
                del self._cohort_state[ck]

    def active_members(self) -> List[Member]:
        return [m for m in self.members if m.active and not m.done]

    def active_members_for(self, part: int) -> List[Member]:
        """Active members still owed morsels from scan partition ``part``."""
        return [m for m in self.members if m.active and not m.done and m.pending_in(part)]

    def progress(self) -> int:
        return max((m.received for m in self.members), default=0)

    def all_done(self) -> bool:
        return all(m.done for m in self.members)

    # -- execution ----------------------------------------------------------
    def _source_bits(self, act: List[Member], cols, n: int, engine) -> np.ndarray:
        """Per-member source predicates -> packed row bitmask, via one fused
        SoA bound-check pass (per-wave matrices cached on the pipeline);
        members outside the interval fragment evaluate individually."""
        key = tuple((m.mid, m.slot) for m in act)
        plan = self._filter_plans.get(key)
        if plan is None:
            attrs, lo, hi, fused, slow = member_bound_matrices(act)
            bitvals = np.array([m.bitval for m in fused], dtype=np.uint64)
            plan = (FusedBoundFilter(attrs, lo, hi, bitvals), fused, slow)
            if len(self._filter_plans) > 64:  # bounded: waves churn members
                self._filter_plans.clear()
            self._filter_plans[key] = plan
        ff, fused, slow = plan
        bits = ff(n, cols)
        engine.counters["fused_filter_rows"] += n * len(fused)
        for m in slow:
            mask = evaluate(m.pred, cols)
            bits |= np.where(mask, m.bitval, U64_0)
        return bits

    def _member_major_plan(self, act: List[Member]) -> dict:
        """Per-wave member-major execution plan (§11), cached on the active
        member set: per-stage lens translation tables + grant fallbacks,
        fused stage-filter matrices, sink tag tables, and aggregate
        cohorts. Beneficiary counts key the cache because qpipe merges can
        extend a zero-progress member's beneficiary list mid-wave."""
        key = tuple((m.mid, m.slot, len(m.beneficiaries)) for m in act)
        plan = self._mm_plans.get(key)
        if plan is not None:
            return plan
        stages = []
        filters = []
        for stage, op in enumerate(self.ops):
            # lens targets: state slot -> pipeline ownership bit. Members
            # with extent-scoped grants need predicate evaluation on entry
            # columns — they keep the per-member lens; members with no slot
            # and no grants can never see an entry (no target bit).
            target = np.zeros(64, dtype=np.uint64)
            grant_members: List[Member] = []
            kernelable = True
            for m in act:
                if op.state.grants.get(m.lens_qid):
                    grant_members.append(m)
                    kernelable = False
                    continue
                slot = op.state.slots.peek(m.lens_qid)
                if slot is not None:
                    # any slot 0..63 serves: the kernel lens mirrors are
                    # (lo, hi) uint32 pairs (DESIGN.md §13)
                    target[slot] |= m.bitval
            stages.append((translation_table(target), tuple(grant_members), kernelable))
            attrs, lo, hi, fused, slow = stage_filter_matrices(act, stage)
            fmask = np.uint64(0)
            for m in fused:
                fmask |= m.bitval
            bitvals = np.array([m.bitval for m in fused], dtype=np.uint64)
            filters.append(
                (FusedBoundFilter(attrs, lo, hi, bitvals), len(fused), fmask, tuple(slow))
            )
        plan = {"stages": stages, "filters": filters}
        if self.build_target is not None:
            bt = self.build_target
            tvis = np.zeros(64, dtype=np.uint64)
            tem = np.zeros(64, dtype=np.uint64)
            for m in act:
                w = np.uint64(0)
                for b in m.beneficiaries:
                    w |= bt.state.slots.mask(b)
                tvis[m.slot] = w
                if m.eid >= 0:
                    tem[m.slot] = U64_1 << np.uint64(m.eid)
            plan["sink"] = (translation_table(tvis), translation_table(tem))
        # aggregate cohorts: members with identically-shaped sinks fold in
        # one segmented pass; distinct aggs take the per-member path
        # (count-distinct dedups through per-state seen-pair indexes)
        cohorts: Dict[tuple, List[Member]] = {}
        for m in act:
            if m.sink is None:
                continue
            s = m.sink
            ck = (s.group_keys, tuple((a.func, a.distinct, repr(a.expr)) for a in s.aggs))
            cohorts.setdefault(ck, []).append(m)
        plan["cohorts"] = [
            (
                ck,
                ms,
                not any(a.distinct for a in ms[0].sink.aggs),
                # columns the fold actually reads: group keys + expr attrs
                tuple(
                    dict.fromkeys(
                        list(ms[0].sink.group_keys)
                        + [
                            attr
                            for a in ms[0].sink.aggs
                            if a.expr is not None
                            for attr in sorted(expr_attrs(a.expr))
                        ]
                    )
                ),
            )
            for ck, ms in cohorts.items()
        ]
        plan["chain"] = self._build_chain_plan(act, plan)
        if len(self._mm_plans) > 64:  # bounded: waves churn members
            self._mm_plans.clear()
        self._mm_plans[key] = plan
        return plan

    def _build_chain_plan(self, act: List[Member], plan: dict):
        """Compile the wave's stage chain for one fused device launch
        (DESIGN.md §13), or record why it cannot fuse.

        Per stage: the chain lens translation table (unlike the staged
        tables it INCLUDES grant members' slot bits — ``visible_mask`` ORs
        the slot bit with the grants, and the kernel does the same), key
        sourcing resolved through the running payload environment (source
        columns stay per-row host keys; a single payload-origin key gathers
        from the origin stage's entry-indexed device key mirror), compiled
        grant intervals, and the fused filter matrices with their operand
        sourcing. Static declines return ``{"ok": False, "reason": ...}``
        so the dispatcher counts them per reason: non-interval grants
        (``grants``), slow stage-filter members (``predicate``),
        mixed/composite payload-origin keys (``keyrange``)."""
        if not self.ops:
            return None
        n_members = len(act)
        env: Dict[str, tuple] = {}
        reason = None
        stages_meta = []
        for stage, op in enumerate(self.ops):
            refs = [env.get(a) for a in op.probe_attrs]
            if all(r is None for r in refs):
                key = ("host", tuple(op.probe_attrs))
            elif len(refs) == 1:
                key = refs[0]
            else:
                # composite keys with payload-origin components would need
                # the radix encode on device — not worth a kernel variant
                key = None
                reason = reason or "keyrange"
            target = np.zeros(64, dtype=np.uint64)
            grants = []
            n_grant_members = 0
            for m in act:
                slot = op.state.slots.peek(m.lens_qid)
                if slot is not None:
                    target[slot] |= m.bitval
                gs = op.state.grants.get(m.lens_qid)
                if gs:
                    n_grant_members += 1
                    for allowed, conj in gs:
                        b = _chain_grant_bounds(conj)
                        if b is None or any(
                            a not in op.state.cols for a, _, _ in b
                        ):
                            reason = reason or "grants"
                        else:
                            grants.append((m.bitval, np.uint64(allowed), b))
            ff, n_fused, fmask, slow = plan["filters"][stage]
            if slow:
                reason = reason or "predicate"
            # payload outputs shadow the environment BEFORE filter operand
            # resolution (stage filters run on the post-gather columns)
            for a, out in zip(op.payload, op.out_names):
                env[out] = ("entry", stage, a)
            fmeta = None
            if n_fused and ff.attrs:
                if np.isnan(ff.lo).any() or np.isnan(ff.hi).any():
                    reason = reason or "predicate"
                frefs = []
                for a in ff.attrs:
                    r = env.get(a)
                    frefs.append(("host", a) if r is None else r)
                fmeta = {
                    "attrs": tuple(frefs),
                    "lo": ff.lo,
                    "hi": ff.hi,
                    "con": ff._con,
                    "bitvals": ff.bitvals,
                    "n_members": n_fused,
                }
            # post-visibility accounting iff the staged path would have
            # taken the single-member fused-lens probe for this stage
            use_post = (
                n_members == 1
                and n_grant_members == 0
                and op.state.slots.peek(act[0].lens_qid) is not None
            )
            stages_meta.append(
                {
                    "state": op.state,
                    "tables": translation_table(target),
                    "key": key,
                    "grants": tuple(grants),
                    "n_grant_members": n_grant_members,
                    "use_post": use_post,
                    "filter": fmeta,
                }
            )
        if reason is not None:
            return {"ok": False, "reason": reason}
        needed = set()
        if self.build_target is not None:
            bt = self.build_target
            needed |= set(bt.key_attrs) | set(bt.state.retained_attrs)
        for _ck, _ms, _fold, ncols in plan["cohorts"]:
            needed |= set(ncols)
        return {
            "ok": True,
            "n_members": n_members,
            "stages": stages_meta,
            "sink": plan.get("sink"),
            "env": dict(env),
            "needed": tuple(sorted(needed)),
            "_dev": {},
        }

    def process(
        self, engine, cols: Dict[str, np.ndarray], row_ids: np.ndarray, part: int = 0
    ) -> float:
        """Run one morsel of scan partition ``part`` through the pipeline
        for every member still owed that shard. Returns the modeled cost
        (seconds) of the work performed.

        Members with a packed-word bit slot run the member-major fused
        path (§11) — or the retained per-member oracle path when the
        engine disables ``member_major``; slot-overflow members (beyond the
        64-bit word) run the member-at-a-time slow lane."""
        act = self.active_members_for(part)
        if not act:
            return 0.0
        packed = [m for m in act if m.slot >= 0]
        overflow = [m for m in act if m.slot < 0]
        cost = 0.0
        if packed:
            if getattr(engine, "member_major", True):
                cost += self._process_packed_fused(engine, packed, cols, row_ids, part)
            else:
                cost += self._process_packed_members(engine, packed, cols, row_ids, part)
        for m in overflow:
            cost += self._process_overflow(engine, m, cols, row_ids, part)
        # morsel accounting (per partition, §9)
        finished: List[Member] = []
        for m in act:
            m.received += 1
            if m.part_received is not None:
                m.part_received[part] += 1
                if m.part_received[part] >= m.part_need[part]:
                    engine.on_member_part_finished(self, m, part)
            if m.received >= m.need:
                m.done = True
                m.active = False
                finished.append(m)
        for m in finished:
            engine.on_member_finished(self, m)
        return cost

    # -- member-major fused path (§11) --------------------------------------
    def _process_packed_fused(
        self, engine, act: List[Member], cols, row_ids: np.ndarray, part: int
    ) -> float:
        """One morsel through every stage as packed uint64 mask
        transformations — per-stage cost independent of the member count:
        semijoin visibility is one lens-word translation, stage filters are
        one fused bound-check, sink tagging is one translate + scatter, and
        aggregate cohorts fold via one (group × member) segmented pass."""
        n = len(row_ids)
        cm = engine.cost_model
        cost = 0.0
        plan = self._member_major_plan(act)

        bits = self._source_bits(act, cols, n, engine)
        cost += cm["filter"] * n * len(act)

        keep = np.flatnonzero(bits)
        cols = {k: v[keep] for k, v in cols.items()}
        bits = bits[keep]
        did = row_ids[keep].astype(np.int64)

        # mesh execution (§14): record the morsel's first-stage repartition
        # in the per-device histogram — stage-0 keys are identical whether
        # the chain or the staged loop serves the morsel, so the histogram
        # is backend-independent
        if engine.mesh_plan is not None and self.ops and len(did) > 0:
            engine.mesh_plan.note_morsel(encode_keys(cols, self.ops[0].probe_attrs))

        backend = engine.backend
        served = False
        chain_sink = None
        cplan = plan.get("chain")
        probe_chain = (
            getattr(backend, "probe_chain", None) if backend is not None else None
        )
        if cplan is not None and probe_chain is not None and len(did) > 0:
            if cplan["ok"]:
                # one fused launch for the whole stage chain (§13); host
                # keys validated backend-side over the full morsel — any
                # dynamic decline falls through to the staged loop below
                host_keys = {
                    si: encode_keys(cols, st["key"][1])
                    for si, st in enumerate(cplan["stages"])
                    if st["key"][0] == "host"
                }
                res = probe_chain(
                    cplan, cols, bits, host_keys, counters=engine.counters
                )
                if res is not None:
                    engine.counters["kernel_chain_launches"] += 1
                    cost, cols, bits, did, chain_sink = self._replay_chain(
                        engine, plan, cplan, res, cols, did, cost
                    )
                    served = True
            else:
                backend.note_fallback(cplan["reason"], engine.counters)
        for stage, op in enumerate(self.ops):
            if served or len(did) == 0:
                break
            keycodes = encode_keys(cols, op.probe_attrs)
            vis_tables, grant_members, kernelable = plan["stages"][stage]
            lens_fused = False
            words = None
            if backend is not None:
                if len(act) == 1 and not grant_members:
                    probe_visible = getattr(backend, "probe_visible", None)
                    if probe_visible is not None:
                        fused_pair = probe_visible(op.state, keycodes, act[0].lens_qid)
                        if fused_pair is not None:
                            probe_idx, entry_idx = fused_pair
                            lens_fused = True
                            engine.counters["kernel_lens_probes"] += 1
                elif kernelable and len(act) > 1:
                    # multi-member lens: one launch returns every probing
                    # member's ownership word (the matched entry's packed
                    # visibility word), translated below
                    probe_multi = getattr(backend, "probe_visible_multi", None)
                    if probe_multi is not None:
                        trip = probe_multi(op.state, keycodes)
                        if trip is not None:
                            probe_idx, entry_idx, words = trip
                            engine.counters["kernel_multi_lens_probes"] += 1
                if not lens_fused and words is None:
                    probe_idx, entry_idx = _backend_probe(
                        backend, op.state, keycodes, engine.counters
                    )
            else:
                probe_idx, entry_idx = op.state.probe(keycodes)
            if engine.mesh_plan is not None:
                # §14: probe rows cross the bucketed all_to_all to their
                # key shard's device before the shard-local probe
                xr = engine.mesh_plan.exchange_rows(len(keycodes))
                cost += cm["exchange"] * xr
                engine.counters["mesh_exchange_rows"] += xr
            cost += cm["probe"] * len(keycodes) + cm["match"] * len(probe_idx)
            engine.counters["probe_rows"] += len(keycodes)
            bits_in = bits[probe_idx]
            if lens_fused:
                new_bits = bits_in & act[0].bitval
            else:
                if words is None:
                    words = op.state.vis.data[entry_idx]
                vis_pl = translate_bits(words, vis_tables)
                for m in grant_members:
                    vm = op.state.visible_mask(m.lens_qid, entry_idx)
                    vis_pl = vis_pl | np.where(vm, m.bitval, U64_0)
                new_bits = bits_in & vis_pl
                engine.counters["fused_vis_rows"] += len(probe_idx) * (
                    len(act) - len(grant_members)
                )
            cols = {k: v[probe_idx] for k, v in cols.items()}
            for a, out in zip(op.payload, op.out_names):
                cols[out] = op.state.cols[a].data[entry_idx]
            if self.compose_did:
                did = did[probe_idx] * np.int64(op.state.did_domain) + op.state.did.data[entry_idx]
            else:
                did = did[probe_idx]
            bits = new_bits
            # post-join stage filters: one fused bound-check over all
            # interval-canonical members (§11); the rest evaluate per-member
            ff, n_fused, fmask, slow = plan["filters"][stage]
            if n_fused:
                fbits = ff(len(bits), cols)
                bits = bits & (~fmask | fbits)
                engine.counters["fused_stage_filter_rows"] += len(bits) * n_fused
            for m in slow:
                for p in m.stage_filters.get(stage, ()):  # e.g. Q5 ColEq
                    bm = bit_of(bits, m.slot) & evaluate(p, cols)
                    bits = (bits & ~m.bitval) | np.where(bm, m.bitval, U64_0)
            keep = np.flatnonzero(bits)
            if len(keep) != len(bits):
                cols = {k: v[keep] for k, v in cols.items()}
                did = did[keep]
                bits = bits[keep]

        # sinks
        if self.build_target is not None and len(did) > 0:
            bt = self.build_target
            if chain_sink is not None:
                # chain launches translate the sink words in-kernel and
                # return per-slot survivor counts alongside (§13)
                vismask, emask, counts = chain_sink
            else:
                vis_tables, em_tables = plan["sink"]
                # all beneficiaries of all members tag in ONE translate +
                # one bitwise_or.at scatter inside insert_or_mark (§11)
                vismask = translate_bits(bits, vis_tables)
                emask = translate_bits(bits, em_tables)
                counts = slot_popcounts(bits)
            engine.counters["fused_sink_rows"] += len(bits)
            idx = np.flatnonzero(vismask)
            if len(idx):
                keycodes = encode_keys(cols, bt.key_attrs)
                ins, mrk = bt.state.insert_or_mark(
                    did[idx],
                    keycodes[idx],
                    {a: cols[a][idx] for a in bt.state.retained_attrs},
                    vismask[idx],
                    emask[idx],
                )
                cost += cm["insert"] * ins + cm["mark"] * mrk
            for m in act:
                nsel = int(counts[m.slot])
                m.rows_sunk += nsel
                key = "residual_build_rows" if m.kind == "residual" else "ordinary_build_rows"
                engine.counters[key] += nsel * len(m.beneficiaries)
        else:
            nsel_of: Dict[int, int] = {}
            for ck, ms, fold, needed in plan["cohorts"]:
                if len(did) == 0:
                    break
                if fold and len(ms) > 1:
                    self._agg_fold_cohort(engine, ck, ms, needed, cols, bits, part, nsel_of)
                else:
                    for m in ms:
                        sel = bit_of(bits, m.slot)
                        nsel = int(sel.sum())
                        if nsel == 0:
                            continue
                        scols = {k: v[sel] for k, v in cols.items()}
                        self._agg_sink_rows(engine, m, scols, nsel, part)
                        nsel_of[m.mid] = nsel
            # accumulate modeled agg cost in member order so the running
            # float sum is bit-identical to the per-member oracle path
            for m in act:
                if m.sink is not None and nsel_of.get(m.mid):
                    cost += cm["agg"] * nsel_of[m.mid]
        return cost

    def _replay_chain(self, engine, plan: dict, cplan: dict, res, cols, did, cost):
        """Fold one chain launch's results back into the morsel loop's
        contract: replay the staged loop's modeled cost and row counters
        from the kernel's per-stage (alive, matched, matched_visible)
        stats, then reconstruct the surviving rows' columns and provenance
        host-side from the returned entry indices. Every formula mirrors a
        line of the staged loop — including threading the RUNNING morsel
        cost through the per-stage adds, since float summation order is
        part of the virtual-clock contract — so the clock and ROW counters
        stay bit-identical whether a wave runs fused or staged (§13)."""
        cm = engine.cost_model
        n_members = cplan["n_members"]
        stats = res["stats"]
        for s, st in enumerate(cplan["stages"]):
            alive = int(stats[s, 0])
            if alive == 0:
                # the staged loop breaks before probing an empty morsel
                break
            # post-visibility match counts iff the staged path would have
            # probed through the single-member fused lens
            matched = int(stats[s, 2] if st["use_post"] else stats[s, 1])
            if engine.mesh_plan is not None:
                # mirrors the staged loop's §14 exchange charge (same
                # summation order — virtual clocks stay bit-identical
                # whether the chain or the staged loop served the morsel)
                xr = engine.mesh_plan.exchange_rows(alive)
                cost += cm["exchange"] * xr
                engine.counters["mesh_exchange_rows"] += xr
            cost += cm["probe"] * alive + cm["match"] * matched
            engine.counters["probe_rows"] += alive
            if st["use_post"]:
                engine.counters["kernel_lens_probes"] += 1
            else:
                engine.counters["kernel_multi_lens_probes"] += 1
                engine.counters["fused_vis_rows"] += int(stats[s, 1]) * (
                    n_members - st["n_grant_members"]
                )
            n_fused = plan["filters"][s][1]
            if n_fused:
                engine.counters["fused_stage_filter_rows"] += matched * n_fused
        keep = np.flatnonzero(res["bits"])
        bits = res["bits"][keep]
        # survivors matched every stage (a probe miss zeroes the row's
        # word), so every gathered entry index is valid
        entries = [e[keep] for e in res["entries"]]
        env = cplan["env"]
        out_cols = {}
        for a in cplan["needed"]:
            ref = env.get(a)
            if ref is None:
                out_cols[a] = cols[a][keep]
            else:
                _, stg, attr = ref
                out_cols[a] = self.ops[stg].state.cols[attr].data[entries[stg]]
        did = did[keep]
        if self.compose_did:
            for s, op in enumerate(self.ops):
                did = did * np.int64(op.state.did_domain) + op.state.did.data[entries[s]]
        sink = None
        if "vismask" in res:
            sink = (res["vismask"][keep], res["emask"][keep], res["slots"])
        return cost, out_cols, bits, did, sink

    def _agg_fold_cohort(
        self, engine, ck, ms: List[Member], needed, cols, bits: np.ndarray,
        part: int, nsel_of: Dict[int, int],
    ) -> None:
        """Fold a cohort of identically-shaped aggregate sinks in one
        segmented pass keyed by (group id × member bit) (§11): group ids
        and aggregate expressions are computed once over the cohort's row
        union, per-(group, member) partials come from one composite
        ``segment_sum``, and each member's scatter goes through a cached
        cohort-gid -> accumulator-id map — in steady state the per-member
        residue is a gather + scatter over its touched groups, no hashing.
        Unseen groups enter a member's accumulator index in that member's
        own first-occurrence row order, so layout and float accumulation
        stay bit-identical to the per-member oracle path."""
        sink = ms[0].sink
        k = len(ms)
        cmask = np.uint64(0)
        for m in ms:
            cmask |= m.bitval
        rows = np.flatnonzero(bits & cmask)
        if not len(rows):
            return
        sub = bits[rows] & cmask
        slots = np.array([m.slot for m in ms], dtype=np.int64)
        nkept = len(rows)
        if not (sub & (sub - U64_1)).any():
            # disjoint ownership (one cohort bit per row — the common fold
            # shape): pairs ARE the rows, no member matrix and no gathers;
            # bit index via branch-free de Bruijn multiply, not float log2
            inv = np.full(64, -1, dtype=np.int64)
            inv[slots] = np.arange(len(ms), dtype=np.int64)
            pm = inv[_DB_TABLE[((sub * _DB64) >> _DB_SHIFT).astype(np.intp)]]
            pr = None  # identity: pairs[i] == row i
        else:
            memmat = unpack_slots(sub, slots)
            pm, pr = np.nonzero(memmat)  # per member, rows ascend
        n_pairs = len(pm)
        scols = {key: cols[key][rows] for key in needed}
        ci = self._cohort_state.get(ck)
        if ci is None:
            ci = self._cohort_state[ck] = _CohortIndex(len(sink.group_keys))
        gids, gvals, ng = ci.resolve([scols[g] for g in sink.group_keys], nkept)
        pair_gids = gids if pr is None else gids[pr]
        code = pair_gids * np.int64(k) + pm
        nbuckets = ng * k
        backend = engine.backend
        segment_sum = (
            backend.segment_sum if backend is not None else _bincount_segment_sum
        )
        counts2d = segment_sum(code, None, nbuckets).reshape(ng, k)
        vals = []
        for a in sink.aggs:
            if a.expr is None:
                vals.append(None)
            else:
                v = expr_eval(a.expr, scols)
                v = np.broadcast_to(np.asarray(v, dtype=np.float64), (nkept,))
                vals.append(v if pr is None else v[pr])
        partials = []
        for a, v in zip(sink.aggs, vals):
            if a.func == "count":
                partials.append(counts2d)
            elif a.func in ("sum", "avg"):
                partials.append(segment_sum(code, v, nbuckets).reshape(ng, k))
            elif a.func == "min":
                p = np.full(nbuckets, math.inf)
                np.minimum.at(p, code, v)
                partials.append(p.reshape(ng, k))
            elif a.func == "max":
                p = np.full(nbuckets, -math.inf)
                np.maximum.at(p, code, v)
                partials.append(p.reshape(ng, k))
            else:
                raise ValueError(a.func)
        engine.counters["agg_cohort_rows"] += n_pairs
        # member-major (k, ng) layouts: contiguous per-member row gathers
        counts2d_t = np.ascontiguousarray(counts2d.T)
        partials_t = [np.ascontiguousarray(p.T) for p in partials]
        tz_m, tz_g = np.nonzero(counts2d_t != 0)
        mb = np.searchsorted(tz_m, np.arange(k + 1))
        nsel_all = np.bincount(pm, minlength=k)
        for i, m in enumerate(ms):
            n_touched = int(mb[i + 1] - mb[i])
            if not n_touched:
                continue
            full = n_touched == ng  # steady state: every group touched
            touched = None if full else tz_g[mb[i] : mb[i + 1]]
            nsel = int(nsel_all[i])
            gmap = ci.member_map(m.mid, part, ng)
            local = gmap if full else gmap[touched]
            if (local < 0).any():
                # first contact with these groups: insert into the member's
                # accumulator index in ITS first-occurrence row order
                sel = pm == i
                g = pair_gids[sel]  # member's rows, ascending
                uq, first = np.unique(g, return_index=True)
                fo = uq[np.argsort(first, kind="stable")]
                new = fo[gmap[fo] < 0]
                gmap[new] = m.sink.agg_state.map_groups(
                    [gv[new] for gv in gvals], part=part
                )
                local = gmap if full else gmap[touched]
            m.sink.agg_state.fold_groups(
                local,
                counts2d_t[i] if full else counts2d_t[i][touched],
                [p[i] if full else p[i][touched] for p in partials_t],
                nsel,
                part=part,
            )
            m.rows_sunk += nsel
            engine.counters["agg_rows"] += nsel
            nsel_of[m.mid] = nsel

    def _agg_sink_rows(self, engine, m: Member, scols, nsel: int, part: int) -> None:
        """Fold one member's selected rows into its aggregate state (the
        per-member sink body, shared by the oracle path, singleton/distinct
        cohorts, and the overflow slow lane)."""
        sink = m.sink
        backend = engine.backend
        key_cols = [scols[k] for k in sink.group_keys]
        vals = [
            expr_eval(a.expr, scols) if a.expr is not None else None
            for a in sink.aggs
        ]
        vals = [
            np.broadcast_to(np.asarray(v, dtype=np.float64), (nsel,))
            if v is not None
            else None
            for v in vals
        ]
        sink.agg_state.update(
            key_cols,
            vals,
            nsel,
            segment_sum=backend.segment_sum if backend is not None else None,
            part=part,
        )
        m.rows_sunk += nsel
        engine.counters["agg_rows"] += nsel

    # -- retained per-member oracle path -------------------------------------
    def _process_packed_members(
        self, engine, act: List[Member], cols, row_ids: np.ndarray, part: int
    ) -> float:
        """The pre-§11 per-member morsel loop, retained verbatim as the
        differential oracle for the fused path (``member_major=False``):
        per-stage visibility, stage filters, sink tagging, and aggregate
        folds each walk the members one by one."""
        n = len(row_ids)
        cm = engine.cost_model
        cost = 0.0

        bits = self._source_bits(act, cols, n, engine)
        cost += cm["filter"] * n * len(act)

        keep = np.flatnonzero(bits)
        cols = {k: v[keep] for k, v in cols.items()}
        bits = bits[keep]
        did = row_ids[keep].astype(np.int64)

        # §14: same first-stage routing histogram as the fused path
        if engine.mesh_plan is not None and self.ops and len(did) > 0:
            engine.mesh_plan.note_morsel(encode_keys(cols, self.ops[0].probe_attrs))

        # hash-probe ops (§4.3: one physical probe step serves all queries
        # whose visibility check succeeds)
        backend = engine.backend
        for stage, op in enumerate(self.ops):
            if len(did) == 0:
                break
            keycodes = encode_keys(cols, op.probe_attrs)
            # single-member probes resolve the state lens in-kernel when the
            # backend can serve it; the runtime then skips visible_mask
            lens_fused = False
            if backend is not None:
                if len(act) == 1:
                    probe_visible = getattr(backend, "probe_visible", None)
                    if probe_visible is not None:
                        fused_pair = probe_visible(op.state, keycodes, act[0].lens_qid)
                        if fused_pair is not None:
                            probe_idx, entry_idx = fused_pair
                            lens_fused = True
                            engine.counters["kernel_lens_probes"] += 1
                if not lens_fused:
                    probe_idx, entry_idx = _backend_probe(
                        backend, op.state, keycodes, engine.counters
                    )
            else:
                probe_idx, entry_idx = op.state.probe(keycodes)
            if engine.mesh_plan is not None:
                # §14 exchange charge — identical to the fused path's so
                # the oracle stays clock-bit-identical under mesh
                xr = engine.mesh_plan.exchange_rows(len(keycodes))
                cost += cm["exchange"] * xr
                engine.counters["mesh_exchange_rows"] += xr
            cost += cm["probe"] * len(keycodes) + cm["match"] * len(probe_idx)
            engine.counters["probe_rows"] += len(keycodes)
            bits_in = bits[probe_idx]
            new_bits = np.zeros(len(probe_idx), dtype=np.uint64)
            for m in act:
                if lens_fused:
                    bm = bit_of(bits_in, m.slot)
                else:
                    vis = op.state.visible_mask(m.lens_qid, entry_idx)
                    bm = bit_of(bits_in, m.slot) & vis
                new_bits |= np.where(bm, m.bitval, U64_0)
            cols = {k: v[probe_idx] for k, v in cols.items()}
            for a, out in zip(op.payload, op.out_names):
                cols[out] = op.state.cols[a].data[entry_idx]
            if self.compose_did:
                did = did[probe_idx] * np.int64(op.state.did_domain) + op.state.did.data[entry_idx]
            else:
                did = did[probe_idx]
            bits = new_bits
            # member post-join filters at this stage
            for m in act:
                for p in m.stage_filters.get(stage, ()):  # e.g. Q5 ColEq
                    bm = bit_of(bits, m.slot) & evaluate(p, cols)
                    bits = (bits & ~m.bitval) | np.where(bm, m.bitval, U64_0)
            keep = np.flatnonzero(bits)
            if len(keep) != len(bits):
                cols = {k: v[keep] for k, v in cols.items()}
                did = did[keep]
                bits = bits[keep]

        # sinks
        if self.build_target is not None and len(did) > 0:
            bt = self.build_target
            vismask = np.zeros(len(did), dtype=np.uint64)
            emask = np.zeros(len(did), dtype=np.uint64)
            member_rows: List[Tuple[Member, int]] = []
            for m in act:
                sel = bit_of(bits, m.slot)
                nsel = int(sel.sum())
                if nsel:
                    for b in m.beneficiaries:
                        vismask[sel] |= bt.state.slots.mask(b)
                    if m.eid >= 0:
                        emask[sel] |= U64_1 << np.uint64(m.eid)
                member_rows.append((m, nsel))
            any_rows = vismask != 0
            idx = np.flatnonzero(any_rows)
            if len(idx):
                keycodes = encode_keys(cols, bt.key_attrs)
                ins, mrk = bt.state.insert_or_mark(
                    did[idx],
                    keycodes[idx],
                    {a: cols[a][idx] for a in bt.state.retained_attrs},
                    vismask[idx],
                    emask[idx],
                )
                cost += cm["insert"] * ins + cm["mark"] * mrk
            for m, nsel in member_rows:
                m.rows_sunk += nsel
                key = "residual_build_rows" if m.kind == "residual" else "ordinary_build_rows"
                engine.counters[key] += nsel * len(m.beneficiaries)
        else:
            for m in act:
                if m.sink is None:
                    continue
                sel = bit_of(bits, m.slot)
                nsel = int(sel.sum())
                if nsel == 0:
                    continue
                scols = {k: v[sel] for k, v in cols.items()}
                self._agg_sink_rows(engine, m, scols, nsel, part)
                cost += cm["agg"] * nsel
        return cost

    # -- overflow slow lane (§11) --------------------------------------------
    def _process_overflow(
        self, engine, m: Member, cols, row_ids: np.ndarray, part: int
    ) -> float:
        """Member-at-a-time pass for one slot-overflow member: the same
        stages on a plain boolean row mask. Sound — rows are never dropped
        when the packed word runs out of bits — just not fused."""
        n = len(row_ids)
        cm = engine.cost_model
        cost = cm["filter"] * n
        sel = np.flatnonzero(evaluate(m.pred, cols))
        mcols = {k: v[sel] for k, v in cols.items()}
        did = row_ids[sel].astype(np.int64)
        backend = engine.backend
        for stage, op in enumerate(self.ops):
            if len(did) == 0:
                break
            keycodes = encode_keys(mcols, op.probe_attrs)
            if backend is not None:
                probe_idx, entry_idx = _backend_probe(
                    backend, op.state, keycodes, engine.counters
                )
            else:
                probe_idx, entry_idx = op.state.probe(keycodes)
            if engine.mesh_plan is not None:
                # §14 exchange charge — the slow lane's rows route through
                # the same bucketed all_to_all as the packed path's
                xr = engine.mesh_plan.exchange_rows(len(keycodes))
                cost += cm["exchange"] * xr
                engine.counters["mesh_exchange_rows"] += xr
            cost += cm["probe"] * len(keycodes) + cm["match"] * len(probe_idx)
            engine.counters["probe_rows"] += len(keycodes)
            vis = op.state.visible_mask(m.lens_qid, entry_idx)
            ksel = np.flatnonzero(vis)
            probe_idx, entry_idx = probe_idx[ksel], entry_idx[ksel]
            mcols = {k: v[probe_idx] for k, v in mcols.items()}
            for a, out in zip(op.payload, op.out_names):
                mcols[out] = op.state.cols[a].data[entry_idx]
            if self.compose_did:
                did = did[probe_idx] * np.int64(op.state.did_domain) + op.state.did.data[entry_idx]
            else:
                did = did[probe_idx]
            keep = np.ones(len(did), dtype=bool)
            for p in m.stage_filters.get(stage, ()):
                keep &= evaluate(p, mcols)
            if not keep.all():
                ks = np.flatnonzero(keep)
                mcols = {k: v[ks] for k, v in mcols.items()}
                did = did[ks]
        if self.build_target is not None and len(did) > 0:
            bt = self.build_target
            w = np.uint64(0)
            for b in m.beneficiaries:
                w |= bt.state.slots.mask(b)
            e = (U64_1 << np.uint64(m.eid)) if m.eid >= 0 else np.uint64(0)
            keycodes = encode_keys(mcols, bt.key_attrs)
            ins, mrk = bt.state.insert_or_mark(
                did,
                keycodes,
                {a: mcols[a] for a in bt.state.retained_attrs},
                np.full(len(did), w, dtype=np.uint64),
                np.full(len(did), e, dtype=np.uint64),
            )
            cost += cm["insert"] * ins + cm["mark"] * mrk
            m.rows_sunk += len(did)
            key = "residual_build_rows" if m.kind == "residual" else "ordinary_build_rows"
            engine.counters[key] += len(did) * len(m.beneficiaries)
        elif m.sink is not None and len(did) > 0:
            self._agg_sink_rows(engine, m, mcols, len(did), part)
            cost += cm["agg"] * len(did)
        return cost


# ---------------------------------------------------------------------------
# Scan node (§4.4 shared cyclic scans)
# ---------------------------------------------------------------------------


class ScanNode:
    """One shared cyclic scan, split into ``n_partitions`` contiguous
    morsel-range shards with independent cyclic cursors (§9). The node
    keeps ONE logical scan identity per table — attachment, zone maps, and
    grafting see a single scan; only delivery is sharded."""

    def __init__(
        self,
        sid: int,
        table: Table,
        morsel_size: int,
        zone_maps: bool = False,
        n_partitions: int = 1,
    ):
        self.sid = sid
        self.table = table
        self.morsel_size = morsel_size
        self.n_morsels = max(1, math.ceil(table.nrows / morsel_size))
        p = max(1, min(int(n_partitions), self.n_morsels))
        self.n_partitions = p
        base, rem = divmod(self.n_morsels, p)
        self.part_counts = np.array(
            [base + (1 if i < rem else 0) for i in range(p)], dtype=np.int64
        )
        self.part_starts = np.concatenate(([0], np.cumsum(self.part_counts)[:-1]))
        # per-partition cyclic cursor (absolute morsel index within the shard)
        self.cursors = [int(s) for s in self.part_starts]
        self.pipelines: List[Pipeline] = []
        self.row_bytes = table.nbytes() / max(table.nrows, 1)
        self.zone_maps = zone_maps
        self._zone_cache: Optional[Tuple[tuple, np.ndarray]] = None

    @property
    def cursor(self) -> int:
        """Partition-0 cursor (seed-compatible view for P == 1)."""
        return self.cursors[0]

    def attach(self, p: Pipeline) -> None:
        self.pipelines.append(p)

    def has_active_work(self) -> bool:
        return any(p.active_members() for p in self.pipelines)

    def _wave_possible(self) -> np.ndarray:
        """Beyond-paper zone-map skipping, hoisted per activation wave: one
        vectorized pass over ALL morsels' [min,max] zones per distinct set
        of active members, instead of per-morsel per-member re-derivation.
        ``possible[i]`` is False only when no active member's canonical
        predicate can match morsel i."""
        act = [m for p in self.pipelines for m in p.active_members()]
        key = tuple(m.mid for m in act)
        cached = self._zone_cache
        if cached is not None and cached[0] == key:
            return cached[1]
        zm = self.table.zone_map(self.morsel_size)
        possible = np.zeros(self.n_morsels, dtype=bool)
        for m in act:
            conj = _member_conj(m)
            if conj is None:
                possible[:] = True  # unprovable predicate -> must read
                break
            ok = np.ones(self.n_morsels, dtype=bool)
            for attr, c in conj.constraints.items():
                if attr not in zm:
                    continue
                mins, maxs = zm[attr]
                if c.lo != -math.inf:
                    ok &= (maxs > c.lo) if not c.lo_inc else (maxs >= c.lo)
                if c.hi != math.inf:
                    ok &= (mins < c.hi) if not c.hi_inc else (mins <= c.hi)
                if c.members is not None:
                    anym = np.zeros(self.n_morsels, dtype=bool)
                    for v in c.members:
                        anym |= (mins <= v) & (maxs >= v)
                    ok &= anym
                if not ok.any():
                    break
            possible |= ok
            if possible.all():
                break
        self._zone_cache = (key, possible)
        return possible

    def _bump_cursor(self, part: int) -> None:
        lo = int(self.part_starts[part])
        self.cursors[part] = lo + (self.cursors[part] + 1 - lo) % int(self.part_counts[part])

    def advance(self, engine, part: int = 0) -> float:
        """Emit partition ``part``'s next morsel to every attached pipeline
        with members still owed that shard. Physical read counted once
        (shared scan)."""
        idx = self.cursors[part]
        if self.zone_maps and not self._wave_possible()[idx]:
            engine.counters["morsels_skipped"] += 1
            cost = engine.cost_model["scan"] * 8  # zone check, not a read
            # the morsel still counts toward every member's delivery cycle
            # (zero rows pass their filters by construction)
            for p in list(self.pipelines):
                finished = []
                for m in p.active_members_for(part):
                    m.received += 1
                    if m.part_received is not None:
                        m.part_received[part] += 1
                        if m.part_received[part] >= m.part_need[part]:
                            engine.on_member_part_finished(p, m, part)
                    if m.received >= m.need:
                        m.done = True
                        m.active = False
                        finished.append(m)
                for m in finished:
                    engine.on_member_finished(p, m)
            self._bump_cursor(part)
            return cost
        start = idx * self.morsel_size
        cols = self.table.morsel(start, self.morsel_size)
        n = len(next(iter(cols.values())))
        row_ids = np.arange(start, start + n, dtype=np.int64)

        engine.counters["scan_rows"] += n
        engine.counters["scan_bytes"] += n * self.row_bytes
        cost = engine.cost_model["scan"] * n

        for p in list(self.pipelines):
            cost += p.process(engine, cols, row_ids, part)
        self._bump_cursor(part)
        return cost

    def detach(self, p: Pipeline) -> None:
        if p in self.pipelines:
            self.pipelines.remove(p)
