"""Vectorized open-addressing hash index: the shared-state data plane core.

Every shared-state hot path that used to walk a Python ``dict`` per row —
derivation-id dedup in ``SharedHashBuildState.insert_or_mark``, group-id
assignment in ``SharedAggregateState``, count(distinct) seen-sets, and the
probe-side key index — runs on this primitive instead (DESIGN.md §8).

``HashIndex`` maps int64 keys to dense ids (0, 1, 2, ... in first-insertion
order) with batched, fully vectorized ``lookup`` / ``lookup_or_insert``:

* triangular (quadratic) probing over a power-of-two table at ≤ 25% load
  — offsets 0, 1, 3, 6, ... visit every slot of a power-of-two table, and
  the low load plus secondary-cluster avoidance keep the longest probe
  chain (= the number of batched rounds) in the single digits,
* splitmix64 finalizer hash (avalanches the mixed-radix keycodes the
  engine produces, which are highly structured in their low bits),
* batch insertion by optimistic per-slot claims: each round, every still
  unplaced key writes itself into its slot if empty (numpy fancy
  assignment, last writer wins), re-reads to learn whether it survived,
  and the losers advance.  Rounds are whole-batch numpy operations — the
  number of rounds is the longest probe chain, not the batch size,
* amortized capacity doubling (a rehash is itself one batched insert of
  the resident keys), counted via the ``index_rebuilds`` perf counter.

``MultiKeyIndex`` lifts the primitive to tuples of columns (group keys,
(group, value) distinct pairs): each column is compacted to dense ids
through its own ``HashIndex``, adjacent id columns are folded pairwise into
``hi * 2^32 + lo`` codes and re-compacted, so arbitrarily many columns
reduce to one int64 stream with no collision risk (dense ids stay far below
2^32).  Float columns are keyed by their exact bit patterns (with -0.0
canonicalized to +0.0 so numpy float equality and bit equality agree).

The core is NumPy-only; the Pallas batch-insert path for the probe-table
mirror lives in ``kernels/hash_probe.py`` (``hash_build_insert``).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

EMPTY_KEY = np.int64(np.iinfo(np.int64).min)  # reserved slot sentinel

_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_FOLD = np.int64(1) << np.int64(32)


def _mix64(keys: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer over int64 keys -> uint64 hash values."""
    h = keys.astype(np.uint64)
    h = (h ^ (h >> np.uint64(30))) * _M1
    h = (h ^ (h >> np.uint64(27))) * _M2
    return h ^ (h >> np.uint64(31))


def key_partition(keys: np.ndarray, n_partitions: int) -> np.ndarray:
    """Key-hash partition id per int64 key: ``splitmix64(key) % P``.

    This is the one partitioning function of the sharded state plane
    (DESIGN.md §9): shared hash-build states route derivations, probe keys,
    and index shards through it, so a key's shard is stable across the
    producer and consumer sides of every boundary. P == 1 short-circuits to
    an all-zeros vector (the unpartitioned engine never hashes)."""
    keys = np.asarray(keys, dtype=np.int64)
    if n_partitions <= 1:
        return np.zeros(len(keys), dtype=np.int64)
    return (_mix64(keys) % np.uint64(n_partitions)).astype(np.int64)


def float_key_codes(col: np.ndarray) -> np.ndarray:
    """Exact int64 key codes for a float64 column (bit pattern, with -0.0
    canonicalized to +0.0 so float equality matches code equality)."""
    c = np.asarray(col, dtype=np.float64) + 0.0  # -0.0 -> +0.0
    return c.view(np.int64)


class HashIndex:
    """int64 keys -> dense ids in first-insertion order, batch-oriented."""

    __slots__ = ("_keys", "_vals", "n", "rebuilds", "_counters")

    def __init__(self, capacity: int = 256, counters: Optional[Dict] = None):
        cap = 8
        while cap < capacity:
            cap *= 2
        self._keys = np.full(cap, EMPTY_KEY, dtype=np.int64)
        self._vals = np.zeros(cap, dtype=np.int64)
        self.n = 0  # dense ids handed out
        self.rebuilds = 0
        self._counters = counters  # engine counter sink (index_rebuilds)

    # -- queries ----------------------------------------------------------
    def lookup(self, keys: np.ndarray, _hash: Optional[np.ndarray] = None) -> np.ndarray:
        """Dense id per key, -1 where absent. O(batch) whole-batch rounds."""
        keys = np.asarray(keys, dtype=np.int64)
        out = np.full(len(keys), -1, dtype=np.int64)
        if self.n == 0 or len(keys) == 0:
            return out
        tkeys, tvals = self._keys, self._vals
        mask = np.int64(len(tkeys) - 1)
        h = _mix64(keys) if _hash is None else _hash
        pos = (h & np.uint64(mask)).astype(np.int64)
        pend: Optional[np.ndarray] = None  # None = all keys still probing
        cur_keys = keys
        r = np.int64(0)
        while len(pos):
            sk = tkeys[pos]
            hit = sk == cur_keys
            if hit.any():
                tgt = np.flatnonzero(hit) if pend is None else pend[hit]
                out[tgt] = tvals[pos[hit]]
            live = ~hit & (sk != EMPTY_KEY)
            if not live.any():
                break
            pend = np.flatnonzero(live) if pend is None else pend[live]
            r += 1  # triangular offsets: home, +1, +3, +6, ...
            pos = (pos[live] + r) & mask
            cur_keys = keys[pend]
        return out

    def lookup_or_insert(self, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Dense id per key, inserting absent keys in first-occurrence order.

        Returns ``(ids, is_new)`` — ``is_new[i]`` is True exactly where a
        Python ``dict.setdefault(k, len(dict))`` over the same stream would
        have inserted (first occurrence of a previously absent key)."""
        keys = np.asarray(keys, dtype=np.int64)
        n_in = len(keys)
        is_new = np.zeros(n_in, dtype=bool)
        if n_in == 0:
            return np.empty(0, dtype=np.int64), is_new
        if (keys == EMPTY_KEY).any():
            raise ValueError("int64 min is reserved as the empty-slot sentinel")
        h = _mix64(keys)
        found = self.lookup(keys, _hash=h)
        absent = found < 0
        if absent.any():
            # dedupe only the absent subset (usually far smaller than the
            # batch), in first-occurrence order for dict parity
            aidx = np.flatnonzero(absent)
            uniq, first, inv = np.unique(keys[aidx], return_index=True, return_inverse=True)
            order = np.argsort(first, kind="stable")
            rank = np.empty(len(uniq), dtype=np.int64)
            rank[order] = np.arange(len(uniq), dtype=np.int64)
            n_new = len(uniq)
            self._reserve(self.n + n_new)
            new_ids = self.n + np.arange(n_new, dtype=np.int64)
            src = aidx[first[order]]  # first occurrence of each new key
            self._insert_unique(keys[src], new_ids, _hash=h[src])
            self.n += n_new
            found[aidx] = new_ids[rank[np.asarray(inv).ravel()]]
            is_new[src] = True
        return found, is_new

    # -- internals --------------------------------------------------------
    def _reserve(self, target: int) -> None:
        cap = len(self._keys)
        if 4 * target <= cap:
            return
        while cap < 4 * target:
            cap *= 2
        old_keys, old_vals = self._keys, self._vals
        live = old_keys != EMPTY_KEY
        self._keys = np.full(cap, EMPTY_KEY, dtype=np.int64)
        self._vals = np.zeros(cap, dtype=np.int64)
        self._insert_unique(old_keys[live], old_vals[live])
        self.rebuilds += 1
        if self._counters is not None:
            self._counters["index_rebuilds"] += 1

    def _insert_unique(
        self, keys: np.ndarray, vals: np.ndarray, _hash: Optional[np.ndarray] = None
    ) -> None:
        """Batch-insert keys known to be distinct and absent: optimistic
        claims (fancy assignment, last writer per slot wins), survival
        check by re-read, then an unconditional value write for the
        survivors so the key/value pairing never depends on numpy's
        duplicate-index write order. Non-winners advance."""
        tkeys, tvals = self._keys, self._vals
        mask = np.int64(len(tkeys) - 1)
        h = _mix64(keys) if _hash is None else _hash
        pos = (h & np.uint64(mask)).astype(np.int64)
        pend: Optional[np.ndarray] = None
        cur_keys = keys
        r = np.int64(0)
        while len(pos):
            free = tkeys[pos] == EMPTY_KEY
            if free.any():
                pf = pos[free]
                tkeys[pf] = cur_keys[free]  # optimistic claim
                won = free & (tkeys[pos] == cur_keys)  # survived the write?
                wp = pos[won]
                tvals[wp] = vals[won] if pend is None else vals[pend[won]]
                live = ~won
            else:
                live = np.ones(len(pos), dtype=bool)
            if not live.any():
                break
            pend = np.flatnonzero(live) if pend is None else pend[live]
            r += 1  # triangular offsets, matching lookup()
            pos = (pos[live] + r) & mask
            cur_keys = keys[pend]

    def __len__(self) -> int:
        return self.n

    def __contains__(self, key: int) -> bool:
        return int(self.lookup(np.asarray([key], dtype=np.int64))[0]) >= 0


class MultiKeyIndex:
    """Dense ids for tuples of column values (group keys, distinct pairs).

    Columns may be float64 (keyed by exact bit pattern) or any integer
    dtype (keyed by value). Dense ids are assigned in first-occurrence
    order of the full tuple, matching a ``dict`` over tuple keys."""

    __slots__ = ("_cols", "_folds", "n")

    def __init__(self, n_cols: int, counters: Optional[Dict] = None):
        if n_cols < 1:
            raise ValueError("MultiKeyIndex needs at least one key column")
        self._cols = [HashIndex(counters=counters) for _ in range(n_cols)]
        self._folds = [HashIndex(counters=counters) for _ in range(n_cols - 1)]
        self.n = 0

    @staticmethod
    def _codes(col: np.ndarray) -> np.ndarray:
        col = np.asarray(col)
        if col.dtype.kind == "f":
            return float_key_codes(col)
        return col.astype(np.int64)

    def lookup_or_insert(self, cols: Sequence[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
        if len(cols) != len(self._cols):
            raise ValueError("column count mismatch")
        ids, is_new = self._cols[0].lookup_or_insert(self._codes(cols[0]))
        for k in range(1, len(cols)):
            nxt, _ = self._cols[k].lookup_or_insert(self._codes(cols[k]))
            ids, is_new = self._folds[k - 1].lookup_or_insert(ids * _FOLD + nxt)
        self.n = (self._folds[-1] if self._folds else self._cols[0]).n
        return ids, is_new
