"""Deterministic fault injection (DESIGN.md §16).

GraftDB's folding widens every query's failure domain: operator state is
shared, so a fault inside one producer's morsel threatens every folded
beneficiary. The fault plane makes that failure domain *testable*: a seeded
``FaultPlan`` injects failures at the engine's real boundaries — morsel
execution, the mesh exchange, artifact rehydration, worker stalls — as a
pure function of ``(seed, site, occurrence index)``. Because the scheduler
is a deterministic simulation under the virtual clock, the occurrence
indexes replay identically run over run, so every chaos schedule is
bit-reproducible: same seed + same workload ⇒ same faults at the same
virtual instants ⇒ same surviving results.

Sites:

* ``morsel``    — a (scan × partition) morsel advance fails before any
  state mutation (kernel error / worker crash). Retried with
  WorkClock-charged exponential backoff; retry exhaustion escalates to
  quarantine (build pipelines) or unfold (main pipelines).
* ``exchange``  — the §14 bucketed all_to_all exhausts its bucket-overflow
  regrowth. Drawn instead of ``morsel`` on mesh sessions (every morsel
  there transits the sharded exchange).
* ``rehydrate`` — a spilled artifact is corrupt at rehydration: the reuse
  plane counts ``cache_corrupt``, drops the artifact, and falls through to
  recompute — never raising into the arrival path.
* ``stall``     — a worker stalls for ``stall_s`` virtual seconds before
  executing its morsel (slow node / GC pause). Pure delay, never an error.

``FaultPlan(schedule={})`` arms the hooks with zero perturbation: every
draw misses and charges nothing, so results, counters, and virtual clocks
are identical to ``faults=None`` — the overhead-identity leg of
``benchmarks/chaos_sweep.py`` pins this.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple, Union

SITES = ("morsel", "exchange", "rehydrate", "stall")

_MASK64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """splitmix64 finalizer: a high-quality pure-int hash, no RNG state."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, declarative chaos schedule for one session.

    * ``seed`` — hash seed; two sessions with the same seed + schedule +
      workload inject bit-identical fault sequences.
    * ``schedule`` — ``site -> rate | occurrence indexes``: a float in
      [0, 1] fires probabilistically per draw (hashed, not sampled — no
      RNG state), a collection of ints fires at exactly those per-site
      occurrence indexes (0-based). Unlisted sites never fire.
    * ``retry_limit`` — bounded deterministic retries per faulted morsel
      before escalation (quarantine / unfold).
    * ``backoff_s`` — virtual seconds charged to the executing worker's
      clock per retry, doubling each attempt.
    * ``stall_s`` — virtual seconds one fired ``stall`` delays a worker.
    * ``max_injections`` — global cap on fired faults (None = unbounded);
      a chaos run at rate 1.0 still terminates without it (escalation
      unfolds then fails each query), but the cap keeps sweeps cheap.
    """

    seed: int = 0
    schedule: Mapping[str, Union[float, Tuple[int, ...]]] = field(
        default_factory=dict
    )
    retry_limit: int = 2
    backoff_s: float = 1e-4
    stall_s: float = 5e-4
    max_injections: Optional[int] = None

    def __post_init__(self):
        if not isinstance(self.seed, int):
            raise ValueError(f"FaultPlan.seed must be an int, got {self.seed!r}")
        for site, spec in dict(self.schedule).items():
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; expected one of {SITES}"
                )
            if isinstance(spec, bool):
                raise ValueError(f"fault schedule for {site!r} must be a rate "
                                 f"in [0, 1] or a collection of occurrence "
                                 f"indexes, got {spec!r}")
            if isinstance(spec, (int, float)):
                if not (0.0 <= float(spec) <= 1.0):
                    raise ValueError(
                        f"fault rate for {site!r} must be in [0, 1], got {spec!r}"
                    )
            else:
                try:
                    idxs = tuple(int(i) for i in spec)
                except TypeError:
                    raise ValueError(
                        f"fault schedule for {site!r} must be a rate or a "
                        f"collection of occurrence indexes, got {spec!r}"
                    ) from None
                if any(i < 0 for i in idxs):
                    raise ValueError(
                        f"occurrence indexes for {site!r} must be >= 0, got {idxs}"
                    )
        if not isinstance(self.retry_limit, int) or self.retry_limit < 0:
            raise ValueError(
                f"retry_limit must be a non-negative int, got {self.retry_limit!r}"
            )
        if self.backoff_s < 0 or self.stall_s < 0:
            raise ValueError("backoff_s and stall_s must be non-negative")
        if self.max_injections is not None and (
            not isinstance(self.max_injections, int) or self.max_injections < 0
        ):
            raise ValueError(
                f"max_injections must be a non-negative int or None, "
                f"got {self.max_injections!r}"
            )


class FaultPlane:
    """Runtime of one FaultPlan: per-site occurrence counters + the pure
    fire decision. Owned by the engine, consulted by the scheduler (morsel /
    exchange / stall sites) and the reuse plane (rehydrate site). All state
    is a deterministic function of the draw sequence, which the virtual
    clock makes a deterministic function of the workload."""

    def __init__(self, plan: FaultPlan, counters: Optional[Dict] = None):
        self.plan = plan
        self.counters = counters if counters is not None else {}
        self._calls: Dict[str, int] = {s: 0 for s in SITES}
        self._injected = 0
        # normalize the schedule once: site -> ('rate', p) | ('at', frozenset)
        self._sched: Dict[str, Tuple[str, object]] = {}
        for site, spec in dict(plan.schedule).items():
            if isinstance(spec, (int, float)):
                if float(spec) > 0.0:
                    self._sched[site] = ("rate", float(spec))
            else:
                idxs = frozenset(int(i) for i in spec)
                if idxs:
                    self._sched[site] = ("at", idxs)

    def fire(self, site: str) -> bool:
        """One draw at ``site``: advances the per-site occurrence index and
        returns whether this occurrence faults. Pure in (seed, site, index)."""
        i = self._calls[site]
        self._calls[site] = i + 1
        spec = self._sched.get(site)
        if spec is None:
            return False
        cap = self.plan.max_injections
        if cap is not None and self._injected >= cap:
            return False
        kind, val = spec
        if kind == "at":
            hit = i in val
        else:
            h = _mix64(_mix64(self.plan.seed & _MASK64) ^ _mix64(
                (SITES.index(site) << 48) ^ i
            ))
            hit = (h / 2.0**64) < val
        if hit:
            self._injected += 1
            self.counters["faults_injected"] = (
                self.counters.get("faults_injected", 0) + 1
            )
        return hit

    def stall(self) -> float:
        """Virtual delay of one potential worker stall (0.0 = no stall).
        Only draws when the schedule lists the site, so stall-free plans
        keep the other sites' occurrence indexes unperturbed."""
        if "stall" not in self._sched:
            return 0.0
        return self.plan.stall_s if self.fire("stall") else 0.0

    def attempt(self, site: str, clock) -> bool:
        """Bounded deterministic retry of one morsel-boundary fault site:
        draws up to ``retry_limit + 1`` times, charging exponential backoff
        to the executing worker's clock between attempts. Returns True when
        an attempt succeeds, False when retries are exhausted (escalate)."""
        plan = self.plan
        for i in range(plan.retry_limit + 1):
            if not self.fire(site):
                return True
            if i < plan.retry_limit:
                self.counters["fault_retries"] = (
                    self.counters.get("fault_retries", 0) + 1
                )
                clock.tick(plan.backoff_s * (2.0**i))
        return False
