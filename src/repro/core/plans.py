"""Physical plan representation for GraftDB queries.

GraftDB targets finite analytical SELECT queries representable as acyclic
relational operator plans built from base-table scans, selections,
projections, hash joins, and aggregations (§3.2). A query instance is a plan
tree plus concrete parameter values already substituted into predicates.

Plans here are *physical*: join order and operator sequence are fixed per
template before any sharing decision is applied (mirroring the paper's
PostgreSQL-pinned plans), and sharing decisions never change the plan shape —
they only re-source stateful boundaries onto shared state.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .predicates import Pred, TRUE, free_attrs

# ---------------------------------------------------------------------------
# Scalar expression AST (aggregate inputs like sum(price * (1 - discount)))
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Col:
    name: str


@dataclass(frozen=True)
class Const:
    value: float


@dataclass(frozen=True)
class BinOp:
    op: str  # '+', '-', '*'
    lhs: object
    rhs: object


@dataclass(frozen=True)
class WhereEq:
    """CASE WHEN attr == value THEN then_expr ELSE else_expr (TPC-H Q8)."""

    attr: str
    value: float
    then: object
    other: object


Expr = object  # Col | Const | BinOp | WhereEq


def expr_eval(e: Expr, cols: Dict[str, np.ndarray]) -> np.ndarray:
    if isinstance(e, Col):
        return cols[e.name]
    if isinstance(e, Const):
        return e.value  # broadcasts
    if isinstance(e, BinOp):
        a, b = expr_eval(e.lhs, cols), expr_eval(e.rhs, cols)
        if e.op == "+":
            return a + b
        if e.op == "-":
            return a - b
        if e.op == "*":
            return a * b
        raise ValueError(e.op)
    if isinstance(e, WhereEq):
        return np.where(
            cols[e.attr] == e.value, expr_eval(e.then, cols), expr_eval(e.other, cols)
        )
    raise TypeError(e)


def expr_attrs(e: Expr) -> frozenset:
    if isinstance(e, Col):
        return frozenset((e.name,))
    if isinstance(e, Const):
        return frozenset()
    if isinstance(e, BinOp):
        return expr_attrs(e.lhs) | expr_attrs(e.rhs)
    if isinstance(e, WhereEq):
        return frozenset((e.attr,)) | expr_attrs(e.then) | expr_attrs(e.other)
    raise TypeError(e)


def expr_key(e: Expr):
    if isinstance(e, Col):
        return ("col", e.name)
    if isinstance(e, Const):
        return ("const", float(e.value))
    if isinstance(e, BinOp):
        return ("bin", e.op, expr_key(e.lhs), expr_key(e.rhs))
    if isinstance(e, WhereEq):
        return ("where_eq", e.attr, float(e.value), expr_key(e.then), expr_key(e.other))
    raise TypeError(e)


# ---------------------------------------------------------------------------
# Plan nodes
# ---------------------------------------------------------------------------


@dataclass
class Scan:
    """Base-table scan + selection + projection (filters fold into scans)."""

    table: str
    pred: Pred = TRUE
    columns: Tuple[str, ...] = ()


@dataclass
class HashJoin:
    """Inner equi hash join. ``build`` is the state-side input subtree;
    ``probe`` drives lookups (consumer-side data flow, §3.3).

    ``payload_as`` optionally renames payload attrs in the join output (the
    state keeps canonical names so sharing is preserved; e.g. TPC-H Q7 probes
    two nation-derived states whose payloads would otherwise collide).
    ``post_filter`` is applied to the join output (evaluation-only predicates
    such as Q5's c_nationkey = s_nationkey)."""

    build: object
    probe: object
    build_keys: Tuple[str, ...]
    probe_keys: Tuple[str, ...]
    payload: Tuple[str, ...]  # build-side attrs carried to output (RetainedAttrs)
    payload_as: Optional[Tuple[str, ...]] = None
    post_filter: Pred = TRUE


@dataclass(frozen=True)
class AggSpec:
    func: str  # 'sum' | 'count' | 'avg' | 'min' | 'max'
    expr: Optional[Expr] = None  # None for count(*)
    distinct: bool = False
    name: str = ""


@dataclass
class Aggregate:
    input: object
    group_keys: Tuple[str, ...]
    aggs: Tuple[AggSpec, ...]


@dataclass
class OrderBy:
    """Final presentation operator — never shared, negligible work."""

    input: object
    keys: Tuple[str, ...]
    ascending: Tuple[bool, ...]
    limit: Optional[int] = None


PlanNode = object  # Scan | HashJoin | Aggregate | OrderBy


@dataclass
class Query:
    """A query instance: template id, plan, params (for reporting)."""

    qid: int
    template: str
    plan: PlanNode
    params: Dict[str, object] = field(default_factory=dict)
    arrival: float = 0.0


# ---------------------------------------------------------------------------
# Plan utilities
# ---------------------------------------------------------------------------


def plan_scans(node: PlanNode) -> List[Scan]:
    if isinstance(node, Scan):
        return [node]
    if isinstance(node, HashJoin):
        return plan_scans(node.build) + plan_scans(node.probe)
    if isinstance(node, (Aggregate, OrderBy)):
        return plan_scans(node.input)
    raise TypeError(node)


def plan_output_columns(node: PlanNode) -> Tuple[str, ...]:
    """Columns available at a node's output."""
    if isinstance(node, Scan):
        return tuple(node.columns)
    if isinstance(node, HashJoin):
        out_names = node.payload_as if node.payload_as is not None else node.payload
        return tuple(plan_output_columns(node.probe)) + tuple(out_names)
    if isinstance(node, Aggregate):
        return tuple(node.group_keys) + tuple(a.name for a in node.aggs)
    if isinstance(node, OrderBy):
        return plan_output_columns(node.input)
    raise TypeError(node)


def collect_subtree_pred(node: PlanNode) -> Pred:
    """All predicates applied inside a subtree, as one conjunction. This is
    the state-side predicate of a hash-build subtree (coverage vocabulary)."""
    from .predicates import pred_and

    if isinstance(node, Scan):
        return node.pred
    if isinstance(node, HashJoin):
        return pred_and(
            collect_subtree_pred(node.build),
            collect_subtree_pred(node.probe),
            node.post_filter,
        )
    if isinstance(node, (Aggregate, OrderBy)):
        return collect_subtree_pred(node.input)
    raise TypeError(node)


def strip_pred_subtree(node: PlanNode):
    """Structural skeleton of a subtree with predicates removed — the
    non-predicate part of a state signature (§4.3: relation, keys, payload
    layout, required upstream state)."""
    if isinstance(node, Scan):
        return ("scan", node.table, tuple(node.columns))
    if isinstance(node, HashJoin):
        return (
            "hashjoin",
            strip_pred_subtree(node.build),
            strip_pred_subtree(node.probe),
            tuple(node.build_keys),
            tuple(node.probe_keys),
            tuple(node.payload),
            tuple(node.payload_as) if node.payload_as is not None else None,
        )
    if isinstance(node, Aggregate):
        return (
            "aggregate",
            strip_pred_subtree(node.input),
            tuple(node.group_keys),
            tuple((a.func, expr_key(a.expr) if a.expr is not None else None, a.distinct) for a in node.aggs),
        )
    if isinstance(node, OrderBy):
        return strip_pred_subtree(node.input)
    raise TypeError(node)
