"""Lens descriptors, state signatures, and coverage metadata (§4.1, §4.3).

A *state signature* fixes the exact non-predicate identity of a shared state:

* hash-build state: build relation subtree (structure only), build keys,
  payload layout, and required upstream state (captured structurally by the
  subtree skeleton). Predicates are NOT part of the signature — they live in
  coverage metadata, so one physical table can cover several predicate
  extents.
* aggregate state: exact aggregate identity — the aggregate input *including
  the per-query input condition* (predicates), grouping keys, aggregate
  functions, and distinct-argument semantics (§4.5).

A *lens descriptor* is what an arriving query requires at a stateful
boundary: the signature it must match exactly plus the predicate/extent
obligations checked by the prover.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from .plans import Aggregate, HashJoin, PlanNode, expr_key, strip_pred_subtree, collect_subtree_pred
from .predicates import Conjunction, Pred


@dataclass(frozen=True)
class StateSignature:
    kind: str  # 'hash_build' | 'aggregate'
    key: tuple  # canonical structural key

    def __repr__(self):
        return f"StateSignature({self.kind}, {hash(self.key) & 0xFFFFFF:06x})"


def hash_build_signature(join: HashJoin) -> StateSignature:
    """Signature of the hash-build state at a HashJoin boundary."""
    return StateSignature(
        kind="hash_build",
        key=(
            strip_pred_subtree(join.build),
            tuple(join.build_keys),
            tuple(join.payload),
        ),
    )


def aggregate_signature(agg: Aggregate) -> Optional[StateSignature]:
    """Exact aggregate identity. Includes the canonicalized per-query input
    condition; returns None when the input condition is outside the
    supported predicate fragment (identity then unprovable -> no sharing)."""
    cond = Conjunction.from_pred(collect_subtree_pred(agg.input))
    if cond is None:
        return None
    return StateSignature(
        kind="aggregate",
        key=(
            strip_pred_subtree(agg.input),
            cond.key(),
            tuple(agg.group_keys),
            tuple(
                (a.func, expr_key(a.expr) if a.expr is not None else None, a.distinct)
                for a in agg.aggs
            ),
        ),
    )


@dataclass(frozen=True)
class LensDescriptor:
    """d = (a, rho): lens signature + operator rule (§5.2).

    For hash-probe boundaries ``rho`` is the (fixed) inner-join rule and
    ``build_pred`` is B_q, the query's required build-side predicate as a
    canonical conjunction (None when outside the fragment — then nothing can
    be proven represented). For aggregate boundaries the signature alone *is*
    the identity."""

    signature: StateSignature
    build_pred: Optional[Conjunction] = None  # hash_build only
    rule: str = "inner"
