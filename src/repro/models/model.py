"""Model assembly: parameter trees, training forward, prefill, and decode.

Layers are stacked per repetition group (scan-over-layers) so HLO size and
compile time are O(1) in depth. A "group" is one repetition pattern — e.g.
recurrentgemma's ("rec", "rec", "attn") period — whose parameters carry a
leading repetition dim; `jax.lax.scan` + `jax.checkpoint` iterate it.

Modes:
* forward_train: full-sequence, remat per period, optional sequence-sharded
  residual stream (Megatron-style sequence parallelism via sharding
  constraints),
* prefill: full-sequence, also returns the per-layer KV/recurrent caches,
* decode_step: one token against ring-buffer KV caches / recurrent states.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import attention, attention_decode, mlp, rms_norm
from .moe import moe_ffn
from .recurrent import (
    recurrent_block,
    recurrent_block_decode,
    rwkv_time_mix,
    rwkv_time_mix_decode,
)

# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------


def layer_groups(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """[(pattern, n_repetitions)] covering cfg.n_layers decoder layers."""
    pattern = cfg.block_pattern or ("attn",)
    period = len(pattern)
    n_full, rem = divmod(cfg.n_layers, period)
    groups = []
    if n_full:
        groups.append((tuple(pattern), n_full))
    if rem:
        groups.append((tuple(pattern[:rem]), 1))
    return groups


def _block_kinds(cfg: ModelConfig, pattern: Tuple[str, ...], cross: bool) -> List[str]:
    return [f"{k}{i}" for i, k in enumerate(pattern)]


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def _attn_defs(cfg, cross=False):
    D, H, KV, dh = cfg.d_model, cfg.n_heads_padded, cfg.n_kv_heads, cfg.d_head
    pre = "c" if cross else ""
    return {
        f"{pre}wq": (D, H, dh),
        f"{pre}wk": (D, KV, dh),
        f"{pre}wv": (D, KV, dh),
        f"{pre}wo": (H, dh, D),
    }


def _ffn_defs(cfg, moe_layer: bool):
    D, F = cfg.d_model, cfg.d_ff
    if moe_layer:
        mc = cfg.moe
        E, Fe = mc.n_experts, mc.d_ff_expert
        d = {
            "router": (D, E),
            "w_gate": (E, D, Fe),
            "w_up": (E, D, Fe),
            "w_down": (E, Fe, D),
        }
        if mc.n_shared:
            d.update(
                shared_gate=(D, Fe * mc.n_shared),
                shared_up=(D, Fe * mc.n_shared),
                shared_down=(Fe * mc.n_shared, D),
            )
        return d
    if cfg.mlp_kind == "swiglu":
        return {"w_gate": (D, F), "w_up": (D, F), "w_down": (F, D)}
    if cfg.mlp_kind == "gelu":
        return {"w_up": (D, F), "w_down": (F, D)}
    if cfg.mlp_kind == "rwkv_cm":
        return {"w_up": (D, F), "w_down": (F, D), "w_recept": (D, D)}
    raise ValueError(cfg.mlp_kind)


def _block_defs(cfg, kind: str, cross: bool) -> Dict[str, Tuple[int, ...]]:
    D, R = cfg.d_model, cfg.lru_dim
    if kind.startswith("attn"):
        moe_layer = cfg.moe is not None and not kind.startswith("attn_dense")
        d = {"ln1": (D,), "ln2": (D,)}
        d.update(_attn_defs(cfg))
        d.update(_ffn_defs(cfg, moe_layer))
        if cross:
            d["ln_cross"] = (D,)
            d.update(_attn_defs(cfg, cross=True))
        return d
    if kind.startswith("rec"):
        d = {
            "ln1": (D,),
            "ln2": (D,),
            "w_gate_in": (D, R),
            "w_rec_in": (D, R),
            "conv_w": (cfg.conv_width, R),
            "conv_b": (R,),
            "w_a": (R, R),
            "w_x": (R, R),
            "lam": (R,),
            "w_out": (R, D),
        }
        d.update(_ffn_defs(cfg, False))
        return d
    if kind.startswith("rwkv"):
        K = cfg.n_heads * cfg.rwkv_head_dim
        d = {
            "ln1": (D,),
            "ln2": (D,),
            "w_r": (D, K),
            "w_k": (D, K),
            "w_v": (D, K),
            "w_g": (D, K),
            "w_o": (K, D),
            "w_dec0": (K,),
            "w_dec1": (D, 64),
            "w_dec2": (64, K),
            "u": (K,),
            "ln_w": (cfg.n_heads, cfg.rwkv_head_dim),
            "ln_b": (cfg.n_heads, cfg.rwkv_head_dim),
            "mu_r": (D,),
            "mu_k": (D,),
            "mu_v": (D,),
            "mu_g": (D,),
            "mu_w": (D,),
        }
        d.update(_ffn_defs(cfg, False))
        return d
    raise ValueError(kind)


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    """Shape tree (tuples) for the whole model."""
    Vp, D = cfg.vocab_padded, cfg.d_model
    cross = cfg.n_encoder_layers > 0
    tree: Dict[str, Any] = {"embed": (Vp, D), "final_norm": (D,)}
    if not cfg.tied_embeddings:
        tree["lm_head"] = (D, Vp)
    groups = []
    for pattern, n_rep in layer_groups(cfg):
        g = {}
        for name, kind in zip(_block_kinds(cfg, pattern, cross), pattern):
            g[name] = {
                k: (n_rep,) + shape for k, shape in _block_defs(cfg, kind, cross).items()
            }
        groups.append(g)
    tree["groups"] = groups
    if cross:
        eg = {
            "attn0": {
                k: (cfg.n_encoder_layers,) + s
                for k, s in _block_defs(cfg, "attn", False).items()
            }
        }
        tree["enc_groups"] = [eg]
        tree["enc_final_norm"] = (D,)
    return tree


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, dtype),
        param_defs(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    defs = param_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    paths = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, tuple)
    )[0]

    def init_one(path, shape, k):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name.startswith(("ln", "final_norm", "enc_final_norm", "conv_b", "w_dec0")):
            return jnp.zeros(shape, dtype)
        if name.startswith("mu"):
            return jnp.full(shape, 0.5, dtype)
        if name == "lam":
            # init so the decay a = exp(-c*softplus(lam)) ~ U(0.9, 0.99)
            return jnp.asarray(
                jax.random.uniform(k, shape, jnp.float32, -4.0, -2.0), dtype
            )
        if name == "u":
            return jnp.asarray(jax.random.normal(k, shape) * 0.1, dtype)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 0.02 if name in ("embed",) else 1.0 / math.sqrt(max(fan_in, 1))
        return jnp.asarray(jax.random.normal(k, shape) * scale, dtype)

    out = [init_one(p, s, k) for (p, s), k in zip(paths, keys)]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Blocks (forward)
# ---------------------------------------------------------------------------


def _ffn_apply(cfg, p, x):
    if cfg.moe is not None and "router" in p:
        return moe_ffn(p, x, cfg)
    return mlp(p, x, cfg.mlp_kind)


def _block_apply(cfg, kind: str, p, x, *, causal=True, memory=None, act_spec=None):
    if kind.startswith("attn"):
        window = cfg.attn_window if causal else None
        x = x + attention(p, rms_norm(p["ln1"], x), cfg, causal=causal, window=window)
        if memory is not None:
            cp = {"wq": p["cwq"], "wk": p["cwk"], "wv": p["cwv"], "wo": p["cwo"]}
            x = x + attention(
                cp, rms_norm(p["ln_cross"], x), cfg, causal=False, kv_source=memory, use_rope=False
            )
        x = x + _ffn_apply(cfg, p, rms_norm(p["ln2"], x))
    elif kind.startswith("rec"):
        x = x + recurrent_block(p, rms_norm(p["ln1"], x), cfg)
        x = x + mlp(p, rms_norm(p["ln2"], x), cfg.mlp_kind)
    elif kind.startswith("rwkv"):
        x = x + rwkv_time_mix(p, rms_norm(p["ln1"], x), cfg)
        x = x + mlp(p, rms_norm(p["ln2"], x), cfg.mlp_kind)
    else:
        raise ValueError(kind)
    if act_spec is not None:
        x = jax.lax.with_sharding_constraint(x, act_spec)
    return x


def _run_groups(cfg, groups_params, patterns, x, *, causal, memory, act_spec, remat):
    for (pattern, n_rep), gp in zip(patterns, groups_params):
        kinds = _block_kinds(cfg, pattern, memory is not None)

        def period(xc, pp):
            for name, kind in zip(kinds, pattern):
                xc = _block_apply(
                    cfg, kind, pp[name], xc, causal=causal, memory=memory, act_spec=act_spec
                )
            return xc, None

        body = jax.checkpoint(period) if remat else period
        x, _ = jax.lax.scan(body, x, gp)
    return x


# ---------------------------------------------------------------------------
# Public forward passes
# ---------------------------------------------------------------------------


def embed_tokens(cfg, params, tokens):
    return params["embed"][tokens]


def forward_train(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray], act_spec=None):
    """-> final hidden states [B, S, D]."""
    x = embed_tokens(cfg, params, batch["tokens"]).astype(params["embed"].dtype)
    if cfg.frontend == "vision_stub":
        x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
    memory = None
    if cfg.n_encoder_layers:
        m = batch["src_embeds"].astype(x.dtype)
        m = _run_groups(
            cfg,
            params["enc_groups"],
            [(("attn",), cfg.n_encoder_layers)],
            m,
            causal=False,
            memory=None,
            act_spec=act_spec,
            remat=cfg.remat,
        )
        memory = rms_norm(params["enc_final_norm"], m)
    x = _run_groups(
        cfg,
        params["groups"],
        layer_groups(cfg),
        x,
        causal=True,
        memory=memory,
        act_spec=act_spec,
        remat=cfg.remat,
    )
    return rms_norm(params["final_norm"], x)


def lm_head_weight(cfg, params):
    if cfg.tied_embeddings:
        return params["embed"].T
    return params["lm_head"]


def loss_fn(cfg: ModelConfig, params, batch, act_spec=None, chunk: int = 1024):
    """Chunked softmax cross-entropy (the [B,S,V] logits tensor never
    materializes — §Dry-run memory)."""
    hidden = forward_train(cfg, params, batch, act_spec=act_spec)
    targets = batch["targets"]
    S = targets.shape[1]
    hidden = hidden[:, -S:]  # vlm: loss over the text suffix only
    W = lm_head_weight(cfg, params)
    chunk = min(chunk, S)
    n = S // chunk
    hs = hidden[:, : n * chunk].reshape(hidden.shape[0], n, chunk, -1).swapaxes(0, 1)
    ts = targets[:, : n * chunk].reshape(targets.shape[0], n, chunk).swapaxes(0, 1)

    def step(acc, xs):
        h, t = xs
        logits = jnp.einsum("bsd,dv->bsv", h, W).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hs, ts))
    return total / (targets.shape[0] * n * chunk)


# ---------------------------------------------------------------------------
# Serving: prefill & decode
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Shape/dtype tree of the decode cache (ring-buffer KV / recurrent)."""
    KV, dh, R, D = cfg.n_kv_heads, cfg.d_head, cfg.lru_dim, cfg.d_model
    H = cfg.n_heads
    cross = cfg.n_encoder_layers > 0
    groups = []
    for pattern, n_rep in layer_groups(cfg):
        g = {}
        for name, kind in zip(_block_kinds(cfg, pattern, cross), pattern):
            if kind.startswith("attn"):
                cap = cache_len if cfg.attn_window is None else min(cache_len, cfg.attn_window)
                ent = {
                    "k": ((n_rep, batch, cap, KV, dh), dtype),
                    "v": ((n_rep, batch, cap, KV, dh), dtype),
                    "pos": ((n_rep, cap), jnp.int32),
                }
                if cross:
                    src = max(cache_len // 4, 1)
                    ent["ck"] = ((n_rep, batch, src, KV, dh), dtype)
                    ent["cv"] = ((n_rep, batch, src, KV, dh), dtype)
                g[name] = ent
            elif kind.startswith("rec"):
                g[name] = {
                    "h": ((n_rep, batch, R), jnp.float32),
                    "conv": ((n_rep, batch, cfg.conv_width - 1, R), dtype),
                }
            elif kind.startswith("rwkv"):
                g[name] = {
                    "S": ((n_rep, batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
                    "x_prev": ((n_rep, batch, D), dtype),
                }
        groups.append(g)
    return groups


def abstract_cache(cfg, batch, cache_len, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(*sd),
        cache_defs(cfg, batch, cache_len, dtype),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple),
    )


def init_cache(cfg, batch, cache_len, dtype=jnp.bfloat16):
    def mk(sd):
        shape, dt = sd
        if dt == jnp.int32:
            return jnp.full(shape, -(1 << 30), jnp.int32)  # invalid positions
        return jnp.zeros(shape, dt)

    return jax.tree.map(
        mk,
        cache_defs(cfg, batch, cache_len, dtype),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple),
    )


def _block_decode(cfg, kind, p, c, x, pos):
    if kind.startswith("attn"):
        window = cfg.attn_window
        attn_out, c2 = _attn_ring_decode(p, rms_norm(p["ln1"], x), c, pos, cfg, window)
        x = x + attn_out
        new_c = dict(c)
        new_c.update(c2)
        if "ck" in c:  # cross-attention against precomputed encoder memory
            cp = {"wq": p["cwq"], "wk": p["cwk"], "wv": p["cwv"], "wo": p["cwo"]}
            o, _ = attention_decode(
                cp, rms_norm(p["ln_cross"], x), {"k": c["ck"], "v": c["cv"]}, pos, cfg, cross=True
            )
            x = x + o
        x = x + _ffn_apply(cfg, p, rms_norm(p["ln2"], x))
        return x, new_c
    if kind.startswith("rec"):
        o, st = recurrent_block_decode(p, rms_norm(p["ln1"], x), c, cfg)
        x = x + o
        x = x + mlp(p, rms_norm(p["ln2"], x), cfg.mlp_kind)
        return x, st
    if kind.startswith("rwkv"):
        o, st = rwkv_time_mix_decode(p, rms_norm(p["ln1"], x), c, cfg)
        x = x + o
        x = x + mlp(p, rms_norm(p["ln2"], x), cfg.mlp_kind)
        return x, st
    raise ValueError(kind)


def _attn_ring_decode(p, x, c, pos, cfg, window):
    """Ring-buffer KV decode: slot = pos % capacity, masked by stored pos."""
    import jax.numpy as jnp

    B = x.shape[0]
    H, KV, dh = cfg.n_heads_padded, cfg.n_kv_heads, cfg.d_head
    cap = c["k"].shape[1]
    slot = jax.lax.rem(pos, cap)
    from .layers import rope

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    posb = jnp.broadcast_to(pos[None, None].astype(jnp.int32), (B, 1))
    k_new = rope(jnp.einsum("bsd,dgk->bsgk", x, p["wk"]), posb, cfg.rope_frac, cfg.rope_theta)
    v_new = jnp.einsum("bsd,dgk->bsgk", x, p["wv"])
    q = rope(q, posb, cfg.rope_frac, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice(c["k"], k_new.astype(c["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(c["v"], v_new.astype(c["v"].dtype), (0, slot, 0, 0))
    posbuf = jax.lax.dynamic_update_slice(c["pos"], pos[None].astype(jnp.int32), (slot,))
    rep = H // KV
    qg = q.reshape(B, 1, KV, rep, dh)
    s = jnp.einsum("bqgrk,btgk->bgrqt", qg, k).astype(jnp.float32) / math.sqrt(dh)
    ok = (posbuf >= 0) & (posbuf <= pos)
    if window is not None:
        ok &= pos - posbuf < window
    s = s + jnp.where(ok, 0.0, -1e30)[None, None, None, None, :]
    a = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bgrqt,btgk->bqgrk", a, v).reshape(B, 1, H, dh)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": k, "v": v, "pos": posbuf}


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    """One decode step. token: [B, 1] int32; pos: scalar int32.
    Returns (logits [B, 1, Vp], new_cache)."""
    x = embed_tokens(cfg, params, token).astype(params["embed"].dtype)
    cross = cfg.n_encoder_layers > 0
    new_groups = []
    for (pattern, n_rep), gp, gc in zip(layer_groups(cfg), params["groups"], cache):
        kinds = _block_kinds(cfg, pattern, cross)

        def step(xc, pc):
            pp, cc = pc
            new_cc = {}
            for name, kind in zip(kinds, pattern):
                xc, new_cc[name] = _block_decode(cfg, kind, pp[name], cc[name], xc, pos)
            return xc, new_cc

        x, new_gc = jax.lax.scan(step, x, (gp, gc))
        new_groups.append(new_gc)
    x = rms_norm(params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, lm_head_weight(cfg, params)).astype(jnp.float32)
    return logits, new_groups


def prefill(cfg: ModelConfig, params, batch, act_spec=None):
    """Full-sequence forward that also returns the populated KV cache and
    the last-position logits. (Recurrent/rwkv caches are produced by a final
    decode-style pass in serving; for the dry-run the attention KV cache is
    the memory-dominant artifact.)"""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens).astype(params["embed"].dtype)
    cross = cfg.n_encoder_layers > 0
    memory = None
    if cross:
        m = batch["src_embeds"].astype(x.dtype)
        m = _run_groups(
            cfg,
            params["enc_groups"],
            [(("attn",), cfg.n_encoder_layers)],
            m,
            causal=False,
            memory=None,
            act_spec=act_spec,
            remat=False,
        )
        memory = rms_norm(params["enc_final_norm"], m)

    caches = []
    for (pattern, n_rep), gp in zip(layer_groups(cfg), params["groups"]):
        kinds = _block_kinds(cfg, pattern, cross)

        def step(xc, pp):
            cc = {}
            for name, kind in zip(kinds, pattern):
                if kind.startswith("attn"):
                    p = pp[name]
                    h = rms_norm(p["ln1"], xc)
                    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
                    from .layers import rope as _rope

                    k = _rope(
                        jnp.einsum("bsd,dgk->bsgk", h, p["wk"]),
                        positions,
                        cfg.rope_frac,
                        cfg.rope_theta,
                    )
                    v = jnp.einsum("bsd,dgk->bsgk", h, p["wv"])
                    cc[name] = {"k": k, "v": v}
                xc = _block_apply(
                    cfg, kind, pp[name], xc, causal=True, memory=memory, act_spec=act_spec
                )
            return xc, cc

        x, gc = jax.lax.scan(step, x, gp)
        caches.append(gc)
    x = rms_norm(params["final_norm"], x)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], lm_head_weight(cfg, params)).astype(jnp.float32)
    return logits, caches


def input_specs(cfg: ModelConfig, shape: Dict, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a given workload
    shape — weak-type-correct, shardable, no device allocation."""
    B, S = shape["global_batch"], shape["seq_len"]
    kind = shape["kind"]
    sds = jax.ShapeDtypeStruct
    if kind == "train":
        n_text = S - cfg.n_prefix_embeds if cfg.frontend == "vision_stub" else S
        out = {
            "tokens": sds((B, n_text), jnp.int32),
            "targets": sds((B, n_text), jnp.int32),
        }
        if cfg.frontend == "vision_stub":
            out["prefix_embeds"] = sds((B, cfg.n_prefix_embeds, cfg.d_model), dtype)
        if cfg.n_encoder_layers:
            out["src_embeds"] = sds((B, max(S // 4, 1), cfg.d_model), dtype)
        return out
    if kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.frontend == "vision_stub":
            out["prefix_embeds"] = sds((B, cfg.n_prefix_embeds, cfg.d_model), dtype)
        if cfg.n_encoder_layers:
            out["src_embeds"] = sds((B, max(S // 4, 1), cfg.d_model), dtype)
        return out
    if kind == "decode":
        return {
            "token": sds((B, 1), jnp.int32),
            "pos": sds((), jnp.int32),
        }
    raise ValueError(kind)
