"""Sharding-hint context: lets layer internals place GSPMD constraints on
large intermediates (MoE dispatch tensors, attention scores) without
threading mesh objects through every call.

The dry-run / train / serve drivers call ``set_shard_hints(mesh)``; layer
code calls ``constrain(x, 'dp', None, 'mp', ...)`` which resolves the
logical axes to the mesh's axis names and applies
``with_sharding_constraint`` — skipping any dim that is not divisible (a
fallback to replication, never a failure). Outside a mesh context the calls
are no-ops, so smoke tests on CPU are unaffected.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_HINTS = {"mesh": None, "dp": None, "mp": None}


def set_shard_hints(mesh) -> None:
    if mesh is None:
        _HINTS.update(mesh=None, dp=None, mp=None)
        return
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    _HINTS.update(mesh=mesh, dp=dp if len(dp) > 1 else dp[0], mp="model")


def clear_shard_hints() -> None:
    set_shard_hints(None)


def _axsize(mesh, ax) -> int:
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def constrain(x, *axes):
    """axes: 'dp' | 'mp' | None per dim."""
    mesh = _HINTS["mesh"]
    if mesh is None:
        return x
    spec = []
    for size, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        resolved = _HINTS[ax]
        if resolved is None or size % _axsize(mesh, resolved) != 0:
            spec.append(None)
        else:
            spec.append(resolved)
    # NamedSharding (not bare PartitionSpec): carries its mesh, so callers
    # never need an ambient mesh context at trace time
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
