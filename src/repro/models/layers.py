"""Common transformer layers: RMSNorm, (partial) RoPE, GQA attention with
optional sliding window and KV cache, and gated MLPs.

All matmul-heavy paths are plain jnp (XLA fuses them onto the MXU); the
optional Pallas kernels in repro.kernels provide the hand-tiled variants and
are validated against these as oracles.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# chunk length for memory-bounded (flash-style) attention on long sequences:
# scores materialize per q-chunk only ([B, H_shard, QCHUNK, S] fp32), which
# keeps 4k-train and 32k-prefill peaks inside v5e HBM (EXPERIMENTS.md §Perf)
QCHUNK_THRESHOLD = 2048
QCHUNK = 1024


def rms_norm(w: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (partial rotary supported: stablelm 25%, chatglm 50%)
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, frac: float, theta: float) -> jnp.ndarray:
    """x: [B, S, H, dh]; positions: [B, S] (int). Rotates the first
    ``frac * dh`` dims, passes the rest through."""
    dh = x.shape[-1]
    rot = int(dh * frac)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None, None] * freqs  # [B,S,1,half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool, window: Optional[int]):
    """[Sq, Sk] additive bias in fp32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cfg,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    positions: Optional[jnp.ndarray] = None,
    kv_source: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
) -> jnp.ndarray:
    """Full-sequence attention (training/prefill). GQA: H query heads grouped
    over KV heads; KV stays replicated across the model axis (DESIGN.md §4).
    Sequences beyond QCHUNK_THRESHOLD use query-chunked online softmax."""
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads_padded, cfg.n_kv_heads, cfg.d_head
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    kv_in = x if kv_source is None else kv_source
    Sk = kv_in.shape[1]
    kv_positions = (
        positions
        if kv_source is None
        else jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32)[None], (B, Sk))
    )

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", kv_in, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", kv_in, p["wv"])
    if use_rope and kv_source is None:
        q = rope(q, positions, cfg.rope_frac, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_frac, cfg.rope_theta)

    rep = H // KV
    qg = q.reshape(B, S, KV, rep, dh)
    scale = 1.0 / math.sqrt(dh)

    def block(q_blk, qpos_blk):
        s = jnp.einsum("bqgrk,btgk->bgrqt", q_blk, k).astype(jnp.float32) * scale
        bias = _mask_bias(qpos_blk, kv_positions[0], causal and kv_source is None, window)
        s = s + bias[None, None, None]
        a = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bgrqt,btgk->bqgrk", a, v)

    if S <= QCHUNK_THRESHOLD:
        o = block(qg, positions[0])
    else:
        nchunk = S // QCHUNK
        qg_c = qg.reshape(B, nchunk, QCHUNK, KV, rep, dh).transpose(1, 0, 2, 3, 4, 5)
        pos_c = positions[0].reshape(nchunk, QCHUNK)

        def step(_, qc):
            q_blk, qpos = qc
            return None, block(q_blk, qpos)

        _, o_c = jax.lax.scan(step, None, (qg_c, pos_c))
        o = o_c.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, rep, dh)

    o = o.reshape(B, S, H, dh)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attention_decode(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cache: Dict[str, jnp.ndarray],
    pos: jnp.ndarray,
    cfg,
    *,
    window: Optional[int] = None,
    cross: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode against a KV cache. cache: {'k','v'}: [B, Smax, KV, dh].
    ``pos`` is the current position (scalar int32). For cross-attention the
    cache is the (precomputed) encoder memory and is not updated."""
    B, S1, D = x.shape  # S1 == 1
    H, KV, dh = cfg.n_heads_padded, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    posb = jnp.broadcast_to(pos[None, None].astype(jnp.int32), (B, 1))
    if not cross:
        k_new = jnp.einsum("bsd,dgk->bsgk", x, p["wk"])
        v_new = jnp.einsum("bsd,dgk->bsgk", x, p["wv"])
        q = rope(q, posb, cfg.rope_frac, cfg.rope_theta)
        k_new = rope(k_new, posb, cfg.rope_frac, cfg.rope_theta)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
        new_cache = {"k": k, "v": v}
    else:
        k, v = cache["k"], cache["v"]
        new_cache = cache
    Smax = k.shape[1]
    rep = H // KV
    qg = q.reshape(B, 1, KV, rep, dh)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqgrk,btgk->bgrqt", qg, k).astype(jnp.float32) * scale
    kpos = jnp.arange(Smax, dtype=jnp.int32)
    ok = kpos[None] <= pos if not cross else jnp.ones((1, Smax), dtype=bool)
    if window is not None and not cross:
        ok = ok & (pos - kpos[None] < window)
    s = s + jnp.where(ok, 0.0, -1e30)[:, None, None, None, :]
    a = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bgrqt,btgk->bqgrk", a, v).reshape(B, 1, H, dh)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp(p: Dict[str, jnp.ndarray], x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"])
    if kind == "gelu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    if kind == "rwkv_cm":  # rwkv channel-mix: squared-relu key, receptance gate
        kx = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        kx = jnp.square(jax.nn.relu(kx))
        r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["w_recept"]))
        return r * jnp.einsum("bsf,fd->bsd", kx, p["w_down"])
    raise ValueError(kind)
