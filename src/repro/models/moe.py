"""Mixture-of-experts FFN: top-k routing with capacity-bounded
scatter/gather dispatch (no dense one-hot einsum — dispatch is pure data
movement, expert matmuls are the only FLOPs).

Experts are sharded over the 'model' mesh axis (EP); tokens are grouped per
batch row, so dispatch stays within the data shard and XLA inserts the
expert all-to-all only where the sharding demands it.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .layers import mlp
from .shardctx import constrain


def moe_ffn(p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg) -> jnp.ndarray:
    """x: [B, S, D] -> [B, S, D]."""
    mc = cfg.moe
    B, S, D = x.shape
    E, K = mc.n_experts, mc.top_k
    C = max(1, int(math.ceil(S * K / E * mc.capacity_factor)))

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, K)  # [B,S,K]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # keep routing tensors batch-sharded: without these constraints GSPMD
    # replicates the combine gather across the data axis, producing
    # [GLOBAL_B, S, K, D] fp32 all-reduces (dry-run: ~120 GiB each on dbrx;
    # EXPERIMENTS.md §Perf iteration 2)
    topw = constrain(topw, "dp", None, None)
    topi = constrain(topi, "dp", None, None)

    # position-in-expert via cumulative count of earlier assignments.
    # The [B, S*K, E] routing intermediates are the memory hot spot of MoE
    # dispatch — sharding E over 'model' keeps them O(S*K*E/16) per device
    # (dry-run: dbrx temp 152 GiB -> ~10 GiB; see EXPERIMENTS.md §Perf).
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int8)  # [B,S,K,E]
    onehot = constrain(onehot, "dp", None, None, "mp")
    flat = onehot.reshape(B, S * K, E)
    pos_flat = jnp.cumsum(flat, axis=1, dtype=jnp.int32) - flat  # count before slot
    pos_flat = constrain(pos_flat, "dp", None, "mp")
    pos = (pos_flat.reshape(B, S, K, E) * onehot).sum(-1)  # [B,S,K]
    pos = constrain(pos, "dp", None, None)
    keep = pos < C  # capacity drop

    s_idx = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, K))
    safe_pos = jnp.where(keep, pos, 0)
    # flat slot index into [E*C] — all gathers/scatters below are expressed
    # with an explicit leading batch dim (take_along_axis / vmapped scatter)
    # so GSPMD keeps B sharded over the data axis. The naive 3-index-array
    # formulation made XLA replicate the combine across data shards
    # ([GLOBAL_B,S,K,D] fp32 all-reduces — EXPERIMENTS.md §Perf iteration 2).
    slot_flat = topi * C + safe_pos  # [B,S,K]
    flat_src = jnp.where(keep, s_idx, S)  # S = out-of-range -> dropped

    def scat_src(idx, val):
        return jnp.zeros((E * C,), jnp.int32).at[idx.reshape(-1)].set(val.reshape(-1), mode="drop")

    def scat_used(idx, val):
        return jnp.zeros((E * C,), x.dtype).at[idx.reshape(-1)].max(val.reshape(-1), mode="drop")

    slot_src = jax.vmap(scat_src)(jnp.where(keep, slot_flat, E * C), flat_src)  # [B, E*C]
    slot_used = jax.vmap(scat_used)(
        jnp.where(keep, slot_flat, E * C), keep.astype(x.dtype)
    )
    slot_src = constrain(slot_src.reshape(B, E, C), "dp", "mp", None).reshape(B, E * C)
    slot_used = constrain(slot_used.reshape(B, E, C), "dp", "mp", None).reshape(B, E * C)

    # dispatch: gather tokens into [B, E, C, D] (batched along-axis gather;
    # out-of-range index S is dropped to zero via the used mask)
    xd = jnp.take_along_axis(x, jnp.minimum(slot_src, S - 1)[..., None], axis=1)
    xd = xd.reshape(B, E, C, D) * slot_used.reshape(B, E, C, 1)
    xd = constrain(xd, "dp", "mp", None, None)

    # expert FFN (swiglu), experts sharded over 'model'
    g = jnp.einsum("becd,edf->becf", xd, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", xd, p["w_up"])
    yd = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["w_down"])
    yd = constrain(yd, "dp", "mp", None, None)

    # combine: each (token, k) gathers its slot output, weighted
    y = jnp.take_along_axis(
        yd.reshape(B, E * C, D), slot_flat.reshape(B, S * K, 1), axis=1
    ).reshape(B, S, K, D)
    y = constrain(y, "dp", None, None, None)
    w = (topw.astype(x.dtype) * keep.astype(x.dtype))[..., None]
    out = constrain((y * w).sum(axis=2), "dp", None, None)

    if mc.n_shared:
        out = out + mlp(
            {"w_gate": p["shared_gate"], "w_up": p["shared_up"], "w_down": p["shared_down"]},
            x,
            "swiglu",
        )
    return out
