"""Model zoo for the 10 assigned architectures: dense GQA/SWA transformers,
MoE (top-k, shared experts), RG-LRU hybrid, RWKV6, encoder-decoder, and
VLM/audio backbones with stub modality frontends."""

from .model import (
    abstract_params,
    forward_train,
    init_params,
    input_specs,
    loss_fn,
)
