"""Recurrent blocks: RG-LRU (recurrentgemma/Griffin) and RWKV6 (Finch).

RG-LRU: real-gated linear recurrent unit. h_t = a_t * h_{t-1} +
sqrt(1-a_t^2) * (i_t * x_t), a_t = exp(-c * softplus(L) * r_t). The scan is
a first-order elementwise linear recurrence -> jax.lax.associative_scan
(log-depth on TPU).

RWKV6: data-dependent per-channel decay linear attention. Per head,
S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] v_t[j];
o_t[j] = sum_i r_t[i] (S_{t-1}[i,j] + u[i] k_t[i] v_t[j]).
Computed chunk-parallel (intra-chunk matmuls on the MXU + inter-chunk state
carry) — the same algorithm as the Pallas `linrec` kernel, which treats this
implementation's ref as its oracle.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

RG_LRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU (recurrentgemma)
# ---------------------------------------------------------------------------


def _rg_lru_gates(p, x):
    r = jax.nn.sigmoid(jnp.einsum("bsr,ro->bso", x, p["w_a"]))
    i = jax.nn.sigmoid(jnp.einsum("bsr,ro->bso", x, p["w_x"]))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i * x).astype(jnp.float32) * jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, gated


RG_CHUNK = 512


def rg_lru(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, R] -> [B, S, R].

    Chunked: a sequential scan over S/RG_CHUNK chunks carrying h [B, R],
    with a log-depth associative scan inside each chunk. Bounds peak memory
    to O(B * chunk * R) instead of the O(B * S * R) working set of a
    full-sequence associative scan (dry-run: recurrentgemma train temp
    19.6 GiB -> fits; see EXPERIMENTS.md §Perf). Same algorithm as the
    Pallas `linrec` kernel."""
    B, S, R = x.shape
    a, b = _rg_lru_gates(p, x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    if S <= RG_CHUNK or S % RG_CHUNK != 0:
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        return h.astype(x.dtype)

    n = S // RG_CHUNK
    ac = a.reshape(B, n, RG_CHUNK, R).swapaxes(0, 1)
    bc = b.reshape(B, n, RG_CHUNK, R).swapaxes(0, 1)

    def chunk_step(h0, ab):
        ai, bi = ab
        A, Bv = jax.lax.associative_scan(combine, (ai, bi), axis=1)
        h = A * h0[:, None] + Bv
        return h[:, -1], h

    h0 = jnp.zeros((B, R), jnp.float32)
    _, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
    return hs.swapaxes(0, 1).reshape(B, S, R).astype(x.dtype)


def rg_lru_decode(
    p: Dict[str, jnp.ndarray], x: jnp.ndarray, h: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-step recurrence. x: [B, 1, R]; h: [B, R]."""
    a, b = _rg_lru_gates(p, x)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(x.dtype)[:, None], h_new


def causal_conv1d(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, width W. x: [B,S,R]; p['conv_w']: [W, R]."""
    W = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1]] * p["conv_w"][i]
    return out + p["conv_b"]


def causal_conv1d_decode(p, x, buf):
    """x: [B,1,R], buf: [B, W-1, R] previous inputs."""
    W = p["conv_w"].shape[0]
    win = jnp.concatenate([buf, x], axis=1)  # [B, W, R]
    out = jnp.einsum("bwr,wr->br", win, p["conv_w"]) + p["conv_b"]
    return out[:, None], win[:, 1:]


def recurrent_block(p, x, cfg):
    """Griffin recurrent block: (gelu gate branch) * (conv -> RG-LRU branch)."""
    g = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_gate_in"]))
    y = jnp.einsum("bsd,dr->bsr", x, p["w_rec_in"])
    y = causal_conv1d(p, y)
    y = rg_lru(p, y)
    return jnp.einsum("bsr,rd->bsd", g * y, p["w_out"])


def recurrent_block_decode(p, x, state, cfg):
    g = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_gate_in"]))
    y = jnp.einsum("bsd,dr->bsr", x, p["w_rec_in"])
    y, conv_buf = causal_conv1d_decode(p, y, state["conv"])
    y, h = rg_lru_decode(p, y, state["h"])
    out = jnp.einsum("bsr,rd->bsd", g * y, p["w_out"])
    return out, {"conv": conv_buf, "h": h}


# ---------------------------------------------------------------------------
# RWKV6 time-mix (chunked linear attention with data-dependent decay)
# ---------------------------------------------------------------------------


def _rwkv_proj(p, x, cfg):
    """Token-shift mixing + r/k/v/g and data-dependent decay w."""
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.rwkv_head_dim
    xx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]  # previous token

    def mix(mu):
        return x * mu + xx * (1.0 - mu)

    r = jnp.einsum("bsd,dk->bsk", mix(p["mu_r"]), p["w_r"]).reshape(B, S, H, dh)
    k = jnp.einsum("bsd,dk->bsk", mix(p["mu_k"]), p["w_k"]).reshape(B, S, H, dh)
    v = jnp.einsum("bsd,dk->bsk", mix(p["mu_v"]), p["w_v"]).reshape(B, S, H, dh)
    g = jax.nn.silu(jnp.einsum("bsd,dk->bsk", mix(p["mu_g"]), p["w_g"]))
    # Finch: data-dependent decay via low-rank MLP
    dd = jnp.tanh(jnp.einsum("bsd,dl->bsl", mix(p["mu_w"]), p["w_dec1"]))
    wlog = p["w_dec0"] + jnp.einsum("bsl,lk->bsk", dd, p["w_dec2"])
    # decay floor: exp(wlog) <= 5 bounds the per-chunk exponent so the
    # chunked relative-decay factorization stays inside fp32 range
    # (5 * chunk(16) = 80 < log(fp32_max) ~ 88).
    wlog = jnp.clip(wlog.astype(jnp.float32), None, 1.609)
    w = jnp.exp(-jnp.exp(wlog)).reshape(B, S, H, dh)  # in (0, 1)
    return r, k, v, g, w


def rwkv_time_mix(p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg) -> jnp.ndarray:
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.rwkv_head_dim
    r, k, v, g, w = _rwkv_proj(p, x, cfg)
    u = p["u"].reshape(H, dh)

    T = cfg.rwkv_chunk
    n = S // T if S % T == 0 else None
    if n is None:  # pad to chunk multiple
        pad = T - S % T
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        n = (S + pad) // T
    rc = r.reshape(B, n, T, H, dh).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    kc = k.reshape(B, n, T, H, dh).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vc = v.reshape(B, n, T, H, dh).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    wc = w.reshape(B, n, T, H, dh).transpose(1, 0, 3, 2, 4).astype(jnp.float32)

    def chunk_step(S_carry, inp):
        rc_, kc_, vc_, wc_ = inp  # [B,H,T,dh]
        logw = jnp.log(jnp.maximum(wc_, 1e-30))
        cw = jnp.cumsum(logw, axis=2)  # inclusive cumulative log-decay
        Wtot = jnp.exp(cw[:, :, -1])  # [B,H,dh]
        # decay from chunk start to just before t:
        decay_to_t = jnp.exp(cw - logw)  # prod_{tau < t}
        # matmul inputs in bf16 (fp32 accumulation): halves the HBM traffic
        # of the chunk tensors, which dominates rwkv's memory roofline term
        # (§Perf iteration 9); the decay factorization stays fp32.
        bf = jnp.bfloat16
        r_in = (rc_ * decay_to_t).astype(bf)
        # inter-chunk: o_inter[t] = (r_t * decay_to_t) @ S
        o_inter = jnp.einsum(
            "bhtk,bhkv->bhtv", r_in, S_carry.astype(bf), preferred_element_type=jnp.float32
        )
        # intra-chunk: A[t,s] = sum_i r_t[i] k_s[i] prod_{s<tau<t} w_tau[i], s<t
        k_out = (kc_ * jnp.exp(cw[:, :, -1:] - cw)).astype(bf)
        # A via relative decays: r~_t = r_t*exp(cw_{t-1}), k~_s = k_s*exp(-cw_s)
        k_rel = (kc_ * jnp.exp(-cw)).astype(bf)
        A = jnp.einsum(
            "bhtk,bhsk->bhts", r_in, k_rel, preferred_element_type=jnp.float32
        )
        tri = jnp.tril(jnp.ones((rc_.shape[2], rc_.shape[2]), jnp.float32), -1)
        A = A * tri
        vb = vc_.astype(bf)
        o_intra = jnp.einsum("bhts,bhsv->bhtv", A.astype(bf), vb, preferred_element_type=jnp.float32)
        # diagonal bonus term: u * k_t
        diag = jnp.einsum("bhtk,bhtk->bht", rc_, kc_ * u[None, :, None, :])
        o_diag = diag[..., None] * vc_
        # state update: S' = S * Wtot + sum_s k_s (prod_{s<tau<=end} w) v_s
        S_new = S_carry * Wtot[..., None] + jnp.einsum(
            "bhsk,bhsv->bhkv", k_out, vb, preferred_element_type=jnp.float32
        )
        return S_new, o_inter + o_intra + o_diag

    S0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    _, oc = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
    o = oc.transpose(1, 0, 3, 2, 4).reshape(B, -1, H, dh)[:, :S]
    o = _rwkv_groupnorm(p, o).astype(x.dtype) * g.reshape(B, S, H, dh)
    return jnp.einsum("bsk,kd->bsd", o.reshape(B, S, H * dh), p["w_o"])


def _rwkv_groupnorm(p, o):
    mean = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    return (o - mean) * jax.lax.rsqrt(var + 64e-5) * p["ln_w"].reshape(
        1, 1, *p["ln_w"].shape
    ) + p["ln_b"].reshape(1, 1, *p["ln_b"].shape)


def rwkv_time_mix_decode(p, x, state, cfg):
    """One step. state['S']: [B,H,dh,dh] fp32."""
    B, S1, D = x.shape
    H, dh = cfg.n_heads, cfg.rwkv_head_dim
    # token-shift uses the previous input stored in state
    x_prev = state["x_prev"]
    xx = x_prev[:, None]

    def mix(mu):
        return x * mu + xx * (1.0 - mu)

    r = jnp.einsum("bsd,dk->bsk", mix(p["mu_r"]), p["w_r"]).reshape(B, H, dh)
    k = jnp.einsum("bsd,dk->bsk", mix(p["mu_k"]), p["w_k"]).reshape(B, H, dh)
    v = jnp.einsum("bsd,dk->bsk", mix(p["mu_v"]), p["w_v"]).reshape(B, H, dh)
    g = jax.nn.silu(jnp.einsum("bsd,dk->bsk", mix(p["mu_g"]), p["w_g"]))
    dd = jnp.tanh(jnp.einsum("bsd,dl->bsl", mix(p["mu_w"]), p["w_dec1"]))
    wlog = jnp.clip(
        (p["w_dec0"] + jnp.einsum("bsl,lk->bsk", dd, p["w_dec2"])).astype(jnp.float32),
        None,
        1.609,
    )
    w = jnp.exp(-jnp.exp(wlog)).reshape(B, H, dh)
    u = p["u"].reshape(H, dh)

    Sm = state["S"]
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    bonus = (u[None] * kf)[..., None] * vf[:, :, None, :]
    o = jnp.einsum("bhk,bhkv->bhv", rf, Sm + bonus)
    S_new = Sm * w[..., None] + kf[..., None] * vf[:, :, None, :]
    o = _rwkv_groupnorm(p, o[:, None].reshape(B, 1, H, dh))[:, 0]
    o = (o * g.reshape(B, H, dh)).reshape(B, 1, H * dh).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", o, p["w_o"])
    return out, {"S": S_new, "x_prev": x[:, 0]}
