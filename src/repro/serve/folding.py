"""Dynamic folding for LM serving: GraftDB's mechanism over KV-prefix state.

Mapping (DESIGN.md §6, beyond-paper):

| GraftDB (paper)                  | serving (here)                         |
|----------------------------------|----------------------------------------|
| shared hash-build state          | KV cache of a token prefix             |
| state signature (exact identity) | (model, weights-version)               |
| coverage metadata                | number of prefix tokens prefilled      |
| derivation-identified occurrence | token position in the prefix           |
| represented extent               | matched prefix already prefilled       |
| residual extent                  | matched portion a RUNNING prefill will |
|                                  | still produce (request waits on gate)  |
| unattached extent                | the request's unique suffix (ordinary  |
|                                  | prefill work)                          |
| per-query state lens             | request may read cache[0:matched_len)  |
| state-readiness gate             | covered_tokens >= matched_len          |
| retention policy                 | release prefix states with no refs, or |
|                                  | retain them under a token budget (§10) |
| retention epoch / evictor        | zero-ref prefixes stamped + reclaimed  |
|                                  | oldest-first past memory_budget_tokens |

The scheduler is executor-agnostic: `SimExecutor` models token costs (used
by tests/benchmarks); a real executor runs models/model.py prefill/decode.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Tuple[int, ...]
    n_decode: int
    arrival: float
    # filled by the scheduler
    t_first_token: Optional[float] = None
    t_complete: Optional[float] = None
    represented_tokens: int = 0
    residual_tokens: int = 0
    ordinary_tokens: int = 0


class PrefixState:
    """A shared KV-prefix state. ``covered`` is the coverage metadata: the
    producer (a running prefill) has materialized cache for [0, covered).

    State ids are scheduler-scoped (allocated by the owning
    FoldingScheduler), so repeated scheduler constructions are isolated —
    ids never leak across instances."""

    def __init__(self, sid: int, tokens: Tuple[int, ...]):
        self.sid = sid
        self.tokens = tokens
        self.covered = 0
        self.refs: set = set()
        # retention epoch stamp (§10): None while any request pins the
        # state; set when retired under retain_prefixes
        self.retired_epoch: Optional[int] = None

    def visible_len(self, request_prefix_len: int) -> int:
        """Per-request state lens: a request observes only its matched
        prefix, and only once covered."""
        return min(self.covered, request_prefix_len)


def _match_len(a: Tuple[int, ...], b: Tuple[int, ...]) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class FoldingScheduler:
    """Admission + scheduling of requests over shared prefix states.

    ``fold=False`` gives the isolated baseline (every request prefills its
    whole prompt). Single-server cost model mirroring the paper's
    single-worker evaluation: the executor serves one token-batch at a time.
    """

    def __init__(
        self,
        executor,
        fold: bool = True,
        min_share: int = 16,
        retain_prefixes: bool = False,
        memory_budget_tokens: Optional[int] = None,
        reuse_cache_tokens: Optional[int] = None,
        batch_fold: bool = False,
    ):
        self.ex = executor
        self.fold = fold
        self.min_share = min_share
        # §15 batch planning, serving flavor: when several requests are due
        # at the same decision step, admit the longest prompt first so the
        # fresh prefix state it creates covers every shorter same-prefix
        # prompt in the group (they fold at their full match length instead
        # of only the shortest arrival's).
        self.batch_fold = batch_fold
        # §10 lifecycle: retain zero-ref prefix states (their covered KV
        # cache keeps serving later requests with the same prefix) and
        # evict oldest-epoch-first past the token budget.
        self.retain_prefixes = retain_prefixes
        self.memory_budget_tokens = memory_budget_tokens
        self._epoch = 0
        self.states: List[PrefixState] = []
        self.metrics = {
            "represented": 0,
            "residual": 0,
            "ordinary": 0,
            # §15: same-instant admission groups planned jointly, and the
            # members that folded onto a group-mate's state
            "batch_groups": 0,
            "batch_folded": 0,
        }
        # lifecycle gauges kept apart from the per-episode token metrics
        self.lifecycle_metrics = {
            "evicted_states": 0,
            "evicted_tokens": 0,
            "revived_states": 0,
            "retained_tokens": 0,
            "retained_tokens_high_water": 0,
            # reuse plane (§12) — zero whether or not the cache is on
            "cache_spills": 0,
            "cache_hits": 0,
            "cache_evictions": 0,
            "rehydrate_tokens": 0,
        }
        # Reuse plane (DESIGN.md §12): evicted KV prefixes spill into the
        # same tiered ArtifactStore the relational engine uses (8 bytes per
        # cached token models the KV page handle) and rehydrate when a
        # later prompt matches.
        self.reuse = None
        if reuse_cache_tokens is not None:
            if not retain_prefixes:
                raise ValueError("reuse_cache_tokens requires retain_prefixes=True")
            from ..core.reuse import ArtifactStore

            self.reuse = ArtifactStore(
                budget=8 * reuse_cache_tokens, counters=self.lifecycle_metrics
            )
        self._next_sid = 0  # scheduler-scoped state ids (no cross-instance leaks)
        # Admission hook for the Session facade (api/serving.py): called as
        # on_admit(req, attachment) right after each request is admitted.
        self.on_admit: Optional[object] = None

    def _new_state(self, tokens: Tuple[int, ...]) -> PrefixState:
        self._next_sid += 1
        return PrefixState(self._next_sid, tokens)

    # -- query grafting (admission) ----------------------------------------
    def preview(self, prompt: Tuple[int, ...]) -> Dict:
        """Read-only admission preview: how ``prompt`` would partition
        against the current live prefix states. Mutates nothing — the
        single source of truth for both ``admit`` and the Session facade's
        ``explain_fold``."""
        best, best_m = None, 0
        if self.fold:
            for st in self.states:
                m = _match_len(st.tokens, prompt)
                if m > best_m:
                    best, best_m = st, m
        if best is None or best_m < self.min_share:
            return {
                "state": None,  # admission would create a fresh state
                "matched": 0,
                "represented": 0,
                "residual": 0,
                "suffix": len(prompt),
                "created": True,
                # a spilled prefix artifact would rehydrate first (§12) —
                # read-only peek, surfaced through explain_fold
                "served_from_cache": self._cached_match(prompt) is not None,
            }
        represented = min(best.covered, best_m)
        return {
            "state": best,
            "matched": best_m,
            "represented": represented,
            "residual": best_m - represented,  # gate: running producer delivers
            "suffix": len(prompt) - best_m,
            "created": False,
            "served_from_cache": False,
        }

    def _cached_match(self, prompt: Tuple[int, ...]):
        """Best spilled prefix artifact for ``prompt`` (longest common
        prefix >= min_share), or None. Deterministic: spill order breaks
        ties. Read-only — ``_admit`` takes the winner."""
        if self.reuse is None or not self.fold:
            return None
        best, best_m = None, 0
        for art in self.reuse.iter_kind("kv_prefix"):
            m = _match_len(tuple(art.meta["tokens"]), prompt)
            if m > best_m:
                best, best_m = art, m
        if best is None or best_m < self.min_share:
            return None
        return best

    def admit(self, req: Request) -> Dict:
        """Partition the request's prompt into represented / residual /
        unattached extents against the best compatible live prefix state."""
        att = self._admit(req)
        if self.on_admit is not None:
            self.on_admit(req, att)
        return att

    def _admit(self, req: Request) -> Dict:
        att = self.preview(req.prompt)
        if att["created"] and att.get("served_from_cache"):
            # reuse plane (§12): rehydrate the spilled prefix before
            # creating fresh state — the restored coverage serves this
            # request's matched prefix as represented tokens
            art = self._cached_match(req.prompt)
            taken = self.reuse.take(art.fingerprint)
            st = self._new_state(tuple(taken.meta["tokens"]))
            st.covered = int(taken.meta["covered"])
            self.states.append(st)
            lm = self.lifecycle_metrics
            lm["cache_hits"] += 1
            lm["rehydrate_tokens"] += len(taken.meta["tokens"])
            att = self.preview(req.prompt)  # re-partition against it
        if att["created"]:
            st = self._new_state(req.prompt)
            st.refs.add(req.rid)
            self.states.append(st)
            req.ordinary_tokens = len(req.prompt)
            self.metrics["ordinary"] += req.ordinary_tokens
            # matched = whole prompt: the created state covers it once this
            # request's own prefill completes (run() advances st.covered by
            # it); "created" lets observers tell this from a full match.
            return {**att, "state": st, "matched": len(req.prompt), "suffix": 0}
        st: PrefixState = att["state"]
        st.refs.add(req.rid)
        if st.retired_epoch is not None:  # revive a retained prefix (§10)
            st.retired_epoch = None
            self.lifecycle_metrics["revived_states"] += 1
        req.represented_tokens = att["represented"]
        req.residual_tokens = att["residual"]
        req.ordinary_tokens = att["suffix"]
        self.metrics["represented"] += att["represented"]
        self.metrics["residual"] += att["residual"]
        self.metrics["ordinary"] += att["suffix"]
        return att

    def release(self, req: Request) -> None:
        for st in self.states:
            st.refs.discard(req.rid)
        if not self.retain_prefixes:
            self.states = [s for s in self.states if s.refs]  # drop at zero refs
            return
        # §10: retire zero-ref prefixes (their KV cache keeps serving later
        # matching requests), then enforce the token budget oldest-first
        for s in self.states:
            if not s.refs and s.retired_epoch is None:
                self._epoch += 1
                s.retired_epoch = self._epoch
        self._enforce_token_budget()

    def _enforce_token_budget(self) -> None:
        """Evict retired prefix states oldest-epoch-first until the retained
        tokens fit ``memory_budget_tokens``. Pinned (ref'd) states are never
        evicted — a request's lens may still read them."""
        retired = sorted(
            (s for s in self.states if s.retired_epoch is not None),
            key=lambda s: s.retired_epoch,
        )
        total = sum(len(s.tokens) for s in retired)
        budget = self.memory_budget_tokens
        evicted: set = set()
        if budget is not None:
            for s in retired:
                if total <= budget:
                    break
                assert not s.refs, "evicting a pinned prefix state"
                evicted.add(s.sid)
                total -= len(s.tokens)
                self.lifecycle_metrics["evicted_states"] += 1
                self.lifecycle_metrics["evicted_tokens"] += len(s.tokens)
                if self.reuse is not None and s.covered > 0:
                    # spill instead of destroy (§12): the covered KV pages
                    # become a cached artifact a later prompt can rehydrate
                    from ..core.reuse import StateArtifact, prefix_fingerprint

                    self.reuse.put(
                        StateArtifact(
                            prefix_fingerprint(s.tokens),
                            "kv_prefix",
                            None,
                            8 * len(s.tokens),
                            {"tokens": tuple(s.tokens), "covered": s.covered},
                            arrays={},
                        )
                    )
        if evicted:
            self.states = [s for s in self.states if s.sid not in evicted]
        lm = self.lifecycle_metrics
        lm["retained_tokens"] = total
        if total > lm["retained_tokens_high_water"]:
            lm["retained_tokens_high_water"] = total

    # -- execution ------------------------------------------------------------
    def run(self, requests: List[Request]) -> Dict:
        """Event loop over a single-server executor."""
        now = 0.0
        pending = sorted(requests, key=lambda r: r.arrival)
        i = 0
        # active: (ready_time, rid) -> phases
        work: List[Tuple[float, int, Request, Dict]] = []
        done: List[Request] = []
        decode_pool: List[Request] = []
        decode_left: Dict[int, int] = {}

        while i < len(pending) or work or decode_pool:
            due: List[Request] = []
            while i < len(pending) and pending[i].arrival <= now:
                due.append(pending[i])
                i += 1
            if self.batch_fold and self.fold and len(due) > 1:
                # §15 joint admission: longest prompt first, so its fresh
                # state is live (at its full length) when the shorter
                # group-mates partition against it. Execution order below
                # is unchanged — the work heap still pops (arrival, rid).
                self.metrics["batch_groups"] += 1
                due = sorted(due, key=lambda r: (-len(r.prompt), r.arrival, r.rid))
                for req in due:
                    att = self.admit(req)
                    if not att["created"]:
                        self.metrics["batch_folded"] += 1
                    heapq.heappush(work, (req.arrival, req.rid, req, att))
            else:
                for req in due:
                    att = self.admit(req)
                    heapq.heappush(work, (req.arrival, req.rid, req, att))
            if not work and not decode_pool:
                if i < len(pending):
                    now = pending[i].arrival
                    continue
                break
            # prefill obligations first (producers open downstream gates)
            if work:
                _, _, req, att = heapq.heappop(work)
                st: PrefixState = att["state"]
                m = att["matched"]
                # state lens at execution time: the represented extent may
                # have GROWN since admission (another producer advanced
                # coverage) — observe it, produce the rest.
                covered_now = st.visible_len(m)
                todo = (len(req.prompt) - m) + (m - covered_now)
                self.metrics["computed"] = self.metrics.get("computed", 0) + todo
                now += self.ex.prefill_cost(todo)
                # residual production contributes to the shared state
                st.covered = max(st.covered, m)
                req.t_first_token = now
                decode_pool.append(req)
                decode_left[req.rid] = req.n_decode
                continue
            # decode: one batched step over all active decodes
            batch = len(decode_pool)
            now += self.ex.decode_cost(batch)
            finished = []
            for r in decode_pool:
                decode_left[r.rid] -= 1
                if decode_left[r.rid] <= 0:
                    r.t_complete = now
                    finished.append(r)
            for r in finished:
                decode_pool.remove(r)
                self.release(r)
                done.append(r)
        lat = [r.t_complete - r.arrival for r in done]
        return {
            "completed": len(done),
            "elapsed": now,
            "mean_latency": sum(lat) / max(len(lat), 1),
            "p95_latency": sorted(lat)[int(0.95 * (len(lat) - 1))] if lat else 0.0,
            "prefill_tokens": dict(self.metrics),
        }


class SimExecutor:
    """Token-cost model of one serving worker (prefill compute-bound,
    decode latency per batched step)."""

    def __init__(self, prefill_tok_s: float = 8000.0, decode_step_s: float = 0.02):
        self.prefill_tok_s = prefill_tok_s
        self.decode_step_s = decode_step_s

    def prefill_cost(self, n_tokens: int) -> float:
        return n_tokens / self.prefill_tok_s

    def decode_cost(self, batch: int) -> float:
        return self.decode_step_s * (1.0 + 0.02 * batch)
