"""Serving substrate: continuous batching, decode driver, and dynamic
folding of concurrent requests over shared KV-prefix state (the paper's
mechanism transferred to LM serving — DESIGN.md §6)."""
