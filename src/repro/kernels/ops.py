"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute via ``interpret=True`` (the kernel
body runs in Python for correctness validation); on TPU set
``interpret=False`` (or rely on the platform default) for the compiled
VMEM-tiled versions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .flash_attention import flash_attention
from .hash_probe import EMPTY, hash_build_insert, hash_probe_lens
from .linrec import linrec
from .seg_aggregate import seg_aggregate


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def build_hash_table(keys: np.ndarray, vis: np.ndarray, load: float = 0.5):
    """Host-side open-addressing build (the engine's build path is
    append-only; the probe kernel consumes this SoA layout). Returns
    (table_keys, table_vis, table_entry_idx)."""
    n = len(keys)
    cap = 1 << max(int(np.ceil(np.log2(max(n / load, 8)))), 3)
    mask = cap - 1
    tk = np.full(cap, int(EMPTY), np.int32)
    tv = np.zeros(cap, np.uint32)
    te = np.full(cap, -1, np.int32)
    pos = (keys.astype(np.uint64) * np.uint64(2654435761)).astype(np.int64) & mask
    for i in range(n):
        p = int(pos[i])
        while tk[p] != int(EMPTY):
            p = (p + 1) & mask
        tk[p] = keys[i]
        tv[p] = vis[i]
        te[p] = i
    return jnp.asarray(tk), jnp.asarray(tv), jnp.asarray(te)


def build_insert(keys, capacity=None, interpret=None):
    """In-kernel batch build of the open-addressing table (the device-side
    counterpart of ``build_hash_table``). Returns (table_keys, table_entry,
    ok) — ``ok[0] == 0`` flags duplicate keys / over-long probe chains."""
    interpret = default_interpret() if interpret is None else interpret
    n = len(keys)
    if capacity is None:
        # default to <=25% load: keeps clusters well inside the kernel's
        # bounded probe scan (callers managing their own tables pass cap)
        capacity = 8
        while capacity < 4 * n:
            capacity *= 2
    return hash_build_insert(
        jnp.asarray(keys, jnp.int32), capacity=capacity, interpret=interpret
    )


def probe(probe_keys, table_keys, table_vis, query_mask, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return hash_probe_lens(
        jnp.asarray(probe_keys, jnp.int32),
        table_keys,
        table_vis,
        jnp.asarray(query_mask, jnp.uint32).reshape(1),
        interpret=interpret,
    )


def segmented_sum(codes, values, n_groups, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return seg_aggregate(
        jnp.asarray(codes, jnp.int32), jnp.asarray(values), n_groups, interpret=interpret
    )


def attention(q, k, v, window=None, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return flash_attention(q, k, v, window=window, interpret=interpret)


def linear_recurrence(a, b, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return linrec(a, b, interpret=interpret)
