"""Pallas TPU kernel: chunked first-order linear recurrence.

h_t = a_t * h_{t-1} + b_t  (elementwise over channels) — the RG-LRU /
gated-linear-recurrence primitive (recurrentgemma; also the inter-chunk
carry of RWKV6).

TPU adaptation: grid (B, D/BLOCK_D, S/BLOCK_S) with the sequence chunks as
the innermost (sequential) dim. Each kernel instance scans its
[BLOCK_S, BLOCK_D] tile with a log-depth doubling scan (dense VPU ops, no
serial loop), then composes with the cross-chunk carry held in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_S = 256
BLOCK_D = 128


def _linrec_kernel(a_ref, b_ref, o_ref, h_ref, *, n_chunks: int, block_s: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)  # [S, D]
    b = b_ref[0].astype(jnp.float32)

    # inclusive doubling scan of the affine composition
    # (A,B)[t] <- (A,B)[t-k] ∘ (A,B)[t]:  A'=A*A_shift, B'=B+A*B_shift
    A, B = a, b
    k = 1
    while k < block_s:
        A_shift = jnp.concatenate([jnp.ones((k, A.shape[1]), A.dtype), A[:-k]], axis=0)
        B_shift = jnp.concatenate([jnp.zeros((k, B.shape[1]), B.dtype), B[:-k]], axis=0)
        B = B + A * B_shift
        A = A * A_shift
        k *= 2

    h0 = h_ref[...]
    h = A * h0[None, :] + B
    o_ref[0] = h.astype(o_ref.dtype)
    h_ref[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def linrec(a: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """a, b: [B, S, D] -> h: [B, S, D] with h_t = a_t h_{t-1} + b_t, h_0=0."""
    Bn, S, D = a.shape
    assert S % BLOCK_S == 0 and D % BLOCK_D == 0, "tile-aligned shapes required"
    n_chunks = S // BLOCK_S
    grid = (Bn, D // BLOCK_D, n_chunks)
    return pl.pallas_call(
        functools.partial(_linrec_kernel, n_chunks=n_chunks, block_s=BLOCK_S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_S, BLOCK_D), lambda bb, dd, cc: (bb, cc, dd)),
            pl.BlockSpec((1, BLOCK_S, BLOCK_D), lambda bb, dd, cc: (bb, cc, dd)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_S, BLOCK_D), lambda bb, dd, cc: (bb, cc, dd)),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((BLOCK_D,), jnp.float32)],
        interpret=interpret,
    )(a, b)
