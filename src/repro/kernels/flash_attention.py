"""Pallas TPU kernel: causal (optionally sliding-window) flash attention.

Online-softmax tiling: q blocks of BLOCK_Q x d_head live in VMEM; the KV
sequence is the innermost grid dim, revisiting per-q-block accumulators
(m, l, acc) held in VMEM scratch. Causal/window masking is positional;
fully-masked KV blocks still iterate (structural dry-run target — the
skip-block optimization is a §Perf variant).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, window, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [BQ, dh]
    k = k_ref[0]  # [BK, dh]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [BQ, BK]
    qpos = qi * BLOCK_Q + jax.lax.iota(jnp.int32, BLOCK_Q)[:, None]
    kpos = ki * BLOCK_K + jax.lax.iota(jnp.int32, BLOCK_K)[None, :]
    ok = qpos >= kpos
    if window is not None:
        ok &= qpos - kpos < window
    s = jnp.where(ok, s, NEG)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # [BH, S, dh]
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    window=None,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, s, dh = q.shape
    assert s % BLOCK_Q == 0 and s % BLOCK_K == 0, "seq must be tile-aligned"
    scale = 1.0 / math.sqrt(dh)
    n_q, n_k = s // BLOCK_Q, s // BLOCK_K
    grid = (bh, n_q, n_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, window=window, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BLOCK_K, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BLOCK_K, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q,), jnp.float32),
            pltpu.VMEM((BLOCK_Q,), jnp.float32),
            pltpu.VMEM((BLOCK_Q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
