"""Pallas TPU kernel: segmented aggregation (shared aggregate state update).

Grouped sum over group codes — the data-plane op behind SharedAggregateState
(§4.5). TPU adaptation: the reduction is expressed as a one-hot matmul so it
runs on the MXU: for each VMEM tile of rows, ``onehot(codes)^T @ values``
accumulates into the [G, V] output tile, which is revisited across the
sequential TPU grid (accumulate-in-place pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512


def _seg_kernel(codes_ref, vals_ref, out_ref, *, n_groups: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]
    vals = vals_ref[...]
    onehot = (codes[:, None] == jax.lax.iota(jnp.int32, n_groups)[None, :]).astype(
        vals.dtype
    )
    out_ref[...] += jnp.dot(onehot.T, vals, preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_groups", "interpret"))
def seg_aggregate(
    codes: jnp.ndarray,  # [N] int32 in [0, n_groups)
    values: jnp.ndarray,  # [N, V] float
    n_groups: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    n, v = values.shape
    pad = (-n) % BLOCK_N
    codes_p = jnp.pad(codes, (0, pad), constant_values=-1)  # -1 matches no group
    vals_p = jnp.pad(values, ((0, pad), (0, 0)))
    grid = (codes_p.shape[0] // BLOCK_N,)
    out = pl.pallas_call(
        functools.partial(_seg_kernel, n_groups=n_groups),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_groups, v), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, v), jnp.float32),
        interpret=interpret,
    )(codes_p, vals_p)
    return out
