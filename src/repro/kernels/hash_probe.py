"""Pallas TPU kernel: hash-join probe with a fused per-query state lens.

The paper's hot spot (§4.3): probe a shared open-addressing hash-build state
and emit, per probe key, the matching entry index — but only when the entry
is visible to the probing query (visibility bitmask AND query mask), i.e.
the per-query state lens is fused into the probe.

TPU adaptation (DESIGN.md §2/§7): probe keys are tiled into VMEM blocks of
``BLOCK_N``; the SoA table (keys / entry-visibility words) is VMEM-resident
per kernel instance (slab-sized tables; the engine's sort-probe handles
overflow sizes). The linear-probe loop is a bounded ``fori_loop`` of fully
vectorized gathers+compares on the VPU — no pointer chasing.

Unique-key tables only (FK-keyed dimension states); the engine routes
multi-match states through the reference path.

``hash_probe_lens_multi`` is the multi-member variant (DESIGN.md §11): one
launch returns, per probe key, the matched slot AND the matched entry's
packed visibility word — the per-row ownership mask of every probing
member at once. The host translates the word from state-slot space into
pipeline ownership bits (``core.visibility.translate_bits``), so per-morsel
kernel cost is independent of how many queries share the probe.

``hash_build_insert`` is the batch-insert companion: one kernel call builds
the whole open-addressing table from a key batch (linear-probe placement,
bounded by ``MAX_PROBE``; duplicate keys or over-long clusters clear the
``ok`` flag so the caller can fall back). The placement loop is sequential
in-kernel — the win over host insertion is batching the dispatch, so the
backend keeps it opt-in off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024
MAX_PROBE = 16
EMPTY = -0x7FFFFFFF
MULT = 2654435761


def _hash(keys: jnp.ndarray, mask) -> jnp.ndarray:
    return (keys.astype(jnp.uint32) * jnp.uint32(MULT)).astype(jnp.int32) & mask


def _probe_kernel(probe_ref, tkeys_ref, tvis_ref, qmask_ref, out_ref):
    tkeys = tkeys_ref[...]
    tvis = tvis_ref[...]
    qmask = qmask_ref[0]
    cap_mask = jnp.int32(tkeys.shape[0] - 1)
    keys = probe_ref[...]
    pos = _hash(keys, cap_mask)
    found = jnp.full(keys.shape, -1, jnp.int32)
    done = jnp.zeros(keys.shape, jnp.bool_)

    def step(_, carry):
        pos, found, done = carry
        slot_keys = tkeys[pos]
        hit = (slot_keys == keys) & ~done
        empty = (slot_keys == jnp.int32(EMPTY)) & ~done
        # state lens: entry visible to this query?
        vis = (tvis[pos] & qmask) != 0
        found = jnp.where(hit & vis, pos, found)
        done = done | hit | empty
        pos = (pos + 1) & cap_mask
        return pos, found, done

    _, found, _ = jax.lax.fori_loop(0, MAX_PROBE, step, (pos, found, done))
    out_ref[...] = found


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_probe_lens(
    probe_keys: jnp.ndarray,  # [N] int32
    table_keys: jnp.ndarray,  # [T] int32, power-of-two T, EMPTY sentinel
    table_vis: jnp.ndarray,  # [T] uint32 per-entry visibility words
    query_mask: jnp.ndarray,  # [1] uint32
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    n = probe_keys.shape[0]
    pad = (-n) % BLOCK_N
    pk = jnp.pad(probe_keys, (0, pad), constant_values=jnp.int32(EMPTY))
    grid = (pk.shape[0] // BLOCK_N,)
    out = pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec(table_keys.shape, lambda i: (0,)),
            pl.BlockSpec(table_vis.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(pk.shape, jnp.int32),
        interpret=interpret,
    )(pk, table_keys, table_vis, query_mask)
    return out[:n]


def _probe_multi_kernel(probe_ref, tkeys_ref, tvis_ref, out_slot_ref, out_vis_ref):
    tkeys = tkeys_ref[...]
    tvis = tvis_ref[...]
    cap_mask = jnp.int32(tkeys.shape[0] - 1)
    keys = probe_ref[...]
    pos = _hash(keys, cap_mask)
    found = jnp.full(keys.shape, -1, jnp.int32)
    vis = jnp.zeros(keys.shape, jnp.uint32)
    done = jnp.zeros(keys.shape, jnp.bool_)

    def step(_, carry):
        pos, found, vis, done = carry
        slot_keys = tkeys[pos]
        hit = (slot_keys == keys) & ~done
        empty = (slot_keys == jnp.int32(EMPTY)) & ~done
        # multi-member lens: emit the whole packed visibility word — every
        # probing member's ownership bit resolves from one gather
        found = jnp.where(hit, pos, found)
        vis = jnp.where(hit, tvis[pos], vis)
        done = done | hit | empty
        pos = (pos + 1) & cap_mask
        return pos, found, vis, done

    _, found, vis, _ = jax.lax.fori_loop(0, MAX_PROBE, step, (pos, found, vis, done))
    out_slot_ref[...] = found
    out_vis_ref[...] = vis


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_probe_lens_multi(
    probe_keys: jnp.ndarray,  # [N] int32
    table_keys: jnp.ndarray,  # [T] int32, power-of-two T, EMPTY sentinel
    table_vis: jnp.ndarray,  # [T] uint32 per-entry visibility words
    *,
    interpret: bool = True,
):
    """Multi-member probe (§11): per probe key, the matched table slot
    (-1 = no match, pre-visibility — the pair stream matches the reference
    probe exactly) and the matched entry's packed visibility word. One
    launch serves every probing member; the host maps the word to
    pipeline ownership bits."""
    n = probe_keys.shape[0]
    pad = (-n) % BLOCK_N
    pk = jnp.pad(probe_keys, (0, pad), constant_values=jnp.int32(EMPTY))
    grid = (pk.shape[0] // BLOCK_N,)
    found, vis = pl.pallas_call(
        _probe_multi_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec(table_keys.shape, lambda i: (0,)),
            pl.BlockSpec(table_vis.shape, lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(pk.shape, jnp.int32),
            jax.ShapeDtypeStruct(pk.shape, jnp.uint32),
        ],
        interpret=interpret,
    )(pk, table_keys, table_vis)
    return found[:n], vis[:n]


def _probe_lens64_kernel(
    probe_ref, tkeys_ref, tentry_ref, evlo_ref, evhi_ref, qmask_ref, out_ref
):
    tkeys = tkeys_ref[...]
    tentry = tentry_ref[...]
    evlo = evlo_ref[...]
    evhi = evhi_ref[...]
    qlo = qmask_ref[0]
    qhi = qmask_ref[1]
    cap_mask = jnp.int32(tkeys.shape[0] - 1)
    keys = probe_ref[...]
    pos = _hash(keys, cap_mask)
    found = jnp.full(keys.shape, -1, jnp.int32)
    done = jnp.zeros(keys.shape, jnp.bool_)

    def step(_, carry):
        pos, found, done = carry
        slot_keys = tkeys[pos]
        hit = (slot_keys == keys) & ~done
        empty = (slot_keys == jnp.int32(EMPTY)) & ~done
        # 64-slot lens: the visibility word lives entry-indexed (split into
        # uint32 halves), so a table rebuild never touches the mirror
        entry = jnp.where(hit, tentry[pos], 0)
        vis = ((evlo[entry] & qlo) | (evhi[entry] & qhi)) != 0
        found = jnp.where(hit & vis, pos, found)
        done = done | hit | empty
        pos = (pos + 1) & cap_mask
        return pos, found, done

    _, found, _ = jax.lax.fori_loop(0, MAX_PROBE, step, (pos, found, done))
    out_ref[...] = found


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_probe_lens64(
    probe_keys: jnp.ndarray,  # [N] int32
    table_keys: jnp.ndarray,  # [T] int32, power-of-two T, EMPTY sentinel
    table_entry: jnp.ndarray,  # [T] int32 slot -> entry index
    evis_lo: jnp.ndarray,  # [E] uint32 entry-indexed visibility low words
    evis_hi: jnp.ndarray,  # [E] uint32 entry-indexed visibility high words
    query_mask: jnp.ndarray,  # [2] uint32 (lo, hi) lens mask
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Single-query fused-lens probe over the full 64-slot space
    (DESIGN.md §13): visibility words are entry-indexed uint32 pairs, so
    any slot 0..63 resolves in-kernel and rebuilds leave the mirror
    untouched. Returns the matched table slot per probe key (-1 = no
    visible match)."""
    n = probe_keys.shape[0]
    pad = (-n) % BLOCK_N
    pk = jnp.pad(probe_keys, (0, pad), constant_values=jnp.int32(EMPTY))
    grid = (pk.shape[0] // BLOCK_N,)
    out = pl.pallas_call(
        _probe_lens64_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec(table_keys.shape, lambda i: (0,)),
            pl.BlockSpec(table_entry.shape, lambda i: (0,)),
            pl.BlockSpec(evis_lo.shape, lambda i: (0,)),
            pl.BlockSpec(evis_hi.shape, lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(pk.shape, jnp.int32),
        interpret=interpret,
    )(pk, table_keys, table_entry, evis_lo, evis_hi, query_mask)
    return out[:n]


def _probe_multi64_kernel(
    probe_ref, tkeys_ref, tentry_ref, evlo_ref, evhi_ref,
    out_slot_ref, out_lo_ref, out_hi_ref,
):
    tkeys = tkeys_ref[...]
    tentry = tentry_ref[...]
    evlo = evlo_ref[...]
    evhi = evhi_ref[...]
    cap_mask = jnp.int32(tkeys.shape[0] - 1)
    keys = probe_ref[...]
    pos = _hash(keys, cap_mask)
    found = jnp.full(keys.shape, -1, jnp.int32)
    done = jnp.zeros(keys.shape, jnp.bool_)

    def step(_, carry):
        pos, found, done = carry
        slot_keys = tkeys[pos]
        hit = (slot_keys == keys) & ~done
        empty = (slot_keys == jnp.int32(EMPTY)) & ~done
        found = jnp.where(hit, pos, found)
        done = done | hit | empty
        pos = (pos + 1) & cap_mask
        return pos, found, done

    _, found, _ = jax.lax.fori_loop(0, MAX_PROBE, step, (pos, found, done))
    matched = found >= 0
    entry = jnp.where(matched, tentry[jnp.where(matched, found, 0)], 0)
    out_slot_ref[...] = found
    out_lo_ref[...] = jnp.where(matched, evlo[entry], jnp.uint32(0))
    out_hi_ref[...] = jnp.where(matched, evhi[entry], jnp.uint32(0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_probe_lens_multi64(
    probe_keys: jnp.ndarray,  # [N] int32
    table_keys: jnp.ndarray,  # [T] int32, power-of-two T, EMPTY sentinel
    table_entry: jnp.ndarray,  # [T] int32 slot -> entry index
    evis_lo: jnp.ndarray,  # [E] uint32 entry-indexed visibility low words
    evis_hi: jnp.ndarray,  # [E] uint32 entry-indexed visibility high words
    *,
    interpret: bool = True,
):
    """Multi-member probe returning the full uint64 lens word as (lo, hi)
    uint32 halves (DESIGN.md §13): like ``hash_probe_lens_multi`` but
    serving all 64 slots from entry-indexed (rebuild-invariant) mirrors.
    The pair stream is pre-visibility and identical to ``probe``."""
    n = probe_keys.shape[0]
    pad = (-n) % BLOCK_N
    pk = jnp.pad(probe_keys, (0, pad), constant_values=jnp.int32(EMPTY))
    grid = (pk.shape[0] // BLOCK_N,)
    found, wlo, whi = pl.pallas_call(
        _probe_multi64_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec(table_keys.shape, lambda i: (0,)),
            pl.BlockSpec(table_entry.shape, lambda i: (0,)),
            pl.BlockSpec(evis_lo.shape, lambda i: (0,)),
            pl.BlockSpec(evis_hi.shape, lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(pk.shape, jnp.int32),
            jax.ShapeDtypeStruct(pk.shape, jnp.uint32),
            jax.ShapeDtypeStruct(pk.shape, jnp.uint32),
        ],
        interpret=interpret,
    )(pk, table_keys, table_entry, evis_lo, evis_hi)
    return found[:n], wlo[:n], whi[:n]


def _insert_kernel(keys_ref, tkeys_ref, tentry_ref, ok_ref):
    cap = tkeys_ref.shape[0]
    cap_mask = jnp.int32(cap - 1)
    n = keys_ref.shape[0]
    tkeys_ref[...] = jnp.full((cap,), jnp.int32(EMPTY), jnp.int32)
    tentry_ref[...] = jnp.full((cap,), -1, jnp.int32)

    def insert_one(i, ok):
        key = keys_ref[i]
        home = (key.astype(jnp.uint32) * jnp.uint32(MULT)).astype(jnp.int32) & cap_mask

        def step(h, carry):
            pos, state = carry  # state: 0=searching, 1=slot found, 2=duplicate
            slot = (home + h) & cap_mask
            cur = tkeys_ref[slot]
            searching = state == 0
            hit_empty = searching & (cur == jnp.int32(EMPTY))
            hit_dup = searching & (cur == key)
            pos = jnp.where(hit_empty, slot, pos)
            state = jnp.where(hit_empty, 1, jnp.where(hit_dup, 2, state))
            return pos, state

        pos, state = jax.lax.fori_loop(
            0, MAX_PROBE, step, (jnp.int32(0), jnp.int32(0))
        )
        # unconditional read-modify-write keeps the store branch-free
        place = state == 1
        tkeys_ref[pos] = jnp.where(place, key, tkeys_ref[pos])
        tentry_ref[pos] = jnp.where(place, i.astype(jnp.int32), tentry_ref[pos])
        return ok & place.astype(jnp.int32)

    ok_ref[0] = jax.lax.fori_loop(0, n, insert_one, jnp.int32(1))


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def hash_build_insert(
    keys: jnp.ndarray,  # [N] int32, no EMPTY values
    capacity: int,  # power of two, >= 2 * N
    *,
    interpret: bool = True,
):
    """Batch-insert ``keys`` into a fresh open-addressing table.

    Returns ``(table_keys, table_entry, ok)``: the slab layout
    ``hash_probe_lens`` consumes (entry i of the batch at its linear-probe
    slot), with ``ok[0] == 0`` when a duplicate key or a probe chain
    longer than ``MAX_PROBE`` makes the table unservable."""
    assert capacity & (capacity - 1) == 0, "capacity must be a power of two"
    return pl.pallas_call(
        _insert_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(keys)
