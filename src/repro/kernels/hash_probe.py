"""Pallas TPU kernel: hash-join probe with a fused per-query state lens.

The paper's hot spot (§4.3): probe a shared open-addressing hash-build state
and emit, per probe key, the matching entry index — but only when the entry
is visible to the probing query (visibility bitmask AND query mask), i.e.
the per-query state lens is fused into the probe.

TPU adaptation (DESIGN.md §2/§7): probe keys are tiled into VMEM blocks of
``BLOCK_N``; the SoA table (keys / entry-visibility words) is VMEM-resident
per kernel instance (slab-sized tables; the engine's sort-probe handles
overflow sizes). The linear-probe loop is a bounded ``fori_loop`` of fully
vectorized gathers+compares on the VPU — no pointer chasing.

Unique-key tables only (FK-keyed dimension states); the engine routes
multi-match states through the reference path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024
MAX_PROBE = 16
EMPTY = -0x7FFFFFFF
MULT = 2654435761


def _hash(keys: jnp.ndarray, mask) -> jnp.ndarray:
    return (keys.astype(jnp.uint32) * jnp.uint32(MULT)).astype(jnp.int32) & mask


def _probe_kernel(probe_ref, tkeys_ref, tvis_ref, qmask_ref, out_ref):
    tkeys = tkeys_ref[...]
    tvis = tvis_ref[...]
    qmask = qmask_ref[0]
    cap_mask = jnp.int32(tkeys.shape[0] - 1)
    keys = probe_ref[...]
    pos = _hash(keys, cap_mask)
    found = jnp.full(keys.shape, -1, jnp.int32)
    done = jnp.zeros(keys.shape, jnp.bool_)

    def step(_, carry):
        pos, found, done = carry
        slot_keys = tkeys[pos]
        hit = (slot_keys == keys) & ~done
        empty = (slot_keys == jnp.int32(EMPTY)) & ~done
        # state lens: entry visible to this query?
        vis = (tvis[pos] & qmask) != 0
        found = jnp.where(hit & vis, pos, found)
        done = done | hit | empty
        pos = (pos + 1) & cap_mask
        return pos, found, done

    _, found, _ = jax.lax.fori_loop(0, MAX_PROBE, step, (pos, found, done))
    out_ref[...] = found


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_probe_lens(
    probe_keys: jnp.ndarray,  # [N] int32
    table_keys: jnp.ndarray,  # [T] int32, power-of-two T, EMPTY sentinel
    table_vis: jnp.ndarray,  # [T] uint32 per-entry visibility words
    query_mask: jnp.ndarray,  # [1] uint32
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    n = probe_keys.shape[0]
    pad = (-n) % BLOCK_N
    pk = jnp.pad(probe_keys, (0, pad), constant_values=jnp.int32(EMPTY))
    grid = (pk.shape[0] // BLOCK_N,)
    out = pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec(table_keys.shape, lambda i: (0,)),
            pl.BlockSpec(table_vis.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(pk.shape, jnp.int32),
        interpret=interpret,
    )(pk, table_keys, table_vis, query_mask)
    return out[:n]
