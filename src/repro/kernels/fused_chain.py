"""Fused morsel stage-chain Pallas kernel (DESIGN.md §13).

One launch runs a morsel's entire packed stage chain — hash probe →
lens-word translation → grant-predicate visibility → interval-matrix stage
filter, for every stage in sequence — plus the build-sink word translation,
over device-resident state mirrors. This replaces the per-stage host
round-trips of the member-major pipeline (§11): the host hands the kernel
the morsel's packed ownership words and per-row probe keys once, and gets
back the final words, per-stage matched entry indices, per-stage
alive/matched counts, per-slot survivor counts, and the sink's
visibility/extent words. Everything that must stay bit-exact in float64
(aggregate accumulation, payload values) is reconstructed host-side from
the returned entry indices; the kernel only ever computes set membership,
so results are bit-identical to the NumPy member-major path.

Two representation choices make the full 64-slot lens space and float64
predicates kernel-servable without 64-bit device types (TPUs have neither
int64 nor float64 lanes; the repo never enables jax x64):

* every packed uint64 word — ownership bits, lens words, translation
  tables, sink masks — travels as a (lo, hi) uint32 pair
  (``core.visibility.split_words``), with the byte-table translation done
  as 8 byte-lane gathers ORing into both halves;
* float64 predicate operands (grant bounds, stage-filter bounds, payload
  columns they compare against) are encoded host-side through a *monotone
  total-order* map onto a (hi, lo) uint32 pair (``total_order_u32``), so
  unsigned lexicographic compares in-kernel reproduce IEEE ``>=``/``<=``
  bit-exactly — including -0.0 == 0.0 (canonicalized before encoding) and
  NaN failing every constrained interval (NaN encodes outside the
  ±inf-bounded range on its sign's side).

The launch is shaped by a static, hashable *chain spec* (stage count, key
sourcing, grant/filter arity); the host assembles a flat canonical input
list (``input_kinds`` documents the traversal) and ``chain_launch``
dispatches through a cached jitted ``pallas_call``. Under interpret mode
the whole morsel runs as a single grid step (the grid would otherwise
unroll into Python-loop tracing at bench sizes); on a real TPU the same
kernel tiles by ``block_n`` with the stats/popcount outputs accumulated
across grid steps.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hash_probe import EMPTY, MAX_PROBE, MULT

__all__ = [
    "total_order_u32",
    "total_order_bound",
    "input_kinds",
    "chain_launch",
]

_SIGN = np.uint64(0x8000000000000000)


def total_order_u32(vals: np.ndarray):
    """Monotone total-order encoding of float64 onto (hi, lo) uint32 pairs.

    ``a <= b`` (IEEE, finite or infinite) iff ``enc(a) <= enc(b)`` as
    unsigned 64-bit lexicographic pairs. ``-0.0`` is canonicalized to
    ``+0.0`` first so the two zeros encode equal; NaNs land strictly
    outside the [-inf, +inf] band on their sign's side, so every
    constrained interval compare rejects them — exactly IEEE semantics
    for ``(x >= lo) & (x <= hi)``."""
    v = np.ascontiguousarray(np.asarray(vals, dtype=np.float64) + 0.0)
    b = v.view(np.uint64)
    m = np.where((b & _SIGN) != 0, ~b, b | _SIGN)
    hi = (m >> np.uint64(32)).astype(np.uint32)
    lo = (m & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return hi, lo


def total_order_bound(x: float):
    """Scalar :func:`total_order_u32` for predicate bounds."""
    hi, lo = total_order_u32(np.array([x], dtype=np.float64))
    return int(hi[0]), int(lo[0])


# -- chain spec ---------------------------------------------------------------
#
# spec = (stages, sink)
#   stages: tuple of (key_mode, n_grants, grant_attrs, filt)
#     key_mode    -1  => per-row host-encoded int32 keys
#                 s>=0 => keys gathered from an entry-indexed int32 column
#                         mirror through stage s's matched entry index
#     n_grants    number of compiled grant predicates ORed into this
#                 stage's lens resolution (0 = grant-free)
#     grant_attrs union count of bound attrs across this stage's grants
#     filt        None, or (n_members, attr_srcs): an interval stage-filter
#                 matrix over attr_srcs, each -1 (per-row host pair) or an
#                 origin stage index (entry-indexed mirror pair)
#   sink: True when the chain ends in a build sink (emit per-row
#         beneficiary-visibility and extent words from the final bits)


def input_kinds(spec):
    """Canonical flat input traversal for a chain spec.

    Returns a list of ``"row"`` (morsel-length, block-tiled) /
    ``"full"`` (whole-array-per-block: tables, mirrors, parameter
    matrices) markers, in the exact order the host must assemble inputs
    and the kernel consumes them:

    ``bits_lo, bits_hi``, then per stage: key array; ``tkeys, tentry``;
    ``evis_lo, evis_hi``; ``ttab_lo, ttab_hi``; grants block
    (``eem_lo, eem_hi, gbit[G,2], gallow[G,2], gcon[G,A], glo[G,A,2],
    ghi[G,A,2]``, then per grant attr its mirror pair); filter block
    (per attr its value pair, then ``flo[M,A,2], fhi[M,A,2], fcon[M,A],
    fbit[M,2]``); finally the sink's two table pairs."""
    stages, sink = spec
    kinds = ["row", "row"]
    for key_mode, n_grants, grant_attrs, filt in stages:
        kinds.append("row" if key_mode == -1 else "full")
        kinds += ["full"] * 6
        if n_grants:
            kinds += ["full"] * 7
            kinds += ["full"] * (2 * grant_attrs)
        if filt is not None:
            _, srcs = filt
            for src in srcs:
                kinds += ["row", "row"] if src == -1 else ["full", "full"]
            kinds += ["full"] * 4
    if sink:
        kinds += ["full"] * 4
    return kinds


def _ge(xh, xl, bh, bl):
    """(xh, xl) >= (bh, bl), unsigned lexicographic — IEEE >= on
    total-order-encoded float64."""
    return (xh > bh) | ((xh == bh) & (xl >= bl))


def _le(xh, xl, bh, bl):
    return (xh < bh) | ((xh == bh) & (xl <= bl))


def _translate(bl, bh, tlo, thi):
    """8 byte-lane gathers: OR the split translation tables over every
    byte of the (lo, hi) word pair — ``core.visibility.translate_bits``
    on device."""
    olo = jnp.zeros_like(bl)
    ohi = jnp.zeros_like(bh)
    for b in range(4):
        idx = ((bl >> jnp.uint32(8 * b)) & jnp.uint32(0xFF)).astype(jnp.int32)
        olo = olo | tlo[b][idx]
        ohi = ohi | thi[b][idx]
    for b in range(4):
        idx = ((bh >> jnp.uint32(8 * b)) & jnp.uint32(0xFF)).astype(jnp.int32)
        olo = olo | tlo[4 + b][idx]
        ohi = ohi | thi[4 + b][idx]
    return olo, ohi


def _build_kernel(spec):
    stages, sink = spec
    n_stages = len(stages)

    def kernel(*refs):
        it = iter(refs)
        bl = next(it)[...]
        bh = next(it)[...]
        stage_refs = []
        for key_mode, n_grants, grant_attrs, filt in stages:
            d = {"key": next(it)[...]}
            d["tkeys"] = next(it)[...]
            d["tentry"] = next(it)[...]
            d["evlo"] = next(it)[...]
            d["evhi"] = next(it)[...]
            d["ttlo"] = next(it)[...]
            d["tthi"] = next(it)[...]
            if n_grants:
                d["eemlo"] = next(it)[...]
                d["eemhi"] = next(it)[...]
                d["gbit"] = next(it)[...]
                d["gallow"] = next(it)[...]
                d["gcon"] = next(it)[...]
                d["glo"] = next(it)[...]
                d["ghi"] = next(it)[...]
                d["gattrs"] = [(next(it)[...], next(it)[...]) for _ in range(grant_attrs)]
            if filt is not None:
                _, srcs = filt
                d["fvals"] = [(next(it)[...], next(it)[...]) for _ in srcs]
                d["flo"] = next(it)[...]
                d["fhi"] = next(it)[...]
                d["fcon"] = next(it)[...]
                d["fbit"] = next(it)[...]
            stage_refs.append(d)
        if sink:
            stlo = next(it)[...]
            sthi = next(it)[...]
            selo = next(it)[...]
            sehi = next(it)[...]
        obl_ref = next(it)
        obh_ref = next(it)
        oent_refs = [next(it) for _ in range(n_stages)]
        ostats_ref = next(it)
        oslot_ref = next(it)
        if sink:
            osv_lo_ref = next(it)
            osv_hi_ref = next(it)
            ose_lo_ref = next(it)
            ose_hi_ref = next(it)

        entries = []
        stats = []
        for s, (key_mode, n_grants, grant_attrs, filt) in enumerate(stages):
            d = stage_refs[s]
            alive = (bl | bh) != 0
            if key_mode == -1:
                keys = d["key"]
            else:
                e = entries[key_mode]
                ok = e >= 0
                keys = jnp.where(ok, d["key"][jnp.where(ok, e, 0)], jnp.int32(EMPTY))
            keys = jnp.where(alive, keys, jnp.int32(EMPTY))
            tkeys = d["tkeys"]
            cap_mask = jnp.int32(tkeys.shape[0] - 1)
            pos = (keys.astype(jnp.uint32) * jnp.uint32(MULT)).astype(jnp.int32) & cap_mask
            found0 = jnp.full(keys.shape, -1, jnp.int32)
            done0 = keys == jnp.int32(EMPTY)

            def cond(carry):
                i, _pos, _found, done = carry
                return (i < MAX_PROBE) & jnp.any(~done)

            def body(carry, keys=keys, tkeys=tkeys, cap_mask=cap_mask):
                i, pos, found, done = carry
                slot_keys = tkeys[pos]
                hit = (slot_keys == keys) & ~done
                empty = (slot_keys == jnp.int32(EMPTY)) & ~done
                found = jnp.where(hit, pos, found)
                done = done | hit | empty
                pos = (pos + 1) & cap_mask
                return i + 1, pos, found, done

            _, _, found, _ = jax.lax.while_loop(
                cond, body, (jnp.int32(0), pos, found0, done0)
            )
            matched = found >= 0
            entry = jnp.where(matched, d["tentry"][jnp.where(matched, found, 0)], -1)
            entries.append(entry)
            safe_e = jnp.where(matched, entry, 0)
            # lens gather (entry-indexed: rebuild-invariant) + translation
            vlo = jnp.where(matched, d["evlo"][safe_e], jnp.uint32(0))
            vhi = jnp.where(matched, d["evhi"][safe_e], jnp.uint32(0))
            plo, phi = _translate(vlo, vhi, d["ttlo"], d["tthi"])
            if n_grants:
                # compiled extent-scoped grants: emask ∩ allowed, then the
                # conjunction's interval bounds on total-order-encoded cols
                elo = jnp.where(matched, d["eemlo"][safe_e], jnp.uint32(0))
                ehi = jnp.where(matched, d["eemhi"][safe_e], jnp.uint32(0))
                gvals = [
                    (gh[safe_e], gl[safe_e]) for gh, gl in d["gattrs"]
                ]
                for g in range(n_grants):
                    gok = ((elo & d["gallow"][g, 0]) | (ehi & d["gallow"][g, 1])) != 0
                    for a in range(grant_attrs):
                        xh, xl = gvals[a]
                        inb = _ge(xh, xl, d["glo"][g, a, 0], d["glo"][g, a, 1]) & _le(
                            xh, xl, d["ghi"][g, a, 0], d["ghi"][g, a, 1]
                        )
                        gok = gok & (inb | (d["gcon"][g, a] == 0))
                    plo = plo | jnp.where(gok, d["gbit"][g, 0], jnp.uint32(0))
                    phi = phi | jnp.where(gok, d["gbit"][g, 1], jnp.uint32(0))
            nbl = bl & jnp.where(matched, plo, jnp.uint32(0))
            nbh = bh & jnp.where(matched, phi, jnp.uint32(0))
            m_post = matched & ((nbl | nbh) != 0)
            bl, bh = nbl, nbh
            if filt is not None:
                n_members, srcs = filt
                vals = []
                for a, src in enumerate(srcs):
                    vh, vl = d["fvals"][a]
                    if src == -1:
                        vals.append((vh, vl))
                    else:
                        e2 = entries[src]
                        s2 = jnp.where(e2 >= 0, e2, 0)
                        vals.append((vh[s2], vl[s2]))
                fblo = jnp.zeros_like(bl)
                fbhi = jnp.zeros_like(bh)
                fmlo = jnp.zeros_like(bl)
                fmhi = jnp.zeros_like(bh)
                for m in range(n_members):
                    okm = None
                    for a in range(len(srcs)):
                        xh, xl = vals[a]
                        inb = _ge(
                            xh, xl, d["flo"][m, a, 0], d["flo"][m, a, 1]
                        ) & _le(xh, xl, d["fhi"][m, a, 0], d["fhi"][m, a, 1])
                        oka = inb | (d["fcon"][m, a] == 0)
                        okm = oka if okm is None else okm & oka
                    fblo = fblo | jnp.where(okm, d["fbit"][m, 0], jnp.uint32(0))
                    fbhi = fbhi | jnp.where(okm, d["fbit"][m, 1], jnp.uint32(0))
                    fmlo = fmlo | d["fbit"][m, 0]
                    fmhi = fmhi | d["fbit"][m, 1]
                bl = bl & (~fmlo | fblo)
                bh = bh & (~fmhi | fbhi)
            stats.append(
                jnp.stack(
                    [
                        jnp.sum(alive.astype(jnp.int32)),
                        jnp.sum(matched.astype(jnp.int32)),
                        jnp.sum(m_post.astype(jnp.int32)),
                    ]
                )
            )

        obl_ref[...] = bl
        obh_ref[...] = bh
        for s in range(n_stages):
            oent_refs[s][...] = entries[s]
        slot_counts = jnp.stack(
            [
                jnp.sum(((bl >> jnp.uint32(j)) & jnp.uint32(1)).astype(jnp.int32))
                for j in range(32)
            ]
            + [
                jnp.sum(((bh >> jnp.uint32(j)) & jnp.uint32(1)).astype(jnp.int32))
                for j in range(32)
            ]
        )
        block_stats = jnp.stack(stats)

        @pl.when(pl.program_id(0) == 0)
        def _init():
            ostats_ref[...] = jnp.zeros(ostats_ref.shape, jnp.int32)
            oslot_ref[...] = jnp.zeros(oslot_ref.shape, jnp.int32)

        ostats_ref[...] = ostats_ref[...] + block_stats
        oslot_ref[...] = oslot_ref[...] + slot_counts
        if sink:
            svlo, svhi = _translate(bl, bh, stlo, sthi)
            oelo, oehi = _translate(bl, bh, selo, sehi)
            osv_lo_ref[...] = svlo
            osv_hi_ref[...] = svhi
            ose_lo_ref[...] = oelo
            ose_hi_ref[...] = oehi

    return kernel


@functools.lru_cache(maxsize=None)
def _chain_fn(spec, block_n, interpret):
    stages, sink = spec
    n_stages = len(stages)
    kinds = input_kinds(spec)
    kernel = _build_kernel(spec)

    @jax.jit
    def run(*arrays):
        n = arrays[0].shape[0]
        block = n if block_n is None else block_n
        grid = (n // block,)

        def spec_of(kind, arr):
            if kind == "row":
                return pl.BlockSpec((block,), lambda i: (i,))
            return pl.BlockSpec(arr.shape, lambda i, nd=arr.ndim: (0,) * nd)

        in_specs = [spec_of(k, a) for k, a in zip(kinds, arrays)]
        row_spec = pl.BlockSpec((block,), lambda i: (i,))
        out_specs = [row_spec, row_spec]
        out_shape = [
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ]
        for _ in range(n_stages):
            out_specs.append(row_spec)
            out_shape.append(jax.ShapeDtypeStruct((n,), jnp.int32))
        out_specs.append(pl.BlockSpec((n_stages, 3), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((n_stages, 3), jnp.int32))
        out_specs.append(pl.BlockSpec((64,), lambda i: (0,)))
        out_shape.append(jax.ShapeDtypeStruct((64,), jnp.int32))
        if sink:
            for _ in range(4):
                out_specs.append(row_spec)
                out_shape.append(jax.ShapeDtypeStruct((n,), jnp.uint32))
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(*arrays)

    return run


@functools.lru_cache(maxsize=None)
def _chain_fn_sharded(spec, block_n, interpret, mesh, axis_name):
    """Shard-local chain launch (DESIGN.md §14): row inputs partitioned
    over the mesh's data axis, state mirrors replicated, one pallas launch
    per shard inside shard_map. Stats/slot-count outputs are additive over
    row shards and psum'd so every shard (and the host) sees the global
    totals; row outputs stay sharded. Row buffers are donated off-CPU —
    the packed words and keys are dead after the launch, so the device
    reuses their memory for the outputs."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    stages, sink = spec
    n_stages = len(stages)
    kinds = input_kinds(spec)
    inner = _chain_fn(spec, block_n, interpret)
    row = PartitionSpec(axis_name)
    rep = PartitionSpec()
    stats_i = 2 + n_stages

    def local(*arrays):
        out = list(inner(*arrays))
        out[stats_i] = jax.lax.psum(out[stats_i], axis_name)
        out[stats_i + 1] = jax.lax.psum(out[stats_i + 1], axis_name)
        return tuple(out)

    n_out = 2 + n_stages + 2 + (4 if sink else 0)
    out_specs = [row, row] + [row] * n_stages + [rep, rep]
    if sink:
        out_specs += [row] * 4
    assert len(out_specs) == n_out
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=tuple(row if k == "row" else rep for k in kinds),
        out_specs=tuple(out_specs),
        check_rep=False,
    )
    donate = ()
    if jax.default_backend() != "cpu":
        # mirror the scatter-path donation gating: CPU jax warns and
        # ignores donation, so only donate on real accelerators
        donate = tuple(i for i, k in enumerate(kinds) if k == "row")
    return jax.jit(fn, donate_argnums=donate)


def chain_launch(spec, arrays, *, block_n=None, interpret=True, mesh=None,
                 axis_name="data"):
    """Dispatch one fused stage-chain launch.

    ``arrays`` must follow :func:`input_kinds`'s traversal, with every
    "row" array padded to a common power-of-two length (dead padding rows
    carry zero ownership words and EMPTY keys, so they contribute to no
    output). Returns the raw output tuple:
    ``(bits_lo, bits_hi, entry_0..entry_{S-1}, stats[S,3], slots[64]``
    ``[, sink_vis_lo, sink_vis_hi, sink_em_lo, sink_em_hi])``.
    ``stats[s]`` is ``(alive_in, matched, matched_visible)`` for stage s.

    With ``mesh`` set, the launch runs shard-locally inside shard_map over
    the mesh's ``axis_name`` axis (§14): row arrays must be divisible by
    the axis size (the power-of-two padding guarantees this for power-of-
    two meshes), row outputs come back in shard order, and stats/slot
    counts are global. A 1-device mesh is bit-identical to the unsharded
    launch."""
    if mesh is None:
        return _chain_fn(spec, block_n, interpret)(*arrays)
    return _chain_fn_sharded(spec, block_n, interpret, mesh, axis_name)(*arrays)
