"""Pure-jnp oracles for every Pallas kernel (the correctness references the
shape/dtype sweep tests assert against)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def hash_probe_lens_ref(probe_keys, table_keys, table_vis, query_mask):
    """For each probe key: index of the matching, query-visible entry in the
    open-addressing table, else -1. (Unique keys.)"""
    T = table_keys.shape[0]
    eq = probe_keys[:, None] == table_keys[None, :]  # [N, T]
    vis = (table_vis & query_mask[0]) != 0
    hit = eq & vis[None, :]
    idx = jnp.argmax(hit, axis=1).astype(jnp.int32)
    return jnp.where(hit.any(axis=1), idx, -1)


def seg_aggregate_ref(codes, values, n_groups):
    return jax.ops.segment_sum(
        values.astype(jnp.float32), codes, num_segments=n_groups
    )


def flash_attention_ref(q, k, v, *, window=None):
    bh, s, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = qpos >= kpos
    if window is not None:
        ok &= qpos - kpos < window
    scores = jnp.where(ok, scores, -1e30)
    a = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", a.astype(v.dtype), v).astype(q.dtype)


def linrec_ref(a, b):
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a32 = a.astype(jnp.float32).swapaxes(0, 1)
    b32 = b.astype(jnp.float32).swapaxes(0, 1)
    h0 = jnp.zeros(a.shape[::2], jnp.float32) if False else jnp.zeros(
        (a.shape[0], a.shape[2]), jnp.float32
    )
    _, hs = jax.lax.scan(step, h0, (a32, b32))
    return hs.swapaxes(0, 1)
