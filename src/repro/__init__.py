"""repro: GraftDB paper reproduction (internal implementation package).

The supported public surface is the ``graftdb`` package (``repro.api``
re-exported); see README.md. This file exists so setuptools package
discovery installs ``repro`` alongside ``graftdb``.
"""
