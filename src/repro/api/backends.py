"""ExecutionBackend: pluggable data-plane kernels behind one Session.

The engine's two hot vectorized operations — hash-probe against a shared
build state (§4.3) and segmented aggregation into shared accumulators
(§4.5) — are routed through a per-session backend:

* ``ReferenceBackend`` — the NumPy row engine (incremental hash/dup-run
  probe index in ``core.state``, ``np.bincount`` reductions). Always
  available; the correctness oracle path (``relational/refexec.py``
  semantics).
* ``PallasBackend`` — the jax_pallas TPU kernels (``kernels/hash_probe.py``,
  ``kernels/fused_chain.py``, ``kernels/seg_aggregate.py``), run in
  interpret mode off-TPU. States that the kernels cannot serve (multi-match
  keys, out-of-range keycodes, over-long probe clusters) fall back to the
  reference path per-call, mirroring the routing note in the kernel
  docstrings; per-reason fallback counters record why.

The Pallas backend keeps a device-resident mirror of every served state's
SoA (DESIGN.md §13): open-addressing keycode table, *entry-indexed* packed
visibility/provenance words as (lo, hi) uint32 pairs, and on demand
total-order-encoded retained columns and int32 key columns. Entry indexing
makes the mirrors rebuild-invariant — growing or rehashing the probe table
never touches them — and the state's mark log patches exactly the re-ORed
entries, so steady-state maintenance is O(appended + marked), not
O(entries). Mirror patches run through donated-buffer jitted scatters when
the platform supports donation (CPU jax warns on donation, so it is gated).
"""

from __future__ import annotations

import functools
import math
import weakref
from typing import Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from ..core.state import SharedHashBuildState, _bincount_segment_sum
from ..core.visibility import join_words, split_words

#: chain-level / probe-level decline reasons (DESIGN.md §13). ``grants``,
#: ``predicate`` and ``slot_limit`` are chain-plan declines (the staged
#: kernels may still serve the probes); ``keyrange`` and ``capacity`` are
#: table-level declines that route the probe to the reference path.
FALLBACK_REASONS = ("grants", "slot_limit", "keyrange", "capacity", "predicate")


@runtime_checkable
class ExecutionBackend(Protocol):
    """Data-plane operations a Session's engine dispatches per morsel.

    Backends may additionally provide ``probe_visible(state, keycodes,
    qid)`` / ``probe_visible_multi(state, keycodes)`` /
    ``probe_chain(cplan, cols, bits, host_keys)`` returning
    visibility-resolved results (or None to decline); the runtime discovers
    them via getattr, so they are not part of the required protocol
    surface. A backend that sets ``probe_accepts_counters = True`` receives
    the engine's counter dict as a ``counters=`` kwarg on ``probe`` so
    per-reason fallback counters surface in ``QueryFuture.stats()``."""

    name: str

    def probe(
        self, state: SharedHashBuildState, keycodes: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """All (probe_row_idx, entry_idx) match pairs, pre-visibility."""
        ...

    def segment_sum(
        self, gids: np.ndarray, values: Optional[np.ndarray], n_groups: int
    ) -> np.ndarray:
        """Per-group sum of ``values`` (counts when values is None)."""
        ...


class ReferenceBackend:
    """NumPy data plane — delegates to the state's own incremental probe
    index (shard-routed under ``n_partitions > 1``, DESIGN.md §9) and the
    core bincount reduction (the same code that runs with no backend)."""

    name = "reference"
    probe_accepts_counters = True

    def probe(self, state, keycodes, counters=None):
        return state.probe(keycodes)

    def segment_sum(self, gids, values, n_groups):
        return _bincount_segment_sum(gids, values, n_groups)

    def stats(self) -> dict:
        return {}


@functools.lru_cache(maxsize=None)
def _scatter_set(donate: bool):
    """Jitted mirror patch ``buf.at[idx].set(vals)``; the mirror buffer is
    donated where the platform supports it so steady-state patches update
    device memory in place instead of copying the whole mirror."""
    import jax

    def f(buf, idx, vals):
        return buf.at[idx].set(vals)

    if donate:
        return jax.jit(f, donate_argnums=(0,))
    return jax.jit(f)


class _ProbeTable:
    """Device-resident mirror of one state's SoA (DESIGN.md §13).

    The open-addressing keycode table is slot-indexed; everything else —
    visibility/provenance words, total-order column encodings, int32 key
    columns — is *entry-indexed* (padded to ``ecap``), so table rebuilds
    never invalidate it. Appends patch ``[rows:n]``; visibility marks patch
    the state's mark-log entries; a mark-log compaction or a ``detach``
    epoch bump forces one full regather."""

    __slots__ = (
        "n",
        "tkeys",
        "slot_entry",
        "jkeys",
        "jentry",
        "jones",
        "bad",
        "ecap",
        "jvlo",
        "jvhi",
        "jelo",
        "jehi",
        "vis_rows",
        "em_rows",
        "vis_stamp",
        "mark_sync",
        "ords",
        "keycols",
        "badkeys",
    )

    def __init__(self):
        self.n = 0  # state entries inserted so far
        self.tkeys: Optional[np.ndarray] = None  # int32 slots (EMPTY sentinel)
        self.slot_entry: Optional[np.ndarray] = None  # slot -> entry index
        self.jkeys = None  # device copy of tkeys, refreshed on growth
        self.jentry = None  # device int32 slot -> entry index
        self.jones = None  # constant all-visible lens words (pre-vis probes)
        self.bad = False  # sticky: kernel cannot serve this state's table
        # entry-indexed mirrors, padded to ecap (power of two)
        self.ecap = 0
        self.jvlo = None  # visibility word low halves, uint32[ecap]
        self.jvhi = None
        self.jelo = None  # provenance (emask) halves, built on demand
        self.jehi = None
        self.vis_rows = 0  # entries the vis mirror reflects
        self.em_rows = 0
        self.vis_stamp = None  # (rows_inserted, rows_marked, vis_epoch)
        self.mark_sync = (0, 0)  # (mark_log_epoch, consumed log length)
        self.ords = {}  # attr -> [j_hi, j_lo, rows] total-order encodings
        self.keycols = {}  # attr -> [j_i32, rows] entry-origin key mirrors
        self.badkeys = set()  # attrs whose values left the int32 key range


class PallasBackend:
    """jax_pallas data plane (interpret mode off-TPU).

    Unique-key states probe through the fused-lens Pallas kernels over
    entry-indexed device mirrors. Single-query probes route through
    ``probe_visible`` — the query's slot bit (any of the 64) becomes the
    kernel lens mask, so visibility resolves in-kernel and the runtime
    skips its NumPy ``visible_mask`` pass. Multi-member probes take
    ``probe_visible_multi``, which returns the matched entries' full packed
    uint64 words. ``probe_chain`` fuses a morsel's entire stage chain —
    probe → lens translation → compiled grant predicates → interval stage
    filters → sink word translation — into one launch
    (``kernels/fused_chain.py``). Everything the kernels cannot serve
    (multi-match keys, out-of-range keycodes, over-long probe clusters)
    falls back to the reference probe, with the decline reason counted in
    ``fallback_reasons``.

    Probe-table maintenance is batch-oriented: new keys insert via
    vectorized per-slot winner election (``_batch_insert``), or through the
    Pallas ``hash_build_insert`` kernel when ``use_insert_kernel`` is set
    (opt-in: the in-kernel insert loop is sequential, which only pays off
    compiled on-device).

    Segmented sums route through the one-hot MXU kernel below
    ``max_kernel_groups`` groups when ``use_agg_kernel`` is set; it
    accumulates in float32, so it is opt-in — the default keeps aggregate
    accumulation in float64 to preserve exact oracle parity.
    """

    name = "pallas"
    probe_accepts_counters = True

    # Keycodes must fit int32 and stay clear of the kernel's EMPTY sentinel.
    _KEY_LIMIT = 2**31 - 2

    def __init__(
        self,
        interpret: bool = True,
        max_kernel_groups: int = 4096,
        use_agg_kernel: bool = False,
        use_insert_kernel: bool = False,
    ):
        import jax

        from ..kernels.fused_chain import chain_launch, total_order_u32
        from ..kernels.hash_probe import (
            hash_build_insert,
            hash_probe_lens,
            hash_probe_lens64,
            hash_probe_lens_multi64,
        )
        from ..kernels.seg_aggregate import seg_aggregate

        self._hash_probe_lens = hash_probe_lens
        self._hash_probe_lens64 = hash_probe_lens64
        self._hash_probe_lens_multi64 = hash_probe_lens_multi64
        self._hash_build_insert = hash_build_insert
        self._seg_aggregate = seg_aggregate
        self._chain_launch = chain_launch
        self._total_order_u32 = total_order_u32
        self.interpret = interpret
        self.max_kernel_groups = max_kernel_groups
        self.use_agg_kernel = use_agg_kernel
        self.use_insert_kernel = use_insert_kernel
        self._ref = ReferenceBackend()
        # donated in-place mirror patches (CPU jax warns on donation)
        self._donate = jax.default_backend() != "cpu"
        # mesh execution (§14): when a Session pins a data mesh here, the
        # fused stage chain launches shard-locally inside shard_map on it
        # (None = plain single-device launches)
        self.mesh = None
        # Probe tables keyed weakly by the state OBJECT (state_ids are
        # engine-local, so an id key would collide when one backend instance
        # is reused across sessions); released states evict automatically.
        self._tables: "weakref.WeakKeyDictionary[SharedHashBuildState, _ProbeTable]" = (
            weakref.WeakKeyDictionary()
        )
        self._qmask = None  # constant all-ones lens mask, built lazily
        self.kernel_probes = 0
        self.kernel_lens_probes = 0
        self.kernel_multi_probes = 0
        self.fallback_probes = 0
        self.chain_launches = 0
        self.mirror_full_regathers = 0
        self.mirror_patched_rows = 0
        self.fallback_reasons = {r: 0 for r in FALLBACK_REASONS}

    def stats(self) -> dict:
        """Kernel-dispatch counters (surfaced via ``Session.stats``).

        Partitioned states (``n_partitions > 1``) need no special casing
        here: the probe-table mirror is built from the state's global
        keycode SoA, whose entry ids are partition-independent (§9) — each
        (fragment × partition) unit simply lands its own batched kernel
        call, which is the real per-partition work the pool models."""
        out = {
            "kernel_probes": self.kernel_probes,
            "kernel_lens_probes": self.kernel_lens_probes,
            "kernel_multi_probes": self.kernel_multi_probes,
            "fallback_probes": self.fallback_probes,
            "chain_launches": self.chain_launches,
            "mirror_full_regathers": self.mirror_full_regathers,
            "mirror_patched_rows": self.mirror_patched_rows,
        }
        for r in FALLBACK_REASONS:
            out[f"fallback_{r}"] = self.fallback_reasons[r]
        return out

    def note_fallback(self, reason: str, counters=None) -> None:
        """Record one kernel decline by reason, on the backend and (when
        the engine's counter dict is handed in) in the session counters."""
        self.fallback_reasons[reason] += 1
        if counters is not None:
            counters[f"fallback_probes_{reason}"] += 1

    # -- probe ---------------------------------------------------------------
    def probe(self, state, keycodes, counters=None):
        if state.keycode.n == 0 or len(keycodes) == 0:
            return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
        table = self._table_for(state)
        if table is None:
            self.fallback_probes += 1
            self.note_fallback("capacity", counters)
            return self._ref.probe(state, keycodes)
        if keycodes.min() < 0 or keycodes.max() > self._KEY_LIMIT:
            self.fallback_probes += 1
            self.note_fallback("keyrange", counters)
            return self._ref.probe(state, keycodes)
        import jax.numpy as jnp

        tkeys, tones, slot_entry = table
        if self._qmask is None:  # lens off: pure key match
            self._qmask = jnp.asarray([0xFFFFFFFF], dtype=jnp.uint32)
        found_slots = np.asarray(
            self._hash_probe_lens(
                jnp.asarray(keycodes, dtype=jnp.int32),
                tkeys,
                tones,
                self._qmask,
                interpret=self.interpret,
            )
        )
        self.kernel_probes += 1
        probe_idx = np.flatnonzero(found_slots >= 0).astype(np.int64)
        entry_idx = slot_entry[found_slots[probe_idx]]
        return probe_idx, entry_idx

    def probe_visible(self, state, keycodes, qid):
        """Single-query probe with the state lens fused in-kernel.

        Returns visibility-filtered (probe_idx, entry_idx) pairs, or None
        when the kernel cannot take over the lens (extent-scoped grants
        need predicate evaluation — unless routed through ``probe_chain``'s
        compiled form; unservable tables fall back entirely). The lens
        words are entry-indexed uint32 pairs, so any slot 0..63 serves —
        the former uint32-word slot<32 limit is gone (DESIGN.md §13)."""
        if state.grants.get(qid):
            return None
        slot = state.slots.peek(qid)
        if slot is None:
            return None
        if state.keycode.n == 0 or len(keycodes) == 0:
            # decline instead of returning the empty pair: keeps the
            # kernel_lens_probes backend attr == engine counter invariant
            return None
        table = self._table_for(state)
        if table is None or keycodes.min() < 0 or keycodes.max() > self._KEY_LIMIT:
            return None
        import jax.numpy as jnp

        ent = self._tables[state]
        self._sync_mirrors(ent, state)
        mask = np.uint64(1) << np.uint64(slot)
        mlo, mhi = split_words(np.array([mask], dtype=np.uint64))
        found = np.asarray(
            self._hash_probe_lens64(
                jnp.asarray(keycodes, dtype=jnp.int32),
                ent.jkeys,
                ent.jentry,
                ent.jvlo,
                ent.jvhi,
                jnp.asarray(np.array([mlo[0], mhi[0]], dtype=np.uint32)),
                interpret=self.interpret,
            )
        )
        self.kernel_probes += 1
        self.kernel_lens_probes += 1
        probe_idx = np.flatnonzero(found >= 0).astype(np.int64)
        entry_idx = ent.slot_entry[found[probe_idx]]
        return probe_idx, entry_idx

    def probe_visible_multi(self, state, keycodes):
        """Multi-member probe with the packed lens words gathered in-kernel
        (§11): returns ``(probe_idx, entry_idx, vis_words)`` where
        ``vis_words[i]`` is the matched entry's full uint64 visibility
        word (rejoined from the kernel's uint32 halves), or None when the
        kernel cannot serve the state. The pair stream is pre-visibility
        and identical to ``probe`` — ownership filtering happens in the
        runtime's packed translation — so results stay bit-identical to
        the reference path for every member count and any slot 0..63."""
        if state.keycode.n == 0 or len(keycodes) == 0:
            return None
        table = self._table_for(state)
        if table is None or keycodes.min() < 0 or keycodes.max() > self._KEY_LIMIT:
            return None
        import jax.numpy as jnp

        ent = self._tables[state]
        self._sync_mirrors(ent, state)
        found, wlo, whi = self._hash_probe_lens_multi64(
            jnp.asarray(keycodes, dtype=jnp.int32),
            ent.jkeys,
            ent.jentry,
            ent.jvlo,
            ent.jvhi,
            interpret=self.interpret,
        )
        found = np.asarray(found)
        self.kernel_probes += 1
        self.kernel_multi_probes += 1
        probe_idx = np.flatnonzero(found >= 0).astype(np.int64)
        entry_idx = ent.slot_entry[found[probe_idx]]
        vis_words = join_words(
            np.asarray(wlo)[probe_idx], np.asarray(whi)[probe_idx]
        )
        return probe_idx, entry_idx, vis_words

    # -- fused stage chain (DESIGN.md §13) -----------------------------------
    def probe_chain(self, cplan, cols, bits, host_keys, counters=None):
        """One fused launch for a morsel's entire stage chain.

        ``cplan`` is the runtime's compiled chain plan (``Pipeline.
        _build_chain_plan``): per stage the target state, lens translation
        tables, key sourcing, compiled grants and interval filter matrices;
        plus the sink translation tables. ``cols`` are the morsel's
        source-compacted columns, ``bits`` the packed ownership words and
        ``host_keys`` the per-stage host-encoded keycodes for
        source-origin keys. Returns None on a dynamic decline (reason
        counted), else a dict with the final packed words, per-stage
        matched entry indices, per-stage (alive, matched,
        matched_visible) stats, per-slot survivor counts, and — for build
        chains — the sink visibility/provenance words. Device parameter
        uploads are cached on the plan (``cplan["_dev"]``), so steady-state
        morsels ship only the row-length arrays."""
        stages = cplan["stages"]
        n = len(bits)
        if n == 0:
            return None

        # collect per-state mirror needs across the chain
        needs: dict = {}

        def need(state):
            nd = needs.get(id(state))
            if nd is None:
                nd = needs[id(state)] = {
                    "state": state,
                    "em": False,
                    "ords": set(),
                    "keys": set(),
                }
            return nd

        for st in stages:
            state = st["state"]
            if state.keycode.n == 0:
                return None  # no rows can survive; the staged path is as cheap
            need(state)
            key = st["key"]
            if key[0] == "entry":
                need(stages[key[1]]["state"])["keys"].add(key[2])
            if st["grants"]:
                nd = need(state)
                nd["em"] = True
                for _, _, bounds in st["grants"]:
                    for a, _, _ in bounds:
                        nd["ords"].add(a)
            f = st["filter"]
            if f is not None:
                for ref in f["attrs"]:
                    if ref[0] == "entry":
                        need(stages[ref[1]]["state"])["ords"].add(ref[2])
        for st in stages:
            if self._table_for(st["state"]) is None:
                self.note_fallback("capacity", counters)
                return None
        for nd in needs.values():
            state = nd["state"]
            ent = self._tables[state]
            self._sync_mirrors(
                ent,
                state,
                need_em=nd["em"],
                ord_attrs=sorted(nd["ords"]),
                key_attrs=sorted(nd["keys"]),
            )
            for a in nd["keys"]:
                if a in ent.badkeys:
                    self.note_fallback("keyrange", counters)
                    return None

        import jax.numpy as jnp

        from ..kernels.hash_probe import EMPTY

        npad = 8
        while npad < n:
            npad *= 2

        def pad_row(a, fill=0):
            if len(a) < npad:
                a = np.concatenate(
                    [a, np.full(npad - len(a), fill, dtype=a.dtype)]
                )
            return jnp.asarray(a)

        blo, bhi = split_words(bits)
        arrays = [pad_row(blo), pad_row(bhi)]
        spec_stages = []
        dev = cplan["_dev"]
        for si, st in enumerate(stages):
            ent = self._tables[st["state"]]
            key = st["key"]
            if key[0] == "host":
                kc = host_keys[si]
                if len(kc) and (kc.min() < 0 or kc.max() > self._KEY_LIMIT):
                    self.note_fallback("keyrange", counters)
                    return None
                key_mode = -1
                arrays.append(pad_row(kc.astype(np.int32), EMPTY))
            else:
                key_mode = key[1]
                oent = self._tables[stages[key_mode]["state"]]
                arrays.append(oent.keycols[key[2]][0])
            arrays += [ent.jkeys, ent.jentry, ent.jvlo, ent.jvhi]
            tt = dev.get(("tt", si))
            if tt is None:
                tlo, thi = split_words(st["tables"].ravel())
                tt = (
                    jnp.asarray(tlo.reshape(8, 256)),
                    jnp.asarray(thi.reshape(8, 256)),
                )
                dev[("tt", si)] = tt
            arrays += [tt[0], tt[1]]
            n_grants = len(st["grants"])
            g_attrs = 0
            if n_grants:
                gp = dev.get(("g", si))
                if gp is None:
                    gp = self._grant_params(st["grants"])
                    dev[("g", si)] = gp
                gattr_names, gbit, gallow, gcon, glo, ghi = gp
                g_attrs = len(gattr_names)
                arrays += [ent.jelo, ent.jehi, gbit, gallow, gcon, glo, ghi]
                for a in gattr_names:
                    rec = ent.ords[a]
                    arrays += [rec[0], rec[1]]
            f = st["filter"]
            fspec = None
            if f is not None and len(f["attrs"]):
                srcs = []
                for ref in f["attrs"]:
                    if ref[0] == "host":
                        vh, vl = self._total_order_u32(
                            np.asarray(cols[ref[1]], dtype=np.float64)
                        )
                        arrays += [pad_row(vh), pad_row(vl)]
                        srcs.append(-1)
                    else:
                        rec = self._tables[stages[ref[1]]["state"]].ords[ref[2]]
                        arrays += [rec[0], rec[1]]
                        srcs.append(ref[1])
                fp = dev.get(("f", si))
                if fp is None:
                    fp = self._filter_params(f)
                    dev[("f", si)] = fp
                arrays += list(fp)
                fspec = (f["n_members"], tuple(srcs))
            spec_stages.append((key_mode, n_grants, g_attrs, fspec))
        sink = cplan["sink"]
        if sink is not None:
            sp = dev.get("sink")
            if sp is None:
                vt, et = sink
                vlo, vhi = split_words(vt.ravel())
                elo, ehi = split_words(et.ravel())
                sp = tuple(
                    jnp.asarray(x.reshape(8, 256)) for x in (vlo, vhi, elo, ehi)
                )
                dev["sink"] = sp
            arrays += list(sp)
        spec = (tuple(spec_stages), sink is not None)
        out = self._chain_launch(
            spec, tuple(arrays), interpret=self.interpret, mesh=self.mesh
        )
        n_stages = len(stages)
        res = {
            "bits": join_words(np.asarray(out[0])[:n], np.asarray(out[1])[:n]),
            "entries": [
                np.asarray(out[2 + s])[:n].astype(np.int64)
                for s in range(n_stages)
            ],
            "stats": np.asarray(out[2 + n_stages]).astype(np.int64),
            "slots": np.asarray(out[3 + n_stages]).astype(np.int64),
        }
        if sink is not None:
            res["vismask"] = join_words(
                np.asarray(out[4 + n_stages])[:n],
                np.asarray(out[5 + n_stages])[:n],
            )
            res["emask"] = join_words(
                np.asarray(out[6 + n_stages])[:n],
                np.asarray(out[7 + n_stages])[:n],
            )
        self.kernel_probes += 1
        self.chain_launches += 1
        stats = res["stats"]
        for s, st in enumerate(stages):
            if stats[s, 0] == 0:
                break
            if st["use_post"]:
                self.kernel_lens_probes += 1
            else:
                self.kernel_multi_probes += 1
        return res

    def _grant_params(self, grants):
        """Device parameter matrices of one stage's compiled grants: the
        union attr list, per-grant split bit/allowed words, and the
        per-(grant, attr) constrained flags + total-order interval bounds
        (unconstrained cells carry flag 0 and the full [-inf, inf] band)."""
        import jax.numpy as jnp

        from ..kernels.fused_chain import total_order_bound

        attrs = []
        for _, _, bounds in grants:
            for a, _, _ in bounds:
                if a not in attrs:
                    attrs.append(a)
        n_g = len(grants)
        n_a = max(len(attrs), 1)
        gbit = np.zeros((n_g, 2), np.uint32)
        gallow = np.zeros((n_g, 2), np.uint32)
        gcon = np.zeros((n_g, n_a), np.int32)
        glo = np.zeros((n_g, n_a, 2), np.uint32)
        ghi = np.zeros((n_g, n_a, 2), np.uint32)
        glo[:, :, 0], glo[:, :, 1] = total_order_bound(-math.inf)
        ghi[:, :, 0], ghi[:, :, 1] = total_order_bound(math.inf)
        for g, (bitval, allowed, bounds) in enumerate(grants):
            lo, hi = split_words(np.array([bitval], dtype=np.uint64))
            gbit[g] = (lo[0], hi[0])
            lo, hi = split_words(np.array([allowed], dtype=np.uint64))
            gallow[g] = (lo[0], hi[0])
            for a, blo, bhi in bounds:
                j = attrs.index(a)
                gcon[g, j] = 1
                glo[g, j] = total_order_bound(blo)
                ghi[g, j] = total_order_bound(bhi)
        return (
            tuple(attrs),
            jnp.asarray(gbit),
            jnp.asarray(gallow),
            jnp.asarray(gcon),
            jnp.asarray(glo),
            jnp.asarray(ghi),
        )

    def _filter_params(self, f):
        """Device matrices of one stage's fused interval filter: bounds as
        total-order uint32 pairs, constrained flags, split member bits."""
        import jax.numpy as jnp

        n_m = f["n_members"]
        n_a = len(f["attrs"])
        lh, ll = self._total_order_u32(np.asarray(f["lo"], np.float64).ravel())
        hh, hl = self._total_order_u32(np.asarray(f["hi"], np.float64).ravel())
        flo = np.stack([lh, ll], axis=-1).reshape(n_m, n_a, 2)
        fhi = np.stack([hh, hl], axis=-1).reshape(n_m, n_a, 2)
        fcon = np.asarray(f["con"], np.int32).reshape(n_m, n_a)
        blo, bhi = split_words(np.asarray(f["bitvals"], np.uint64))
        fbit = np.stack([blo, bhi], axis=-1)
        return (
            jnp.asarray(flo),
            jnp.asarray(fhi),
            jnp.asarray(fcon),
            jnp.asarray(fbit),
        )

    # -- entry-indexed device mirrors ----------------------------------------
    def _upload(self, vals, cap):
        import jax.numpy as jnp

        if len(vals) < cap:
            vals = np.pad(vals, (0, cap - len(vals)))
        return jnp.asarray(vals)

    def _patch(self, buf, idx, vals):
        """Scatter ``vals`` into the device mirror at entry ids ``idx``.
        Index/value lengths pad to the next power of two (repeating the
        first element — duplicate same-value writes are benign) so the
        jitted scatter compiles O(log n) shapes, not one per batch size."""
        import jax.numpy as jnp

        m = len(idx)
        cap = 1
        while cap < m:
            cap *= 2
        idx = np.asarray(idx, dtype=np.int32)
        if cap != m:
            idx = np.concatenate([idx, np.full(cap - m, idx[0], dtype=np.int32)])
            vals = np.concatenate([vals, np.full(cap - m, vals[0], dtype=vals.dtype)])
        return _scatter_set(self._donate)(buf, jnp.asarray(idx), jnp.asarray(vals))

    def _sync_mirrors(self, ent, state, need_em=False, ord_attrs=(), key_attrs=()):
        """Bring the entry-indexed device mirrors up to the state's SoA.

        Steady state is incremental: appended entries patch ``[rows:n]``,
        visibility/provenance marks patch exactly the state's mark-log
        entry ids. Only a mark-log compaction, a ``detach`` visibility
        epoch bump, or a capacity realloc trigger a full regather
        (``mirror_full_regathers`` counts them). Total-order column
        encodings and int32 key-column mirrors are append-only — retained
        column values never change after insert. Key columns whose values
        leave the int32 key range mark ``badkeys`` sticky."""
        n = ent.n
        if ent.jvlo is None or ent.ecap < n:
            cap = max(ent.ecap, 256)
            while cap < n:
                cap *= 2
            ent.ecap = cap
            ent.jvlo = ent.jvhi = ent.jelo = ent.jehi = None
            ent.vis_rows = ent.em_rows = 0
            ent.ords = {}
            ent.keycols = {}
        epoch = state.mark_log_epoch
        stamp = (state.rows_inserted, state.rows_marked, state.vis_epoch)
        if ent.jvlo is None:
            lo, hi = split_words(state.vis.data[:n])
            ent.jvlo = self._upload(lo, ent.ecap)
            ent.jvhi = self._upload(hi, ent.ecap)
            ent.vis_rows = n
            ent.mark_sync = (epoch, state.mark_log.n)
            ent.vis_stamp = stamp
        elif ent.vis_stamp != stamp:
            se, sp = ent.mark_sync
            if se != epoch or ent.vis_stamp[2] != stamp[2]:
                # mark-log compaction or a detach bit-clear: regather once
                lo, hi = split_words(state.vis.data[:n])
                ent.jvlo = self._upload(lo, ent.ecap)
                ent.jvhi = self._upload(hi, ent.ecap)
                ent.vis_rows = n
                if ent.jelo is not None:
                    lo, hi = split_words(state.emask.data[:n])
                    ent.jelo = self._upload(lo, ent.ecap)
                    ent.jehi = self._upload(hi, ent.ecap)
                    ent.em_rows = n
                self.mirror_full_regathers += 1
            else:
                ids = state.mark_log.data[sp:]
                if len(ids):
                    ids = np.unique(ids)
                    vm = ids[ids < ent.vis_rows]
                    if len(vm):
                        lo, hi = split_words(state.vis.data[vm])
                        ent.jvlo = self._patch(ent.jvlo, vm, lo)
                        ent.jvhi = self._patch(ent.jvhi, vm, hi)
                        self.mirror_patched_rows += len(vm)
                    if ent.jelo is not None:
                        em = ids[ids < ent.em_rows]
                        if len(em):
                            lo, hi = split_words(state.emask.data[em])
                            ent.jelo = self._patch(ent.jelo, em, lo)
                            ent.jehi = self._patch(ent.jehi, em, hi)
                if ent.vis_rows < n:
                    idx = np.arange(ent.vis_rows, n, dtype=np.int64)
                    lo, hi = split_words(state.vis.data[ent.vis_rows : n])
                    ent.jvlo = self._patch(ent.jvlo, idx, lo)
                    ent.jvhi = self._patch(ent.jvhi, idx, hi)
                    ent.vis_rows = n
                if ent.jelo is not None and ent.em_rows < n:
                    idx = np.arange(ent.em_rows, n, dtype=np.int64)
                    lo, hi = split_words(state.emask.data[ent.em_rows : n])
                    ent.jelo = self._patch(ent.jelo, idx, lo)
                    ent.jehi = self._patch(ent.jehi, idx, hi)
                    ent.em_rows = n
            ent.mark_sync = (epoch, state.mark_log.n)
            ent.vis_stamp = stamp
        if need_em and ent.jelo is None:
            lo, hi = split_words(state.emask.data[:n])
            ent.jelo = self._upload(lo, ent.ecap)
            ent.jehi = self._upload(hi, ent.ecap)
            ent.em_rows = n
        for a in ord_attrs:
            rec = ent.ords.get(a)
            if rec is None:
                h, lo = self._total_order_u32(state.cols[a].data[:n])
                ent.ords[a] = [self._upload(h, ent.ecap), self._upload(lo, ent.ecap), n]
            elif rec[2] < n:
                h, lo = self._total_order_u32(state.cols[a].data[rec[2] : n])
                idx = np.arange(rec[2], n, dtype=np.int64)
                rec[0] = self._patch(rec[0], idx, h)
                rec[1] = self._patch(rec[1], idx, lo)
                rec[2] = n
        for a in key_attrs:
            if a in ent.badkeys:
                continue
            rec = ent.keycols.get(a)
            start = rec[1] if rec is not None else 0
            if start >= n:
                continue
            vals = state.cols[a].data[start:n]
            with np.errstate(invalid="ignore"):
                # truncate exactly like encode_keys' int64 cast; NaN/inf
                # truncate to INT64_MIN, caught by the range check below
                iv = vals.astype(np.int64)
            if len(iv) and (iv.min() < 0 or iv.max() > self._KEY_LIMIT):
                ent.badkeys.add(a)
                ent.keycols.pop(a, None)
                continue
            i32 = iv.astype(np.int32)
            if rec is None:
                ent.keycols[a] = [self._upload(i32, ent.ecap), n]
            else:
                idx = np.arange(start, n, dtype=np.int64)
                rec[0] = self._patch(rec[0], idx, i32)
                rec[1] = n

    def _table_for(self, state) -> Optional[Tuple[object, object, np.ndarray]]:
        """Open-addressing probe table over the state's SoA keycodes, cached
        per state and grown incrementally: when the state gains entries,
        only the new keys are inserted (full rebuild only when the table
        must double), so aggregate build cost stays amortized O(n) instead
        of O(n^2/morsel). Unservable states (duplicate keys, out-of-range
        keycodes, over-long clusters) are marked bad once and fall back to
        the reference probe forever."""
        n = state.keycode.n
        ent = self._tables.get(state)
        if ent is None:
            ent = _ProbeTable()
            self._tables[state] = ent
        if ent.bad:
            return None
        if ent.n < n:
            self._insert_keys(ent, state.keycode.data, n)
            if ent.bad:
                return None
        return ent.jkeys, ent.jones, ent.slot_entry

    def _insert_keys(self, ent: "_ProbeTable", keys, n: int) -> None:
        """Insert keys[ent.n:n] into the table, rebuilding at a larger
        capacity when the 50% load factor would be exceeded. Insertion is
        one batched winner-election pass (or the Pallas insert kernel on
        full rebuilds when ``use_insert_kernel`` is set) — never a
        per-key Python loop. Rebuilds reassign table slots but leave the
        entry-indexed mirrors untouched (they are keyed by entry id, not
        slot — the §13 incremental-maintenance invariant)."""
        from ..kernels.hash_probe import EMPTY

        new = keys[ent.n : n]
        if len(new) and (new.min() < 0 or new.max() > self._KEY_LIMIT):
            ent.bad = True
            return
        if ent.tkeys is None or 2 * n > len(ent.tkeys):
            cap = 1
            while cap < 2 * n:
                cap *= 2
            if self.use_insert_kernel:
                if not self._kernel_rebuild(ent, keys[:n], cap):
                    ent.bad = True
                    return
            else:
                ent.tkeys = np.full(cap, EMPTY, dtype=np.int32)
                ent.slot_entry = np.full(cap, -1, dtype=np.int64)
                if not self._batch_insert(ent, keys[:n], 0):
                    ent.bad = True
                    return
        elif not self._batch_insert(ent, keys[ent.n : n], ent.n):
            ent.bad = True
            return
        import jax.numpy as jnp

        ent.n = n
        ent.jkeys = jnp.asarray(ent.tkeys)
        ent.jentry = jnp.asarray(ent.slot_entry.astype(np.int32))
        if ent.jones is None or ent.jones.shape[0] != len(ent.tkeys):
            ent.jones = jnp.ones(len(ent.tkeys), dtype=jnp.uint32)

    @staticmethod
    def _batch_insert(ent: "_ProbeTable", seg, base: int) -> bool:
        """Vectorized linear-probe insertion of ``seg`` (entry indices
        ``base + i``): each round, every unplaced key inspects its current
        slot; per empty slot the lowest-ranked contender wins, everyone
        else advances. Returns False on duplicate keys (multi-match state)
        or a probe chain exceeding the kernel's bounded scan."""
        from ..kernels.hash_probe import EMPTY, MAX_PROBE, MULT

        if len(seg) == 0:
            return True
        tkeys, slot_entry = ent.tkeys, ent.slot_entry
        mask = len(tkeys) - 1
        seg32 = np.asarray(seg, dtype=np.int32)
        pos = ((seg.astype(np.uint32) * np.uint32(MULT)).astype(np.int32)) & mask
        hops = np.zeros(len(seg), dtype=np.int64)
        pending = np.arange(len(seg), dtype=np.int64)
        while len(pending):
            p = pos[pending]
            cur = tkeys[p]
            if (cur == seg32[pending]).any():
                return False  # duplicate key: multi-match state
            free = cur == EMPTY
            won = np.zeros(len(pending), dtype=bool)
            if free.any():
                cand = np.flatnonzero(free)
                slots = p[cand]
                so = np.argsort(slots, kind="stable")
                firsts = np.ones(len(so), dtype=bool)
                firsts[1:] = slots[so][1:] != slots[so][:-1]
                winners = cand[so[firsts]]
                wp = p[winners]
                tkeys[wp] = seg32[pending[winners]]
                slot_entry[wp] = base + pending[winners]
                won[winners] = True
                # a same-batch duplicate that contended for the same slot
                # never revisits it — re-read after the winners' writes so
                # in-batch duplicate keys are caught, not silently placed
                lost = free & ~won
                if lost.any() and (tkeys[p[lost]] == seg32[pending[lost]]).any():
                    return False  # duplicate key within the batch
            rest = ~won
            if not rest.any():
                break
            pr = pending[rest]
            pos[pr] = (p[rest] + 1) & mask
            hops[pr] += 1
            if hops[pr].max() >= MAX_PROBE:
                return False  # cluster exceeds the kernel's bounded probe
            pending = pr
        return True

    def _kernel_rebuild(self, ent: "_ProbeTable", keys, cap: int) -> bool:
        """Full-table rebuild through the Pallas batch-insert kernel."""
        import jax.numpy as jnp

        tkeys, tentry, ok = self._hash_build_insert(
            jnp.asarray(keys, dtype=jnp.int32), capacity=cap, interpret=self.interpret
        )
        if int(np.asarray(ok)[0]) == 0:
            return False
        ent.tkeys = np.asarray(tkeys)
        ent.slot_entry = np.asarray(tentry, dtype=np.int64)
        return True

    # -- segmented aggregation ------------------------------------------------
    def segment_sum(self, gids, values, n_groups):
        if n_groups == 0 or len(gids) == 0:
            return np.zeros(n_groups, dtype=np.float64)
        if not self.use_agg_kernel or n_groups > self.max_kernel_groups:
            return self._ref.segment_sum(gids, values, n_groups)
        import jax.numpy as jnp

        vals = (
            np.ones((len(gids), 1))
            if values is None
            else np.asarray(values, dtype=np.float64).reshape(-1, 1)
        )
        out = self._seg_aggregate(
            jnp.asarray(gids, dtype=jnp.int32),
            jnp.asarray(vals, dtype=jnp.float32),
            n_groups,
            interpret=self.interpret,
        )
        return np.asarray(out, dtype=np.float64)[:, 0]


def resolve_backend(spec) -> ExecutionBackend:
    """Accept a backend name or instance (EngineConfig.backend)."""
    if isinstance(spec, str):
        if spec == "reference":
            return ReferenceBackend()
        if spec == "pallas":
            return PallasBackend()
        raise ValueError(f"unknown backend {spec!r}")
    if not isinstance(spec, ExecutionBackend):
        raise TypeError(f"backend must implement ExecutionBackend, got {spec!r}")
    return spec
