"""ExecutionBackend: pluggable data-plane kernels behind one Session.

The engine's two hot vectorized operations — hash-probe against a shared
build state (§4.3) and segmented aggregation into shared accumulators
(§4.5) — are routed through a per-session backend:

* ``ReferenceBackend`` — the NumPy row engine (sort-based probe in
  ``core.state``, ``np.bincount`` reductions). Always available; the
  correctness oracle path (``relational/refexec.py`` semantics).
* ``PallasBackend`` — the jax_pallas TPU kernels (``kernels/hash_probe.py``,
  ``kernels/seg_aggregate.py``), run in interpret mode off-TPU. States that
  the kernels cannot serve (multi-match keys, out-of-range keycodes,
  over-long probe clusters) fall back to the reference path per-call,
  mirroring the routing note in the kernel docstrings.

Backends are deliberately stateless between sessions; the Pallas backend
keeps only a per-state probe-table cache invalidated by entry count.
"""

from __future__ import annotations

import weakref
from typing import Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from ..core.state import SharedHashBuildState, _bincount_segment_sum


@runtime_checkable
class ExecutionBackend(Protocol):
    """Data-plane operations a Session's engine dispatches per morsel."""

    name: str

    def probe(
        self, state: SharedHashBuildState, keycodes: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """All (probe_row_idx, entry_idx) match pairs, pre-visibility."""
        ...

    def segment_sum(
        self, gids: np.ndarray, values: Optional[np.ndarray], n_groups: int
    ) -> np.ndarray:
        """Per-group sum of ``values`` (counts when values is None)."""
        ...


class ReferenceBackend:
    """NumPy data plane — delegates to the state's own sort-based probe and
    the core bincount reduction (the same code that runs with no backend)."""

    name = "reference"

    def probe(self, state, keycodes):
        return state.probe(keycodes)

    def segment_sum(self, gids, values, n_groups):
        return _bincount_segment_sum(gids, values, n_groups)


class _ProbeTable:
    """Mutable open-addressing table mirror of one state's keycodes."""

    __slots__ = ("n", "tkeys", "slot_entry", "jkeys", "jvis", "bad")

    def __init__(self):
        self.n = 0  # state entries inserted so far
        self.tkeys: Optional[np.ndarray] = None  # int32 slots (EMPTY sentinel)
        self.slot_entry: Optional[np.ndarray] = None  # slot -> entry index
        self.jkeys = None  # device copy of tkeys, refreshed on growth
        self.jvis = None  # constant all-visible lens words, sized to capacity
        self.bad = False  # sticky: kernel cannot serve this state


class PallasBackend:
    """jax_pallas data plane (interpret mode off-TPU).

    Unique-key states probe through the fused-lens Pallas kernel with the
    lens mask disabled — per-member visibility is applied by the runtime
    afterwards, exactly as on the reference path. Everything else falls
    back to the reference probe. Segmented sums route through the one-hot
    MXU kernel below ``max_kernel_groups`` groups when ``use_agg_kernel`` is
    set; it accumulates in float32, so it is opt-in — the default keeps
    aggregate accumulation in float64 to preserve exact oracle parity.
    """

    name = "pallas"

    # Keycodes must fit int32 and stay clear of the kernel's EMPTY sentinel.
    _KEY_LIMIT = 2**31 - 2

    def __init__(
        self,
        interpret: bool = True,
        max_kernel_groups: int = 4096,
        use_agg_kernel: bool = False,
    ):
        import jax  # noqa: F401 — fail fast if jax is unavailable

        from ..kernels.hash_probe import hash_probe_lens
        from ..kernels.seg_aggregate import seg_aggregate

        self._hash_probe_lens = hash_probe_lens
        self._seg_aggregate = seg_aggregate
        self.interpret = interpret
        self.max_kernel_groups = max_kernel_groups
        self.use_agg_kernel = use_agg_kernel
        self._ref = ReferenceBackend()
        # Probe tables keyed weakly by the state OBJECT (state_ids are
        # engine-local, so an id key would collide when one backend instance
        # is reused across sessions); released states evict automatically.
        self._tables: "weakref.WeakKeyDictionary[SharedHashBuildState, _ProbeTable]" = (
            weakref.WeakKeyDictionary()
        )
        self._qmask = None  # constant all-ones lens mask, built lazily
        self.kernel_probes = 0
        self.fallback_probes = 0

    # -- probe ---------------------------------------------------------------
    def probe(self, state, keycodes):
        if state.keycode.n == 0 or len(keycodes) == 0:
            return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
        table = self._table_for(state)
        if (
            table is None
            or keycodes.min() < 0
            or keycodes.max() > self._KEY_LIMIT
        ):
            self.fallback_probes += 1
            return self._ref.probe(state, keycodes)
        import jax.numpy as jnp

        tkeys, tvis, slot_entry = table
        if self._qmask is None:  # lens off: pure key match
            self._qmask = jnp.asarray([0xFFFFFFFF], dtype=jnp.uint32)
        found_slots = np.asarray(
            self._hash_probe_lens(
                jnp.asarray(keycodes, dtype=jnp.int32),
                tkeys,
                tvis,
                self._qmask,
                interpret=self.interpret,
            )
        )
        self.kernel_probes += 1
        probe_idx = np.flatnonzero(found_slots >= 0).astype(np.int64)
        entry_idx = slot_entry[found_slots[probe_idx]]
        return probe_idx, entry_idx

    def _table_for(self, state) -> Optional[Tuple[object, object, np.ndarray]]:
        """Open-addressing probe table over the state's SoA keycodes, cached
        per state and grown incrementally: when the state gains entries,
        only the new keys are inserted (full rebuild only when the table
        must double), so aggregate build cost stays amortized O(n) instead
        of O(n^2/morsel). Unservable states (duplicate keys, out-of-range
        keycodes, over-long clusters) are marked bad once and fall back to
        the reference probe forever."""
        n = state.keycode.n
        ent = self._tables.get(state)
        if ent is None:
            ent = _ProbeTable()
            self._tables[state] = ent
        if ent.bad:
            return None
        if ent.n < n:
            self._insert_keys(ent, state.keycode.data, n)
            if ent.bad:
                return None
        return ent.jkeys, ent.jvis, ent.slot_entry

    def _insert_keys(self, ent: "_ProbeTable", keys, n: int) -> None:
        """Insert keys[ent.n:n] into the table, rebuilding at a larger
        capacity when the 50% load factor would be exceeded."""
        from ..kernels.hash_probe import EMPTY, MAX_PROBE, MULT

        new = keys[ent.n : n]
        if len(new) and (new.min() < 0 or new.max() > self._KEY_LIMIT):
            ent.bad = True
            return
        if ent.tkeys is None or 2 * n > len(ent.tkeys):
            cap = 1
            while cap < 2 * n:
                cap *= 2
            ent.tkeys = np.full(cap, EMPTY, dtype=np.int32)
            ent.slot_entry = np.full(cap, -1, dtype=np.int64)
            start = 0  # re-insert everything at the new capacity
        else:
            start = ent.n
        tkeys, slot_entry = ent.tkeys, ent.slot_entry
        mask = len(tkeys) - 1
        seg = keys[start:n]
        home = ((seg.astype(np.uint32) * np.uint32(MULT)).astype(np.int32)) & mask
        for k, i in zip(seg.tolist(), range(start, n)):
            p = int(home[i - start])
            hops = 0
            key32 = np.int32(k)
            while tkeys[p] != EMPTY:
                if tkeys[p] == key32:
                    ent.bad = True  # duplicate key: multi-match state
                    return
                p = (p + 1) & mask
                hops += 1
                if hops >= MAX_PROBE:
                    ent.bad = True  # cluster exceeds the kernel's bounded probe
                    return
            tkeys[p] = key32
            slot_entry[p] = i
        import jax.numpy as jnp

        ent.n = n
        ent.jkeys = jnp.asarray(tkeys)
        if ent.jvis is None or ent.jvis.shape[0] != len(tkeys):
            ent.jvis = jnp.ones(len(tkeys), dtype=jnp.uint32)

    # -- segmented aggregation ------------------------------------------------
    def segment_sum(self, gids, values, n_groups):
        if n_groups == 0 or len(gids) == 0:
            return np.zeros(n_groups, dtype=np.float64)
        if not self.use_agg_kernel or n_groups > self.max_kernel_groups:
            return self._ref.segment_sum(gids, values, n_groups)
        import jax.numpy as jnp

        vals = (
            np.ones((len(gids), 1))
            if values is None
            else np.asarray(values, dtype=np.float64).reshape(-1, 1)
        )
        out = self._seg_aggregate(
            jnp.asarray(gids, dtype=jnp.int32),
            jnp.asarray(vals, dtype=jnp.float32),
            n_groups,
            interpret=self.interpret,
        )
        return np.asarray(out, dtype=np.float64)[:, 0]


def resolve_backend(spec) -> ExecutionBackend:
    """Accept a backend name or instance (EngineConfig.backend)."""
    if isinstance(spec, str):
        if spec == "reference":
            return ReferenceBackend()
        if spec == "pallas":
            return PallasBackend()
        raise ValueError(f"unknown backend {spec!r}")
    if not isinstance(spec, ExecutionBackend):
        raise TypeError(f"backend must implement ExecutionBackend, got {spec!r}")
    return spec
